"""Continuous-batching rung server: seeded Poisson replay benchmark.

Replays one :func:`repro.data.request_stream` (seeded Poisson arrivals
over a mixed-grid case set) through :class:`repro.launch.RungServer`
twice on an injected :class:`SimClock`:

* **pass 1 (cold)** — counts compiles by diffing the key sets of the two
  serving caches (``_BATCHED_WINDOW_CACHE`` for the factorization sweep,
  ``_BATCHED_SOLVE_CACHE`` for the panel solves).  The gate: each stays
  at **#canonical rungs hit**, not #distinct source grids — that is the
  whole point of canonical-grid bucketing under serving traffic.
* **pass 2 (warm)** — times the replay for throughput and per-request
  wall latency p50/p99 (host-dependent, recorded but never thresholded,
  like every wall-clock figure in this suite).

Determinism is asserted *across the two passes*: identical batch
composition + flush order (``server.history``) and bit-identical result
bytes — the replay contract ``tests/test_serving.py`` enforces, here
re-checked on the benchmark stream and recorded as
``replay_determinism`` (gated at 1.0).  A per-request sequential oracle
(``factorize_window`` + ``solve_many``) bounds the numerical parity of
the batched path.

Emits a ``BENCH_serving.json`` trajectory point at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import GridBucketPolicy, factorize_window, solve_many
from repro.launch.rung_server import (RungServer, SimClock, _build_arrivals,
                                      replay)
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# mixed-grid case set: 4 distinct source grids landing on 3 canonical
# rungs at t=8 (the (96,12,8)/(136,10,8) pair shares ndt16.bt2.nat1.t8)
_CASES = [(64, 6, 4), (96, 12, 8), (120, 16, 4), (136, 10, 8)]
_SEED = 7


def _caches():
    import importlib
    cho = importlib.import_module("repro.core.cholesky")
    sol = importlib.import_module("repro.core.solve")
    return cho._BATCHED_WINDOW_CACHE, sol._BATCHED_SOLVE_CACHE


def _replay_once(arrivals, max_batch, max_delay):
    clock = SimClock()
    server = RungServer(max_batch=max_batch, max_delay=max_delay,
                        clock=clock)
    t0 = time.perf_counter()
    futures = replay(server, clock, arrivals)
    wall = time.perf_counter() - t0
    results = [f.result(timeout=0) for f in futures]
    return server, results, wall


def run(quick: bool = True):
    from repro.data import request_stream

    num = 24 if quick else 64
    stream = request_stream(_SEED, _CASES, num, rate=2000.0, k=4)
    arrivals = _build_arrivals(stream)

    policy = GridBucketPolicy()
    grids = {m.grid for _, m, _, _ in arrivals}
    rungs = {policy.canonicalize(g) for g in grids}

    fac_cache, sol_cache = _caches()
    fac0, sol0 = set(fac_cache.keys()), set(sol_cache.keys())
    server1, res1, cold_s = _replay_once(arrivals, max_batch=4,
                                         max_delay=2e-3)
    fac_compiles = len(set(fac_cache.keys()) - fac0)
    sol_compiles = len(set(sol_cache.keys()) - sol0)

    server2, res2, warm_s = _replay_once(arrivals, max_batch=4,
                                         max_delay=2e-3)

    # determinism: same seed ⇒ identical batch composition/flush order
    # and bit-identical numerical results across the two passes
    deterministic = (server1.history == server2.history
                     and all(a.x.tobytes() == b.x.tobytes()
                             for a, b in zip(res1, res2)))

    completed = sum(1 for r in res2 if r.status in (0, 1))
    completed_ratio = completed / len(arrivals)

    # sequential per-request oracle parity on a stride of the stream
    parity = 0.0
    for i in range(0, len(arrivals), max(1, len(arrivals) // 6)):
        _, m, b, _ = arrivals[i]
        f = factorize_window(m, options=SolverOptions(regularize=True))
        x = np.asarray(solve_many(f, b))
        parity = max(parity, float(np.abs(res2[i].x - x).max()))

    lat_ms = np.array([r.wall_latency_s for r in res2]) * 1e3
    p50, p99 = (float(np.percentile(lat_ms, q)) for q in (50, 99))
    throughput = len(arrivals) / warm_s
    reasons = {}
    for r in res2:
        reasons[r.flush_reason] = reasons.get(r.flush_reason, 0) + 1

    rows = [
        ("serving_throughput_rps", throughput,
         f"requests={len(arrivals)};batches={len(server2.history)}"),
        ("serving_latency_p50_ms", p50, "warm_pass;wall_clock"),
        ("serving_latency_p99_ms", p99, "warm_pass;wall_clock"),
        ("serving_factor_compiles", float(fac_compiles),
         f"rungs={len(rungs)};grids={len(grids)}"),
        ("serving_solve_compiles", float(sol_compiles),
         f"rungs={len(rungs)};grids={len(grids)}"),
        ("serving_oracle_parity_err", parity, "batched_vs_sequential"),
    ]

    record = {
        "bench": "serving",
        "quick": quick,
        "seed": _SEED,
        "requests": len(arrivals),
        "cases": [{"n": n, "bandwidth": bw, "arrow": ar}
                  for n, bw, ar in _CASES],
        "distinct_grids": len(grids),
        "canonical_rungs_hit": len(rungs),
        "batches": len(server2.history),
        "flush_reasons": reasons,
        "factor_compiles": fac_compiles,
        "solve_compiles": sol_compiles,
        "completed_ratio": completed_ratio,
        "replay_determinism": 1.0 if deterministic else 0.0,
        "oracle_parity_err": parity,
        # the gates: every request's future resolves OK/RECOVERED, replay
        # is bit-exact across passes, compiles stay at #rungs (not
        # #grids), and the batched path matches the sequential oracle
        "thresholds": {"completed_ratio_min": 1.0,
                       "replay_determinism_min": 1.0},
        "pass": bool(completed_ratio == 1.0
                     and deterministic
                     and fac_compiles <= len(rungs)
                     and sol_compiles <= len(rungs)
                     and len(grids) > len(rungs)
                     and parity < 1e-4),
    }
    # wall-clock of the replay passes: informative only (CPU/interpret
    # hosts time Python dispatch, not the TPU sweeps), never gated
    record["interpret_diagnostics"] = {
        "cold_pass_s": cold_s,
        "warm_pass_s": warm_s,
        "throughput_rps": throughput,
        "latency_p50_ms": p50,
        "latency_p99_ms": p99,
    }
    with open(os.path.join(_ROOT, "BENCH_serving.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
