"""Paper Fig. 12: factorization with vs without tree reduction, matrices
with few vs many accumulations (ids 2 and 14).

The paper's contrast: id 2 (84 accumulations) saturates quickly; id 14
(4166 accumulations) keeps scaling.  We measure wall time (single-core XLA:
the tree mainly exposes vectorization here) and the accumulation counts +
critical-path compression that produce the paper's multi-core effect.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        symbolic_factorize, tile_pattern_from_coo)
from repro.core.tree_reduction import should_use_tree
from repro.data import table2_matrix


def _time(fn, reps=2):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True, scale: float = 0.05, tile: int = 32):
    rows = []
    for mid in (2, 14):
        A, struct = table2_matrix(mid, scale=scale)
        g = TileGrid(struct, t=tile)
        bm = BandedCTSF.from_sparse(A, g)
        symb = symbolic_factorize(tile_pattern_from_coo(A, g))
        n_acc = int(symb.accumulation_counts().max())
        times = {}
        for chunks in (1, 8, 32):
            fn = jax.jit(lambda m=bm, c=chunks:
                         factorize_window(m, tree_chunks=c).ctsf.Dr)
            times[chunks] = _time(lambda: jax.block_until_ready(fn()))
        use = should_use_tree(n_acc, 32)
        rows.append((
            f"fig12_matrix{mid}", times[8] * 1e6,
            f"seq_us={times[1]*1e6:.0f};tree8_us={times[8]*1e6:.0f};"
            f"tree32_us={times[32]*1e6:.0f};max_accum={n_acc};"
            f"paper_rule_use_tree={use}"))
    return rows
