"""Fused factorization path: one-launch Pallas sweeps vs the scan paths.

Two comparisons on the same banded-arrowhead problem:

* ``factorize_window(options=SolverOptions(impl="pallas"))`` — the whole band + arrow
  factorization as **one** ``kernels.band_cholesky`` launch (VMEM panel
  ring, in-kernel potrf/trsm, corner Schur accumulated on the fly) — vs
  ``impl="ref"``, the ring-buffer ``lax.scan`` dispatching per-panel ops.
* ``selected_inverse(options=SolverOptions(impl="pallas"))`` — the whole Takahashi recurrence as
  one ``kernels.selinv_sweep`` launch — vs the per-column scan.

Gating is on **counted kernel launches**, not wall time: the fused sweeps
must trace to exactly one ``pallas_call`` each (counted by jaxpr
traversal), versus the 3·ndt (potrf + trsm + band_update) / 2·ndt
(solve_panel + selinv_step) per-panel launches the pre-fusion paths
dispatched.  Launch counts are backend-independent, so this gate holds on
CPU CI; wall-clock timings on non-TPU hosts run the kernels in interpret
mode and are recorded under ``interpret_diagnostics`` only (run.py
excludes that block from gating), becoming top-level gated metrics on
real TPU hardware.

Emits a ``BENCH_cholesky.json`` trajectory point at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        selected_inverse)
from repro.kernels import ops
from repro.kernels.ring import band_row_to_col
# single library implementation of the launch counter + static cost model
# (ISSUE 7: the bench imports it, it no longer defines its own copy)
from repro.runtime.telemetry import count_pallas_launches, kernel_report
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, reps=2):
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    from repro.data import make_arrowhead

    n, bw, ar, t = (1024, 32, 16, 16) if quick else (4096, 64, 32, 32)
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=0)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)
    ndt = grid.n_diag_tiles
    backend = jax.default_backend()
    interpret = backend != "tpu"

    # --- launch counts + static costs (backend-independent, the CI gate) ---
    Ac = band_row_to_col(bm.Dr)
    fact_report = kernel_report(
        lambda a, r: ops.band_cholesky_sweep(a, r, nchunks=8, impl="pallas"),
        Ac, bm.R, grid=grid, sweep="cholesky")
    fused_fact_launches = fact_report.pallas_launches
    f0 = factorize_window(bm, options=SolverOptions(impl="ref"))
    ctsf = f0.ctsf
    nat = grid.n_arrow_tiles
    sc_shape = jax.ShapeDtypeStruct((nat, nat, t, t), ctsf.C.dtype)
    selinv_report = kernel_report(
        lambda l, r, s: ops.selinv_sweep(l, r, s, impl="pallas"),
        band_row_to_col(ctsf.Dr), ctsf.R, sc_shape, grid=grid,
        sweep="selinv")
    fused_selinv_launches = selinv_report.pallas_launches
    # the pre-fusion per-panel dispatch counts (one potrf + trsm +
    # band_update launch per band panel; one solve_panel + selinv_step per
    # selinv column)
    scan_fact_launches = 3 * ndt
    scan_selinv_launches = 2 * ndt
    fact_reduction = scan_fact_launches / max(fused_fact_launches, 1)
    selinv_reduction = scan_selinv_launches / max(fused_selinv_launches, 1)

    rows = [("cholesky_fused_launches", float(fused_fact_launches),
             f"scan_equiv={scan_fact_launches};reduction={fact_reduction:.0f}x"),
            ("selinv_fused_launches", float(fused_selinv_launches),
             f"scan_equiv={scan_selinv_launches};reduction={selinv_reduction:.0f}x")]

    # --- partitioned sweep: one 2D launch over all ND partitions ----------
    # a block-separable problem (the post-adaptive-ND shape): the whole
    # multi-partition factorization must still be ONE counted launch, its
    # sequential grid axis must shrink from ndt to the largest partition
    # (+ the separator handled densely after the tree combine), and the
    # partition decomposition must be bit-identical to the fused oracle.
    from repro.core import detect_partition_plan
    from repro.data import block_separable_arrowhead
    n_parts = 4
    Ab, structb, bounds = block_separable_arrowhead(
        n, bw, ar, t, n_parts=n_parts, rho=0.6, seed=0)
    gridb = TileGrid(structb, t=t)
    mb = BandedCTSF.from_sparse(Ab, gridb)
    plan = detect_partition_plan(Ab, structb, t)
    assert plan.boundaries == bounds and plan.n_partitions == n_parts
    Acb = band_row_to_col(mb.Dr)
    part_report = kernel_report(
        lambda a, r: ops.band_cholesky_partitioned_sweep(
            a, r, plan.boundaries, impl="pallas"),
        Acb, mb.R)
    part_launches = part_report.pallas_launches
    seq_depth = plan.max_tiles                  # length of the 2D grid's
    seq_bound = plan.max_tiles + plan.sep_tiles  # sequential axis
    depth_reduction = gridb.n_diag_tiles / max(seq_depth, 1)
    # bit-identity vs the fused oracle, within one backend (CPU CI = ref)
    p_f, r_f, _, _ = ops.band_cholesky_sweep(Acb, mb.R, nchunks=1,
                                             impl="ref")
    p_p, r_p, _, _ = ops.band_cholesky_partitioned_sweep(
        Acb, mb.R, plan.boundaries, impl="ref")
    import numpy as _np
    part_bit_identical = (
        _np.asarray(p_f).tobytes() == _np.asarray(p_p).tobytes()
        and _np.asarray(r_f).tobytes() == _np.asarray(r_p).tobytes())
    # a trivial (single-partition) plan must reproduce the plan-less fused
    # factorization bit for bit, corner included
    from repro.core.ordering import PartitionPlan
    triv = PartitionPlan.trivial(gridb.n_diag_tiles)
    f_triv = factorize_window(
        mb, options=SolverOptions(impl="ref", partition_plan=triv))
    f_none = factorize_window(mb, options=SolverOptions(impl="ref"))
    trivial_bit_identical = all(
        _np.asarray(a).tobytes() == _np.asarray(b).tobytes()
        for a, b in zip(f_triv.ctsf.arrays(), f_none.ctsf.arrays()))
    rows.append(("partitioned_launches", float(part_launches),
                 f"partitions={n_parts};seq_depth={seq_depth}"
                 f"(bound={seq_bound});depth_reduction="
                 f"{depth_reduction:.1f}x"))
    rows.append(("partitioned_bit_identical", float(part_bit_identical),
                 f"trivial_plan_bit_identical={trivial_bit_identical}"))

    # --- timings: fused vs scan (interpret-mode diagnostics off-TPU) -------
    def fact_fused():
        jax.block_until_ready(factorize_window(bm, options=SolverOptions(impl="pallas")).ctsf.Dr)

    def fact_scan():
        jax.block_until_ready(factorize_window(bm, options=SolverOptions(impl="ref")).ctsf.Dr)

    t_ff = _time(fact_fused)
    t_fs = _time(fact_scan)

    def si_fused():
        jax.block_until_ready(selected_inverse(f0, options=SolverOptions(impl="pallas")).Dr)

    def si_scan():
        jax.block_until_ready(selected_inverse(f0, options=SolverOptions(impl="ref")).Dr)

    t_sf = _time(si_fused)
    t_ss = _time(si_scan)
    tag = "[interpret-diagnostic]" if interpret else ""
    rows.append((f"factorize_fused{tag}", t_ff * 1e6,
                 f"scan_us={t_fs*1e6:.0f};backend={backend}"))
    rows.append((f"selinv_fused{tag}", t_sf * 1e6,
                 f"scan_us={t_ss*1e6:.0f};backend={backend}"))

    record = {
        "bench": "cholesky",
        "quick": quick,
        "problem": {"n": n, "bandwidth": bw, "arrow": ar, "t": t,
                    "ndt": ndt, "band_tiles": grid.band_tiles,
                    "arrow_tiles": nat},
        "fused_factorize_launches": fused_fact_launches,
        "scan_factorize_launch_equiv": scan_fact_launches,
        "factorize_launch_reduction": fact_reduction,
        "fused_selinv_launches": fused_selinv_launches,
        "scan_selinv_launch_equiv": scan_selinv_launches,
        "selinv_launch_reduction": selinv_reduction,
        # static per-sweep cost estimates from telemetry.kernel_report
        # (flops / bytes-moved / arithmetic intensity under the shared
        # roofline hardware model) — informational, never gated
        "kernel_report": {
            "cholesky": {"flops": fact_report.flops,
                         "bytes_moved": fact_report.bytes_moved,
                         "intensity": fact_report.intensity,
                         "bound": fact_report.bound},
            "selinv": {"flops": selinv_report.flops,
                       "bytes_moved": selinv_report.bytes_moved,
                       "intensity": selinv_report.intensity,
                       "bound": selinv_report.bound},
        },
        # partitioned-sweep gates (ISSUE 10): the multi-partition
        # factorization is one counted launch, its sequential depth is
        # bounded by the largest partition + the separator, and both the
        # partition decomposition and the trivial plan are bit-identical
        # to the fused path
        "partitioned_problem": {"n_parts": n_parts,
                                "boundaries": list(plan.boundaries),
                                "sep_tiles": plan.sep_tiles,
                                "ndt": gridb.n_diag_tiles,
                                "seq_depth": seq_depth,
                                "seq_depth_bound": seq_bound,
                                "depth_reduction": depth_reduction},
        "partitioned_launches": part_launches,
        "partitioned_single_launch": float(part_launches == 1),
        "partitioned_depth_within_bound": float(seq_depth <= seq_bound
                                                and seq_depth
                                                < gridb.n_diag_tiles),
        "partitioned_bit_identical": float(part_bit_identical),
        "trivial_plan_bit_identical": float(trivial_bit_identical),
        "backend": backend,
        # interpret-mode timings never gate; launch counts do.  On TPU the
        # speedups graduate to top-level gated metrics.
        "thresholds": {"factorize_launch_reduction_min": 8.0,
                       "selinv_launch_reduction_min": 8.0,
                       "partitioned_single_launch_min": 1.0,
                       "partitioned_depth_within_bound_min": 1.0,
                       "partitioned_bit_identical_min": 1.0,
                       "trivial_plan_bit_identical_min": 1.0},
    }
    timing = {
        "factorize_fused_us": t_ff * 1e6,
        "factorize_scan_us": t_fs * 1e6,
        "factorize_fused_speedup": t_fs / t_ff,
        "selinv_fused_us": t_sf * 1e6,
        "selinv_scan_us": t_ss * 1e6,
        "selinv_fused_speedup": t_ss / t_sf,
    }
    passing = (fused_fact_launches == 1 and fused_selinv_launches == 1
               and fact_reduction >= 8.0 and selinv_reduction >= 8.0
               and part_launches == 1
               and seq_depth <= seq_bound and seq_depth < gridb.n_diag_tiles
               and part_bit_identical and trivial_bit_identical)
    if interpret:
        record["interpret_diagnostics"] = {**timing, "interpret_mode": True}
    else:
        record.update(timing)
        record["thresholds"].update({"factorize_fused_speedup_min": 1.2,
                                     "selinv_fused_speedup_min": 1.2})
        passing = passing and timing["factorize_fused_speedup"] >= 1.2 \
            and timing["selinv_fused_speedup"] >= 1.2
    record["pass"] = bool(passing)
    with open(os.path.join(_ROOT, "BENCH_cholesky.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
