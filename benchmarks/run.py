"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale
matrices (long on CPU); the default is structure-preserving scaled versions.

  Table I     -> bench_accumulation   (sequential GEMM/SYRK chains vs tree)
  Fig. 10     -> bench_libraries      (sTiles vs dense/sparse baselines)
  Fig. 11     -> bench_scalability    (DAG width/depth + speedup bounds)
  Fig. 12     -> bench_tree_reduction (tree on/off, matrices 2 & 14)
  Fig. 13     -> bench_libraries (dense crossover column)
  Table III   -> bench_tile_size      (+ accelerator tile-size terms)
  App. A      -> bench_concurrent     (concurrent factorizations, precond)
  Serving     -> bench_solve          (multi-RHS sweeps, batched factorize;
                                       writes BENCH_solve.json)
  Selinv      -> bench_selinv         (Takahashi recurrence vs dense-panel
                                       marginals vs np.linalg.inv; writes
                                       BENCH_selinv.json)
  Fused fact. -> bench_cholesky       (one-launch factorization/selinv vs
                                       scan: launch counts + timings;
                                       writes BENCH_cholesky.json)
  §Roofline   -> roofline             (from dry-run artifacts)

  Bucketing   -> bench_bucketing     (canonical-grid policy: compile
                                      counts for a mixed-grid stream;
                                      writes BENCH_bucketing.json)

  Rung server -> bench_serving       (continuous-batching front-end:
                                      seeded Poisson replay, throughput +
                                      latency percentiles, compile-per-rung
                                      and bit-exact-replay gates; writes
                                      BENCH_serving.json)

  Chaos       -> bench_chaos         (rung server under seeded faults +
                                      burst overload: conservation, closed
                                      status taxonomy, breaker isolation,
                                      bit-exact chaos replay; writes
                                      BENCH_chaos.json)

``--check-only`` validates every committed ``BENCH_*.json`` against its
embedded thresholds without re-running anything — the fast CI gate
against landing a record that fails its own pass criteria.  Suites
listed in ``RECORD_SUITES`` *must* have a committed record: a deleted
(or never-committed) ``BENCH_<suite>.json`` fails the check, so a
regression cannot slip in by dropping its record.  Timings recorded
under a record's ``interpret_diagnostics`` block (Pallas interpret-mode
numbers on non-TPU hosts) are never gated, in check-only or full runs;
fused-kernel records gate on counted launches instead.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# suites that emit a BENCH_<name>.json trajectory point; --check-only
# requires each of these records to exist at the repo root (and pass its
# own thresholds), so deleting a record cannot silently pass CI
RECORD_SUITES = ("solve", "selinv", "cholesky", "bucketing", "robustness",
                 "serving", "chaos")


def _record_failures(record: dict) -> list:
    """Spell out which criteria a BENCH_*.json record misses.

    Threshold keys follow the ``<metric>_min`` convention (e.g.
    ``solve_many_speedup_min`` gates ``solve_many_speedup``).  A
    thresholded metric must exist at the record's top level — except
    metrics listed under ``interpret_diagnostics``, which are
    interpret-mode-only timings and are consistently excluded from
    gating.  ``pass: false`` fails regardless."""
    diag = record.get("interpret_diagnostics") or {}
    out = []
    for name, lo in (record.get("thresholds") or {}).items():
        metric = name[: -len("_min")] if name.endswith("_min") else name
        if metric in diag:
            continue
        val = record.get(metric)
        if val is None:
            out.append(f"{metric} missing (gated by threshold {name})")
        elif isinstance(val, (int, float)) and val < lo:
            out.append(f"{metric}={val:.3g} (min {lo:.3g})")
    if record.get("pass") is False:
        out.append("record has pass=false")
    return out


def _gated_metrics_cell(record: dict) -> str:
    """Compact ``metric=value(min lo)`` listing of a record's gated
    metrics for the check-only summary table (interpret-mode diagnostics
    excluded, like gating itself)."""
    diag = record.get("interpret_diagnostics") or {}
    cells = []
    for name, lo in sorted((record.get("thresholds") or {}).items()):
        metric = name[: -len("_min")] if name.endswith("_min") else name
        if metric in diag:
            continue
        val = record.get(metric)
        shown = f"{val:.3g}" if isinstance(val, (int, float)) else "?"
        cells.append(f"{metric}={shown}(min {lo:.3g})")
    return " ".join(cells) if cells else "-"


def check_records(root: str = _ROOT) -> int:
    """Validate all committed BENCH_*.json against their embedded
    thresholds; returns the number of failing records (printing a
    one-line-per-suite summary table, then every regressed suite).
    Every suite in ``RECORD_SUITES`` must have a committed record — a
    registered suite with no BENCH_<suite>.json fails."""
    bad = 0
    failing = []   # (suite/record name, reasons)
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    for suite in RECORD_SUITES:
        expected = os.path.join(root, f"BENCH_{suite}.json")
        if expected not in paths:
            reason = (f"suite {suite!r} is registered in benchmarks/run.py "
                      "but has no committed record")
            print(f"FAIL: BENCH_{suite}.json — {reason}")
            failing.append((suite, [reason]))
            bad += 1
    if not paths:
        print("no BENCH_*.json records found", file=sys.stderr)
        return bad or 1
    rows = []
    for path in paths:
        with open(path) as f:
            record = json.load(f)
        name = os.path.basename(path)[len("BENCH_"): -len(".json")]
        reasons = _record_failures(record)
        rows.append((name, _gated_metrics_cell(record),
                     "FAIL" if reasons else "pass"))
        if reasons:
            failing.append((name, reasons))
            bad += 1
    widths = [max(len(r[i]) for r in rows + [("suite", "gated metrics",
                                              "status")]) for i in range(3)]
    fmt = "  ".join("{:<%d}" % w for w in widths)
    print(fmt.format("suite", "gated metrics", "status"))
    for row in rows:
        print(fmt.format(*row))
    if failing:
        print(f"\n{len(failing)} suite(s) failing:")
        for name, reasons in failing:
            for r in reasons:
                print(f"  {name}: {r}")
    else:
        print("\nall records pass")
    return bad


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None)
    p.add_argument("--check-only", action="store_true",
                   help="validate committed BENCH_*.json thresholds "
                        "without re-running any benchmark")
    p.add_argument("--telemetry", default=None, metavar="PATH",
                   help="enable the runtime telemetry registry for the run "
                        "and dump a Chrome trace-event JSON (spans + a "
                        "'metrics' snapshot; open in Perfetto) to PATH")
    args = p.parse_args()
    quick = not args.full

    if args.check_only:
        raise SystemExit(1 if check_records() else 0)

    from repro.runtime import telemetry
    if args.telemetry:
        telemetry.enable()

    from . import (bench_accumulation, bench_bucketing, bench_chaos,
                   bench_cholesky, bench_concurrent, bench_libraries,
                   bench_robustness, bench_scalability, bench_selinv,
                   bench_serving, bench_solve, bench_tile_size,
                   bench_tree_reduction, roofline)
    suites = {
        "accumulation": bench_accumulation,
        "libraries": bench_libraries,
        "scalability": bench_scalability,
        "tree_reduction": bench_tree_reduction,
        "tile_size": bench_tile_size,
        "concurrent": bench_concurrent,
        "solve": bench_solve,
        "selinv": bench_selinv,
        "cholesky": bench_cholesky,
        "bucketing": bench_bucketing,
        "robustness": bench_robustness,
        "serving": bench_serving,
        "chaos": bench_chaos,
        "roofline": roofline,
    }
    failures = []  # (suite, [reasons...])
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        t_start = time.time()
        try:
            with telemetry.span(f"bench.{name}", quick=quick):
                for row in mod.run(quick=quick):
                    print(f"{row[0]},{row[1]:.1f},{row[2]}")
                    sys.stdout.flush()
        except Exception as e:
            failures.append((name, [f"crashed: {type(e).__name__}: {e}"]))
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
            continue
        # suites that emit a BENCH_<name>.json trajectory point also gate on
        # its `pass` flag — a speedup-threshold regression fails the run (and
        # therefore the CI benchmark step), not just the artifact.
        record_path = os.path.join(_ROOT, f"BENCH_{name}.json")
        if os.path.exists(record_path):
            if os.path.getmtime(record_path) >= t_start:
                print(f"# wrote {record_path}", flush=True)
            with open(record_path) as f:
                record = json.load(f)
            reasons = _record_failures(record)
            if reasons:
                failures.append((name, reasons))
                print(f"{name},THRESHOLD_FAIL,{';'.join(reasons)}",
                      flush=True)
    if args.telemetry:
        telemetry.write_trace(args.telemetry)
        print(f"# wrote telemetry trace {args.telemetry}", flush=True)
    if failures:
        print("\nFAILED benchmark suites:", file=sys.stderr)
        for name, reasons in failures:
            for r in reasons:
                print(f"  {name}: {r}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
