"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale
matrices (long on CPU); the default is structure-preserving scaled versions.

  Table I     -> bench_accumulation   (sequential GEMM/SYRK chains vs tree)
  Fig. 10     -> bench_libraries      (sTiles vs dense/sparse baselines)
  Fig. 11     -> bench_scalability    (DAG width/depth + speedup bounds)
  Fig. 12     -> bench_tree_reduction (tree on/off, matrices 2 & 14)
  Fig. 13     -> bench_libraries (dense crossover column)
  Table III   -> bench_tile_size      (+ accelerator tile-size terms)
  App. A      -> bench_concurrent     (concurrent factorizations, precond)
  Serving     -> bench_solve          (multi-RHS sweeps, batched factorize;
                                       writes BENCH_solve.json)
  Selinv      -> bench_selinv         (Takahashi recurrence vs dense-panel
                                       marginals vs np.linalg.inv; writes
                                       BENCH_selinv.json)
  §Roofline   -> roofline             (from dry-run artifacts)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _regressed_thresholds(record: dict) -> list:
    """Spell out *which* thresholds a BENCH_*.json record missed.

    Threshold keys follow the ``<metric>_min`` convention (e.g.
    ``solve_many_speedup_min`` gates ``solve_many_speedup``)."""
    out = []
    for name, lo in (record.get("thresholds") or {}).items():
        metric = name[: -len("_min")] if name.endswith("_min") else name
        val = record.get(metric)
        if isinstance(val, (int, float)) and val < lo:
            out.append(f"{metric}={val:.3g} (min {lo:.3g})")
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None)
    args = p.parse_args()
    quick = not args.full

    from . import (bench_accumulation, bench_concurrent, bench_libraries,
                   bench_scalability, bench_selinv, bench_solve,
                   bench_tile_size, bench_tree_reduction, roofline)
    suites = {
        "accumulation": bench_accumulation,
        "libraries": bench_libraries,
        "scalability": bench_scalability,
        "tree_reduction": bench_tree_reduction,
        "tile_size": bench_tile_size,
        "concurrent": bench_concurrent,
        "solve": bench_solve,
        "selinv": bench_selinv,
        "roofline": roofline,
    }
    failures = []  # (suite, [reasons...])
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        t_start = time.time()
        try:
            for row in mod.run(quick=quick):
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                sys.stdout.flush()
        except Exception as e:
            failures.append((name, [f"crashed: {type(e).__name__}: {e}"]))
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
            continue
        # suites that emit a BENCH_<name>.json trajectory point also gate on
        # its `pass` flag — a speedup-threshold regression fails the run (and
        # therefore the CI benchmark step), not just the artifact.
        record_path = os.path.join(_ROOT, f"BENCH_{name}.json")
        if os.path.exists(record_path):
            if os.path.getmtime(record_path) >= t_start:
                print(f"# wrote {record_path}", flush=True)
            with open(record_path) as f:
                record = json.load(f)
            if record.get("pass") is False:
                reasons = (_regressed_thresholds(record)
                           or ["record has pass=false"])
                failures.append((name, reasons))
                print(f"{name},THRESHOLD_FAIL,{';'.join(reasons)}",
                      flush=True)
    if failures:
        print("\nFAILED benchmark suites:", file=sys.stderr)
        for name, reasons in failures:
            for r in reasons:
                print(f"  {name}: {r}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
