"""Paper Appendix B (Fig. 15): tile-size sweep on Matrix ID 12 — time and
GFLOP/s vs tile size; plus Table III's accelerator tile-size analysis
transposed to TPU (derived roofline terms per tile size).

The paper found 120–240 optimal on CPU (L3-bound) and 600 on GPU
(occupancy-bound).  On TPU the governing constraints are MXU alignment
(t % 128) and the VMEM working set of the fused band window
(2·jb·t²·4B) — reported per tile size below.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        symbolic_factorize, tile_pattern_from_coo)
from repro.data import table2_matrix

_PEAK_TPU_F32 = 197e12 / 3  # bf16 peak / 3 ~ f32 MXU throughput per chip
_VMEM_BYTES = 128 * 2 ** 20


def _time(fn, reps=2):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True, scale: float = 0.05):
    A, struct = table2_matrix(12, scale=scale)
    tiles = [16, 32, 64] if quick else [16, 24, 32, 48, 64, 96, 128]
    rows = []
    for t in tiles:
        g = TileGrid(struct, t=t)
        bm = BandedCTSF.from_sparse(A, g)
        symb = symbolic_factorize(tile_pattern_from_coo(A, g))
        flops = symb.total_flops(t)
        fn = jax.jit(lambda m=bm: factorize_window(m, tree_chunks=8).ctsf.Dr)
        dt = _time(lambda: jax.block_until_ready(fn()))
        gflops = flops / dt / 1e9
        # TPU derived terms for this tile size (Table III analogue)
        bt = g.band_tiles
        vmem_window = (2 * min(8, bt + 1) + 1) * t * t * 4
        mxu_align = min(1.0, (t / 128.0) if t < 128 else 1.0)
        rows.append((
            f"appB_tile{t}", dt * 1e6,
            f"gflops={gflops:.2f};cpu_measured=1;"
            f"tpu_vmem_window_kib={vmem_window/1024:.0f};"
            f"tpu_mxu_alignment={mxu_align:.2f};"
            f"extra_flops_vs_t16={flops/symb_flops_ref(struct, scale):.2f}"))
    return rows


def symb_flops_ref(struct, scale, t_ref: int = 16):
    A, s2 = table2_matrix(12, scale=scale)
    g = TileGrid(s2, t=t_ref)
    symb = symbolic_factorize(tile_pattern_from_coo(A, g))
    return symb.total_flops(t_ref)
