"""Paper Appendix A + Table III context: concurrent Cholesky factorizations
(the INLA gradient workload: 2n independent factorizations) — batched vmap
throughput vs one-at-a-time, plus the arrowhead-preconditioner step cost
(sTiles inside the LM optimizer).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BandedCTSF, TileGrid
from repro.core.concurrent import concurrent_factorize, concurrent_logdet, stack_ctsf
from repro.data import make_arrowhead


def _time(fn, reps=2):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True):
    n, bw, ar, t = (640, 32, 16, 16) if quick else (2560, 64, 32, 32)
    batch = 8 if quick else 16
    mats = []
    for s in range(batch):
        A, st = make_arrowhead(n, bw, ar, rho=0.6, seed=s)
        mats.append(BandedCTSF.from_sparse(A, TileGrid(st, t=t)))
    stacked = stack_ctsf(mats)

    one = jax.jit(lambda m=mats[0]: concurrent_factorize(
        stack_ctsf([m])).ctsf.Dr)
    many = jax.jit(lambda s=stacked: concurrent_factorize(s).ctsf.Dr)
    t_one = _time(lambda: jax.block_until_ready(one()))
    t_many = _time(lambda: jax.block_until_ready(many()))
    rows = [(
        f"appA_concurrent_b{batch}", t_many * 1e6,
        f"one_us={t_one*1e6:.0f};per_matrix_us={t_many/batch*1e6:.0f};"
        f"batching_efficiency={t_one*batch/t_many:.2f}x")]

    # arrowhead preconditioner step (sTiles in the optimizer)
    from repro.optim.arrowhead import build_precond
    params = {"embed": jnp.ones((512, 64)),
              "layers": {"w": jnp.ones((24, 4096)), "b": jnp.ones((24, 64))}}
    pre = build_precond(params, r=32, band=2)
    state = pre.init_state()
    grads = jax.tree.map(jnp.ones_like, params)
    upd = jax.jit(pre.update_stats)
    state = upd(state, grads)
    fac = jax.jit(pre.factorize)
    factor = fac(state)
    prec = jax.jit(pre.precondition)
    t_upd = _time(lambda: jax.block_until_ready(upd(state, grads)["Dr"]))
    t_fac = _time(lambda: jax.block_until_ready(fac(state)["Dr"]))
    t_pre = _time(lambda: jax.block_until_ready(
        jax.tree.leaves(prec(factor, grads))[0]))
    rows.append((
        "precond_arrowhead_L24_r32", t_fac * 1e6,
        f"update_us={t_upd*1e6:.0f};factorize_us={t_fac*1e6:.0f};"
        f"precondition_us={t_pre*1e6:.0f}"))
    return rows
