"""Paper Fig. 11 (scalability across cores) — structural analogue.

This container has one physical core, so wall-clock core-scaling cannot be
measured.  What *determines* that scaling is the task-DAG shape the paper
plots in Fig. 2: width (available parallelism) vs depth (critical path).
We compute both from the symbolic factorization for each matrix, with and
without tree reduction, and report the derived max speedup bound
(Brent: T_p >= max(T_1/p, depth)).  Wall-clock on real hardware scales with
exactly these numbers; see EXPERIMENTS.md §Fig11 for the mapping.
"""
from __future__ import annotations

import numpy as np

from repro.core import TileGrid, symbolic_factorize, tile_pattern_from_coo
from repro.data import table2_matrix


def run(quick: bool = True, scale: float = 0.04, tile: int = 32):
    ids = [2, 9, 14] if quick else [1, 2, 5, 9, 12, 14, 18]
    rows = []
    for mid in ids:
        A, struct = table2_matrix(mid, scale=scale)
        g = TileGrid(struct, t=tile)
        symb = symbolic_factorize(tile_pattern_from_coo(A, g))
        n_tasks = len(symb.tasks)
        depth = symb.critical_path_length()
        width = symb.max_parallelism()
        acc = symb.accumulation_counts()
        max_chain = int(acc.max())
        # tree reduction rewrites the longest accumulation chain k -> log2 k
        depth_tree = depth - max_chain + int(np.ceil(np.log2(max(max_chain, 1)))) + 1
        for cores in (1, 4, 16, 64):
            bound_seq = n_tasks / max(n_tasks / cores, depth)
            bound_tree = n_tasks / max(n_tasks / cores, depth_tree)
            rows.append((
                f"fig11_matrix{mid}_cores{cores}", 0.0,
                f"tasks={n_tasks};depth={depth};width={width};"
                f"speedup_bound={bound_seq:.1f};"
                f"speedup_bound_tree={bound_tree:.1f}"))
    return rows
