"""Numerical fault tolerance: injection-suite recovery and detection cost.

Replays a batched factorization over a suite with seeded numerical faults
(``runtime.fault_tolerance.NumericalFaultInjector`` + the pathological
generators in ``data/synthetic.py``):

* **indefinite** elements (negative diagonal shift) must be *detected*
  in-sweep and *recovered* by the escalating-jitter ladder
  (``core/robustness.py``) — the gate demands a 100% recovery rate;
* **NaN-contaminated** elements must be detected and come back flagged
  ``STATUS_FAILED`` (graceful degradation) without poisoning any healthy
  batch sibling;
* **healthy** elements must keep bit-identical factors vs the same batched
  call without ``regularize=``.

Detection cost is measured on the *clean* path: the status word is computed
in-graph by every sweep (regularized or not), so the overhead of
``regularize=True`` on an all-SPD batch is just the ladder wrapper's scale
computation + one tiny status readback.  Recorded as ``detection_efficiency
= t_plain / t_robust`` (best-of-N of the same compiled sweep) and gated at
>= 0.95 — the <= 5% clean-path overhead criterion.

Emits a ``BENCH_robustness.json`` trajectory point at the repo root,
validated by ``benchmarks/run.py --check-only`` in CI.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BandedCTSF, TileGrid, factorize_window_batched,
                        STATUS_FAILED, STATUS_OK, STATUS_RECOVERED)
from repro.runtime.fault_tolerance import NumericalFaultInjector
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, reps: int = 7) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    from repro.data import make_arrowhead, near_singular_arrowhead

    n, bw, ar, t = (384, 24, 8, 8) if quick else (1024, 32, 16, 16)
    B = 8
    mats = []
    grid = None
    for s in range(B - 1):
        A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=s)
        grid = TileGrid(struct, t=t)
        mats.append(BandedCTSF.from_sparse(A, grid))
    # one near-singular element: factorizable, pivots at the fp32 cliff
    A_ns, _ = near_singular_arrowhead(n, bw, ar, rho=0.6, seed=B,
                                      eig_min=1e-5)
    mats.append(BandedCTSF.from_sparse(A_ns, grid))
    batch = BandedCTSF(grid, jnp.stack([m.Dr for m in mats]),
                       jnp.stack([m.R for m in mats]),
                       jnp.stack([m.C for m in mats]))

    injector = NumericalFaultInjector(seed=0, shift=10.0)
    modes = {1: "indefinite", 4: "indefinite", 6: "nan"}
    corrupted = injector.corrupt(batch, modes)
    indef = [i for i, m in modes.items() if m == "indefinite"]
    nans = [i for i, m in modes.items() if m == "nan"]
    healthy = [i for i in range(B) if i not in modes and i != B - 1]

    f = factorize_window_batched(corrupted, bucket=False, options=SolverOptions(impl=None, regularize=True))
    status = np.asarray(f.info.status)
    attempts = np.asarray(f.info.attempts)

    # detection: every corrupted element must be flagged non-OK
    detected = sum(status[i] != STATUS_OK for i in modes)
    detection_rate = detected / len(modes)
    # recovery: every finite (recoverable) injection must come back usable
    recovered = sum(status[i] == STATUS_RECOVERED for i in indef)
    recovery_rate = recovered / len(indef)
    # graceful degradation: NaN elements flagged FAILED, never raising
    nan_flagged = all(status[i] == STATUS_FAILED for i in nans)
    # containment: healthy elements bit-identical to the unregularized call
    f_plain = factorize_window_batched(corrupted, bucket=False, options=SolverOptions(impl=None))
    contained = all(
        np.array_equal(np.asarray(f.ctsf.Dr[i]), np.asarray(f_plain.ctsf.Dr[i]))
        and np.array_equal(np.asarray(f.ctsf.R[i]), np.asarray(f_plain.ctsf.R[i]))
        and np.array_equal(np.asarray(f.ctsf.C[i]), np.asarray(f_plain.ctsf.C[i]))
        and np.isfinite(np.asarray(f.ctsf.Dr[i])).all()
        for i in healthy) and status[healthy].max(initial=0) == STATUS_OK

    # clean-path detection overhead: same compiled sweep, with vs without
    # the ladder wrapper (scale compute + one status readback)
    clean = BandedCTSF(grid, jnp.stack([m.Dr for m in mats]),
                       jnp.stack([m.R for m in mats]),
                       jnp.stack([m.C for m in mats]))

    def plain():
        jax.block_until_ready(factorize_window_batched(
            clean, bucket=False, options=SolverOptions(impl=None)).ctsf.Dr)

    def robust():
        jax.block_until_ready(factorize_window_batched(
            clean, bucket=False, options=SolverOptions(impl=None, regularize=True)).ctsf.Dr)

    t_plain = _time(plain)
    t_robust = _time(robust)
    detection_efficiency = t_plain / t_robust

    backend = jax.default_backend()
    rows = [
        ("robustness_detection_rate", detection_rate * 100.0,
         f"injected={len(modes)};detected={detected}"),
        ("robustness_recovery_rate", recovery_rate * 100.0,
         f"indefinite={len(indef)};recovered={recovered}"),
        ("robustness_mean_attempts", float(attempts.mean()),
         f"max={int(attempts.max())}"),
        ("robustness_detection_efficiency", detection_efficiency * 100.0,
         f"t_plain={t_plain*1e3:.2f}ms;t_robust={t_robust*1e3:.2f}ms"),
    ]

    record = {
        "bench": "robustness",
        "quick": quick,
        "grid": {"n": n, "bandwidth": bw, "arrow": ar, "tile": t},
        "batch": B,
        "injections": {str(k): v for k, v in modes.items()},
        "injected_tiles": [list(map(str, rec)) for rec in injector.injected],
        "status": status.tolist(),
        "attempts": attempts.tolist(),
        "tau": np.asarray(f.info.tau).tolist(),
        "detection_rate": detection_rate,
        "recovery_rate": recovery_rate,
        "nan_flagged_failed": bool(nan_flagged),
        "healthy_contained": bool(contained),
        "mean_attempts": float(attempts.mean()),
        "max_attempts": int(attempts.max()),
        "detection_efficiency": detection_efficiency,
        "backend": backend,
        # the gates: every injected fault detected, every recoverable fault
        # recovered, and the clean path pays <= 5% for always-on detection
        "thresholds": {"detection_rate_min": 1.0,
                       "recovery_rate_min": 1.0,
                       "detection_efficiency_min": 0.95},
        "pass": bool(detection_rate == 1.0 and recovery_rate == 1.0
                     and nan_flagged and contained
                     and detection_efficiency >= 0.95),
    }
    record["interpret_diagnostics"] = {
        "t_plain_s": t_plain,
        "t_robust_s": t_robust,
        "interpret_mode": backend != "tpu",
    }
    with open(os.path.join(_ROOT, "BENCH_robustness.json"), "w") as f_out:
        json.dump(record, f_out, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
