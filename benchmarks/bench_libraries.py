"""Paper Fig. 10: Cholesky factorization time, sTiles vs baseline libraries,
on the Table II matrix suite.

Baselines available in this environment (the paper's CHOLMOD/MUMPS/SymPACK/
PARDISO are closed/compiled libraries; we stand in the same roles with):
  * dense-LAPACK  (scipy.linalg.cho_factor)      — the "PLASMA/dense" end
  * sparse-direct (scipy.sparse.linalg.splu)     — the "general sparse" end
  * sTiles-window (ours, tree reduction on)
  * sTiles-window, no tree reduction             — ablation

Matrices are Table II scaled by --scale (default 0.04: CPU container); the
structure ratios (bandwidth/size, arrow thickness) are preserved, which is
what determines the relative behaviour the paper reports.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import scipy.linalg as sla
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import BandedCTSF, TileGrid, factorize_window
from repro.data import TABLE2, table2_matrix


def _time(fn, reps=2):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True, scale: float = 0.04, tile: int = 32):
    ids = [1, 2, 4, 5, 7, 10] if quick else list(TABLE2)
    rows = []
    for mid in ids:
        A, struct = table2_matrix(mid, scale=scale)
        n = A.shape[0]
        g = TileGrid(struct, t=tile)
        bm = BandedCTSF.from_sparse(A, g)
        Ad = bm.to_dense(lower_only=False)[:n, :n]

        t_dense = _time(lambda: sla.cho_factor(Ad, lower=True))
        t_splu = _time(lambda: spla.splu(sp.csc_matrix(A)))

        f = jax.jit(lambda m=bm: factorize_window(m, tree_chunks=8).ctsf.Dr)
        t_stiles = _time(lambda: jax.block_until_ready(f()))
        f1 = jax.jit(lambda m=bm: factorize_window(m, tree_chunks=1).ctsf.Dr)
        t_seq = _time(lambda: jax.block_until_ready(f1()))

        best_base = min(t_dense, t_splu)
        rows.append((
            f"fig10_matrix{mid}_n{n}", t_stiles * 1e6,
            f"dense_us={t_dense*1e6:.0f};splu_us={t_splu*1e6:.0f};"
            f"stiles_seq_us={t_seq*1e6:.0f};"
            f"speedup_vs_best_baseline={best_base/t_stiles:.2f}x"))
    return rows
