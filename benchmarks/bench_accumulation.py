"""Paper Table I: execution time of k sequential GEMM/SYRK accumulations,
and the tree-reduction (Alg. 3) counterpart.

The paper shows near-linear growth of the sequential chain (the left-looking
accumulator is the critical path).  We measure the same chain as a lax.scan
(sequential semantics) vs chunked_tree_sum (Alg. 3), plus the derived
critical-path depth (k vs ceil(k/c) + log2 c).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree_reduction import chunked_tree_sum


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True):
    t = 120 if quick else 256
    ks = [100, 500, 1000] if quick else [1000, 5000, 10000]
    rng = np.random.default_rng(0)
    rows = []
    for k in ks:
        a = jnp.asarray(rng.standard_normal((k, t, t)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, t, t)), jnp.float32)

        @jax.jit
        def seq_gemm(a, b):
            def body(c, xs):
                x, y = xs
                return c + x @ y.T, None
            return jax.lax.scan(body, jnp.zeros((t, t), jnp.float32), (a, b))[0]

        @jax.jit
        def seq_syrk(a):
            def body(c, x):
                return c + x @ x.T, None
            return jax.lax.scan(body, jnp.zeros((t, t), jnp.float32), a)[0]

        @jax.jit
        def tree_gemm(a, b):
            terms = jnp.einsum("kab,kcb->kac", a, b)
            return chunked_tree_sum(terms, 32)

        t_gemm = _time(seq_gemm, a, b)
        t_syrk = _time(seq_syrk, a)
        t_tree = _time(tree_gemm, a, b)
        ref = np.asarray(seq_gemm(a, b))
        got = np.asarray(tree_gemm(a, b))
        assert np.abs(ref - got).max() < 1e-2 * max(1, np.abs(ref).max())
        depth_seq, depth_tree = k, int(np.ceil(k / 32)) + 5
        rows.append((f"tableI_gemms_k{k}", t_gemm * 1e6,
                     f"seq_syrk_us={t_syrk*1e6:.0f};tree_us={t_tree*1e6:.0f};"
                     f"depth_seq={depth_seq};depth_tree={depth_tree};"
                     f"tree_speedup={t_gemm/t_tree:.2f}x"))
    return rows
