"""Chaos benchmark: the rung server under seeded faults and overload.

Replays one *bursty* :func:`repro.data.request_stream` (Markov-modulated
Poisson overload mode) through :class:`repro.launch.RungServer` with the
full resilience stack armed — admission bounds, degradation policy,
per-rung circuit breakers — and a seeded
:class:`~repro.runtime.fault_tolerance.DispatchFaultInjector` raising
transient faults, poisoning one whole canonical rung, and injecting
stragglers.  Two identical passes on injected ``SimClock``\\ s drill the
resilience contract:

* **conservation** — every submitted future resolves exactly once:
  nothing lost, duplicated, or stuck (gated at 1.0);
* **closed taxonomy** — every terminal status is one of
  OK/RECOVERED/FAILED/SHED, and every shed result names its reason
  (``explicit_shed_ratio`` gated at 1.0): load shedding is always an
  explicit result, never a dropped future;
* **breaker isolation** — the poisoned rung's breaker opens within
  ``failure_threshold`` dispatched flushes and no request *outside*
  that rung ever fails (transients must recover via the retry ladder,
  overload resolves as shed) — gated at 1.0;
* **replay determinism** — batch history, resilience events (retries,
  bisects, quarantines, breaker transitions), statuses and result bytes
  are bit-identical across the two passes (gated at 1.0): the chaos
  schedule itself is replayable, which is what makes any failure this
  suite ever surfaces debuggable offline.

Emits a ``BENCH_chaos.json`` trajectory point at the repo root.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import GridBucketPolicy
from repro.launch.rung_server import (STATUS_FAILED, STATUS_OK,
                                      STATUS_RECOVERED, STATUS_SHED)
from repro.launch.rung_server import (DegradationPolicy, RungServer,
                                      SimClock, _build_arrivals, replay)
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import DispatchFaultInjector

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CASES = [(64, 6, 4), (96, 12, 8), (120, 16, 4), (136, 10, 8)]
_SEED = 23
_BREAKER_THRESHOLD = 3


def _poison_tag(arrivals) -> str:
    """Canonical-rung tag of the first arrival — the rung the injector
    poisons permanently (its breaker must open and contain the blast)."""
    policy = GridBucketPolicy()
    return telemetry.rung_tag(policy.canonicalize(arrivals[0][1].grid))


def _run_pass(arrivals, poison):
    clock = SimClock()
    injector = DispatchFaultInjector(
        seed=_SEED, transient_rate=0.25, transient_attempts=1,
        poison_rungs=(poison,), straggler_rate=0.15, straggler_extra=3e-3)
    server = RungServer(
        clock=clock, max_batch=4, max_delay=2e-3, max_queue=4,
        on_overload="shed",
        degradation=DegradationPolicy(step_dwell=1e-3),
        max_retries=1, backoff_base=1e-3, seed=_SEED,
        breaker_threshold=_BREAKER_THRESHOLD, breaker_reset=10.0,
        injector=injector)
    t0 = time.perf_counter()
    futures = replay(server, clock, arrivals)
    wall = time.perf_counter() - t0
    return server, futures, wall


def _fingerprint(server, futures):
    """Everything that must be bit-identical across chaos passes."""
    results = [f.result(timeout=0) if f.done() else None for f in futures]
    return (list(server.history), list(server.events),
            [None if r is None else
             (r.rid, r.status, r.detail, r.flush_reason,
              None if r.x is None else r.x.tobytes())
             for r in results])


def run(quick: bool = True):
    from repro.data import request_stream

    num = 24 if quick else 48
    stream = request_stream(_SEED, _CASES, num, rate=2000.0, k=4,
                            deadline_budget=8e-3, burst_factor=6.0,
                            burst_len=2e-3, normal_len=8e-3)
    arrivals = _build_arrivals(stream)
    poison = _poison_tag(arrivals)

    server1, fut1, pass1_s = _run_pass(arrivals, poison)
    server2, fut2, pass2_s = _run_pass(arrivals, poison)

    deterministic = _fingerprint(server1, fut1) == _fingerprint(server2,
                                                                fut2)

    results = [f.result(timeout=0) if f.done() else None for f in fut2]
    resolved = sum(1 for r in results if r is not None)
    duplicates = sum(f.duplicate_resolves for f in fut2)
    conservation = 1.0 if (resolved == len(fut2) == num
                           and duplicates == 0) else 0.0

    closed = {STATUS_OK, STATUS_RECOVERED, STATUS_FAILED, STATUS_SHED}
    statuses = [r.status for r in results if r is not None]
    taxonomy_closed = 1.0 if all(s in closed for s in statuses) else 0.0

    shed = [r for r in results if r is not None and r.status == STATUS_SHED]
    explicit_shed_ratio = (sum(1 for r in shed if r.detail) / len(shed)
                           if shed else 1.0)

    # breaker isolation: the poisoned rung burned at most
    # failure_threshold dispatched flushes before its breaker opened
    # (attempt-0 failures count top-level dispatches), and every FAILED
    # result lives on the poisoned rung — transients recovered, overload
    # shed, nothing else broke
    poison_dispatches = sum(1 for e in server2.events
                            if e[0] == "fail" and e[1] == poison
                            and e[3] == 0)
    breaker_opened = any(e[0] == "breaker" and e[1] == poison
                         and e[2] == "open" for e in server2.events)
    failed_off_rung = sum(1 for r in results
                          if r is not None and r.status == STATUS_FAILED
                          and r.rung != poison)
    breaker_isolation = 1.0 if (breaker_opened
                                and poison_dispatches <= _BREAKER_THRESHOLD
                                and failed_off_rung == 0) else 0.0

    counts = {name: sum(1 for s in statuses if s == code)
              for name, code in (("ok", STATUS_OK),
                                 ("recovered", STATUS_RECOVERED),
                                 ("failed", STATUS_FAILED),
                                 ("shed", STATUS_SHED))}
    shed_details = {}
    for r in shed:
        shed_details[r.detail] = shed_details.get(r.detail, 0) + 1
    event_kinds = {}
    for e in server2.events:
        event_kinds[e[0]] = event_kinds.get(e[0], 0) + 1

    # coverage sanity: the chaos schedule must actually exercise the
    # paths it claims to gate — retries fired, load was shed, the
    # poisoned rung both quarantined and tripped its breaker
    coverage = bool(event_kinds.get("retry", 0) > 0
                    and event_kinds.get("quarantine", 0) > 0
                    and breaker_opened and len(shed) > 0)

    rows = [
        ("chaos_conservation", conservation,
         f"resolved={resolved}/{num};duplicates={duplicates}"),
        ("chaos_taxonomy_closed", taxonomy_closed,
         ";".join(f"{k}={v}" for k, v in counts.items())),
        ("chaos_explicit_shed_ratio", explicit_shed_ratio,
         ";".join(f"{k}={v}" for k, v in sorted(shed_details.items()))),
        ("chaos_breaker_isolation", breaker_isolation,
         f"poison_dispatches={poison_dispatches};"
         f"threshold={_BREAKER_THRESHOLD};off_rung_failed={failed_off_rung}"),
        ("chaos_replay_determinism", 1.0 if deterministic else 0.0,
         f"events={len(server2.events)};batches={len(server2.history)}"),
    ]

    record = {
        "bench": "chaos",
        "quick": quick,
        "seed": _SEED,
        "requests": num,
        "cases": [{"n": n, "bandwidth": bw, "arrow": ar}
                  for n, bw, ar in _CASES],
        "poison_rung": poison,
        "status_counts": counts,
        "shed_details": shed_details,
        "event_counts": event_kinds,
        "batches": len(server2.history),
        "conservation": conservation,
        "taxonomy_closed": taxonomy_closed,
        "explicit_shed_ratio": explicit_shed_ratio,
        "breaker_isolation": breaker_isolation,
        "replay_determinism": 1.0 if deterministic else 0.0,
        # the gates: no future lost/duplicated/stuck, every terminal
        # status in the closed set with sheds explicit, the poisoned
        # rung contained within its breaker budget, and the whole chaos
        # schedule bit-identical on replay
        "thresholds": {"conservation_min": 1.0,
                       "taxonomy_closed_min": 1.0,
                       "explicit_shed_ratio_min": 1.0,
                       "breaker_isolation_min": 1.0,
                       "replay_determinism_min": 1.0},
        "pass": bool(conservation == 1.0 and taxonomy_closed == 1.0
                     and explicit_shed_ratio == 1.0
                     and breaker_isolation == 1.0 and deterministic
                     and coverage),
    }
    record["interpret_diagnostics"] = {
        "pass1_s": pass1_s,
        "pass2_s": pass2_s,
    }
    with open(os.path.join(_ROOT, "BENCH_chaos.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
