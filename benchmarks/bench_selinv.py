"""Selected inversion: Takahashi recurrence vs dense-panel marginals vs
np.linalg.inv.

Three ways to get posterior marginal variances from one banded-arrowhead
factor, timed at full-diagonal selection (k = n, the INLA serving case):

* :func:`selected_inverse` — one backward tile sweep, cost independent of k
  (and it yields the whole band + arrow block of Σ, not just the diagonal).
* ``marginal_variances(options=SolverOptions(method="panels"))`` — k unit-vector RHS riding one
  blocked forward sweep; cost grows with k (the (t, t) @ (t, k) band steps).
* ``np.linalg.inv`` of the densified matrix — the O(n³) strawman.

A small-k panels point is also timed to show the crossover: panels win when
k is tiny, the recurrence wins long before the full diagonal.  Emits a
``BENCH_selinv.json`` trajectory point (speedups + thresholds) at the repo
root in addition to the harness CSV rows.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        marginal_variances, selected_inverse)
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, reps=3):
    """Min over reps — robust to transient host contention."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    from repro.data import make_arrowhead

    n, bw, ar, t = (1024, 32, 16, 16) if quick else (4096, 64, 32, 32)
    k_small = 32
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=0)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)
    factor = factorize_window(bm)
    full_idx = np.arange(n)
    small_idx = np.linspace(0, n - 1, k_small).astype(np.int64)

    # --- Takahashi recurrence (cost independent of k) ----------------------
    def selinv():
        jax.block_until_ready(selected_inverse(factor).Dr)

    # --- dense unit-vector panels at full-diagonal selection ---------------
    def panels_full():
        jax.block_until_ready(
            marginal_variances(factor, full_idx, options=SolverOptions(method="panels")))

    def panels_small():
        jax.block_until_ready(
            marginal_variances(factor, small_idx, options=SolverOptions(method="panels")))

    t_selinv = _time(selinv)
    t_panels_full = _time(panels_full)
    t_panels_small = _time(panels_small)

    # --- dense inverse strawman (timed once; O(n³)) ------------------------
    dense = bm.to_dense(lower_only=False)
    t0 = time.perf_counter()
    np.linalg.inv(dense)
    t_npinv = time.perf_counter() - t0

    speedup_full = t_panels_full / t_selinv
    rows = [
        (f"selinv_recurrence_n{n}", t_selinv * 1e6,
         f"full_diag;k_independent"),
        (f"marginals_panels_k{n}", t_panels_full * 1e6,
         f"speedup_vs_recurrence={speedup_full:.1f}x"),
        (f"marginals_panels_k{k_small}", t_panels_small * 1e6,
         f"small_k_point"),
        (f"np_linalg_inv_n{n}", t_npinv * 1e6,
         f"dense_strawman;speedup={t_npinv / t_selinv:.1f}x"),
    ]

    record = {
        "bench": "selinv",
        "quick": quick,
        "problem": {"n": n, "bandwidth": bw, "arrow": ar, "t": t,
                    "k_small": k_small},
        "selinv_us": t_selinv * 1e6,
        "panels_full_diag_us": t_panels_full * 1e6,
        "panels_small_k_us": t_panels_small * 1e6,
        "np_linalg_inv_us": t_npinv * 1e6,
        "selinv_vs_panels_full_speedup": speedup_full,
        "selinv_vs_np_inv_speedup": t_npinv / t_selinv,
        "thresholds": {"selinv_vs_panels_full_speedup_min": 1.0},
        "pass": bool(speedup_full >= 1.0),
    }
    with open(os.path.join(_ROOT, "BENCH_selinv.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
