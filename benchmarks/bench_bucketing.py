"""Canonical-grid bucketing: compile counts for a mixed-grid serving stream.

The serving caches of ``core/batching.py`` bound *how many* traced
callables live at once, but without a grid policy every distinct
:class:`TileGrid` in the traffic still costs its own trace + XLA compile
(and churns the LRU).  This benchmark replays a stream of >= 8 distinct
grids through ``factorize_window_batched`` twice:

* **baseline** — no policy: one compile cache entry per distinct grid;
* **bucketed** — with a :class:`GridBucketPolicy`: entries are keyed on
  the *canonical* grid, so the count is bounded by the number of
  canonical rungs the stream actually hits.

Compiles are counted by diffing the key set of the bounded serving cache
(each new key is exactly one trace + compile), which is backend- and
wall-clock-independent — the CI-stable gate, like ``bench_cholesky``'s
launch counts.  The price of bucketing is padded flops (band/arrow
widening only; the identity diagonal prefix is *skipped* by the sweeps'
traced ``start_tile``): recorded as ``padded_flop_overhead_mean/max``
from the policy's tile-matmul model.  Parity of the bucketed factors
against the unbucketed ones is asserted and recorded.

Emits a ``BENCH_bucketing.json`` trajectory point at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid,
                        factorize_window_batched, padded_flop_overhead,
                        restrict_factor)
from repro.core import cholesky as _cholesky
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (n, bandwidth, arrow) triples of the mixed-size stream — >= 8 distinct
# tile grids at t=8, landing on a handful of canonical rungs
_STREAM_QUICK = [
    (64, 6, 4), (72, 8, 4), (80, 10, 4), (88, 6, 8), (96, 12, 8),
    (100, 12, 4), (104, 8, 4), (112, 14, 8), (120, 16, 4), (128, 8, 8),
    (136, 10, 8), (144, 18, 8),
]
_STREAM_FULL = _STREAM_QUICK + [
    (152, 20, 4), (168, 24, 8), (184, 12, 16), (200, 28, 8), (216, 30, 16),
]


def run(quick: bool = True):
    from repro.data import make_arrowhead

    t = 8
    stream = _STREAM_QUICK if quick else _STREAM_FULL
    policy = GridBucketPolicy()
    problems = []
    for i, (n, bw, ar) in enumerate(stream):
        A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=i)
        grid = TileGrid(struct, t=t)
        problems.append((grid, BandedCTSF.from_sparse(A, grid)))

    grids = [g for g, _ in problems]
    distinct = {g for g in grids}
    rungs = {policy.canonicalize(g) for g in grids}
    cache = _cholesky._BATCHED_WINDOW_CACHE

    def replay(policy_arg):
        before = set(cache.keys())
        t0 = time.perf_counter()
        factors = []
        for _, m in problems:
            f = factorize_window_batched([m, m], options=SolverOptions(impl=None, policy=policy_arg))
            jax.block_until_ready(f.ctsf.Dr)
            factors.append(f)
        dt = time.perf_counter() - t0
        return len(set(cache.keys()) - before), dt, factors

    base_compiles, base_s, base_factors = replay(None)
    buck_compiles, buck_s, buck_factors = replay(policy)

    # exactness of the embedding: bucketed factors, restricted back to the
    # source grid, must match the unbucketed ones
    parity = 0.0
    for f0, f1 in zip(base_factors, buck_factors):
        r = restrict_factor(f1)
        parity = max(parity,
                     float(jnp.abs(f0.ctsf.Dr - r.ctsf.Dr).max()),
                     float(jnp.abs(f0.ctsf.R - r.ctsf.R).max()),
                     float(jnp.abs(f0.ctsf.C - r.ctsf.C).max()))

    overheads = [padded_flop_overhead(g, policy.canonicalize(g))
                 for g in grids]
    reduction = base_compiles / max(buck_compiles, 1)
    backend = jax.default_backend()

    rows = [
        ("bucketing_baseline_compiles", float(base_compiles),
         f"distinct_grids={len(distinct)}"),
        ("bucketing_bucketed_compiles", float(buck_compiles),
         f"canonical_rungs_hit={len(rungs)};reduction={reduction:.1f}x"),
        ("bucketing_flop_overhead_max", max(overheads) * 100.0,
         "percent;identity_prefix_skipped"),
        ("bucketing_parity_err", parity, "bucketed_vs_unbucketed_factor"),
    ]

    record = {
        "bench": "bucketing",
        "quick": quick,
        "tile": t,
        "stream": [{"n": n, "bandwidth": bw, "arrow": ar}
                   for n, bw, ar in stream],
        "distinct_grids": len(distinct),
        "canonical_rungs_hit": len(rungs),
        "baseline_compiles": base_compiles,
        "bucketed_compiles": buck_compiles,
        "compile_reduction": reduction,
        "padded_flop_overhead_mean": sum(overheads) / len(overheads),
        "padded_flop_overhead_max": max(overheads),
        "parity_max_abs_err": parity,
        "backend": backend,
        # the gate: a >= 8-distinct-grid stream must compile at most one
        # sweep per canonical rung it hits, and at least 2x fewer than the
        # one-per-grid baseline; parity must hold to fp32 tolerance.
        "thresholds": {"compile_reduction_min": 1.8},
        "pass": bool(buck_compiles <= len(rungs)
                     and len(distinct) >= 8
                     and reduction >= 1.8
                     and parity < 1e-5),
    }
    # wall-clock of the replay loops: informative only (CPU/interpret
    # hosts time Python dispatch, not the TPU sweeps), never gated
    record["interpret_diagnostics"] = {
        "baseline_stream_s": base_s,
        "bucketed_stream_s": buck_s,
        "interpret_mode": backend != "tpu",
    }
    with open(os.path.join(_ROOT, "BENCH_bucketing.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
