"""Roofline analysis (deliverable g): three-term roofline per (arch × shape)
from the dry-run artifacts in results/dryrun/.

    compute term    = HLO_FLOPs / (chips · peak_FLOP/s)
    memory term     = HLO_bytes / (chips · HBM_bw)
    collective term = collective_bytes / (chips · link_bw)

HLO_FLOPs/bytes come from per-layer extrapolated cost analysis (dryrun.py);
collective bytes from the optimized-HLO parse.  cost_analysis numbers are
already per-device (the SPMD module), so `chips·` is folded in.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get as get_cfg
from repro.configs.base import SHAPES
# hardware model lives in the library (single source of truth shared with
# telemetry.kernel_report); re-exported here for existing importers
from repro.runtime.telemetry import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS convention: 6·N·D train (N active for MoE), 2·N·D
    prefill, 2·N·B decode (D = tokens processed)."""
    cfg = get_cfg(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row


def analyse_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_chips = 1
    for d in rec["mesh"].split("x"):
        n_chips *= int(d)
    cost = rec.get("cost_extrapolated") or rec.get("cost_scanned")
    coll = cost.get("coll") if "coll" in cost else \
        rec.get("collectives_scanned", {}).get("total", 0.0)
    # linear extrapolation can undershoot when the partitioner's collective
    # schedule differs between the 1- and 2-layer probes; floor at the
    # scanned (trip-count-undercounted) measurement
    coll = max(coll, rec.get("collectives_scanned", {}).get("total", 0.0))
    flops_dev = cost["flops"]
    bytes_dev = cost["bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * n_chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS)
        / max(max(terms.values()), 1e-30),
        "mem_gib": rec["memory"]["total_per_device_gib"],
    }


def load_all(mesh_tag: str = "single") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh_tag}.json"))):
        with open(fn) as f:
            rec = json.load(f)
        a = analyse_record(rec)
        if a:
            out.append(a)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "skipped": rec["reason"]})
    return out


def markdown_table(rows: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | mem GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['mem_gib']:.2f} |")
    return "\n".join(lines)


def run(quick: bool = True):
    rows = load_all("single")
    if not rows:
        return [("roofline", 0.0, "no dryrun records yet — run launch/dryrun")]
    out = []
    for r in rows:
        if "skipped" in r:
            continue
        out.append((
            f"roofline_{r['arch']}_{r['shape']}",
            max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
            f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f}"))
    # also write the markdown table for EXPERIMENTS.md
    os.makedirs(os.path.join(os.path.dirname(RESULTS)), exist_ok=True)
    with open(os.path.join(os.path.dirname(RESULTS), "roofline_table.md"), "w") as f:
        f.write(markdown_table(rows) + "\n")
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
