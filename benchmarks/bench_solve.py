"""Batched serving path: multi-RHS triangular sweeps vs per-vector solves.

Three comparisons, all on the same banded-arrowhead factor:

* ``solve_many`` with a k-RHS panel vs k sequential :func:`solve` calls —
  Ruipeng Li's observation that sparse triangular solves are latency-bound
  until RHS are blocked into panels.
* one-sweep :func:`marginal_variances` (k selected indices as one multi-RHS
  forward sweep) vs the pre-batching ``lax.map`` per-index path.
* ``factorize_window_batched`` over a θ-sweep batch vs a Python loop of
  :func:`factorize_window` — the INLA gradient workload.
* the *fused* Pallas band-sweep kernels (``impl="pallas"``: whole sweep in
  one launch, VMEM ring of recent panels) vs the per-tile-looped sweep
  (``impl="ref"``: one ``solve_panel`` per band tile through a
  ``fori_loop``).  On CPU the Pallas kernels run in *interpret mode*, so
  the looped path wins there — the timings document the dispatch-count
  contrast; the fusion pays off on real TPU hardware, and correctness
  parity is asserted by tier-1 tests either way.

Emits a ``BENCH_solve.json`` trajectory point (speedups + thresholds) at
the repo root in addition to the harness CSV rows.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        factorize_window_batched, marginal_variances, solve,
                        solve_many)
from repro.core.solve import _marginal_variances_map
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, reps=3):
    """Min over reps — robust to transient host contention, which otherwise
    dominates millisecond-scale solve timings."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    from repro.data import make_arrowhead

    n, bw, ar, t = (1024, 32, 16, 16) if quick else (4096, 64, 32, 32)
    k = 64
    batch = 8 if quick else 16
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=0)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)
    factor = factorize_window(bm)

    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, k)).astype(np.float32))
    cols = [B[:, i] for i in range(k)]

    # --- multi-RHS panel sweep vs k per-vector solves ----------------------
    def many():
        jax.block_until_ready(solve_many(factor, B))

    def seq():
        outs = [solve(factor, c) for c in cols]
        jax.block_until_ready(outs)

    t_many = _time(many)
    t_seq = _time(seq)
    solve_speedup = t_seq / t_many
    rows = [(f"solve_many_k{k}", t_many * 1e6,
             f"seq_us={t_seq*1e6:.0f};speedup={solve_speedup:.1f}x")]

    # --- one-sweep marginal variances vs per-index lax.map -----------------
    idx = jnp.asarray(np.linspace(0, struct.n_diag - 1, k).astype(np.int64))

    def mv_batched():
        jax.block_until_ready(
            marginal_variances(factor, idx, options=SolverOptions(method="panels")))

    def mv_map():
        jax.block_until_ready(_marginal_variances_map(factor, idx))

    t_mv = _time(mv_batched)
    t_mv_map = _time(mv_map)
    mv_speedup = t_mv_map / t_mv
    rows.append((f"marginal_variances_k{k}", t_mv * 1e6,
                 f"map_us={t_mv_map*1e6:.0f};speedup={mv_speedup:.1f}x"))

    # --- fused band-sweep kernels vs per-tile-looped sweep ------------------
    # Smaller panel so CPU interpret-mode execution of the fused kernels
    # stays in benchmark budget; both impls solve the identical problem.
    kf = 16
    Bf = B[:, :kf]

    def sweep_fused():
        jax.block_until_ready(solve_many(factor, Bf, options=SolverOptions(impl="pallas")))

    def sweep_looped():
        jax.block_until_ready(solve_many(factor, Bf, options=SolverOptions(impl="ref")))

    t_fused = _time(sweep_fused, reps=2)
    t_looped = _time(sweep_looped, reps=2)
    backend = jax.default_backend()
    interpret = backend != "tpu"
    # On non-TPU hosts the fused kernel executes in interpret mode, so its
    # timing is a diagnostic, not a speedup claim — the CSV row is tagged
    # and the JSON record quarantines it under interpret_diagnostics.
    rows.append((f"solve_sweep_fused_k{kf}"
                 + ("[interpret-diagnostic]" if interpret else ""),
                 t_fused * 1e6,
                 f"looped_us={t_looped*1e6:.0f};backend={backend}"))

    # --- batched vs looped window factorization ----------------------------
    # Stacking happens once outside the timed region (serving keeps the
    # θ-sweep batch resident); on single-core CPU the vmapped sweep has no
    # parallelism to exploit, so ~1x here is expected — the batch axis maps
    # to parallel hardware on TPU and to fewer dispatches everywhere.
    from repro.core.concurrent import stack_ctsf
    mats = []
    for s in range(batch):
        Ai, sti = make_arrowhead(n, bw, ar, rho=0.6, seed=s)
        mats.append(BandedCTSF.from_sparse(Ai, TileGrid(sti, t=t)))
    stacked = stack_ctsf(mats)

    def fac_batched():
        jax.block_until_ready(
            factorize_window_batched(stacked, bucket=False).ctsf.Dr)

    def fac_loop():
        outs = [factorize_window(m).ctsf.Dr for m in mats]
        jax.block_until_ready(outs)

    t_fb = _time(fac_batched, reps=2)
    t_fl = _time(fac_loop, reps=2)
    fac_speedup = t_fl / t_fb
    rows.append((f"factorize_batched_b{batch}", t_fb * 1e6,
                 f"loop_us={t_fl*1e6:.0f};speedup={fac_speedup:.1f}x"))

    record = {
        "bench": "solve",
        "quick": quick,
        "problem": {"n": n, "bandwidth": bw, "arrow": ar, "t": t,
                    "k_rhs": k, "batch": batch},
        "solve_many_us": t_many * 1e6,
        "solve_sequential_us": t_seq * 1e6,
        "solve_many_speedup": solve_speedup,
        "marginal_variances_us": t_mv * 1e6,
        "marginal_variances_map_us": t_mv_map * 1e6,
        "marginal_variances_speedup": mv_speedup,
        "factorize_batched_us": t_fb * 1e6,
        "factorize_loop_us": t_fl * 1e6,
        "factorize_batched_speedup": fac_speedup,
        "thresholds": {"solve_many_speedup_min": 3.0,
                       "marginal_variances_speedup_min": 5.0},
        "pass": bool(solve_speedup >= 3.0 and mv_speedup >= 5.0),
    }
    # fused (single-launch Pallas) vs per-tile-looped sweep.  Only
    # meaningful as a speedup on real TPU hardware; in interpret mode the
    # numbers live under interpret_diagnostics, which run.py consistently
    # excludes from gating — they never sit alongside production metrics
    # without the flag.
    sweep_stats = {
        "sweep_k": kf,
        "sweep_fused_us": t_fused * 1e6,
        "sweep_looped_us": t_looped * 1e6,
        "sweep_fused_speedup": t_looped / t_fused,
        "sweep_backend": backend,
    }
    if interpret:
        record["interpret_diagnostics"] = {**sweep_stats,
                                           "interpret_mode": True}
    else:
        record.update(sweep_stats)
    with open(os.path.join(_ROOT, "BENCH_solve.json"), "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(quick=True):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
