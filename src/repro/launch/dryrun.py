import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware (deliverable (e)).

For every (architecture × input shape × mesh) cell this lowers + compiles
the real step function — ``train_step`` (loss+grad+AdamW) for train shapes,
``prefill`` for prefill shapes, ``decode_step`` for decode shapes — against
ShapeDtypeStruct stand-ins (no allocation), then records:

  * ``compiled.memory_analysis()``  (bytes/device: proves it fits),
  * ``compiled.cost_analysis()``    (per-device HLO FLOPs/bytes),
  * collective bytes parsed from the optimized HLO text,
  * per-layer-extrapolated FLOPs/bytes/collectives (XLA's cost analysis
    counts while-loop bodies once, so the roofline terms are derived from
    1- and 2-layer *unrolled* lowers of the same cell — layers are
    homogeneous, which is what makes scan-over-layers valid in the first
    place).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun   # every cell
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, RunConfig, SHAPES, ShapeConfig
from repro.models.registry import get_model, input_specs, supports_shape
from repro.optim.adamw import adamw_init
from repro.sharding.partition import Rules, make_rules
from .mesh import make_production_mesh
from .train import TrainState, make_train_step

__all__ = ["dryrun_cell", "collective_bytes", "main"]

# optimized-HLO line: "%name = f32[64,16]{1,0} all-gather(%operand), ..."
# (operand shapes are NOT inlined, so operand bytes are derived from the
# result shape + the op's semantics + the replica group size)
_COLLECTIVE_LINE_RE = re.compile(
    r"=\s*(?:\()?((?:f|bf|s|u|pred)[0-9]{0,2})\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8": 1}


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the (optimized) HLO.

    Operand size derivation from the result shape R and group size g:
      all-reduce / all-to-all / collective-permute : R
      all-gather                                   : R / g
      reduce-scatter                               : R * g
    (-start async variants counted once; -done carries no new bytes).
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_LINE_RE.search(line)
        if m is None:
            continue
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        result = float(n * nbytes)
        g = _group_size(line)
        if op == "all-gather":
            operand = result / g
        elif op == "reduce-scatter":
            operand = result * g
        else:
            operand = result
        out[op] = out.get(op, 0.0) + operand
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _reduced_layers(cfg: ModelConfig, n: int) -> ModelConfig:
    upd: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        upd["n_layers"] = n * cfg.shared_attn_every
    else:
        upd["n_layers"] = n
    if cfg.family == "encdec":
        upd["encoder_layers"] = n
    return dataclasses.replace(cfg, **upd)


def _layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    return cfg.n_layers


def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                mesh, rules: Rules):
    """Build + lower the step function for one cell. Returns `lowered`."""
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    max_seq = shape.seq_len

    if shape.kind == "train":
        params = jax.eval_shape(lambda k: api.init(k, cfg, max_seq), key)
        state = TrainState(params=params, opt=adamw_init_shapes(params),
                           step=jax.ShapeDtypeStruct((), jnp.int32))
        step_fn = make_train_step(cfg, run, rules)
        batch = input_specs(cfg, shape)
        state_sh = TrainState(rules.param_shardings(params),
                              type(state.opt)(rules.param_shardings(params),
                                              rules.param_shardings(params),
                                              rules.replicated()),
                              rules.replicated(), None, None)
        rep = rules.replicated()
        metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        with mesh:
            return jax.jit(step_fn, in_shardings=(state_sh, rules.batch_specs(batch)),
                           out_shardings=(state_sh, metrics_sh),
                           donate_argnums=(0,)).lower(state, batch)

    if shape.kind == "prefill":
        params = jax.eval_shape(lambda k: api.init(k, cfg, max_seq), key)
        batch = input_specs(cfg, shape)

        def prefill_fn(p, b):
            return api.prefill(p, b, cfg, run, constrain=rules.constrain)

        with mesh:
            return jax.jit(
                prefill_fn,
                in_shardings=(rules.param_shardings(params),
                              rules.batch_specs(batch)),
            ).lower(params, batch)

    # decode
    params = jax.eval_shape(lambda k: api.init(k, cfg, max_seq), key)
    caches = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, max_seq))
    spec = input_specs(cfg, shape)
    cache_sh = rules.cache_shardings(caches)

    def decode_fn(p, c, tok, pos):
        return api.decode_step(p, c, tok, pos, cfg, run,
                               constrain=rules.constrain)

    with mesh:
        return jax.jit(
            decode_fn,
            in_shardings=(rules.param_shardings(params), cache_sh,
                          rules.batch_specs(spec["token"]), rules.replicated()),
            out_shardings=(rules.batch_specs(
                jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_padded),
                                     jnp.float32)), cache_sh),
            donate_argnums=(1,),
        ).lower(params, caches, spec["token"], spec["pos"])


def adamw_init_shapes(params):
    from repro.optim.adamw import AdamWState
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(lambda x: x, zeros),
                      count=jax.ShapeDtypeStruct((), jnp.int32))


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                run: Optional[RunConfig] = None,
                extrapolate: bool = True, verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run record."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    run = run or RunConfig()
    skip = supports_shape(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, cfg, run, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "x".join(str(s) for s in mesh.devices.shape),
                           "status": "ok", "run": dataclasses.asdict(run)}

    t0 = time.time()
    lowered = _lower_cell(cfg, shape, run, mesh, rules)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "total_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2 ** 30, 3),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_scanned"] = {"flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0))}
    rec["collectives_scanned"] = collective_bytes(compiled.as_text())

    if extrapolate:
        per = {}
        for n in (1, 2):
            cfg_n = _reduced_layers(cfg, n)
            # unrolled layers AND single-block attention / CE (scan trip
            # counts are not multiplied by XLA's cost analysis, so every
            # loop must have trip count 1 for exact FLOP accounting)
            run_n = dataclasses.replace(
                run, scan_layers=False, unroll_attn=True,
                q_chunk=min(4096, shape.seq_len),
                kv_chunk=min(4096, shape.seq_len),
                loss_chunk=shape.seq_len)
            rules_n = make_rules(mesh, cfg_n, run_n, shape)
            low = _lower_cell(cfg_n, shape, run_n, mesh, rules_n)
            comp = low.compile()
            can = comp.cost_analysis() or {}
            per[n] = {"flops": float(can.get("flops", 0.0)),
                      "bytes": float(can.get("bytes accessed", 0.0)),
                      "coll": collective_bytes(comp.as_text())["total"]}
        L = _layer_count(cfg)
        rec["cost_extrapolated"] = {
            k: per[1][k] + (per[2][k] - per[1][k]) * (L - 1)
            for k in ("flops", "bytes", "coll")}
        rec["cost_per_layer"] = {k: per[2][k] - per[1][k]
                                 for k in ("flops", "bytes", "coll")}

    if verbose:
        mem = rec["memory"]["total_per_device_gib"]
        fl = rec.get("cost_extrapolated", rec["cost_scanned"])["flops"]
        print(f"[dryrun] {arch:24s} {shape_name:12s} mesh={rec['mesh']:8s} "
              f"mem/dev={mem:7.2f} GiB flops/dev={fl:.3e} "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=configs.ARCH_IDS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true",
                   help="run every (arch x shape) cell on this mesh")
    p.add_argument("--out", default=None, help="directory for JSON records")
    p.add_argument("--no-extrapolate", action="store_true")
    args = p.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            p.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    records = []
    for arch, shape in cells:
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                              extrapolate=not args.no_extrapolate)
        except Exception as exc:  # record, keep going
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(exc).__name__}: {exc}"}
            print(f"[dryrun] {arch} {shape} FAILED: {rec['error']}")
        records.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            mesh_tag = "multi" if args.multi_pod else "single"
            fn = os.path.join(args.out, f"{rec['arch']}_{rec['shape']}_{mesh_tag}.json")
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"[dryrun] {n_ok} ok / {n_skip} skipped / {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
