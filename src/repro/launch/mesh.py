"""Production mesh definitions.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state.  Single pod: 16×16 =
256 chips (data × model).  Multi-pod: 2 pods × 256 = 512 chips with a
leading DCN-like ``pod`` axis used for data parallelism + gradient
all-reduce only.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests, examples, CPU runs)."""
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))
