"""Training driver: builds the sharded train_step and runs the
fault-tolerant loop.

``python -m repro.launch.train --arch qwen2-7b --steps 100 ...`` trains a
(reduced or full) model on synthetic Markov data with AdamW or the sTiles
arrowhead-preconditioned optimizer.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, RunConfig, SHAPES
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import MarkovStream, token_batch
from repro.models.registry import get_model, input_specs
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_lr)
from repro.optim.arrowhead import ArrowheadPrecond, build_precond
from repro.runtime.fault_tolerance import TrainLoop
from repro.sharding.partition import Rules, make_rules
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["TrainState", "make_train_step", "init_state", "train", "main"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jnp.ndarray
    precond: Optional[Dict[str, jnp.ndarray]] = None   # arrowhead stats
    factor: Optional[Dict[str, jnp.ndarray]] = None    # arrowhead factor

    def tree_flatten(self):
        return (self.params, self.opt, self.step, self.precond, self.factor), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(key, cfg: ModelConfig, run: RunConfig, max_seq: int = 0,
               precond: Optional[ArrowheadPrecond] = None) -> TrainState:
    api = get_model(cfg)
    params = api.init(key, cfg, max_seq)
    state = TrainState(params=params, opt=adamw_init(params),
                       step=jnp.zeros((), jnp.int32))
    if precond is not None:
        state.precond = precond.init_state()
        state.factor = precond.factorize(state.precond)
    return state


def make_train_step(cfg: ModelConfig, run: RunConfig, rules: Optional[Rules],
                    precond: Optional[ArrowheadPrecond] = None,
                    total_steps: int = 10_000):
    """Returns train_step(state, batch) -> (state, metrics)."""
    api = get_model(cfg)
    constrain = rules.constrain if rules is not None else None

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        def loss_fn(p, b):
            return api.loss(p, b, cfg, run, constrain=constrain)

        if run.grad_accum > 1:
            # microbatched gradient accumulation: reshape every batch leaf
            # (B, ...) -> (A, B/A, ...) and scan, peaking one microbatch of
            # activations at a time
            a = run.grad_accum
            micro = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch)

            def accum(carry, mb):
                acc, ltot = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), acc, g)
                return (acc, ltot + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            from repro.models.layers import scan_or_unroll
            (gsum, lsum), _ = scan_or_unroll(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro,
                scan=run.scan_layers, remat="none")
            grads = jax.tree.map(lambda x: x / a, gsum)
            loss = lsum / a
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)

        new_precond, new_factor = state.precond, state.factor
        if precond is not None:
            new_precond = precond.update_stats(state.precond, grads)
            refresh = (state.step % run.precond_every) == 0
            refreshed = precond.factorize(new_precond)
            new_factor = jax.tree.map(
                lambda a, b: jnp.where(refresh, a, b), refreshed, state.factor)
            grads = precond.precondition(new_factor, grads)

        lr = cosine_lr(state.step, run.learning_rate,
                       warmup=max(2, total_steps // 10), total=total_steps)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, lr,
            weight_decay=run.weight_decay)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               new_precond, new_factor)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


def shard_train_step(train_step, mesh, rules: Rules, state: TrainState,
                     batch_template) -> Tuple[Any, Any]:
    """jit the step with explicit state/batch shardings; returns
    (jitted_fn, state_shardings)."""
    param_sh = rules.param_shardings(state.params)
    opt_sh = AdamWState(m=param_sh, v=param_sh,
                        count=rules.replicated())
    rep = rules.replicated()
    pre_sh = None if state.precond is None else jax.tree.map(
        lambda _: rep, state.precond)
    fac_sh = None if state.factor is None else jax.tree.map(
        lambda _: rep, state.factor)
    state_sh = TrainState(param_sh, opt_sh, rep, pre_sh, fac_sh)
    batch_sh = rules.batch_specs(batch_template)
    metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
    fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, metrics_sh), donate_argnums=(0,))
    return fn, state_sh


# ---------------------------------------------------------------------------
# CLI driver (runs reduced configs on local devices; full configs dry-run
# through launch/dryrun.py)
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, layers: int = 4, d_model: int = 256,
                  vocab: int = 512) -> ModelConfig:
    """Scale an assigned architecture down to laptop size, preserving family
    structure (used by smoke tests and the quickstart examples)."""
    factor = max(1, cfg.d_model // d_model)
    upd = dict(
        n_layers=min(cfg.n_layers, layers), d_model=cfg.d_model // factor,
        d_ff=max(8, cfg.d_ff // factor), vocab=min(cfg.vocab, vocab),
        head_dim=max(8, cfg.hd // factor // 2 * 2),   # rope needs even dims
    )
    if cfg.family in ("ssm", "hybrid"):
        upd["ssm_head_dim"] = max(8, cfg.ssm_head_dim // factor)
        upd["ssm_state"] = min(cfg.ssm_state, 32)
    if cfg.family == "hybrid":
        upd["n_layers"] = cfg.shared_attn_every * max(
            1, min(cfg.n_layers, layers) // cfg.shared_attn_every)
    if cfg.family == "moe":
        upd["n_experts"] = min(cfg.n_experts, 8)
        upd["top_k"] = min(cfg.top_k, 2)
        upd["expert_pad_to"] = 0
    if cfg.family == "encdec":
        upd["encoder_layers"] = min(cfg.encoder_layers, layers)
        upd["encoder_seq"] = min(cfg.encoder_seq, 64)
    if cfg.family == "vlm":
        upd["n_image_tokens"] = min(cfg.n_image_tokens, 8)
    return dataclasses.replace(cfg, **upd)


def train(arch: str, steps: int = 50, batch: int = 8, seq: int = 128,
          optimizer: str = "adamw", reduced: bool = True,
          checkpoint_dir: str = "/tmp/repro_ckpt", seed: int = 0,
          log_every: int = 10, injector=None) -> Dict[str, Any]:
    cfg = configs.get(arch)
    if reduced:
        cfg = reduce_config(cfg)
    run = RunConfig(optimizer=optimizer, remat="none", loss_chunk=128,
                    checkpoint_every=max(10, steps // 4))
    mesh = make_local_mesh()
    rules = make_rules(mesh, cfg, run)
    key = jax.random.PRNGKey(seed)

    precond = None
    api = get_model(cfg)
    if optimizer == "arrowhead":
        params0 = jax.eval_shape(lambda k: api.init(k, cfg, seq), key)
        precond = build_precond(params0, r=run.precond_proj_dim,
                                band=run.precond_band, seed=seed)
    state = init_state(key, cfg, run, max_seq=seq, precond=precond)
    step_fn = make_train_step(cfg, run, rules, precond, total_steps=steps)
    jit_step, state_sh = shard_train_step(step_fn, mesh, rules, state,
                                          _host_batch(cfg, 0, batch, seq, seed))

    ckpt = Checkpointer(checkpoint_dir, keep=2)
    stream = MarkovStream(cfg.vocab, seed=seed)

    def batch_fn(step):
        extras = _extras(cfg, batch)
        return stream.batch(step, batch, seq, extras)

    loop = TrainLoop(step_fn=jit_step, batch_fn=batch_fn, checkpointer=ckpt,
                     checkpoint_every=run.checkpoint_every,
                     injector=injector, log_every=log_every)
    with mesh:
        final = loop.run(state, 0, steps)
    losses = [float(m["loss"]) for m in loop.history]
    return {"state": final, "losses": losses, "loop": loop,
            "entropy_floor": stream.entropy_floor, "cfg": cfg}


def _extras(cfg, batch):
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = np.zeros(
            (batch, cfg.n_image_tokens, cfg.d_model), np.float32)
    if cfg.family == "encdec":
        extras["frame_embeds"] = np.random.default_rng(0).standard_normal(
            (batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    return extras


def _host_batch(cfg, step, batch, seq, seed):
    return token_batch(seed, step, batch, seq, cfg.vocab, _extras(cfg, batch))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-7b", choices=configs.ARCH_IDS)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--optimizer", default="adamw",
                   choices=["adamw", "arrowhead"])
    p.add_argument("--full", action="store_true",
                   help="use the full (not reduced) architecture config")
    p.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    args = p.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                optimizer=args.optimizer, reduced=not args.full,
                checkpoint_dir=args.checkpoint_dir)
    print(f"first loss {out['losses'][0]:.4f} -> last {out['losses'][-1]:.4f} "
          f"(markov entropy floor {out['entropy_floor']:.4f})")


if __name__ == "__main__":
    main()
