"""Continuous-batching rung server: the serving front-end over the
canonical-grid bucketing (``core/gridpolicy.py``), batched factorization
(``core/cholesky.py``) and batched solves (``core/solve.py``).

Mixed-grid factorize/solve requests arrive continuously; each is
canonicalized by :class:`~repro.core.gridpolicy.GridBucketPolicy` into a
**rung** (canonical grid × RHS panel width) and queued per rung.  A rung
queue flushes as one micro-batch when any of three conditions fires:

========  ==========================================================
reason    trigger
========  ==========================================================
full      the queue reached ``max_batch`` pending requests
deadline  ``now`` passed some queued request's ``flush_by`` time
          (``min(arrival + max_delay, request deadline)``)
drain     explicit shutdown/idle drain — everything left flushes
========  ==========================================================

A flushed batch is embedded onto its canonical grid
(:func:`~repro.core.gridpolicy.assemble_rung_batch`), factorized through
the rung-keyed compiled sweep (compile count stays O(#rungs), not
O(#grids)) under the jitter ladder (``regularize=``), and solved with
per-request RHS panels (:func:`~repro.core.solve.solve_many_batched`).
Each request's future resolves with its restricted solution/factor, the
per-element :class:`~repro.core.robustness.FactorInfo` outcome (a failed
request degrades to a flagged future, never poisoning its rung siblings)
and telemetry-tagged latency.

**Determinism is the design center.**  The scheduler
(:class:`RungScheduler`) is a pure, clock-injected state machine —
``tick(now, arrivals) -> [RungBatch]`` reads no wall clock, sleeps
never, and iterates its queues in insertion order — so replaying the
same arrival stream produces the identical sequence of batch
compositions and flush reasons, and (since vmap computes batch elements
independently through one compiled executable) bit-identical numerical
results.  Tests drive it with :class:`SimClock`; production drives the
same code with ``time.monotonic``.

**Double buffering.**  The executor keeps one batch in flight: JAX's
async dispatch returns unblocked device arrays, so the server dispatches
batch N, assembles and dispatches batch N+1 on the host, and only then
blocks on N's results (:meth:`RungExecutor.finalize`) — host assembly
overlaps device execution with no threads in the data path.  (With
``regularize=`` on, the jitter ladder's one status readback synchronizes
the *factorization*; the solve sweep — the long stage for wide panels —
still overlaps.)  The optional threaded pump (:meth:`RungServer.start`)
only moves the same synchronous ``pump()`` loop off the caller's thread.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.batching import RungQueue
from repro.core.cholesky import CholeskyFactor, factorize_window_batched
from repro.core.ctsf import BandedCTSF
from repro.core.gridpolicy import (GridBucketPolicy, assemble_rung_batch,
                                   assemble_rung_rhs, restrict_rhs)
from repro.core.robustness import STATUS_FAILED, STATUS_OK, FactorInfo
from repro.core.solve import solve_many_batched
from repro.core.structure import TileGrid
from repro.runtime import telemetry

__all__ = ["FLUSH_FULL", "FLUSH_DEADLINE", "FLUSH_DRAIN", "SimClock",
           "RungRequest", "RungBatch", "RungScheduler", "RungResult",
           "RungFuture", "RungExecutor", "RungServer", "replay"]

FLUSH_FULL = "full"          # queue reached max_batch
FLUSH_DEADLINE = "deadline"  # a queued request's flush_by time passed
FLUSH_DRAIN = "drain"        # explicit drain (shutdown / idle flush)

_STATUS_NAMES = {0: "ok", 1: "recovered", 2: "failed"}


class SimClock:
    """Deterministic injectable clock for tests, replays and benchmarks:
    call it for the current time, advance it explicitly.  Time only moves
    when the driver says so — the scheduler never sleeps — which is what
    makes deadline-expiry paths unit-testable without wall-clock waits."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt {dt}")
        self.now += dt
        return self.now

    def advance_to(self, t: float) -> float:
        """Move to absolute time ``t`` (no-op if already past it)."""
        self.now = max(self.now, float(t))
        return self.now


@dataclasses.dataclass
class RungRequest:
    """One queued unit of work: a matrix to factorize, optionally with an
    RHS panel to solve.  ``deadline`` is an absolute clock time (in the
    injected clock's units) the request must be flushed by; None means
    only the scheduler's ``max_delay`` bounds its wait.  ``arrival`` /
    ``flush_by`` / ``rung`` are stamped by the scheduler at submit."""
    rid: int
    matrix: BandedCTSF
    rhs: Optional[jnp.ndarray] = None
    deadline: Optional[float] = None
    future: Optional["RungFuture"] = None
    submitted_wall: float = 0.0
    arrival: float = 0.0
    flush_by: float = 0.0
    rung: Optional[TileGrid] = None

    @property
    def grid(self) -> TileGrid:
        return self.matrix.grid

    @property
    def k(self) -> Optional[int]:
        return None if self.rhs is None else int(self.rhs.shape[-1])


@dataclasses.dataclass(frozen=True)
class RungBatch:
    """One flush decision: the requests (arrival order preserved), the
    rung key ``(canonical grid, rhs width or None)``, why it flushed and
    when.  ``signature()`` is the host-comparable composition record the
    replay tests diff across runs."""
    key: Tuple[TileGrid, Optional[int]]
    requests: Tuple[RungRequest, ...]
    reason: str
    decided_at: float

    def signature(self) -> Tuple[str, Optional[int], Tuple[int, ...], str]:
        return (telemetry.rung_tag(self.key[0]), self.key[1],
                tuple(r.rid for r in self.requests), self.reason)


class RungScheduler:
    """Pure clock-injected micro-batching state machine.

    All methods take ``now`` explicitly; nothing here reads a clock,
    sleeps, or spawns a thread.  Rung queues live in an insertion-ordered
    dict and items in arrival order, so for a fixed sequence of
    ``submit``/``tick``/``drain`` calls the emitted batches — membership,
    order, and flush reasons — are exactly reproducible.
    """

    def __init__(self, policy: Optional[GridBucketPolicy] = None,
                 max_batch: int = 8, max_delay: float = 10e-3):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        self.policy = policy or GridBucketPolicy()
        self.max_batch = max_batch
        self.max_delay = max_delay
        self._queues: Dict[Tuple[TileGrid, Optional[int]], RungQueue] = {}

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, now: float, req: RungRequest) -> Tuple[TileGrid,
                                                            Optional[int]]:
        """Enqueue one request under its rung key, stamping arrival and
        flush-by times.  Returns the key (useful for tests); flushing
        happens only in :meth:`tick`/:meth:`drain`, so a submit can never
        reorder ahead of earlier arrivals."""
        cgrid = self.policy.canonicalize(req.matrix.grid)
        key = (cgrid, req.k)
        req.arrival = now
        req.rung = cgrid
        req.flush_by = now + self.max_delay
        if req.deadline is not None:
            req.flush_by = min(req.flush_by, float(req.deadline))
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = RungQueue()
        q.push(req, req.flush_by)
        if telemetry.enabled():
            telemetry.inc("serving.requests")
            telemetry.gauge("serving.queue_depth", len(q),
                            rung=telemetry.rung_tag(cgrid))
        return key

    def next_flush_by(self) -> Optional[float]:
        """Earliest pending flush-by time across all rungs (None when
        idle) — the exact boundary a deterministic driver must tick at,
        and the longest a threaded pump may sleep."""
        if not self._queues:
            return None
        return min(q.earliest_flush_by() for q in self._queues.values())

    def tick(self, now: float,
             arrivals: Sequence[RungRequest] = ()) -> List[RungBatch]:
        """Advance the state machine to ``now``: enqueue ``arrivals``,
        then emit every batch-full and deadline-expired flush, in rung
        insertion order then arrival order.  Pure function of (state,
        now, arrivals) — the unit the replay/property tests drive."""
        for req in arrivals:
            self.submit(now, req)
        out: List[RungBatch] = []
        for key, q in list(self._queues.items()):
            while len(q) >= self.max_batch:
                out.append(self._flush(key, q.pop(self.max_batch),
                                       FLUSH_FULL, now))
            if len(q) and q.earliest_flush_by() <= now:
                out.append(self._flush(key, q.pop(), FLUSH_DEADLINE, now))
            if not len(q):
                del self._queues[key]
        return out

    def drain(self, now: float) -> List[RungBatch]:
        """Flush everything: regular full/deadline flushes first (so a
        drain at a deadline boundary classifies identically to a tick),
        then whatever remains as FLUSH_DRAIN batches."""
        out = self.tick(now)
        for key, q in list(self._queues.items()):
            if len(q):
                out.append(self._flush(key, q.pop(), FLUSH_DRAIN, now))
            del self._queues[key]
        return out

    def _flush(self, key, reqs: List[RungRequest], reason: str,
               now: float) -> RungBatch:
        if telemetry.enabled():
            telemetry.inc("serving.flush", reason=reason)
            telemetry.observe("serving.batch_size", len(reqs))
            for r in reqs:
                telemetry.observe("serving.queue_wait", now - r.arrival)
            q = self._queues.get(key)
            telemetry.gauge("serving.queue_depth", len(q) if q else 0,
                            rung=telemetry.rung_tag(key[0]))
        return RungBatch(key=key, requests=tuple(reqs), reason=reason,
                         decided_at=now)


@dataclasses.dataclass
class RungResult:
    """What a resolved future carries: per-request numerical outcome
    (``status``/``attempts``/``tau`` from the jitter ladder — a FAILED
    element flags only itself), the solution panel ``x`` in the request's
    own padded layout (None for factorize-only requests), the restricted
    per-request ``factor``, and both latency views — ``latency`` in the
    injected clock's units (deterministic under replay) and
    ``wall_latency_s`` in real seconds (what the latency histogram and
    the serving benchmark report)."""
    rid: int
    status: int
    attempts: int
    tau: float
    x: Optional[np.ndarray]
    factor: Optional[CholeskyFactor]
    latency: float
    wall_latency_s: float
    flush_reason: str
    batch_size: int
    rung: str

    def ok(self) -> bool:
        return self.status != STATUS_FAILED


class RungFuture:
    """Per-request completion handle.  ``result()`` blocks (threaded
    serving) or returns immediately once the synchronous pump finalized
    the batch; failures arrive as a FAILED-status result, never as an
    exception leaking from a rung sibling."""

    def __init__(self, rid: int):
        self.rid = rid
        self._event = threading.Event()
        self._result: Optional[RungResult] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RungResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not completed "
                               f"within {timeout}s")
        return self._result

    def _resolve(self, result: RungResult) -> None:
        self._result = result
        self._event.set()


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-not-finalized batch: unblocked device arrays
    (JAX async dispatch) plus the metadata to route results home."""
    batch: RungBatch
    factor: CholeskyFactor
    start: int
    X: Optional[jnp.ndarray]


class RungExecutor:
    """Assembles, dispatches and finalizes rung batches.

    ``dispatch`` embeds+stacks the batch onto its canonical grid and
    launches factorize (+ solve) — returning promptly with unblocked
    arrays so the caller can assemble the next batch while the device
    works.  ``finalize`` blocks on the results, restricts each element
    back to its source layout, and resolves the futures."""

    def __init__(self, impl: Optional[str] = None, tree_chunks: int = 8,
                 sweep: str = "auto", regularize=True, bucket: bool = True):
        self.impl = impl
        self.tree_chunks = tree_chunks
        self.sweep = sweep
        self.regularize = regularize
        self.bucket = bucket

    def dispatch(self, batch: RungBatch, now: float) -> _Inflight:
        cgrid, k = batch.key
        reqs = batch.requests
        with telemetry.span("serving.dispatch", rung=telemetry.rung_tag(cgrid),
                            b=len(reqs), reason=batch.reason):
            stacked, start = assemble_rung_batch(
                [r.matrix for r in reqs], cgrid)
            factor = factorize_window_batched(
                stacked, impl=self.impl, tree_chunks=self.tree_chunks,
                bucket=self.bucket, sweep=self.sweep,
                regularize=self.regularize, start_tile=start)
            X = None
            if k is not None:
                B = assemble_rung_rhs([r.rhs for r in reqs],
                                      [r.grid for r in reqs], cgrid)
                X = solve_many_batched(factor, B, impl=self.impl,
                                       start_tile=start, bucket=self.bucket)
            return _Inflight(batch=batch, factor=factor, start=start, X=X)

    def finalize(self, inflight: _Inflight, now: float) -> List[RungResult]:
        batch = inflight.batch
        cgrid = batch.key[0]
        factor, info = inflight.factor, inflight.factor.info
        with telemetry.span("serving.finalize",
                            rung=telemetry.rung_tag(cgrid),
                            b=len(batch.requests)):
            Xh = None if inflight.X is None else np.asarray(inflight.X)
            f = factor.ctsf
            results = []
            for i, req in enumerate(batch.requests):
                elem = info.element(i) if info is not None else {
                    "status": STATUS_OK, "attempts": 1, "tau": 0.0,
                    "min_pivot": float("nan"), "first_bad_tile": -1}
                x = None
                if Xh is not None:
                    x = np.asarray(restrict_rhs(Xh[i], req.grid, cgrid))
                # per-request factor stays on the canonical grid with
                # source_grid set, so later solve/selinv calls reuse the
                # rung-keyed compilations; a jittered element keeps its
                # original matrix so those solves still refine
                einfo = None
                if info is not None:
                    matrix = None
                    if info.matrix is not None and elem["tau"] > 0:
                        m = info.matrix
                        matrix = BandedCTSF(cgrid, m.Dr[i], m.R[i], m.C[i])
                    einfo = FactorInfo(
                        status=jnp.int32(elem["status"]),
                        attempts=jnp.int32(elem["attempts"]),
                        tau=jnp.float32(elem["tau"]),
                        min_pivot=jnp.float32(elem["min_pivot"]),
                        first_bad_tile=jnp.int32(elem["first_bad_tile"]),
                        matrix=matrix)
                rf = CholeskyFactor(
                    BandedCTSF(cgrid, f.Dr[i], f.R[i], f.C[i]),
                    source_grid=req.grid, info=einfo)
                wall = time.perf_counter() - req.submitted_wall \
                    if req.submitted_wall else 0.0
                res = RungResult(
                    rid=req.rid, status=elem["status"],
                    attempts=elem["attempts"], tau=elem["tau"], x=x,
                    factor=rf, latency=now - req.arrival,
                    wall_latency_s=wall, flush_reason=batch.reason,
                    batch_size=len(batch.requests),
                    rung=telemetry.rung_tag(cgrid))
                if telemetry.enabled():
                    telemetry.inc("serving.completed",
                                  outcome=_STATUS_NAMES.get(
                                      elem["status"], "unknown"))
                    telemetry.observe("serving.request_seconds", wall)
                results.append(res)
                if req.future is not None:
                    req.future._resolve(res)
            return results


class RungServer:
    """The serving front-end: thread-safe submission over the pure
    scheduler, double-buffered execution, per-request futures.

    Synchronous use (tests, replay benchmarks, ``replay``)::

        clock = SimClock()
        server = RungServer(clock=clock, max_batch=4, max_delay=2e-3)
        fut = server.submit(matrix, rhs)
        clock.advance(2e-3); server.pump()   # deadline flush
        server.drain()
        result = fut.result(timeout=0)

    Threaded use (production shape): ``start()`` runs the same ``pump``
    loop on a background thread against the real clock; ``submit`` from
    any thread; ``stop()`` drains and joins.  The numerical pipeline is
    identical — the thread only moves *when* ``pump`` runs.
    """

    def __init__(self, policy: Optional[GridBucketPolicy] = None,
                 max_batch: int = 8, max_delay: float = 10e-3,
                 impl: Optional[str] = None, tree_chunks: int = 8,
                 sweep: str = "auto", regularize=True, bucket: bool = True,
                 clock=None, poll_interval: float = 1e-3):
        self.scheduler = RungScheduler(policy=policy, max_batch=max_batch,
                                       max_delay=max_delay)
        self.executor = RungExecutor(impl=impl, tree_chunks=tree_chunks,
                                     sweep=sweep, regularize=regularize,
                                     bucket=bucket)
        self.clock = clock if clock is not None else time.monotonic
        self.poll_interval = poll_interval
        self.history: List[tuple] = []      # batch signatures, flush order
        self._rids = itertools.count()
        self._lock = threading.RLock()
        self._inflight: Optional[_Inflight] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()

    # -- submission ---------------------------------------------------------

    def submit(self, matrix: BandedCTSF, rhs=None,
               deadline: Optional[float] = None) -> RungFuture:
        """Queue one request; returns its future.  ``rhs`` is an optional
        ``(padded_n, k)`` panel in ``matrix.grid``'s padded layout;
        ``deadline`` an absolute clock time to flush by (the scheduler's
        ``max_delay`` applies regardless)."""
        if rhs is not None:
            rhs = jnp.asarray(rhs)
            if rhs.ndim != 2 or rhs.shape[0] != matrix.grid.padded_n:
                raise ValueError(
                    f"rhs must be (padded_n={matrix.grid.padded_n}, k), "
                    f"got {rhs.shape}")
        with self._lock:
            rid = next(self._rids)
            fut = RungFuture(rid)
            req = RungRequest(rid=rid, matrix=matrix, rhs=rhs,
                              deadline=deadline, future=fut,
                              submitted_wall=time.perf_counter())
            self.scheduler.submit(self.clock(), req)
        return fut

    # -- synchronous pump ---------------------------------------------------

    @property
    def pending(self) -> int:
        with self._lock:
            return self.scheduler.pending

    def next_flush_by(self) -> Optional[float]:
        with self._lock:
            return self.scheduler.next_flush_by()

    def pump(self) -> int:
        """One scheduler step at the current clock: emit due flushes and
        run them double-buffered.  Returns the number of batches
        dispatched (0 = nothing was due)."""
        now = self.clock()
        with self._lock:
            batches = self.scheduler.tick(now)
        self._run(batches)
        return len(batches)

    def drain(self) -> int:
        """Flush every queued request and finalize all in-flight work —
        after this, every submitted future is resolved."""
        now = self.clock()
        with self._lock:
            batches = self.scheduler.drain(now)
        self._run(batches)
        self._finalize_inflight()
        return len(batches)

    def _run(self, batches: List[RungBatch]) -> None:
        # double buffer: dispatch batch N+1 before blocking on batch N,
        # so host-side assembly overlaps device execution of the
        # previous batch (JAX async dispatch carries the rest)
        for batch in batches:
            self.history.append(batch.signature())
            nxt = self.executor.dispatch(batch, batch.decided_at)
            prev, self._inflight = self._inflight, nxt
            if prev is not None:
                self.executor.finalize(prev, batch.decided_at)

    def _finalize_inflight(self) -> None:
        prev, self._inflight = self._inflight, None
        if prev is not None:
            self.executor.finalize(prev, self.clock())

    # -- threaded pump (production shape; the slow e2e smoke test) ----------

    def start(self) -> None:
        """Run the pump loop on a background thread (real clock)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="rung-server-pump", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            if self.pump() == 0:
                # nothing due: settle the in-flight buffer so a lone
                # trailing batch doesn't wait for the next flush, then
                # sleep at most to the next deadline boundary
                self._finalize_inflight()
                nxt = self.next_flush_by()
                wait = self.poll_interval if nxt is None else \
                    max(0.0, min(self.poll_interval, nxt - self.clock()))
                self._stop_evt.wait(wait)

    def stop(self, drain: bool = True) -> None:
        """Stop the pump thread; by default drain first so every
        outstanding future resolves before this returns."""
        if self._thread is None:
            return
        self._stop_evt.set()
        self._thread.join(timeout=120.0)
        self._thread = None
        if drain:
            self.drain()


def replay(server: RungServer, clock: SimClock,
           arrivals: Sequence[tuple]) -> List[RungFuture]:
    """Drive a server deterministically through a timed arrival list.

    ``arrivals`` is a sequence of ``(arrival_time, matrix, rhs, deadline)``
    in nondecreasing arrival order (``rhs``/``deadline`` may be None).
    The clock advances only to arrival times and scheduler flush
    boundaries — exactly the event points a real-time driver would act
    at — then the tail is pumped dry and drained.  Returns the futures in
    submission order, all resolved.  Replaying the same list against a
    fresh server reproduces ``server.history`` and every numerical result
    bit for bit."""
    futures: List[RungFuture] = []
    for arrival, matrix, rhs, deadline in arrivals:
        while True:
            nxt = server.next_flush_by()
            if nxt is None or nxt > arrival:
                break
            clock.advance_to(nxt)
            server.pump()
        clock.advance_to(arrival)
        futures.append(server.submit(matrix, rhs, deadline=deadline))
        server.pump()
    while server.pending:
        nxt = server.next_flush_by()
        clock.advance_to(nxt)
        server.pump()
    server.drain()
    return futures


def _build_arrivals(stream, t: int = 8):
    """Materialize a ``data.synthetic.request_stream`` spec list into
    (arrival, matrix, rhs, deadline) tuples for :func:`replay`."""
    from repro.data.gmrf import make_arrowhead
    arrivals = []
    grids: Dict[tuple, Any] = {}
    for spec in stream:
        n, bw, ar = spec["case"]
        A, _st = make_arrowhead(n, bw, ar, rho=0.7, seed=spec["seed"] % 97)
        key = spec["case"]
        if key not in grids:
            grids[key] = TileGrid(_st, t=t)
        grid = grids[key]
        mat = BandedCTSF.from_sparse(A, grid)
        rng = np.random.default_rng(spec["seed"])
        rhs = None
        if spec["k"]:
            rhs = np.zeros((grid.padded_n, spec["k"]), np.float32)
            rows = np.array([grid.padded_index(i) for i in range(n)])
            rhs[rows] = rng.standard_normal((n, spec["k"])).astype(np.float32)
        arrivals.append((spec["arrival"], mat, rhs, spec["deadline"]))
    return arrivals


def main(argv=None) -> None:
    """CLI driver: replay a seeded Poisson mixed-grid stream through the
    server and print throughput/latency/flush statistics."""
    from repro.data.synthetic import request_stream
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--rate", type=float, default=2000.0,
                   help="arrivals per clock unit (Poisson)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--k", type=int, default=4, help="RHS panel width")
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-delay", type=float, default=2e-3)
    p.add_argument("--impl", default=None)
    args = p.parse_args(argv)

    cases = [(64, 6, 4), (96, 12, 8), (120, 16, 4), (136, 10, 8)]
    stream = request_stream(args.seed, cases, args.requests, rate=args.rate,
                            k=args.k)
    arrivals = _build_arrivals(stream)
    clock = SimClock()
    server = RungServer(max_batch=args.max_batch, max_delay=args.max_delay,
                        impl=args.impl, clock=clock)
    t0 = time.perf_counter()
    futures = replay(server, clock, arrivals)
    wall = time.perf_counter() - t0
    results = [f.result(timeout=0) for f in futures]
    lats = sorted(r.wall_latency_s for r in results)
    reasons: Dict[str, int] = {}
    for sig in server.history:
        reasons[sig[3]] = reasons.get(sig[3], 0) + 1
    print(f"served {len(results)} requests in {wall:.3f}s "
          f"({len(results) / wall:.1f} req/s) over "
          f"{len(server.history)} batches")
    print(f"flush reasons: {reasons}")
    print(f"wall latency p50 {lats[len(lats) // 2] * 1e3:.2f} ms, "
          f"p99 {lats[min(len(lats) - 1, int(0.99 * len(lats)))] * 1e3:.2f} "
          f"ms")
    print("statuses:", {s: sum(r.status == s for r in results)
                        for s in sorted({r.status for r in results})})


if __name__ == "__main__":
    main()
