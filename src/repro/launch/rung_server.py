"""Continuous-batching rung server: the serving front-end over the
canonical-grid bucketing (``core/gridpolicy.py``), batched factorization
(``core/cholesky.py``) and batched solves (``core/solve.py``).

Mixed-grid factorize/solve requests arrive continuously; each is
canonicalized by :class:`~repro.core.gridpolicy.GridBucketPolicy` into a
**rung** (canonical grid × RHS panel width) and queued per rung.  A rung
queue flushes as one micro-batch when any of three conditions fires:

========  ==========================================================
reason    trigger
========  ==========================================================
full      the queue reached ``max_batch`` pending requests
deadline  ``now`` passed some queued request's ``flush_by`` time
          (``min(arrival + max_delay, request deadline)``)
drain     explicit shutdown/idle drain — everything left flushes
========  ==========================================================

A flushed batch is embedded onto its canonical grid
(:func:`~repro.core.gridpolicy.assemble_rung_batch`), factorized through
the rung-keyed compiled sweep (compile count stays O(#rungs), not
O(#grids)) under the jitter ladder (``regularize=``), and solved with
per-request RHS panels (:func:`~repro.core.solve.solve_many_batched`).
Each request's future resolves with its restricted solution/factor, the
per-element :class:`~repro.core.robustness.FactorInfo` outcome (a failed
request degrades to a flagged future, never poisoning its rung siblings)
and telemetry-tagged latency.

**Determinism is the design center.**  The scheduler
(:class:`RungScheduler`) is a pure, clock-injected state machine —
``tick(now, arrivals) -> [RungBatch]`` reads no wall clock, sleeps
never, and iterates its queues in insertion order — so replaying the
same arrival stream produces the identical sequence of batch
compositions and flush reasons, and (since vmap computes batch elements
independently through one compiled executable) bit-identical numerical
results.  Tests drive it with :class:`SimClock`; production drives the
same code with ``time.monotonic``.

**Double buffering.**  The executor keeps one batch in flight: JAX's
async dispatch returns unblocked device arrays, so the server dispatches
batch N, assembles and dispatches batch N+1 on the host, and only then
blocks on N's results (:meth:`RungExecutor.finalize`) — host assembly
overlaps device execution with no threads in the data path.  (With
``regularize=`` on, the jitter ladder's one status readback synchronizes
the *factorization*; the solve sweep — the long stage for wide panels —
still overlaps.)  The optional threaded pump (:meth:`RungServer.start`)
only moves the same synchronous ``pump()`` loop off the caller's thread.

**Failure domains & overload.**  Progress never hinges on one request,
one batch, or one rung completing cleanly:

* *Admission control* — per-rung (``max_queue``) and global
  (``max_pending``) queue-depth bounds; an over-bound ``submit`` raises
  the typed :class:`RungOverloadError` (or, with ``on_overload="shed"``,
  resolves the future immediately with a ``STATUS_SHED`` result).
* *Deadline shedding* — a request whose deadline has already passed at
  flush-decision time (or on arrival) is never embedded or computed: it
  leaves as a ``FLUSH_SHED`` batch and its future resolves with
  ``STATUS_SHED`` / ``SHED_DEADLINE``.
* *Dispatch-failure isolation* — :class:`ResilientRungExecutor` wraps
  the raw executor: a throwing dispatch/finalize fails only its batch
  (retried with seeded exponential backoff + jitter, then bisected so
  poison requests are quarantined as ``STATUS_FAILED`` while survivors
  resolve normally), and a per-rung clock-injected
  :class:`CircuitBreaker` sheds load from a rung whose dispatches keep
  failing while healthy rungs serve on.
* *Graceful degradation* — under sustained overload (queue utilization
  past the high watermark, or flagged stragglers) a
  :class:`DegradationPolicy` shrinks ``max_delay``, caps batch size and
  sheds the lowest-slack queued request first, recovering hysteretically
  once utilization stays below the low watermark.

Every path stays deterministic under the injected clock: backoff burns
time through ``SimClock.advance`` offline (``time.sleep`` on the wall),
the breaker and degradation state machines read only injected ``now``s,
and fault decisions (``runtime.fault_tolerance.DispatchFaultInjector``)
hash the batch composition — so a chaos schedule replays bit-identically
(``benchmarks/bench_chaos.py`` gates it).
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.batching import RungQueue
from repro.core.cholesky import CholeskyFactor, factorize_window_batched
from repro.core.ctsf import BandedCTSF
from repro.core.gridpolicy import (GridBucketPolicy, assemble_rung_batch,
                                   assemble_rung_rhs, restrict_rhs)
from repro.core.options import SolverOptions, UNSET, resolve_options
from repro.core.robustness import (STATUS_FAILED, STATUS_OK,
                                   STATUS_RECOVERED, STATUS_SHED, FactorInfo)
from repro.core.solve import solve_many_batched
from repro.core.structure import TileGrid
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import (DispatchFaultInjector,
                                           StragglerMonitor)

__all__ = ["FLUSH_FULL", "FLUSH_DEADLINE", "FLUSH_DRAIN", "FLUSH_SHED",
           "SHED_DEADLINE", "SHED_OVERLOAD", "SHED_BREAKER", "SHED_SLACK",
           "SHED_SHUTDOWN", "RungOverloadError", "DegradationPolicy",
           "CircuitBreaker", "SimClock",
           "RungRequest", "RungBatch", "RungScheduler", "RungResult",
           "RungFuture", "RungExecutor", "ResilientRungExecutor",
           "RungServer", "replay"]

FLUSH_FULL = "full"          # queue reached max_batch
FLUSH_DEADLINE = "deadline"  # a queued request's flush_by time passed
FLUSH_DRAIN = "drain"        # explicit drain (shutdown / idle flush)
FLUSH_SHED = "shed"          # never dispatched: resolved with STATUS_SHED

# shed details (RungBatch.detail / RungResult.detail): why a request was
# shed — every STATUS_SHED result carries exactly one of these
SHED_DEADLINE = "deadline_expired"   # deadline passed before flush/arrival
SHED_OVERLOAD = "overload"           # admission bound hit (shed mode)
SHED_BREAKER = "breaker_open"        # rung circuit breaker open
SHED_SLACK = "low_slack"             # degradation evicted lowest slack
SHED_SHUTDOWN = "shutdown"           # server stopped with work pending

_STATUS_NAMES = {0: "ok", 1: "recovered", 2: "failed", 3: "shed"}


class RungOverloadError(RuntimeError):
    """Typed backpressure signal raised by ``submit`` when an admission
    bound is hit: carries which bound (``scope`` is ``"rung"`` or
    ``"global"``), the rung tag, the observed depth and the limit, so a
    client can back off or retarget without string-matching a message."""

    def __init__(self, scope: str, rung: str, depth: int, limit: int):
        super().__init__(f"{scope} queue bound hit for rung {rung}: "
                         f"{depth}/{limit} pending")
        self.scope = scope
        self.rung = rung
        self.depth = depth
        self.limit = limit


class SimClock:
    """Deterministic injectable clock for tests, replays and benchmarks:
    call it for the current time, advance it explicitly.  Time only moves
    when the driver says so — the scheduler never sleeps — which is what
    makes deadline-expiry paths unit-testable without wall-clock waits."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt {dt}")
        self.now += dt
        return self.now

    def advance_to(self, t: float) -> float:
        """Move to absolute time ``t`` (no-op if already past it)."""
        self.now = max(self.now, float(t))
        return self.now


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """How the scheduler degrades under sustained overload, and how it
    recovers.  All inputs are clock-injected and queue-derived, so the
    state trajectory is a pure function of the arrival schedule.

    Entering degradation: when queue utilization (global pending over
    ``max_pending``, or the worst per-rung depth over ``max_queue``)
    reaches ``high_watermark`` — or ``straggler_trigger`` straggler flags
    accumulate — the level steps up (at most once per ``step_dwell``).
    At level L the effective ``max_delay`` is scaled by
    ``delay_shrink**L`` (flush sooner, trade batch occupancy for
    latency), the effective ``max_batch`` by ``batch_shrink**L`` (cap
    batch size so one flush never monopolizes the device), and an
    over-bound submit sheds the lowest-slack queued request instead of
    rejecting the newcomer.

    Recovering: hysteretic — the level steps down one rung only after
    utilization has stayed at or below ``low_watermark`` for
    ``recover_dwell`` (a single quiet tick never flaps the policy)."""
    high_watermark: float = 0.75
    low_watermark: float = 0.25
    delay_shrink: float = 0.5
    batch_shrink: float = 0.5
    max_level: int = 2
    step_dwell: float = 1e-3
    recover_dwell: float = 5e-3
    straggler_trigger: int = 3


class _DegradationState:
    """Mutable level tracker for one scheduler (policy stays frozen)."""

    def __init__(self, policy: Optional[DegradationPolicy]):
        self.policy = policy
        self.level = 0
        self._last_step = float("-inf")
        self._below_since: Optional[float] = None
        self._stragglers = 0

    def _step_up(self, now: float) -> None:
        p = self.policy
        if self.level < p.max_level and now - self._last_step >= p.step_dwell:
            self.level += 1
            self._last_step = now
            self._below_since = None
            if telemetry.enabled():
                telemetry.inc("serving.degradation_step", direction="up")
                telemetry.gauge("serving.degradation_level", self.level)

    def update(self, now: float, utilization: float) -> None:
        p = self.policy
        if p is None:
            return
        if utilization >= p.high_watermark:
            self._below_since = None
            self._step_up(now)
        elif utilization <= p.low_watermark:
            if self._below_since is None:
                self._below_since = now
            elif (self.level > 0
                  and now - self._below_since >= p.recover_dwell):
                self.level -= 1
                self._below_since = now
                if telemetry.enabled():
                    telemetry.inc("serving.degradation_step",
                                  direction="down")
                    telemetry.gauge("serving.degradation_level", self.level)
        else:
            self._below_since = None

    def note_straggler(self, now: float) -> None:
        if self.policy is None:
            return
        self._stragglers += 1
        if self._stragglers >= self.policy.straggler_trigger:
            self._stragglers = 0
            self._step_up(now)


class CircuitBreaker:
    """Per-rung closed/open/half-open breaker, clock-injected.

    ``failure_threshold`` consecutive raw dispatch failures open the
    breaker; while open, :meth:`allow` is False and the server sheds the
    rung's batches (``SHED_BREAKER``) without touching the device.  After
    ``reset_timeout`` the next :meth:`allow` transitions to half-open and
    admits one trial batch: success closes the breaker, failure reopens
    it for another full timeout.  All timestamps come from the caller,
    so breaker trajectories replay deterministically under SimClock."""

    def __init__(self, failure_threshold: int = 5, reset_timeout: float = 0.1,
                 on_transition=None):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, "
                             f"got {failure_threshold}")
        if reset_timeout < 0:
            raise ValueError(f"reset_timeout must be >= 0, "
                             f"got {reset_timeout}")
        self.failure_threshold = failure_threshold
        self.reset_timeout = reset_timeout
        self.state = "closed"
        self.failures = 0                 # consecutive, since last success
        self.opened_at: Optional[float] = None
        self._on_transition = on_transition

    def _transition(self, state: str, now: float) -> None:
        if state != self.state:
            self.state = state
            if self._on_transition is not None:
                self._on_transition(state, now)

    def allow(self, now: float) -> bool:
        """May a batch be dispatched at ``now``?  (Open -> half-open once
        the reset timeout elapses, admitting the trial batch.)"""
        if self.state == "open":
            if now - self.opened_at >= self.reset_timeout:
                self._transition("half_open", now)
                return True
            return False
        return True

    def record_success(self, now: float) -> None:
        self.failures = 0
        self._transition("closed", now)

    def record_failure(self, now: float) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.failure_threshold:
            self.opened_at = now
            self._transition("open", now)


@dataclasses.dataclass
class RungRequest:
    """One queued unit of work: a matrix to factorize, optionally with an
    RHS panel to solve.  ``deadline`` is an absolute clock time (in the
    injected clock's units) the request must be flushed by; None means
    only the scheduler's ``max_delay`` bounds its wait.  ``arrival`` /
    ``flush_by`` / ``rung`` are stamped by the scheduler at submit."""
    rid: int
    matrix: BandedCTSF
    rhs: Optional[jnp.ndarray] = None
    deadline: Optional[float] = None
    future: Optional["RungFuture"] = None
    submitted_wall: float = 0.0
    arrival: float = 0.0
    flush_by: float = 0.0
    rung: Optional[TileGrid] = None

    @property
    def grid(self) -> TileGrid:
        return self.matrix.grid

    @property
    def k(self) -> Optional[int]:
        return None if self.rhs is None else int(self.rhs.shape[-1])


@dataclasses.dataclass(frozen=True)
class RungBatch:
    """One flush decision: the requests (arrival order preserved), the
    rung key ``(canonical grid, rhs width or None)``, why it flushed and
    when.  ``detail`` refines ``FLUSH_SHED`` batches with the shed reason
    (``SHED_DEADLINE`` / ``SHED_OVERLOAD`` / ``SHED_SLACK``).
    ``signature()`` is the host-comparable composition record the replay
    tests diff across runs."""
    key: Tuple[TileGrid, Optional[int]]
    requests: Tuple[RungRequest, ...]
    reason: str
    decided_at: float
    detail: str = ""

    def signature(self) -> Tuple[str, Optional[int], Tuple[int, ...], str,
                                 str]:
        return (telemetry.rung_tag(self.key[0]), self.key[1],
                tuple(r.rid for r in self.requests), self.reason,
                self.detail)


class RungScheduler:
    """Pure clock-injected micro-batching state machine.

    All methods take ``now`` explicitly; nothing here reads a clock,
    sleeps, or spawns a thread.  Rung queues live in an insertion-ordered
    dict and items in arrival order, so for a fixed sequence of
    ``submit``/``tick``/``drain`` calls the emitted batches — membership,
    order, and flush reasons — are exactly reproducible.

    Admission control: ``max_queue`` bounds each rung queue and
    ``max_pending`` bounds the global backlog (None = unbounded).  An
    over-bound ``submit`` raises :class:`RungOverloadError` — unless a
    :class:`DegradationPolicy` is active at level > 0, in which case the
    lowest-slack request (queued or the newcomer) is shed instead.
    Requests whose deadline has already passed — on arrival or at
    flush-decision time — leave as ``FLUSH_SHED`` batches, never
    consuming device time; the server resolves them with ``STATUS_SHED``.
    """

    def __init__(self, policy: Optional[GridBucketPolicy] = None,
                 max_batch: int = 8, max_delay: float = 10e-3,
                 max_queue: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 degradation: Optional[DegradationPolicy] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, "
                             f"got {max_queue}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1 or None, "
                             f"got {max_pending}")
        self.policy = policy or GridBucketPolicy()
        self.max_batch = max_batch
        self.max_delay = max_delay
        self.max_queue = max_queue
        self.max_pending = max_pending
        self.degradation = degradation
        self._deg = _DegradationState(degradation)
        self._queues: Dict[Tuple[TileGrid, Optional[int]], RungQueue] = {}
        # requests shed outside tick (arrival-expired, slack eviction):
        # grouped into FLUSH_SHED batches on the next tick
        self._shed_buffer: List[Tuple[tuple, RungRequest, str]] = []

    @property
    def pending(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + len(self._shed_buffer))

    @property
    def level(self) -> int:
        """Current degradation level (0 = healthy)."""
        return self._deg.level

    def utilization(self) -> float:
        """Backlog relative to the admission bounds in [0, 1+]: global
        pending over ``max_pending`` when set, else the worst per-rung
        depth over ``max_queue``; 0.0 when unbounded."""
        if self.max_pending is not None:
            return self.pending / self.max_pending
        if self.max_queue is not None and self._queues:
            return max(len(q) for q in self._queues.values()) / self.max_queue
        return 0.0

    def effective_max_delay(self) -> float:
        if self.degradation is None or self._deg.level == 0:
            return self.max_delay
        return self.max_delay * self.degradation.delay_shrink ** self._deg.level

    def effective_max_batch(self) -> int:
        if self.degradation is None or self._deg.level == 0:
            return self.max_batch
        shrink = self.degradation.batch_shrink ** self._deg.level
        return max(1, int(self.max_batch * shrink))

    def note_straggler(self, now: float) -> None:
        """Feed one straggler flag (from the executor's monitor) to the
        degradation policy — repeated flags step the level up."""
        self._deg.note_straggler(now)

    @staticmethod
    def _slack(req: RungRequest, now: float) -> float:
        return float("inf") if req.deadline is None else req.deadline - now

    def submit(self, now: float, req: RungRequest) -> Tuple[TileGrid,
                                                            Optional[int]]:
        """Enqueue one request under its rung key, stamping arrival and
        flush-by times.  Returns the key (useful for tests); flushing
        happens only in :meth:`tick`/:meth:`drain`, so a submit can never
        reorder ahead of earlier arrivals.  Raises
        :class:`RungOverloadError` when an admission bound is hit (and no
        degradation level is active to shed slack instead); a request
        whose deadline already passed is buffer-shed, never queued."""
        self._deg.update(now, self.utilization())
        cgrid = self.policy.canonicalize(req.matrix.grid)
        key = (cgrid, req.k)
        req.arrival = now
        req.rung = cgrid
        req.flush_by = now + self.effective_max_delay()
        if req.deadline is not None:
            req.flush_by = min(req.flush_by, float(req.deadline))
        if telemetry.enabled():
            telemetry.inc("serving.requests")
        if req.deadline is not None and now > req.deadline:
            # dead on arrival: shed without ever occupying a queue slot
            self._shed_buffer.append((key, req, SHED_DEADLINE))
            return key
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = RungQueue(maxlen=self.max_queue)
        over_rung = q.full
        over_global = (self.max_pending is not None
                       and self.pending >= self.max_pending)
        if over_rung or over_global:
            scope = "rung" if over_rung else "global"
            depth = len(q) if over_rung else self.pending
            limit = self.max_queue if over_rung else self.max_pending
            if self.degradation is not None and self._deg.level > 0:
                # degraded: make room by shedding whoever can least
                # afford to wait — the lowest-slack request, newcomer
                # included (ties keep the oldest, i.e. evict it first)
                victim = q.evict_min(lambda r: self._slack(r, now)) \
                    if len(q) else None
                if victim is None or (self._slack(req, now)
                                      < self._slack(victim, now)):
                    if victim is not None:
                        q.push(victim, victim.flush_by)
                    self._shed_buffer.append((key, req, SHED_SLACK))
                    return key
                self._shed_buffer.append((key, victim, SHED_SLACK))
            else:
                if telemetry.enabled():
                    telemetry.inc("serving.overload_reject", scope=scope)
                raise RungOverloadError(scope, telemetry.rung_tag(cgrid),
                                        depth, limit)
        q.push(req, req.flush_by)
        if telemetry.enabled():
            telemetry.gauge("serving.queue_depth", len(q),
                            rung=telemetry.rung_tag(cgrid))
        return key

    def next_flush_by(self) -> Optional[float]:
        """Earliest pending flush-by time across all rungs (None when
        idle) — the exact boundary a deterministic driver must tick at,
        and the longest a threaded pump may sleep.  Buffered sheds are
        already due (they resolve on the next tick)."""
        if self._shed_buffer:
            return float("-inf")
        if not self._queues:
            return None
        return min(q.earliest_flush_by() for q in self._queues.values())

    def tick(self, now: float,
             arrivals: Sequence[RungRequest] = ()) -> List[RungBatch]:
        """Advance the state machine to ``now``: enqueue ``arrivals``,
        shed expired/buffered requests, then emit every batch-full and
        deadline-expired flush, in rung insertion order then arrival
        order.  Pure function of (state, now, arrivals) — the unit the
        replay/property tests drive."""
        for req in arrivals:
            self.submit(now, req)
        self._deg.update(now, self.utilization())
        out: List[RungBatch] = self._drain_shed_buffer(now)
        eff_batch = self.effective_max_batch()
        for key, q in list(self._queues.items()):
            expired = q.remove_if(
                lambda r: r.deadline is not None and now > r.deadline)
            if expired:
                out.append(self._flush(key, expired, FLUSH_SHED, now,
                                       detail=SHED_DEADLINE))
            while len(q) >= eff_batch:
                out.append(self._flush(key, q.pop(eff_batch),
                                       FLUSH_FULL, now))
            if len(q) and q.earliest_flush_by() <= now:
                out.append(self._flush(key, q.pop(), FLUSH_DEADLINE, now))
            if not len(q):
                del self._queues[key]
        return out

    def drain(self, now: float) -> List[RungBatch]:
        """Flush everything: regular full/deadline flushes first (so a
        drain at a deadline boundary classifies identically to a tick),
        then whatever remains as FLUSH_DRAIN batches."""
        out = self.tick(now)
        for key, q in list(self._queues.items()):
            if len(q):
                out.append(self._flush(key, q.pop(), FLUSH_DRAIN, now))
            del self._queues[key]
        return out

    def abort(self) -> List[RungRequest]:
        """Tear down the state machine without flushing: remove and
        return every queued or buffer-shed request (the server resolves
        them terminally on shutdown).  After this, ``pending`` is 0."""
        reqs: List[RungRequest] = []
        for key, q in list(self._queues.items()):
            reqs.extend(q.pop())
            del self._queues[key]
        reqs.extend(r for _, r, _ in self._shed_buffer)
        self._shed_buffer = []
        return reqs

    def _drain_shed_buffer(self, now: float) -> List[RungBatch]:
        """Group buffered sheds into FLUSH_SHED batches per (key, detail),
        preserving buffer order."""
        if not self._shed_buffer:
            return []
        groups: Dict[Tuple[tuple, str], List[RungRequest]] = {}
        for key, req, detail in self._shed_buffer:
            groups.setdefault((key, detail), []).append(req)
        self._shed_buffer = []
        return [self._flush(key, reqs, FLUSH_SHED, now, detail=detail)
                for (key, detail), reqs in groups.items()]

    def _flush(self, key, reqs: List[RungRequest], reason: str,
               now: float, detail: str = "") -> RungBatch:
        if telemetry.enabled():
            telemetry.inc("serving.flush", reason=reason)
            telemetry.observe("serving.batch_size", len(reqs))
            for r in reqs:
                telemetry.observe("serving.queue_wait", now - r.arrival)
            q = self._queues.get(key)
            telemetry.gauge("serving.queue_depth", len(q) if q else 0,
                            rung=telemetry.rung_tag(key[0]))
        return RungBatch(key=key, requests=tuple(reqs), reason=reason,
                         decided_at=now, detail=detail)


@dataclasses.dataclass
class RungResult:
    """What a resolved future carries: per-request numerical outcome
    (``status``/``attempts``/``tau`` from the jitter ladder — a FAILED
    element flags only itself), the solution panel ``x`` in the request's
    own padded layout (None for factorize-only requests), the restricted
    per-request ``factor``, and both latency views — ``latency`` in the
    injected clock's units (deterministic under replay) and
    ``wall_latency_s`` in real seconds (what the latency histogram and
    the serving benchmark report).

    ``status`` is always one of the closed set ``STATUS_OK`` /
    ``STATUS_RECOVERED`` (ladder-jittered, or served only after dispatch
    retries/bisection) / ``STATUS_FAILED`` (numerically failed, or
    quarantined as dispatch poison — ``x``/``factor`` are None) /
    ``STATUS_SHED`` (never computed; ``detail`` says why)."""
    rid: int
    status: int
    attempts: int
    tau: float
    x: Optional[np.ndarray]
    factor: Optional[CholeskyFactor]
    latency: float
    wall_latency_s: float
    flush_reason: str
    batch_size: int
    rung: str
    detail: str = ""

    def ok(self) -> bool:
        return self.status in (STATUS_OK, STATUS_RECOVERED)


class RungFuture:
    """Per-request completion handle.  ``result()`` blocks (threaded
    serving) or returns immediately once the synchronous pump finalized
    the batch; failures arrive as a FAILED-status result, never as an
    exception leaking from a rung sibling.

    Resolution is strictly once: the first ``_resolve`` wins, later ones
    are counted (``duplicate_resolves``) and dropped — the conservation
    invariant the chaos harness and property tests assert on."""

    def __init__(self, rid: int):
        self.rid = rid
        self._event = threading.Event()
        self._result: Optional[RungResult] = None
        self._resolve_lock = threading.Lock()
        self.duplicate_resolves = 0

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RungResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not completed "
                               f"within {timeout}s")
        return self._result

    def _resolve(self, result: RungResult) -> bool:
        with self._resolve_lock:
            if self._event.is_set():
                self.duplicate_resolves += 1
                return False
            self._result = result
            self._event.set()
            return True


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-not-finalized batch: unblocked device arrays
    (JAX async dispatch) plus the metadata to route results home."""
    batch: RungBatch
    factor: CholeskyFactor
    start: int
    X: Optional[jnp.ndarray]


class RungExecutor:
    """Assembles, dispatches and finalizes rung batches.

    ``dispatch`` embeds+stacks the batch onto its canonical grid and
    launches factorize (+ solve) — returning promptly with unblocked
    arrays so the caller can assemble the next batch while the device
    works.  ``finalize`` blocks on the results, restricts each element
    back to its source layout, and resolves the futures."""

    def __init__(self, impl=UNSET, tree_chunks: int = 8,
                 sweep=UNSET, regularize=UNSET, bucket: bool = True,
                 options: Optional[SolverOptions] = None):
        opts = resolve_options(options, _where="RungExecutor",
                               impl=impl, sweep=sweep, regularize=regularize)
        # the server's historical default is the jitter ladder ON; an
        # explicit options object is respected verbatim
        if options is None and regularize is UNSET:
            opts = opts.replace(regularize=True)
        self.options = opts
        self.tree_chunks = tree_chunks
        self.bucket = bucket

    def dispatch(self, batch: RungBatch, now: float) -> _Inflight:
        cgrid, k = batch.key
        reqs = batch.requests
        with telemetry.span("serving.dispatch", rung=telemetry.rung_tag(cgrid),
                            b=len(reqs), reason=batch.reason):
            stacked, start = assemble_rung_batch(
                [r.matrix for r in reqs], cgrid)
            factor = factorize_window_batched(
                stacked, tree_chunks=self.tree_chunks,
                bucket=self.bucket, start_tile=start, options=self.options)
            X = None
            if k is not None:
                B = assemble_rung_rhs([r.rhs for r in reqs],
                                      [r.grid for r in reqs], cgrid)
                X = solve_many_batched(factor, B, start_tile=start,
                                       bucket=self.bucket,
                                       options=self.options)
            return _Inflight(batch=batch, factor=factor, start=start, X=X)

    def finalize(self, inflight: _Inflight, now: float) -> List[RungResult]:
        batch = inflight.batch
        cgrid = batch.key[0]
        factor, info = inflight.factor, inflight.factor.info
        with telemetry.span("serving.finalize",
                            rung=telemetry.rung_tag(cgrid),
                            b=len(batch.requests)):
            Xh = None if inflight.X is None else np.asarray(inflight.X)
            f = factor.ctsf
            results = []
            for i, req in enumerate(batch.requests):
                elem = info.element(i) if info is not None else {
                    "status": STATUS_OK, "attempts": 1, "tau": 0.0,
                    "min_pivot": float("nan"), "first_bad_tile": -1}
                x = None
                if Xh is not None:
                    x = np.asarray(restrict_rhs(Xh[i], req.grid, cgrid))
                # per-request factor stays on the canonical grid with
                # source_grid set, so later solve/selinv calls reuse the
                # rung-keyed compilations; a jittered element keeps its
                # original matrix so those solves still refine
                einfo = None
                if info is not None:
                    matrix = None
                    if info.matrix is not None and elem["tau"] > 0:
                        m = info.matrix
                        matrix = BandedCTSF(cgrid, m.Dr[i], m.R[i], m.C[i])
                    einfo = FactorInfo(
                        status=jnp.int32(elem["status"]),
                        attempts=jnp.int32(elem["attempts"]),
                        tau=jnp.float32(elem["tau"]),
                        min_pivot=jnp.float32(elem["min_pivot"]),
                        first_bad_tile=jnp.int32(elem["first_bad_tile"]),
                        matrix=matrix)
                rf = CholeskyFactor(
                    BandedCTSF(cgrid, f.Dr[i], f.R[i], f.C[i]),
                    source_grid=req.grid, info=einfo)
                wall = time.perf_counter() - req.submitted_wall \
                    if req.submitted_wall else 0.0
                res = RungResult(
                    rid=req.rid, status=elem["status"],
                    attempts=elem["attempts"], tau=elem["tau"], x=x,
                    factor=rf, latency=now - req.arrival,
                    wall_latency_s=wall, flush_reason=batch.reason,
                    batch_size=len(batch.requests),
                    rung=telemetry.rung_tag(cgrid))
                if telemetry.enabled():
                    telemetry.inc("serving.completed",
                                  outcome=_STATUS_NAMES.get(
                                      elem["status"], "unknown"))
                    telemetry.observe("serving.request_seconds", wall)
                results.append(res)
                if req.future is not None:
                    req.future._resolve(res)
            return results


@dataclasses.dataclass
class _RInflight:
    """Resilient wrapper around one in-flight batch.  ``raw`` is None
    when the first dispatch attempt failed (or was never made) — the
    recovery ladder then runs entirely inside ``finalize``."""
    batch: RungBatch
    raw: Optional[_Inflight]
    dispatched_at: float


class ResilientRungExecutor:
    """Dispatch-failure isolation around a raw :class:`RungExecutor`.

    A throwing ``dispatch``/``finalize`` fails only its batch, and a
    failed batch walks a recovery ladder instead of raising to the pump:

    1. **Retry** the whole batch up to ``max_retries`` times with seeded
       exponential backoff + jitter (delays burn through ``sleep_fn`` —
       ``SimClock.advance`` offline, ``time.sleep`` on the wall — so
       replays stay bit-identical).
    2. **Bisect**: split the batch and execute the halves independently,
       recursing on failures, so poison requests are isolated in
       O(log batch) dispatches while healthy siblings resolve normally.
    3. **Quarantine**: a singleton that still fails resolves with a
       ``STATUS_FAILED`` result (``detail="dispatch_failed"``, no
       solution/factor) — never an exception.

    A per-rung :class:`CircuitBreaker` counts consecutive raw failures;
    while open, :meth:`allow` tells the server to shed the rung's batches
    (``SHED_BREAKER``) without touching the device.  A
    :class:`~repro.runtime.fault_tolerance.StragglerMonitor` watches
    clock-accounted per-batch device time and feeds flags to the
    scheduler's degradation policy via ``on_straggler``.  An optional
    :class:`~repro.runtime.fault_tolerance.DispatchFaultInjector` (the
    chaos harness) raises seeded faults and injects stragglers ahead of
    real dispatches.

    Every decision — backoff jitter, fault draws — hashes the batch
    composition (rung tag + member rids + attempt), never a call counter
    or wall clock, so a chaos schedule replays exactly.  Noteworthy
    transitions append to the shared ``events`` list the server exposes
    (and the chaos benchmark diffs across replay passes).
    """

    def __init__(self, inner: RungExecutor, clock, sleep_fn,
                 events: Optional[List[tuple]] = None, max_retries: int = 2,
                 backoff_base: float = 1e-3, backoff_factor: float = 2.0,
                 seed: int = 0, breaker_threshold: int = 5,
                 breaker_reset: float = 0.1,
                 injector: Optional[DispatchFaultInjector] = None,
                 straggler_factor: float = 3.0, on_straggler=None):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.inner = inner
        self.clock = clock
        self.sleep_fn = sleep_fn
        self.events = events if events is not None else []
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_factor = backoff_factor
        self.seed = seed
        self.breaker_threshold = breaker_threshold
        self.breaker_reset = breaker_reset
        self.injector = injector
        self.monitor = StragglerMonitor(factor=straggler_factor)
        self.on_straggler = on_straggler
        self._breakers: Dict[tuple, CircuitBreaker] = {}
        self._step = 0

    # -- breaker ------------------------------------------------------------

    def breaker(self, key) -> CircuitBreaker:
        br = self._breakers.get(key)
        if br is None:
            tag = telemetry.rung_tag(key[0])

            def on_transition(state, now, _tag=tag):
                self.events.append(("breaker", _tag, state, round(now, 9)))
                if telemetry.enabled():
                    telemetry.inc("serving.breaker_transition", state=state,
                                  rung=_tag)

            br = self._breakers[key] = CircuitBreaker(
                failure_threshold=self.breaker_threshold,
                reset_timeout=self.breaker_reset,
                on_transition=on_transition)
        return br

    def allow(self, key, now: float) -> bool:
        """May a batch for ``key`` be dispatched at ``now``?  False means
        the rung's breaker is open — the server sheds the batch."""
        return self.breaker(key).allow(now)

    # -- deterministic backoff ---------------------------------------------

    def _backoff(self, tag: str, rids: Tuple[int, ...], attempt: int) -> float:
        """attempt-th retry delay: exponential base with a jitter factor
        in [0, 1) drawn from a hash of (seed, batch composition, attempt)
        — same batch, same delays, every replay."""
        ss = np.random.SeedSequence(
            [self.seed, 29, attempt, len(rids), *rids,
             *(ord(c) for c in tag[:16])])
        jitter = float(np.random.default_rng(ss).random())
        return self.backoff_base * self.backoff_factor ** (attempt - 1) \
            * (1.0 + jitter)

    # -- raw attempts -------------------------------------------------------

    def _raw_dispatch(self, batch: RungBatch, now: float,
                      attempt: int) -> _Inflight:
        if self.injector is not None:
            self.injector.before_dispatch(
                telemetry.rung_tag(batch.key[0]),
                tuple(r.rid for r in batch.requests), attempt)
        return self.inner.dispatch(batch, now)

    def _raw_finalize(self, batch: RungBatch, raw,
                      now: float) -> List[RungResult]:
        tag = telemetry.rung_tag(batch.key[0])
        rids = tuple(r.rid for r in batch.requests)
        t0 = self.clock()
        if self.injector is not None:
            extra = self.injector.straggler_extra_for(tag, rids)
            if extra > 0:
                self.sleep_fn(extra)  # the injected stall burns clock time
        results = self.inner.finalize(raw, now)
        dt = self.clock() - t0
        self._step += 1
        if telemetry.enabled():
            telemetry.observe("serving.device_seconds", dt, rung=tag)
        if self.monitor.record(self._step, dt):
            self.events.append(("straggler", tag, self._step, round(dt, 9)))
            if telemetry.enabled():
                telemetry.inc("serving.straggler", rung=tag)
                telemetry.gauge("serving.straggler_seconds", dt, rung=tag)
            if self.on_straggler is not None:
                self.on_straggler(self.clock())
        return results

    def _note_failure(self, batch: RungBatch, now: float, err: Exception,
                      attempt: int) -> None:
        tag = telemetry.rung_tag(batch.key[0])
        rids = tuple(r.rid for r in batch.requests)
        self.events.append(("fail", tag, rids, attempt,
                            type(err).__name__))
        self.breaker(batch.key).record_failure(now)
        if telemetry.enabled():
            telemetry.inc("serving.dispatch_failure", kind=type(err).__name__,
                          rung=tag)

    # -- executor interface -------------------------------------------------

    def dispatch(self, batch: RungBatch, now: float) -> _RInflight:
        """First dispatch attempt.  On success the raw in-flight batch
        rides along (double buffering preserved); on failure the error is
        recorded and recovery is deferred to :meth:`finalize`."""
        try:
            raw = self._raw_dispatch(batch, now, attempt=0)
            return _RInflight(batch=batch, raw=raw, dispatched_at=now)
        except Exception as e:  # noqa: BLE001 — isolation boundary
            self._note_failure(batch, now, e, attempt=0)
            return _RInflight(batch=batch, raw=None, dispatched_at=now)

    def finalize(self, rin: _RInflight, now: float) -> List[RungResult]:
        """Block on the in-flight batch; on any failure run the recovery
        ladder.  Always returns one result per request, all futures
        resolved — exceptions stop at this boundary."""
        batch = rin.batch
        if rin.raw is not None:
            try:
                results = self._raw_finalize(batch, rin.raw, now)
                self.breaker(batch.key).record_success(self.clock())
                return results
            except Exception as e:  # noqa: BLE001 — isolation boundary
                self._note_failure(batch, self.clock(), e, attempt=0)
        return self._recover(batch, self.max_retries)

    # -- recovery ladder ----------------------------------------------------

    def _try_once(self, batch: RungBatch, attempt: int) -> List[RungResult]:
        now = self.clock()
        raw = self._raw_dispatch(batch, now, attempt)
        return self._raw_finalize(batch, raw, self.clock())

    @staticmethod
    def _mark_recovered(results: List[RungResult]) -> List[RungResult]:
        # served, but only after dispatch retries/bisection — surface
        # that in the status (OK -> RECOVERED; ladder RECOVERED stays)
        for res in results:
            if res.status == STATUS_OK:
                res.status = STATUS_RECOVERED
        return results

    def _quarantine(self, batch: RungBatch, attempts: int) -> RungResult:
        req = batch.requests[0]
        tag = telemetry.rung_tag(batch.key[0])
        t = self.clock()
        self.events.append(("quarantine", tag, req.rid, round(t, 9)))
        if telemetry.enabled():
            telemetry.inc("serving.quarantine", rung=tag)
            telemetry.inc("serving.completed", outcome="failed")
        wall = time.perf_counter() - req.submitted_wall \
            if req.submitted_wall else 0.0
        res = RungResult(rid=req.rid, status=STATUS_FAILED,
                         attempts=attempts, tau=0.0, x=None, factor=None,
                         latency=t - req.arrival, wall_latency_s=wall,
                         flush_reason=batch.reason, batch_size=1, rung=tag,
                         detail="dispatch_failed")
        if req.future is not None:
            req.future._resolve(res)
        return res

    def _recover(self, batch: RungBatch, retries: int) -> List[RungResult]:
        """The batch's initial attempt already failed.  Retry whole with
        backoff, then bisect, then quarantine the singleton."""
        tag = telemetry.rung_tag(batch.key[0])
        rids = tuple(r.rid for r in batch.requests)
        for attempt in range(1, retries + 1):
            self.sleep_fn(self._backoff(tag, rids, attempt))
            self.events.append(("retry", tag, rids, attempt,
                                round(self.clock(), 9)))
            if telemetry.enabled():
                telemetry.inc("serving.retry", rung=tag)
            try:
                results = self._try_once(batch, attempt)
                self.breaker(batch.key).record_success(self.clock())
                return self._mark_recovered(results)
            except Exception as e:  # noqa: BLE001 — isolation boundary
                self._note_failure(batch, self.clock(), e, attempt)
        if len(batch.requests) == 1:
            return [self._quarantine(batch, attempts=retries + 1)]
        self.events.append(("bisect", tag, rids, round(self.clock(), 9)))
        if telemetry.enabled():
            telemetry.inc("serving.bisect", rung=tag)
        mid = len(batch.requests) // 2
        out: List[RungResult] = []
        for part in (batch.requests[:mid], batch.requests[mid:]):
            sub = dataclasses.replace(batch, requests=tuple(part))
            try:
                # past the transient window (attempt > max_retries): only
                # genuinely poison sub-batches keep failing here
                results = self._try_once(sub, attempt=retries + 1)
                self.breaker(batch.key).record_success(self.clock())
                out.extend(self._mark_recovered(results))
            except Exception as e:  # noqa: BLE001 — isolation boundary
                self._note_failure(sub, self.clock(), e, attempt=retries + 1)
                out.extend(self._recover(sub, retries=1))
        return out


class RungServer:
    """The serving front-end: thread-safe submission over the pure
    scheduler, double-buffered execution, per-request futures.

    Synchronous use (tests, replay benchmarks, ``replay``)::

        clock = SimClock()
        server = RungServer(clock=clock, max_batch=4, max_delay=2e-3)
        fut = server.submit(matrix, rhs)
        clock.advance(2e-3); server.pump()   # deadline flush
        server.drain()
        result = fut.result(timeout=0)

    Threaded use (production shape): ``start()`` runs the same ``pump``
    loop on a background thread against the real clock; ``submit`` from
    any thread; ``stop()`` drains and joins.  The numerical pipeline is
    identical — the thread only moves *when* ``pump`` runs.
    """

    def __init__(self, policy: Optional[GridBucketPolicy] = None,
                 max_batch: int = 8, max_delay: float = 10e-3,
                 impl=UNSET, tree_chunks: int = 8,
                 sweep=UNSET, regularize=UNSET, bucket: bool = True,
                 options: Optional[SolverOptions] = None,
                 clock=None, poll_interval: float = 1e-3,
                 max_queue: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 degradation: Optional[DegradationPolicy] = None,
                 on_overload: str = "raise", max_retries: int = 2,
                 backoff_base: float = 1e-3, backoff_factor: float = 2.0,
                 breaker_threshold: int = 5, breaker_reset: float = 0.1,
                 injector="auto", straggler_factor: float = 3.0,
                 seed: int = 0, executor: Optional[RungExecutor] = None):
        if on_overload not in ("raise", "shed"):
            raise ValueError(f"on_overload must be 'raise' or 'shed', "
                             f"got {on_overload!r}")
        opts = resolve_options(options, _where="RungServer",
                               impl=impl, sweep=sweep, regularize=regularize)
        if options is None and regularize is UNSET:
            opts = opts.replace(regularize=True)
        self.options = opts
        self.scheduler = RungScheduler(policy=policy, max_batch=max_batch,
                                       max_delay=max_delay,
                                       max_queue=max_queue,
                                       max_pending=max_pending,
                                       degradation=degradation)
        self.clock = clock if clock is not None else time.monotonic
        self.on_overload = on_overload
        self.poll_interval = poll_interval
        self.history: List[tuple] = []      # batch signatures, flush order
        self.events: List[tuple] = []       # resilience events, in order
        if injector == "auto":
            # opt-in chaos for CI legs / soak runs: REPRO_CHAOS_SEED=<int>
            # arms a seeded transient+straggler injector on every server
            cseed = os.environ.get("REPRO_CHAOS_SEED")
            injector = None if cseed is None else DispatchFaultInjector(
                seed=int(cseed), transient_rate=0.1, transient_attempts=1,
                straggler_rate=0.05, straggler_extra=5e-3)
        # offline (SimClock) runs burn waits by advancing the clock —
        # deterministic; wall-clock runs really sleep
        sleep_fn = clock.advance if isinstance(clock, SimClock) \
            else time.sleep
        inner = executor if executor is not None else RungExecutor(
            tree_chunks=tree_chunks, bucket=bucket, options=opts)
        self.executor = ResilientRungExecutor(
            inner, clock=self.clock, sleep_fn=sleep_fn, events=self.events,
            max_retries=max_retries, backoff_base=backoff_base,
            backoff_factor=backoff_factor, seed=seed,
            breaker_threshold=breaker_threshold, breaker_reset=breaker_reset,
            injector=injector, straggler_factor=straggler_factor,
            on_straggler=self._on_straggler)
        self._rids = itertools.count()
        self._lock = threading.RLock()
        self._outstanding: Dict[int, RungFuture] = {}
        self._inflight: Optional[_RInflight] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()

    def _on_straggler(self, now: float) -> None:
        with self._lock:
            self.scheduler.note_straggler(now)

    # -- submission ---------------------------------------------------------

    def submit(self, matrix: BandedCTSF, rhs=None,
               deadline: Optional[float] = None,
               on_overload: Optional[str] = None) -> RungFuture:
        """Queue one request; returns its future.  ``rhs`` is an optional
        ``(padded_n, k)`` panel in ``matrix.grid``'s padded layout;
        ``deadline`` an absolute clock time to flush by (the scheduler's
        ``max_delay`` applies regardless).

        When an admission bound is hit, ``on_overload`` (per-call, else
        the server default) decides: ``"raise"`` propagates the typed
        :class:`RungOverloadError`; ``"shed"`` returns a future already
        resolved with ``STATUS_SHED`` / ``SHED_OVERLOAD``."""
        if rhs is not None:
            rhs = jnp.asarray(rhs)
            if rhs.ndim != 2 or rhs.shape[0] != matrix.grid.padded_n:
                raise ValueError(
                    f"rhs must be (padded_n={matrix.grid.padded_n}, k), "
                    f"got {rhs.shape}")
        mode = on_overload if on_overload is not None else self.on_overload
        with self._lock:
            rid = next(self._rids)
            fut = RungFuture(rid)
            req = RungRequest(rid=rid, matrix=matrix, rhs=rhs,
                              deadline=deadline, future=fut,
                              submitted_wall=time.perf_counter())
            now = self.clock()
            try:
                self.scheduler.submit(now, req)
            except RungOverloadError:
                if mode == "raise":
                    raise
                fut._resolve(self._shed_result(req, SHED_OVERLOAD, now))
                if telemetry.enabled():
                    telemetry.inc("serving.shed", detail=SHED_OVERLOAD)
                return fut
            self._outstanding[rid] = fut
        return fut

    # -- synchronous pump ---------------------------------------------------

    @property
    def pending(self) -> int:
        with self._lock:
            return self.scheduler.pending

    def next_flush_by(self) -> Optional[float]:
        with self._lock:
            return self.scheduler.next_flush_by()

    def pump(self) -> int:
        """One scheduler step at the current clock: emit due flushes and
        run them double-buffered.  Returns the number of batches
        emitted (0 = nothing was due; shed batches count too)."""
        now = self.clock()
        with self._lock:
            batches = self.scheduler.tick(now)
            if len(self._outstanding) > 4 * max(
                    1, self.scheduler.max_batch):
                self._outstanding = {rid: f for rid, f
                                     in self._outstanding.items()
                                     if not f.done()}
        self._run(batches)
        return len(batches)

    def drain(self) -> int:
        """Flush every queued request and finalize all in-flight work —
        after this, every submitted future is resolved."""
        now = self.clock()
        with self._lock:
            batches = self.scheduler.drain(now)
        self._run(batches)
        self._finalize_inflight()
        return len(batches)

    def _shed_result(self, req: RungRequest, detail: str,
                     now: float) -> RungResult:
        wall = time.perf_counter() - req.submitted_wall \
            if req.submitted_wall else 0.0
        rung = telemetry.rung_tag(req.rung) if req.rung is not None \
            else telemetry.rung_tag(req.matrix.grid)
        return RungResult(rid=req.rid, status=STATUS_SHED, attempts=0,
                          tau=0.0, x=None, factor=None,
                          latency=now - req.arrival, wall_latency_s=wall,
                          flush_reason=FLUSH_SHED, batch_size=1, rung=rung,
                          detail=detail)

    def _resolve_shed(self, batch: RungBatch,
                      detail: Optional[str] = None) -> None:
        """Resolve every request of a never-dispatched batch with an
        explicit STATUS_SHED result — shedding is always a result, never
        a dropped or hanging future."""
        detail = detail if detail is not None else \
            (batch.detail or SHED_DEADLINE)
        for req in batch.requests:
            res = self._shed_result(req, detail, batch.decided_at)
            if req.future is not None:
                req.future._resolve(res)
        if telemetry.enabled():
            telemetry.inc("serving.shed", len(batch.requests), detail=detail)
            telemetry.inc("serving.completed", len(batch.requests),
                          outcome="shed")

    def _run(self, batches: List[RungBatch]) -> None:
        # double buffer: dispatch batch N+1 before blocking on batch N,
        # so host-side assembly overlaps device execution of the
        # previous batch (JAX async dispatch carries the rest)
        for batch in batches:
            self.history.append(batch.signature())
            if batch.reason == FLUSH_SHED:
                self._resolve_shed(batch)
                continue
            if not self.executor.allow(batch.key, batch.decided_at):
                # rung breaker open: shed without touching the device —
                # healthy rungs keep dispatching around it
                self.events.append(
                    ("breaker_shed", telemetry.rung_tag(batch.key[0]),
                     tuple(r.rid for r in batch.requests),
                     round(batch.decided_at, 9)))
                self._resolve_shed(batch, detail=SHED_BREAKER)
                continue
            nxt = self.executor.dispatch(batch, batch.decided_at)
            prev, self._inflight = self._inflight, nxt
            if prev is not None:
                self.executor.finalize(prev, batch.decided_at)

    def _finalize_inflight(self) -> None:
        prev, self._inflight = self._inflight, None
        if prev is not None:
            self.executor.finalize(prev, self.clock())

    # -- threaded pump (production shape; the slow e2e smoke test) ----------

    def start(self) -> None:
        """Run the pump loop on a background thread (real clock)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="rung-server-pump", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            if self.pump() == 0:
                # nothing due: settle the in-flight buffer so a lone
                # trailing batch doesn't wait for the next flush, then
                # sleep at most to the next deadline boundary
                self._finalize_inflight()
                nxt = self.next_flush_by()
                wait = self.poll_interval if nxt is None else \
                    max(0.0, min(self.poll_interval, nxt - self.clock()))
                self._stop_evt.wait(wait)

    def stop(self, drain: bool = True, timeout: float = 120.0) -> None:
        """Stop the pump thread and leave **no future unresolved**.

        By default the queue is drained first so pending work completes
        normally.  If the pump thread does not join within ``timeout``
        (a wedged executor — e.g. a dispatch stuck in a device call),
        draining would wedge this caller too: instead the scheduler is
        aborted and every still-unresolved future — queued, in-flight,
        or mid-dispatch — resolves with a terminal ``STATUS_SHED`` /
        ``SHED_SHUTDOWN`` result.  Either way ``stop`` returns with zero
        outstanding futures (asserted), so no client blocks forever on a
        server that no longer exists."""
        if self._thread is None:
            return
        self._stop_evt.set()
        self._thread.join(timeout=timeout)
        wedged = self._thread.is_alive()
        self._thread = None
        if drain and not wedged:
            self.drain()
        # terminal sweep: whatever is still unresolved (everything, when
        # wedged; shed buffers and races otherwise) resolves as shed
        with self._lock:
            now = self.clock()
            for req in self.scheduler.abort():
                if req.future is not None and not req.future.done():
                    req.future._resolve(
                        self._shed_result(req, SHED_SHUTDOWN, now))
            unresolved = [f for f in self._outstanding.values()
                          if not f.done()]
            for fut in unresolved:
                res = RungResult(
                    rid=fut.rid, status=STATUS_SHED, attempts=0, tau=0.0,
                    x=None, factor=None, latency=0.0, wall_latency_s=0.0,
                    flush_reason=FLUSH_SHED, batch_size=1, rung="",
                    detail=SHED_SHUTDOWN)
                fut._resolve(res)
            if unresolved and telemetry.enabled():
                telemetry.inc("serving.shed", len(unresolved),
                              detail=SHED_SHUTDOWN)
            leftover = [f.rid for f in self._outstanding.values()
                        if not f.done()]
            assert not leftover, \
                f"stop() left futures unresolved: {leftover}"
            self._outstanding = {}


def replay(server: RungServer, clock: SimClock,
           arrivals: Sequence[tuple]) -> List[RungFuture]:
    """Drive a server deterministically through a timed arrival list.

    ``arrivals`` is a sequence of ``(arrival_time, matrix, rhs, deadline)``
    in nondecreasing arrival order (``rhs``/``deadline`` may be None).
    The clock advances only to arrival times and scheduler flush
    boundaries — exactly the event points a real-time driver would act
    at — then the tail is pumped dry and drained.  Returns the futures in
    submission order, all resolved.  Replaying the same list against a
    fresh server reproduces ``server.history`` and every numerical result
    bit for bit."""
    futures: List[RungFuture] = []
    for arrival, matrix, rhs, deadline in arrivals:
        while True:
            nxt = server.next_flush_by()
            if nxt is None or nxt > arrival:
                break
            clock.advance_to(nxt)
            server.pump()
        clock.advance_to(arrival)
        futures.append(server.submit(matrix, rhs, deadline=deadline))
        server.pump()
    while server.pending:
        nxt = server.next_flush_by()
        clock.advance_to(nxt)
        server.pump()
    server.drain()
    return futures


def _build_arrivals(stream, t: int = 8):
    """Materialize a ``data.synthetic.request_stream`` spec list into
    (arrival, matrix, rhs, deadline) tuples for :func:`replay`."""
    from repro.data.gmrf import make_arrowhead
    arrivals = []
    grids: Dict[tuple, Any] = {}
    for spec in stream:
        n, bw, ar = spec["case"]
        A, _st = make_arrowhead(n, bw, ar, rho=0.7, seed=spec["seed"] % 97)
        key = spec["case"]
        if key not in grids:
            grids[key] = TileGrid(_st, t=t)
        grid = grids[key]
        mat = BandedCTSF.from_sparse(A, grid)
        rng = np.random.default_rng(spec["seed"])
        rhs = None
        if spec["k"]:
            rhs = np.zeros((grid.padded_n, spec["k"]), np.float32)
            rows = np.array([grid.padded_index(i) for i in range(n)])
            rhs[rows] = rng.standard_normal((n, spec["k"])).astype(np.float32)
        arrivals.append((spec["arrival"], mat, rhs, spec["deadline"]))
    return arrivals


def main(argv=None) -> None:
    """CLI driver: replay a seeded Poisson mixed-grid stream through the
    server and print throughput/latency/flush statistics."""
    from repro.data.synthetic import request_stream
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--rate", type=float, default=2000.0,
                   help="arrivals per clock unit (Poisson)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--k", type=int, default=4, help="RHS panel width")
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-delay", type=float, default=2e-3)
    p.add_argument("--impl", default=None)
    args = p.parse_args(argv)

    cases = [(64, 6, 4), (96, 12, 8), (120, 16, 4), (136, 10, 8)]
    stream = request_stream(args.seed, cases, args.requests, rate=args.rate,
                            k=args.k)
    arrivals = _build_arrivals(stream)
    clock = SimClock()
    server = RungServer(max_batch=args.max_batch, max_delay=args.max_delay,
                        options=SolverOptions(impl=args.impl,
                                              regularize=True),
                        clock=clock)
    t0 = time.perf_counter()
    futures = replay(server, clock, arrivals)
    wall = time.perf_counter() - t0
    results = [f.result(timeout=0) for f in futures]
    lats = sorted(r.wall_latency_s for r in results)
    reasons: Dict[str, int] = {}
    for sig in server.history:
        reasons[sig[3]] = reasons.get(sig[3], 0) + 1
    print(f"served {len(results)} requests in {wall:.3f}s "
          f"({len(results) / wall:.1f} req/s) over "
          f"{len(server.history)} batches")
    print(f"flush reasons: {reasons}")
    print(f"wall latency p50 {lats[len(lats) // 2] * 1e3:.2f} ms, "
          f"p99 {lats[min(len(lats) - 1, int(0.99 * len(lats)))] * 1e3:.2f} "
          f"ms")
    print("statuses:", {s: sum(r.status == s for r in results)
                        for s in sorted({r.status for r in results})})


if __name__ == "__main__":
    main()
