"""Serving driver: batched prefill + autoregressive decode.

``python -m repro.launch.serve --arch qwen2-7b --prompt-len 64 --gen 32``
serves a reduced model on local devices; the full-config serve graphs are
exercised (lower+compile) by launch/dryrun.py on the production meshes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, RunConfig
from repro.models.registry import get_model
from repro.runtime import telemetry
from repro.sharding.partition import make_rules
from .mesh import make_local_mesh
from .train import reduce_config

__all__ = ["Server", "main"]


def _pad_caches(caches, target_len: int):
    """Grow attention caches from prefill length to the serving window."""

    def pad(path, x):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        name = keys[-1]
        if name in ("k", "v") and x.ndim == 5 and x.shape[2] < target_len:
            padw = [(0, 0)] * 5
            padw[2] = (0, target_len - x.shape[2])
            return jnp.pad(x, padw)
        return x

    return jax.tree_util.tree_map_with_path(pad, caches)


class Server:
    """Minimal batched-request server: prefill once, decode greedily."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, max_len: int = 512,
                 seed: int = 0):
        self.cfg, self.run, self.max_len = cfg, run, max_len
        self.api = get_model(cfg)
        self.mesh = make_local_mesh()
        self.rules = make_rules(self.mesh, cfg, run)
        self.params = self.api.init(jax.random.PRNGKey(seed), cfg, max_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, cfg, run))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, cfg, run))

    def generate(self, batch: Dict[str, np.ndarray], gen_len: int
                 ) -> Dict[str, Any]:
        with telemetry.span("serve.request", b=batch["tokens"].shape[0],
                            gen_len=gen_len):
            t0 = time.perf_counter()
            logits, caches = self._prefill(self.params, batch)
            caches = _pad_caches(caches, self.max_len)
            prefill_t = time.perf_counter() - t0
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out = [tok]
            pos = batch["tokens"].shape[1]
            t0 = time.perf_counter()
            for i in range(gen_len - 1):
                logits, caches = self._decode(self.params, caches, tok,
                                              jnp.asarray(pos + i, jnp.int32))
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                out.append(tok)
            tokens = jnp.concatenate(out, axis=1)
            tokens.block_until_ready()
            decode_t = time.perf_counter() - t0
            b = tokens.shape[0]
            if telemetry.enabled():
                telemetry.inc("serve.requests")
                telemetry.inc("serve.tokens_generated", b * gen_len)
                telemetry.observe("serve.prefill_seconds", prefill_t)
                telemetry.observe("serve.decode_seconds", decode_t)
                telemetry.observe("serve.request_seconds",
                                  prefill_t + decode_t)
            return {"tokens": np.asarray(tokens),
                    "prefill_s": prefill_t, "decode_s": decode_t,
                    "decode_tok_per_s": b * (gen_len - 1) / max(decode_t,
                                                                1e-9)}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-7b", choices=configs.ARCH_IDS)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    args = p.parse_args()
    cfg = reduce_config(configs.get(args.arch))
    run = RunConfig(remat="none", loss_chunk=128)
    server = Server(cfg, run, max_len=args.prompt_len + args.gen)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab,
                                    (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = np.zeros(
            (args.batch, cfg.n_image_tokens, cfg.d_model), np.float32)
    if cfg.family == "encdec":
        batch["frame_embeds"] = rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    out = server.generate(batch, args.gen)
    print(f"prefill {out['prefill_s']*1e3:.1f} ms; "
          f"decode {out['decode_tok_per_s']:.1f} tok/s; "
          f"sample: {out['tokens'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
