"""Compressed Tile Storage Format (CTSF) — paper §III-B, Fig. 5.

Two concrete layouts:

* :class:`TileMatrix` — the general CTSF: only nonzero tiles (of the *factor*
  pattern, so fill tiles are pre-allocated by symbolic factorization) are
  stored, stacked into one contiguous ``(n_alloc, t, t)`` buffer.  Host-side
  numpy maps translate (row_tile, col_tile) -> slot.  This is a 1:1 port of
  the paper's format: "each element (i,j) ... is mapped to a corresponding
  tile (k,m), which is allocated only when an element is mapped to it".

* :class:`BandedCTSF` — the regular banded-arrowhead specialization used by
  the TPU-native ``window`` backend (DESIGN.md §4): row-band storage
  ``Dr[m, d] = A_tile[m, m-d]`` plus dense arrow rows ``R[k, i] =
  A_tile[ndt+i, k]`` and corner ``C[i, j]``.  Row-band storage makes every
  left-looking window a contiguous slice.

Both layouts store full (t, t) dense tiles in float32 and read their input
from scipy CSC, matching the paper ("sparse elements are read in CSC").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .structure import ArrowheadStructure, TileGrid, tile_pattern_from_coo
from .symbolic import SymbolicFactorization, symbolic_factorize

__all__ = ["TileMatrix", "BandedCTSF"]


def _dense_padded(mat: sp.spmatrix, grid: TileGrid) -> np.ndarray:
    """Materialize the (padded) dense lower-symmetric matrix for tile slicing.

    Only used on host during construction of test/benchmark problems; the
    factorization itself never touches a dense matrix.
    """
    coo = sp.coo_matrix(mat)
    n_pad = grid.padded_n
    out = np.zeros((n_pad, n_pad), dtype=np.float64)
    pi = np.vectorize(grid.padded_index, otypes=[np.int64])
    r, c = pi(coo.row), pi(coo.col)
    out[r, c] = coo.data
    # pad diagonal with identity so padded tiles stay SPD
    for k in range(grid.structure.n_diag, grid.n_diag_tiles * grid.t):
        out[k, k] = 1.0
    for k in range(grid.n_diag_tiles * grid.t + grid.structure.arrow, n_pad):
        out[k, k] = 1.0
    return out


# ---------------------------------------------------------------------------
# General CTSF
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TileMatrix:
    """General CTSF: stacked nonzero tiles + host-side index map."""

    grid: TileGrid
    symbolic: SymbolicFactorization
    slot: Dict[Tuple[int, int], int]     # (row_tile, col_tile) -> buffer slot
    tiles: jnp.ndarray                   # (n_alloc, t, t) float32

    @classmethod
    def from_sparse(cls, mat: sp.spmatrix, grid: TileGrid,
                    symbolic: Optional[SymbolicFactorization] = None) -> "TileMatrix":
        a_tiles = tile_pattern_from_coo(mat, grid)
        symb = symbolic or symbolic_factorize(a_tiles)
        slots: Dict[Tuple[int, int], int] = {}
        coords = np.argwhere(symb.l_pattern)
        for idx, (i, j) in enumerate(coords):
            slots[(int(i), int(j))] = idx
        dense = _dense_padded(mat, grid)
        t = grid.t
        buf = np.zeros((len(coords), t, t), dtype=np.float32)
        for (i, j), idx in slots.items():
            if a_tiles[i, j]:
                buf[idx] = dense[i * t:(i + 1) * t, j * t:(j + 1) * t]
        return cls(grid, symb, slots, jnp.asarray(buf))

    def to_dense(self, tiles: Optional[jnp.ndarray] = None,
                 lower_only: bool = True) -> np.ndarray:
        t = self.grid.t
        n_pad = self.grid.padded_n
        out = np.zeros((n_pad, n_pad), dtype=np.float32)
        buf = np.asarray(tiles if tiles is not None else self.tiles)
        for (i, j), idx in self.slot.items():
            out[i * t:(i + 1) * t, j * t:(j + 1) * t] = buf[idx]
        if not lower_only:
            out = np.tril(out) + np.tril(out, -1).T
        return out

    @property
    def n_alloc(self) -> int:
        return self.tiles.shape[0]

    def nbytes(self) -> int:
        return int(self.tiles.size * 4)


# ---------------------------------------------------------------------------
# Banded-arrowhead CTSF (regular layout for the `window` backend)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BandedCTSF:
    """Regular banded-arrowhead tile layout.

    Dr: (ndt, bt+1, t, t)  band rows   — Dr[m, d] = A_tile[m, m-d] (d<=min(m,bt))
    R:  (ndt, nat, t, t)   arrow rows  — R[k, i]  = A_tile[ndt+i, k]
    C:  (nat, nat, t, t)   corner      — C[i, j]  = A_tile[ndt+i, ndt+j] (lower)
    """

    grid: TileGrid
    Dr: jnp.ndarray
    R: jnp.ndarray
    C: jnp.ndarray

    @classmethod
    def from_sparse(cls, mat: sp.spmatrix, grid: TileGrid) -> "BandedCTSF":
        dense = _dense_padded(mat, grid)
        return cls.from_dense_padded(dense, grid)

    @classmethod
    def eye(cls, grid: TileGrid) -> "BandedCTSF":
        """Identity matrix in the banded-arrowhead layout: identity diagonal
        tiles, zero band/arrow/corner slack.  This is the neutral element of
        the canonical-grid embedding (``gridpolicy.embed_ctsf``): its
        Cholesky factor, selected inverse and log-determinant contribution
        are all trivial, so padding a problem with identity blocks changes
        nothing about the original entries."""
        t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
        ident = np.eye(t, dtype=np.float32)
        Dr = np.zeros((ndt, bt + 1, t, t), dtype=np.float32)
        if ndt:
            Dr[:, 0] = ident
        C = np.zeros((max(nat, 0), max(nat, 0), t, t), dtype=np.float32)
        for i in range(nat):
            C[i, i] = ident
        R = np.zeros((ndt, max(nat, 0), t, t), dtype=np.float32)
        return cls(grid, jnp.asarray(Dr), jnp.asarray(R), jnp.asarray(C))

    @classmethod
    def from_dense_padded(cls, dense: np.ndarray, grid: TileGrid) -> "BandedCTSF":
        t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
        Dr = np.zeros((ndt, bt + 1, t, t), dtype=np.float32)
        for m in range(ndt):
            for d in range(min(m, bt) + 1):
                j = m - d
                Dr[m, d] = dense[m * t:(m + 1) * t, j * t:(j + 1) * t]
        R = np.zeros((ndt, max(nat, 0), t, t), dtype=np.float32)
        C = np.zeros((max(nat, 0), max(nat, 0), t, t), dtype=np.float32)
        off = ndt * t
        for k in range(ndt):
            for i in range(nat):
                R[k, i] = dense[off + i * t: off + (i + 1) * t, k * t:(k + 1) * t]
        for i in range(nat):
            for j in range(i + 1):
                C[i, j] = dense[off + i * t: off + (i + 1) * t,
                                off + j * t: off + (j + 1) * t]
        return cls(grid, jnp.asarray(Dr), jnp.asarray(R), jnp.asarray(C))

    def to_dense(self, lower_only: bool = True) -> np.ndarray:
        g = self.grid
        t, ndt, nat, bt = g.t, g.n_diag_tiles, g.n_arrow_tiles, g.band_tiles
        n_pad = g.padded_n
        out = np.zeros((n_pad, n_pad), dtype=np.float32)
        Dr, R, C = np.asarray(self.Dr), np.asarray(self.R), np.asarray(self.C)
        for m in range(ndt):
            for d in range(min(m, bt) + 1):
                j = m - d
                out[m * t:(m + 1) * t, j * t:(j + 1) * t] = Dr[m, d]
        off = ndt * t
        for k in range(ndt):
            for i in range(nat):
                out[off + i * t: off + (i + 1) * t, k * t:(k + 1) * t] = R[k, i]
        for i in range(nat):
            for j in range(i + 1):
                out[off + i * t: off + (i + 1) * t, off + j * t: off + (j + 1) * t] = C[i, j]
        if not lower_only:
            out = np.tril(out) + np.tril(out, -1).T
        return out

    def arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        return self.Dr, self.R, self.C

    def nbytes(self) -> int:
        return int((self.Dr.size + self.R.size + self.C.size) * 4)
