"""Numerical fault tolerance: breakdown recovery by escalating diagonal
jitter, with per-element graceful degradation for batched serving.

The detection half lives in the kernels: both backends of
``kernels.ops.band_cholesky_sweep`` emit a (3,) status word
``[min_pivot, nonfinite, first_bad]`` as the sweep runs (in-kernel VMEM
carry on the Pallas path, ``ref.sweep_status`` on the jnp scan), so a bad
pivot is visible without any host sync or mid-batch exception.  This module
is the recovery half — the CHOLMOD-style pivot-perturbation ladder:

* on breakdown, refactorize the *original* matrix with ``tau_k * scale * I``
  added to the diagonal, ``tau_k`` escalating through
  :attr:`RegularizePolicy.taus`;
* a final Gershgorin rung (on by default) shifts failed elements into
  strict diagonal dominance, so any *finite* symmetric input is recovered —
  the 100%-recovery guarantee the injection suite gates on.  Only
  NaN/inf-contaminated inputs can exhaust the ladder, and those end as
  per-element ``STATUS_FAILED`` flags instead of exceptions;
* batched paths retry only the failed batch elements via masking: healthy
  elements keep their attempt-0 outputs bit-for-bit (one ``jnp.where``
  merge), and every retry reuses the same compiled factorization;
* the resulting :class:`FactorInfo` rides on ``CholeskyFactor`` so serving
  callers can surface per-element status, and ``solve_many`` uses the
  retained original matrix for one residual-checked refinement step
  (perturbed-factor-as-preconditioner, cf. Kim et al. in PAPERS.md).

The ladder runs a small host loop — one tiny (3,)-per-element readback per
attempt — but the clean path costs exactly one factorization plus that one
readback, which the robustness benchmark gates at <= 5% overhead.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import telemetry
from .ctsf import BandedCTSF

__all__ = ["STATUS_OK", "STATUS_RECOVERED", "STATUS_FAILED", "STATUS_SHED",
           "RegularizePolicy", "FactorInfo", "diag_scale", "status_ok",
           "gershgorin_shift", "add_diagonal_jitter", "fold_corner_status",
           "run_ladder", "ctsf_matvec"]

_HI = jax.lax.Precision.HIGHEST

STATUS_OK = 0          # factorized clean, no jitter
STATUS_RECOVERED = 1   # breakdown detected, recovered with diagonal jitter
STATUS_FAILED = 2      # ladder exhausted (non-finite input); factor unusable
# Serving-layer terminal status: the request was never computed — shed by
# admission control, deadline expiry, an open circuit breaker, or server
# shutdown (``launch/rung_server.py``).  It completes the closed status
# taxonomy every resolved RungFuture draws from: OK/RECOVERED/FAILED/SHED.
STATUS_SHED = 3


@dataclasses.dataclass(frozen=True)
class RegularizePolicy:
    """Escalating-jitter retry policy (CHOLMOD-style pivot perturbation).

    ``taus`` are *relative* jitter magnitudes: attempt k refactorizes with
    ``taus[k] * scale * I`` added to the diagonal, where ``scale`` is the
    per-element max |diagonal| of the input (:func:`diag_scale`).  The
    default ladder starts near float32 epsilon — anything smaller is a
    no-op addition in fp32 — and escalates by ~100x per rung.

    ``gershgorin=True`` appends a final data-dependent rung: the smallest
    shift making the failed element strictly diagonally dominant (hence
    SPD), so every finite symmetric input is guaranteed to factorize.

    ``pivot_rtol`` declares breakdown when ``min_pivot <= pivot_rtol *
    scale`` (pivots are diag(L)^2, in units of A's diagonal); raise it to
    treat near-singular factors as failures worth jittering.

    ``keep_matrix=True`` retains the original (unjittered) input on the
    :class:`FactorInfo` whenever jitter was applied, enabling the
    residual-checked refinement step in ``solve_many``.
    """
    taus: Tuple[float, ...] = (1e-6, 1e-4, 1e-2)
    pivot_rtol: float = 1e-10
    gershgorin: bool = True
    gershgorin_margin: float = 1e-3
    keep_matrix: bool = True

    @staticmethod
    def resolve(regularize) -> Optional["RegularizePolicy"]:
        """Normalize a ``regularize=`` argument: None/False -> None,
        True -> default policy, a policy -> itself."""
        if regularize is None or regularize is False:
            return None
        if regularize is True:
            return RegularizePolicy()
        if isinstance(regularize, RegularizePolicy):
            return regularize
        raise ValueError(
            f"regularize= must be None, a bool or a RegularizePolicy, "
            f"got {regularize!r}")


@dataclasses.dataclass
class FactorInfo:
    """Per-element numerical outcome of a (possibly batched) factorization.

    All array fields have the factorization's batch shape — ``()`` for a
    single matrix, ``(B,)`` for a batch:

    * ``status`` — int32 ``STATUS_OK`` / ``STATUS_RECOVERED`` /
      ``STATUS_FAILED``;
    * ``attempts`` — int32 factorization attempts consumed (1 = clean);
    * ``tau`` — float32 *absolute* diagonal shift applied (``tau_k *
      scale``; 0 for clean elements);
    * ``min_pivot`` — float32 smallest Cholesky pivot (diag(L)^2) of the
      final factor, over columns with finite diagonals;
    * ``first_bad_tile`` — int32 first failing tile index from the *clean*
      attempt (-1 if it succeeded; ``ndt`` means the arrow corner broke);
    * ``matrix`` — the original unjittered input (kept only when jitter
      was applied and the policy says so), consumed by ``solve_many``'s
      refinement step.
    """
    status: jnp.ndarray
    attempts: jnp.ndarray
    tau: jnp.ndarray
    min_pivot: jnp.ndarray
    first_bad_tile: jnp.ndarray
    matrix: Optional[BandedCTSF] = None

    def ok(self) -> np.ndarray:
        """Host bool array: which elements produced a usable factor."""
        return np.asarray(self.status) != STATUS_FAILED

    def element(self, i: int) -> dict:
        """Host-side scalar view of one batch element's outcome — the
        per-request payload a serving future carries
        (``launch/rung_server.py``): plain Python numbers, no device
        arrays, so completing a future never re-syncs.  Works on scalar
        (unbatched) info too, where ``i`` must be 0."""
        pick = lambda a, cast: cast(np.asarray(a).reshape(-1)[i])
        return {"status": pick(self.status, int),
                "attempts": pick(self.attempts, int),
                "tau": pick(self.tau, float),
                "min_pivot": pick(self.min_pivot, float),
                "first_bad_tile": pick(self.first_bad_tile, int)}


def diag_scale(Dr: jnp.ndarray, C: jnp.ndarray, grid) -> jnp.ndarray:
    """Per-element diagonal scale: max |A_ii| over band + corner diagonals
    (1.0 for an all-zero diagonal so relative jitter stays meaningful).
    Leading batch axes reduce away; NaN diagonals propagate — a
    NaN-contaminated element gets NaN jitter and ends as STATUS_FAILED."""
    parts = []
    if grid.n_diag_tiles:
        d0 = jnp.diagonal(jnp.take(Dr, 0, axis=-3), axis1=-2, axis2=-1)
        parts.append(jnp.max(jnp.abs(d0), axis=(-2, -1)))
    if grid.n_arrow_tiles:
        ct = jnp.diagonal(C, axis1=-4, axis2=-3)
        dc = jnp.diagonal(ct, axis1=-3, axis2=-2)
        parts.append(jnp.max(jnp.abs(dc), axis=(-2, -1)))
    if not parts:
        return jnp.float32(1.0)
    s = functools.reduce(jnp.maximum, parts)
    return jnp.where(s > 0, s, 1.0)


def status_ok(status_vec: jnp.ndarray, scale: jnp.ndarray,
              policy: RegularizePolicy) -> jnp.ndarray:
    """Breakdown predicate on (..., 3) status words: finite everywhere and
    every pivot above ``pivot_rtol * scale``.  (+inf min_pivot — an empty
    or all-prefix sweep — counts as healthy.)"""
    min_piv = status_vec[..., 0]
    nonfin = status_vec[..., 1]
    return (nonfin == 0.0) & (min_piv > policy.pivot_rtol * scale)


def add_diagonal_jitter(Dr: jnp.ndarray, C: jnp.ndarray, grid,
                        shift: jnp.ndarray):
    """``A + shift * I`` in CTSF layout: add ``shift`` (broadcast per batch
    element) to every band and corner diagonal entry."""
    t = grid.t
    eye = jnp.eye(t, dtype=Dr.dtype)
    sh = shift[..., None, None, None]
    if grid.n_diag_tiles:
        Dr = Dr.at[..., 0, :, :].add(sh * eye)
    nat = grid.n_arrow_tiles
    if nat:
        ar = np.arange(nat)
        C = C.at[..., ar, ar, :, :].add(sh * eye)
    return Dr, C


def gershgorin_shift(Dr: jnp.ndarray, R: jnp.ndarray, C: jnp.ndarray,
                     grid) -> jnp.ndarray:
    """Smallest diagonal shift making every Gershgorin disc positive:
    ``max_i (sum_{j != i} |A_ij| - A_ii)``, clipped at 0 — adding it (plus
    any positive margin) makes the matrix strictly diagonally dominant and
    therefore SPD.  The guaranteed final rung of the jitter ladder: NaN
    inputs yield a NaN shift (and stay failed), every finite symmetric
    input becomes factorizable.  Batch axes broadcast."""
    ndt, nat, bt = grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    b1 = bt + 1
    deltas = []
    if ndt:
        absDr = jnp.abs(Dr)
        # lower tiles: row (m, a) sums |Dr[m, d, a, :]| over d, cols
        low = jnp.sum(absDr, axis=(-3, -1))                   # (..., ndt, t)
        # upper tiles: A[m, m+d] = Dr[m+d, d]^T -> |Dr[m+d, d, :, a]|
        pad = [(0, 0)] * (Dr.ndim - 4) + [(0, bt), (0, 0), (0, 0), (0, 0)]
        Drp = jnp.pad(absDr, pad)
        m_idx = np.arange(ndt)[:, None] + np.arange(b1)[None, :]
        d_idx = np.broadcast_to(np.arange(b1)[None, :], m_idx.shape)
        Dup = Drp[..., m_idx, d_idx, :, :]                    # (..., ndt, b1, t, t)
        up = jnp.sum(Dup[..., 1:, :, :], axis=(-3, -2))       # (..., ndt, t)
        rowsum = low + up
        if nat:
            # arrow columns seen from band rows: |R[m, i, :, a]|
            rowsum = rowsum + jnp.sum(jnp.abs(R), axis=(-3, -2))
        dg = jnp.diagonal(jnp.take(Dr, 0, axis=-3), axis1=-2, axis2=-1)
        # rowsum includes |A_ii|; dominance needs A_ii > rowsum - |A_ii|
        deltas.append(jnp.max(rowsum - jnp.abs(dg) - dg, axis=(-2, -1)))
    if nat:
        rows_a = jnp.sum(jnp.abs(R), axis=(-4, -1)) if ndt else 0.0
        absC = jnp.abs(C)
        ii = np.arange(nat)[:, None]
        jj = np.arange(nat)[None, :]
        lowm = (ii >= jj)[:, :, None, None]                   # stored lower
        rows_a = rows_a + jnp.sum(jnp.where(lowm, absC, 0.0), axis=(-3, -1))
        # upper corner tiles: A[i, j>i] = C[j, i]^T -> |C[j, i, :, a]|
        upm = (ii > jj)[:, :, None, None]                     # (j, i) with j>i
        rows_a = rows_a + jnp.sum(jnp.where(upm, absC, 0.0), axis=(-4, -2))
        dcg = jnp.diagonal(jnp.diagonal(C, axis1=-4, axis2=-3),
                           axis1=-3, axis2=-2)                # (..., t, nat)
        dcg = jnp.swapaxes(dcg, -1, -2)                       # (..., nat, t)
        deltas.append(jnp.max(rows_a - jnp.abs(dcg) - dcg, axis=(-2, -1)))
    if not deltas:
        return jnp.float32(0.0)
    return jnp.maximum(functools.reduce(jnp.maximum, deltas), 0.0)


def fold_corner_status(status: jnp.ndarray, C_out: jnp.ndarray,
                       ndt: int, nat: int) -> jnp.ndarray:
    """Fold the dense-corner factor into a band status word: same per-tile
    fold as ``ref.sweep_status`` over the corner's diagonal tiles, with a
    corner breakdown reported as ``first_bad = ndt`` (one past the last
    band tile) when the band itself was clean."""
    if nat == 0:
        return status
    ar = np.arange(nat)
    dg = jnp.diagonal(C_out[..., ar, ar, :, :], axis1=-2, axis2=-1)
    fin_d = jnp.all(jnp.isfinite(dg), axis=(-2, -1))
    piv = jnp.where(fin_d, jnp.min(dg * dg, axis=(-2, -1)), jnp.inf)
    fin = jnp.all(jnp.isfinite(C_out), axis=(-4, -3, -2, -1))
    bad = ~fin | (piv <= 0.0)
    return jnp.stack(
        [jnp.minimum(status[..., 0], piv),
         jnp.maximum(status[..., 1], jnp.where(fin, 0.0, 1.0)),
         jnp.where((status[..., 2] < 0) & bad, float(ndt), status[..., 2])],
        axis=-1)


def _merge(mask: jnp.ndarray, new: jnp.ndarray, old: jnp.ndarray):
    """Per-element select: take ``new`` where ``mask`` (batch-shaped), else
    keep ``old`` — the masking that limits retries to failed elements."""
    m = mask.reshape(mask.shape + (1,) * (new.ndim - mask.ndim))
    return jnp.where(m, new, old)


@functools.partial(jax.jit, static_argnames=("grid", "policy"))
def _first_attempt_eval(sv, Dr, C, grid, policy):
    """Fused scale + breakdown predicate + the clean-path info fields — one
    dispatch on the ladder's hot path instead of the dozen eager ops it
    folds; per-op dispatch is what the <= 5% clean-overhead gate punishes
    (``policy`` is a frozen dataclass, so it keys the jit cache like the
    grid does)."""
    scale = diag_scale(Dr, C, grid)
    ok = status_ok(sv, scale, policy)
    zeros_i = jnp.zeros(ok.shape, jnp.int32)
    return (scale, ok, sv[..., 0], sv[..., 2].astype(jnp.int32),
            zeros_i, zeros_i + 1, jnp.zeros(ok.shape, jnp.float32))


def run_ladder(Dr: jnp.ndarray, R: jnp.ndarray, C: jnp.ndarray, grid,
               call: Callable, policy: RegularizePolicy):
    """Drive ``call(Dr, R, C) -> (Dr_L, R_L, C_L, status_vec)`` through the
    escalating-jitter ladder.  ``call`` may be batched (leading axes on the
    arrays and on ``status_vec[..., 3]``) — retries re-dispatch the same
    compiled callable on the full batch with only the failed elements'
    diagonals jittered, then merge so healthy elements stay bit-identical
    to their first attempt.  Returns ``(Dr_L, R_L, C_L, FactorInfo)``.

    Host control: one (3,)-per-element status readback per attempt (the
    clean path pays exactly one, then short-circuits with constant info
    fields — the <= 5% clean-path overhead the robustness benchmark
    gates), never an exception — exhausted elements come back flagged
    ``STATUS_FAILED`` with their factor left as-is.
    """
    dr, r, c, sv = call(Dr, R, C)
    (scale, ok, min_piv0, first_bad,
     status0, attempts, tau_app) = _first_attempt_eval(sv, Dr, C, grid,
                                                       policy)
    ok_host = np.asarray(ok)          # the ladder's one clean-path readback
    if ok_host.all():
        # telemetry piggybacks on the readback the ladder already pays —
        # no extra device sync rides the <= 5% clean-overhead gate
        if telemetry.enabled():
            n = int(ok_host.size)
            telemetry.inc("robustness.attempts", n)
            telemetry.inc("robustness.status", n, outcome="ok")
        info = FactorInfo(status=status0, attempts=attempts, tau=tau_app,
                          min_pivot=min_piv0, first_bad_tile=first_bad,
                          matrix=None)
        return dr, r, c, info
    shifts = [jnp.float32(tau) * scale for tau in policy.taus]
    if policy.gershgorin:
        shifts.append(gershgorin_shift(Dr, R, C, grid)
                      + jnp.float32(policy.gershgorin_margin) * scale)
    for shift in shifts:
        failed = ~ok
        sh = jnp.where(failed, shift, 0.0)
        DrJ, CJ = add_diagonal_jitter(Dr, C, grid, sh)
        n_dr, n_r, n_c, n_sv = call(DrJ, R, CJ)
        dr = _merge(failed, n_dr, dr)
        r = _merge(failed, n_r, r)
        c = _merge(failed, n_c, c)
        sv = _merge(failed, n_sv, sv)
        tau_app = jnp.where(failed, sh, tau_app)
        attempts = attempts + failed.astype(jnp.int32)
        ok = ok | (failed & status_ok(n_sv, scale, policy))
        if np.asarray(ok).all():
            break
    status = jnp.where(ok,
                       jnp.where(tau_app > 0, STATUS_RECOVERED, STATUS_OK),
                       STATUS_FAILED).astype(jnp.int32)
    jittered = bool(np.asarray(jnp.any(tau_app > 0)))
    if telemetry.enabled():
        # ladder path only — extra readbacks here are off the clean path,
        # which short-circuited above
        st_host = np.asarray(status).ravel()
        telemetry.inc("robustness.attempts",
                      int(np.asarray(attempts).sum()))
        for code, outcome in ((STATUS_OK, "ok"),
                              (STATUS_RECOVERED, "recovered"),
                              (STATUS_FAILED, "failed")):
            n = int((st_host == code).sum())
            if n:
                telemetry.inc("robustness.status", n, outcome=outcome)
    matrix = BandedCTSF(grid, Dr, R, C) \
        if (jittered and policy.keep_matrix) else None
    info = FactorInfo(status=status, attempts=attempts, tau=tau_app,
                      min_pivot=sv[..., 0], first_bad_tile=first_bad,
                      matrix=matrix)
    return dr, r, c, info


@functools.partial(jax.jit, static_argnames=("grid",))
def ctsf_matvec(Dr: jnp.ndarray, R: jnp.ndarray, C: jnp.ndarray,
                xd: jnp.ndarray, xa: jnp.ndarray, grid):
    """``Y = A @ X`` on split tile panels for a *symmetric* banded-arrowhead
    CTSF (an original matrix, not a triangular factor): xd (ndt, t, k) band
    panel, xa (nat, t, k) arrow panel -> (yd, ya) of the same shapes.
    Powers the residual ``B - A X`` of the refinement step in
    ``solve_many``; identity-prefix rows of an embedded matrix map zero
    panels to zero, so canonical-grid residuals need no special casing."""
    t = grid.t
    ndt, nat, bt = grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    b1 = bt + 1
    k = xd.shape[-1]
    if ndt:
        m_idx = np.arange(ndt)[:, None]
        d_idx = np.broadcast_to(np.arange(b1)[None, :], (ndt, b1))
        # lower: y[m] += sum_d Dr[m, d] @ x[m-d]
        xp = jnp.pad(xd, ((bt, 0), (0, 0), (0, 0)))
        yd = jnp.einsum("mdab,mdbk->mak", Dr, xp[m_idx - d_idx + bt],
                        precision=_HI)
        if bt:
            # upper: A[m, m+d] = Dr[m+d, d]^T for d >= 1
            Drp = jnp.pad(Dr, ((0, bt), (0, 0), (0, 0), (0, 0)))
            Dup = Drp[m_idx + d_idx, d_idx]               # (ndt, b1, t, t)
            xq = jnp.pad(xd, ((0, bt), (0, 0), (0, 0)))
            yd = yd + jnp.einsum("mdba,mdbk->mak", Dup[:, 1:],
                                 xq[m_idx + d_idx][:, 1:], precision=_HI)
        if nat:
            # arrow columns seen from band rows: A[m, ndt+i] = R[m, i]^T
            yd = yd + jnp.einsum("miba,ibk->mak", R, xa, precision=_HI)
    else:
        yd = xd
    if nat:
        ya = jnp.einsum("miab,mbk->iak", R, xd, precision=_HI) if ndt \
            else jnp.zeros((nat, t, k), xd.dtype)
        ii = np.arange(nat)[:, None]
        jj = np.arange(nat)[None, :]
        # stored lower corner mirrored: Cfull[i, j>i] = C[j, i]^T
        Cfull = jnp.where((ii >= jj)[:, :, None, None], C,
                          jnp.swapaxes(jnp.swapaxes(C, 0, 1), -1, -2))
        ya = ya + jnp.einsum("ijab,jbk->iak", Cfull, xa, precision=_HI)
    else:
        ya = xa
    return yd, ya
