"""Selected inversion of banded-arrowhead factors — blocked Takahashi recurrence.

INLA (the paper's driving application) follows every factorization with
posterior marginal variances, i.e. selected entries of Σ = A^{-1}.  The
unit-vector panel sweep (``solve.marginal_variances(method="panels")``)
costs one forward solve per selected index and only yields the diagonal;
this module computes *every* Σ entry on the factor's sparsity pattern —
the whole band plus the arrow block — in one backward tile sweep whose cost
is independent of how many entries are selected.

Derivation (blocked Takahashi equations)
----------------------------------------
Let ``A = L L^T`` with block lower-triangular ``L`` and ``Σ = A^{-1}``.
From ``Σ L = L^{-T}`` (upper triangular), taking block entry (i, j) with
``i >= j`` and splitting the sum over ``k >= j``:

    Σ_ij L_jj + Σ_{k>j} Σ_ik L_kj = (L^{-T})_ij

With the *normalized* factor column ``G_kj = L_kj L_jj^{-1}``:

    i > j:   Σ_ij = - Σ_{k>j} Σ_ik G_kj                         (off-diag)
    i = j:   Σ_jj = L_jj^{-T} L_jj^{-1} - Σ_{k>j} Σ_jk G_kj
                  = (L_jj L_jj^T)^{-1} - Σ_{k>j} Σ_kj^T G_kj    (diag)

so column j of Σ needs only Σ entries from trailing columns ``k > j`` — a
*backward* sweep — and, by symmetry ``Σ_jk = Σ_kj^T``, the diagonal needs
only the off-diagonals of column j computed the same step.

For the banded-arrowhead layout, ``L_kj != 0`` only for band rows
``k = j+1 .. j+b`` and arrow rows, so the sum touches Σ tiles with tile
offset ``<= b`` plus arrow/corner tiles: the recurrence *closes* on the
factor's own sparsity pattern and the computed entries are exact entries of
the dense A^{-1}.  The whole backward recurrence is one sweep-level
primitive (``kernels.ops.selinv_sweep``), the mirror image of the
factorization sweep: columns ``j = ndt-1 .. 0`` walk with a
``(b, b+1, t, t)`` ring of the last b computed Σ columns (plus the arrow
ring).  On the Pallas backend the *entire* recurrence is a single fused
kernel launch with the Σ-column ring resident in VMEM across columns
(``kernels/selinv.py``); on the jnp backend it is a ``lax.scan`` of
``kernels.ops.selinv_step`` block-row x block-column contractions.  The
trailing corner seeds the recurrence: the last block columns see no later
columns, hence ``Σ_corner = L_c^{-T} L_c^{-1}`` — one small dense
triangular solve.

Cost: O(ndt · (b + nat)²) tile matmuls — same order as the factorization
itself and independent of the number of selected entries, versus
O(k · ndt · b) for k unit-vector panels.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ring import band_col_to_row, band_row_to_col
from repro.runtime import telemetry
from .batching import LRUCache, bucketed_batched_call
from .cholesky import CholeskyFactor
from .ctsf import BandedCTSF
from .options import UNSET, resolve_options
from .structure import TileGrid

__all__ = ["SelectedInverse", "selected_inverse", "selinv_batched"]

_HI = jax.lax.Precision.HIGHEST


# ---------------------------------------------------------------------------
# Result container (mirrors BandedCTSF's layout)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SelectedInverse:
    """Band + arrow block of Σ = A^{-1} in banded-arrowhead tile layout.

    Dr: (ndt, bt+1, t, t)  band rows   — Dr[m, d] = Σ_tile[m, m-d]
    R:  (ndt, nat, t, t)   arrow rows  — R[k, i]  = Σ_tile[ndt+i, k]
    C:  (nat, nat, t, t)   corner      — C[i, j]  = Σ_tile[ndt+i, ndt+j] (lower)

    Leading batch axes (from :func:`selinv_batched`) are carried transparently
    by :meth:`diagonal`; the elementwise accessors assume an unbatched layout
    but broadcast over leading axes as well.
    """

    grid: TileGrid
    Dr: jnp.ndarray
    R: jnp.ndarray
    C: jnp.ndarray

    def diagonal(self, padded: bool = False) -> jnp.ndarray:
        """diag(Σ) — INLA's posterior marginal variances, every latent at
        once.  Returns the unpadded (n,) diagonal unless ``padded``."""
        g = self.grid
        d0 = jnp.take(self.Dr, 0, axis=-3)               # (..., ndt, t, t)
        db = jnp.diagonal(d0, axis1=-2, axis2=-1)        # (..., ndt, t)
        db = db.reshape(db.shape[:-2] + (-1,))
        if g.n_arrow_tiles:
            ct = jnp.diagonal(self.C, axis1=-4, axis2=-3)   # (..., t, t, nat)
            dc = jnp.diagonal(ct, axis1=-3, axis2=-2)       # (..., nat, t)
            dc = dc.reshape(dc.shape[:-2] + (-1,))
            full = jnp.concatenate([db, dc], axis=-1)
        else:
            full = db
        if padded:
            return full
        idx = np.vectorize(g.padded_index, otypes=[np.int64])(
            np.arange(g.structure.n))
        return jnp.take(full, jnp.asarray(idx), axis=-1)

    def covariance(self, i: int, j: int) -> jnp.ndarray:
        """Σ_ij for element indices of the *original* matrix.  Defined
        whenever the entry lies on the stored pattern: |i-j| within the tile
        band, or at least one index in the arrow block."""
        g = self.grid
        s = g.structure
        for v in (i, j):
            if not 0 <= int(v) < s.n:
                raise ValueError(f"index {v} out of range [0, {s.n})")
        pi, pj = g.padded_index(int(i)), g.padded_index(int(j))
        if pi < pj:
            pi, pj = pj, pi                              # Σ is symmetric
        bi, ri = divmod(pi, g.t)
        bj, rj = divmod(pj, g.t)
        ndt = g.n_diag_tiles
        if bi < ndt:                                     # band x band
            d = bi - bj
            if d > g.band_tiles:
                raise ValueError(
                    f"covariance({i}, {j}) lies outside the stored band "
                    f"(tile offset {d} > {g.band_tiles})")
            return self.Dr[..., bi, d, ri, rj]
        if bj < ndt:                                     # arrow row x band col
            return self.R[..., bj, bi - ndt, ri, rj]
        ia, ja = bi - ndt, bj - ndt                      # corner (lower stored)
        return self.C[..., ia, ja, ri, rj]

    def to_dense_band(self, lower_only: bool = False) -> np.ndarray:
        """Materialize the stored band + arrow entries as a dense
        (padded_n, padded_n) array (zeros off-pattern); symmetrized unless
        ``lower_only``."""
        return BandedCTSF(self.grid, self.Dr, self.R,
                          self.C).to_dense(lower_only=lower_only)

    def arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        return self.Dr, self.R, self.C

    def nbytes(self) -> int:
        return int((self.Dr.size + self.R.size + self.C.size) * 4)


# ---------------------------------------------------------------------------
# The backward tile recurrence
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("grid", "impl"))
def _selinv_impl(Dr, R, C, grid, impl=None, start_tile=0):
    """Blocked Takahashi sweep over one factor.  Returns (Sd, Sr, Sc) in the
    row-band / arrow-row / lower-corner layout of :class:`SelectedInverse`.

    ``start_tile`` declares the first columns an identity-embedding prefix
    (``core/gridpolicy.py``): the sweep emits identity Σ panels there
    (``Σ = blockdiag(I, Σ_src)``), skipping their compute on the fused
    backend.  Callers omit it on the plain path (static 0) and pass a
    traced scalar on the canonical-grid path."""
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    b1 = bt + 1

    # --- corner seed: Σ_cc = L_c^{-T} L_c^{-1} (dense, small) --------------
    if nat:
        nc = nat * t
        cd = C.transpose(0, 2, 1, 3).reshape(nc, nc)
        winv_c = jax.scipy.linalg.solve_triangular(
            cd, jnp.eye(nc, dtype=C.dtype), lower=True)
        sc_dense = jnp.dot(winv_c.T, winv_c, precision=_HI)
        sc_full = sc_dense.reshape(nat, t, nat, t).transpose(0, 2, 1, 3)
    else:
        sc_full = jnp.zeros((0, 0, t, t), Dr.dtype)

    if ndt == 0:
        sd = jnp.zeros((0, b1, t, t), Dr.dtype)
        sr = jnp.zeros((0, nat, t, t), Dr.dtype)
        return sd, sr, _tril_tiles(sc_full, nat)

    # whole backward recurrence as one sweep primitive: the fused Pallas
    # kernel (impl="pallas") or the per-column selinv_step scan ("ref")
    lcol = band_row_to_col(Dr)       # lcol[j, d] = L_tile[j+d, j]
    panels, sr = ops.selinv_sweep(lcol, R, sc_full, start_tile, impl=impl)
    # panels[j, e] = Σ_{j+e, j}; sr[j, i] = Σ_{ndt+i, j}
    sd = band_col_to_row(panels)     # Sd[m, d] = Σ_{m, m-d}
    return sd, sr, _tril_tiles(sc_full, nat)


def _tril_tiles(sc_full: jnp.ndarray, nat: int) -> jnp.ndarray:
    """Keep the lower tile triangle of the (nat, nat, t, t) corner block
    (the storage convention shared with BandedCTSF)."""
    if not nat:
        return sc_full
    ii = jnp.arange(nat)
    return jnp.where((ii[:, None] >= ii[None, :])[:, :, None, None],
                     sc_full, 0.0)


def selected_inverse(factor: CholeskyFactor,
                     impl=UNSET,
                     policy=UNSET,
                     options=None) -> SelectedInverse:
    """Band + arrow block of Σ = A^{-1} from a banded-arrowhead Cholesky
    factor, via the blocked Takahashi recurrence (one backward tile sweep,
    cost independent of how many entries are selected).

    Canonical-grid embedded factors (``factor.source_grid`` set, or
    ``policy`` given) run the recurrence on the canonical grid — one
    compile per canonical rung across all source grids, prefix columns
    skipped via the sweep's traced ``start_tile`` — and the result is
    restricted back to the source grid, so every returned entry is an
    exact entry of the source problem's inverse."""
    from .solve import _resolve_embedding
    opts = resolve_options(options, _where="selected_inverse",
                           impl=impl, policy=policy)
    impl = opts.impl
    with telemetry.span("selinv.selected_inverse") as sp:
        ctsf, src, pad = _resolve_embedding(factor, opts.policy)
        sp.tag(grid=telemetry.rung_tag(ctsf.grid))
        if src is not None:
            from .gridpolicy import restrict_selinv
            sd, sr, sc = _selinv_impl(ctsf.Dr, ctsf.R, ctsf.C, ctsf.grid,
                                      impl, jnp.asarray(pad, jnp.int32))
            return restrict_selinv(SelectedInverse(ctsf.grid, sd, sr, sc),
                                   src)
        sd, sr, sc = _selinv_impl(ctsf.Dr, ctsf.R, ctsf.C, ctsf.grid, impl)
        return SelectedInverse(ctsf.grid, sd, sr, sc)


# ---------------------------------------------------------------------------
# Batched serving path (INLA θ-sweep posterior marginals)
# ---------------------------------------------------------------------------

# bounded traced-callable cache (core/batching.py), mirroring
# cholesky._BATCHED_WINDOW_CACHE
_BATCHED_SELINV_CACHE = LRUCache(maxsize=64, name="batched_selinv")


def _batched_selinv_fn(grid, opts, use_start=False):
    """One vmapped+jitted recurrence per (grid, options compile key) —
    cached on the Python side so repeated same-structure sweeps reuse the
    traced function object (and XLA's compile cache), mirroring
    ``cholesky._batched_window_fn``.  ``use_start=True`` adds the traced
    ``start_tile`` argument of the canonical-grid path (one cache entry per
    canonical rung, shared by every pad depth)."""
    key = (grid, opts.compile_key(), use_start)
    impl = opts.impl

    def build():
        if use_start:
            return jax.jit(jax.vmap(
                lambda dr, r, c, s: _selinv_impl(dr, r, c, grid, impl, s),
                in_axes=(0, 0, 0, None)))
        return jax.jit(jax.vmap(
            lambda dr, r, c: _selinv_impl(dr, r, c, grid, impl)))

    return _BATCHED_SELINV_CACHE.get_or_create(key, build)


def selinv_batched(factor: CholeskyFactor, impl=UNSET,
                   bucket: bool = True, policy=UNSET,
                   options=None) -> SelectedInverse:
    """Selected inversion of a batch of same-grid factors (leading batch
    axis on the CTSF arrays, as returned by ``factorize_window_batched``) in
    one vmapped dispatch.

    Args:
      factor: batched factor — ``ctsf.Dr`` must be 5-D
        ``(batch, ndt, bt+1, t, t)`` (with matching ``R``/``C``).
      impl: kernel backend forwarded to the recurrence's tile primitives
        (``solve_panel`` seeds and ``selinv_step`` contractions).
      bucket: pad the batch (by repeating the last factor) to the next
        power of two before dispatch and drop the padding results — the
        same pow2 bucketing compile cache as the batched factorization,
        bounding XLA compiles per grid at log2(max batch).  With
        ``bucket=False`` every distinct batch size compiles once.

    Returns: a :class:`SelectedInverse` whose arrays carry the leading
    batch axis; ``diagonal()`` / ``covariance(i, j)`` broadcast over it.

    Canonical-grid embedded factors (``factor.source_grid`` set, or
    ``policy`` given) run on the canonical grid — the cache keys on the
    canonical grid, so mixed-size traffic compiles one recurrence per
    rung — and the result is restricted back to the source grid.
    """
    from .solve import _resolve_embedding
    opts = resolve_options(options, _where="selinv_batched",
                           impl=impl, policy=policy)
    with telemetry.span("selinv.batched") as sp:
        ctsf, src, pad = _resolve_embedding(factor, opts.policy)
        if ctsf.Dr.ndim != 5:
            raise ValueError(f"selinv_batched needs a leading batch axis, "
                             f"got Dr.ndim={ctsf.Dr.ndim}")
        sp.tag(b=ctsf.Dr.shape[0], grid=telemetry.rung_tag(ctsf.grid))
        if src is not None:
            from .gridpolicy import restrict_selinv
            fn = _batched_selinv_fn(ctsf.grid, opts, use_start=True)
            start = jnp.asarray(pad, jnp.int32)
            call = lambda dr, r, c: fn(dr, r, c, start)
            sd, sr, sc = bucketed_batched_call(
                call, (ctsf.Dr, ctsf.R, ctsf.C), bucket)
            return restrict_selinv(SelectedInverse(ctsf.grid, sd, sr, sc),
                                   src)
        sd, sr, sc = bucketed_batched_call(
            _batched_selinv_fn(ctsf.grid, opts), (ctsf.Dr, ctsf.R, ctsf.C),
            bucket)
        return SelectedInverse(ctsf.grid, sd, sr, sc)
