"""Fill-reducing orderings for block-arrowhead matrices (paper §III-A).

Implements the three families the paper analyses — RCM, AMD, and Nested
Dissection — plus the paper's two structure-aware twists:

  * **partial** orderings that permute only the banded diagonal part and
    leave the dense arrowhead region untouched (Fig. 3: excluding the orange
    region cut fill-in by ~32.7% on their Matrix B);
  * the **adaptive ND** of §III-A: separator size = bandwidth (+ arrow
    columns), separator moved to the *end* of the matrix, preserving the
    arrowhead shape while exposing independent partitions (Fig. 4).

All orderings are evaluated with the paper's acceptance rule: "the number of
fill-ins is evaluated before and after the ordering; if there is no
improvement, the method is not used."
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np
import scipy.sparse as sp
from scipy.sparse import csgraph

from .structure import ArrowheadStructure, TileGrid, measure_arrowhead, tile_pattern_from_coo

__all__ = [
    "OrderingResult",
    "PartitionPlan",
    "rcm_ordering",
    "amd_ordering",
    "adaptive_nd_ordering",
    "metis_like_nd_ordering",
    "best_ordering",
    "apply_permutation",
    "tile_fill_in",
    "detect_partition_plan",
    "partition_plan_from_ordering",
]


@dataclasses.dataclass
class OrderingResult:
    name: str
    perm: np.ndarray            # new_index -> old_index
    fill_before: int
    fill_after: int
    accepted: bool
    partitions: Optional[np.ndarray] = None  # ND only: partition id per new index

    @property
    def improvement(self) -> float:
        if self.fill_before == 0:
            return 0.0
        return 1.0 - self.fill_after / max(1, self.fill_before)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Tile-level partition layout of a block-separable band.

    Under the adaptive-ND ordering the band's independent partitions are
    contiguous runs of diagonal tiles with *no* band tile crossing a
    partition boundary (the separator's couplings moved to the trailing
    arrow/corner block).  The plan records those runs:

      boundaries: strictly increasing tile indices ``(0, c_1, ..., ndt)``
        — partition ``p`` owns diagonal tiles ``[boundaries[p],
        boundaries[p+1])``.
      sep_tiles: how many trailing arrow tiles are the moved separator
        (informational — the separator factorizes with the corner either
        way; benches fold it into the critical-path accounting).

    Frozen and hashable: a plan is a *static* compile-time argument — the
    partitioned sweep's grid shape is ``(n_partitions, max_tiles)`` — and
    rides :class:`~repro.core.options.SolverOptions` into the batching
    compile-cache keys.
    """

    boundaries: Tuple[int, ...]
    sep_tiles: int = 0

    def __post_init__(self):
        b = tuple(int(x) for x in self.boundaries)
        object.__setattr__(self, "boundaries", b)
        if len(b) < 2:
            raise ValueError(
                f"PartitionPlan needs >= 2 boundaries (got {b!r})")
        if b[0] != 0:
            raise ValueError(f"boundaries must start at 0, got {b!r}")
        if any(b[i + 1] <= b[i] for i in range(len(b) - 1)):
            raise ValueError(
                f"boundaries must be strictly increasing, got {b!r}")
        if self.sep_tiles < 0:
            raise ValueError(f"sep_tiles must be >= 0, got {self.sep_tiles}")

    @property
    def n_partitions(self) -> int:
        return len(self.boundaries) - 1

    @property
    def n_tiles(self) -> int:
        """Total diagonal tiles covered (= the grid's ``n_diag_tiles``)."""
        return self.boundaries[-1]

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(self.boundaries[i + 1] - self.boundaries[i]
                     for i in range(self.n_partitions))

    @property
    def max_tiles(self) -> int:
        """The partitioned sweep's sequential-grid depth: the critical
        path drops from O(ndt) to O(max partition tiles)."""
        return max(self.sizes)

    @classmethod
    def trivial(cls, n_tiles: int) -> "PartitionPlan":
        """The single-partition plan covering ``n_tiles`` diagonal tiles —
        semantically 'no partitioning'; dispatch keeps the plain fused
        sweep for it, bit-for-bit."""
        return cls(boundaries=(0, max(int(n_tiles), 1)))

    def shifted(self, pad: int) -> "PartitionPlan":
        """The plan after a canonical-grid embedding prepends ``pad``
        identity tiles (``core/gridpolicy.py``): the identity prefix is
        decoupled from everything, so it joins partition 0.  ``pad`` is
        static — one compilation per (canonical rung, pad depth) when a
        plan rides the policy path, vs one per rung without a plan."""
        pad = int(pad)
        if pad < 0:
            raise ValueError(f"pad must be >= 0, got {pad}")
        if pad == 0:
            return self
        return PartitionPlan(
            boundaries=(0,) + tuple(b + pad for b in self.boundaries[1:]),
            sep_tiles=self.sep_tiles)


# ---------------------------------------------------------------------------
# Fill-in evaluation (tile level — what sTiles actually allocates)
# ---------------------------------------------------------------------------

def _symbolic_elimination_tiles(tile_lower: np.ndarray) -> np.ndarray:
    """Tile-level symbolic Cholesky: returns the L tile pattern.

    Classic column elimination on the (small) tile graph: eliminating column
    k joins all its below-diagonal neighbours into a clique — restricted to
    the standard quotient-graph shortcut of only linking to the first
    neighbour's column (etree-based transitive reduction would be cheaper;
    tile counts are small so direct set propagation is fine).
    """
    nt = tile_lower.shape[0]
    patt = [set(np.nonzero(tile_lower[:, k])[0][np.nonzero(tile_lower[:, k])[0] > k])
            for k in range(nt)]
    for k in range(nt):
        nbrs = sorted(patt[k])
        if not nbrs:
            continue
        p = nbrs[0]  # etree parent: fill propagates to parent column
        patt[p].update(x for x in nbrs if x > p)
    L = np.zeros_like(tile_lower)
    for k in range(nt):
        L[k, k] = True
        for r in patt[k]:
            L[r, k] = True
    return L


def tile_fill_in(pattern: sp.spmatrix, structure: ArrowheadStructure, t: int,
                 total: bool = False) -> int:
    """Fill tiles created by factorization (|L_tiles| - |A_tiles|), or with
    ``total=True`` the factor's allocated tile count |L_tiles| — the quantity
    that decides storage and FLOPs (a scrambled matrix has *few* fill tiles
    because every tile is already dirty; |L| exposes that)."""
    grid = TileGrid(structure, t)
    a_tiles = tile_pattern_from_coo(pattern, grid)
    l_tiles = _symbolic_elimination_tiles(a_tiles)
    if total:
        return int(l_tiles.sum())
    return int(l_tiles.sum() - a_tiles.sum())


# ---------------------------------------------------------------------------
# Orderings
# ---------------------------------------------------------------------------

def _partial_wrap(perm_diag: np.ndarray, n: int, nd: int) -> np.ndarray:
    """Extend a permutation of the diagonal part with identity on the arrow."""
    perm = np.empty(n, dtype=np.int64)
    perm[:nd] = perm_diag
    perm[nd:] = np.arange(nd, n)
    return perm


def rcm_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                 partial: bool = True) -> np.ndarray:
    """(Partial) Reverse Cuthill-McKee.

    ``partial=True`` is the paper's recommended variant: RCM runs on the
    banded diagonal part only, the arrowhead block keeps its position.
    """
    n, nd = structure.n, structure.n_diag
    csr = sp.csr_matrix(pattern)
    if partial and structure.arrow > 0:
        sub = csr[:nd, :nd]
        perm_diag = np.asarray(csgraph.reverse_cuthill_mckee(sub, symmetric_mode=True),
                               dtype=np.int64)
        return _partial_wrap(perm_diag, n, nd)
    return np.asarray(csgraph.reverse_cuthill_mckee(csr, symmetric_mode=True), dtype=np.int64)


def amd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                 partial: bool = True) -> np.ndarray:
    """Approximate minimum degree (simplified quotient-graph AMD).

    Selects the node of (approximate) least external degree, eliminates it,
    and represents the resulting clique implicitly through element lists —
    the same mechanism AMD [Amestoy/Davis/Duff] uses, without supervariable
    detection (adequate for the moderate graph sizes sTiles preprocesses).
    """
    n, nd = structure.n, structure.n_diag
    csr = sp.csr_matrix(pattern)
    target = csr[:nd, :nd] if (partial and structure.arrow > 0) else csr
    m = target.shape[0]

    adj: list = [set(target.indices[target.indptr[i]:target.indptr[i + 1]]) - {i}
                 for i in range(m)]
    elements: list = [set() for _ in range(m)]  # elements adjacent to each var
    elem_members: Dict[int, set] = {}
    alive = np.ones(m, dtype=bool)
    degree = np.array([len(a) for a in adj], dtype=np.int64)
    order = np.empty(m, dtype=np.int64)

    import heapq
    heap = [(int(degree[i]), i) for i in range(m)]
    heapq.heapify(heap)
    stamp = 0
    for pos in range(m):
        while True:
            d, v = heapq.heappop(heap)
            if alive[v] and d == degree[v]:
                break
        order[pos] = v
        alive[v] = False
        # Build the new element (clique) = adj(v) U members of v's elements.
        clique = set(x for x in adj[v] if alive[x])
        for e in elements[v]:
            clique.update(x for x in elem_members[e] if alive[x])
        clique.discard(v)
        eid = stamp
        stamp += 1
        elem_members[eid] = clique
        for u in clique:
            adj[u].discard(v)
            elements[u] -= elements[v]
            elements[u].add(eid)
            # approximate degree: |adj| + sum of element sizes (upper bound)
            degree[u] = len([x for x in adj[u] if alive[x]]) + sum(
                len(elem_members[e]) for e in elements[u])
            heapq.heappush(heap, (int(degree[u]), u))
        for e in elements[v]:
            elem_members[e].discard(v)

    if partial and structure.arrow > 0:
        return _partial_wrap(order, n, nd)
    return order


def adaptive_nd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                         n_parts: int = 2) -> OrderingResult:
    """The paper's adaptive nested dissection (§III-A, Fig. 4).

    1. The separator size equals the bandwidth (arrow columns are already at
       the end and act as a global separator).
    2. The separator — the ``bandwidth`` columns straddling each partition
       boundary — is moved towards the end of the matrix, preserving the
       arrowhead shape and leaving ``n_parts`` independent diagonal
       partitions.
    """
    n, nd, bw = structure.n, structure.n_diag, structure.bandwidth
    if n_parts < 2 or nd <= n_parts * (bw + 1):
        ident = np.arange(n, dtype=np.int64)
        return OrderingResult("adaptive_nd", ident, 0, 0, accepted=False)

    cuts = [round(nd * p / n_parts) for p in range(1, n_parts)]
    sep_mask = np.zeros(nd, dtype=bool)
    for c in cuts:
        lo, hi = max(0, c - (bw + 1) // 2), min(nd, c + (bw + 1) // 2)
        sep_mask[lo:hi] = True

    part_idx = np.nonzero(~sep_mask)[0]
    sep_idx = np.nonzero(sep_mask)[0]
    perm = np.concatenate([part_idx, sep_idx, np.arange(nd, n)]).astype(np.int64)

    # partition ids in the *new* ordering (for distributed factorization)
    parts = np.full(n, -1, dtype=np.int64)
    bounds = [0] + cuts + [nd]
    pid_of_old = np.zeros(nd, dtype=np.int64)
    for p in range(n_parts):
        pid_of_old[bounds[p]:bounds[p + 1]] = p
    parts[:len(part_idx)] = pid_of_old[part_idx]
    return OrderingResult("adaptive_nd", perm, 0, 0, accepted=True, partitions=parts)


def metis_like_nd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                           levels: int = 2) -> np.ndarray:
    """Generic (METIS-style) recursive nested dissection via spectral-free
    BFS bisection, used as the baseline ND the paper compares against.

    Recursively: pick a pseudo-peripheral node, BFS-level the graph, take the
    median level as separator, recurse on the two halves, emit
    [left, right, separator].
    """
    csr = sp.csr_matrix(pattern)
    n = csr.shape[0]

    def dissect(nodes: np.ndarray, depth: int) -> np.ndarray:
        if depth == 0 or len(nodes) < 32:
            return nodes
        sub = csr[nodes][:, nodes]
        order = np.asarray(csgraph.reverse_cuthill_mckee(sub, symmetric_mode=True))
        # BFS-levelled order: separator = middle slice of width ~ sqrt degree
        mid = len(nodes) // 2
        width = max(1, int(np.sqrt(sub.nnz / max(1, len(nodes)))) * 4)
        lo, hi = max(0, mid - width), min(len(nodes), mid + width)
        left, sep, right = order[:lo], order[lo:hi], order[hi:]
        return np.concatenate([
            dissect(nodes[left], depth - 1),
            dissect(nodes[right], depth - 1),
            nodes[sep],
        ])

    return dissect(np.arange(n, dtype=np.int64), levels)


def apply_permutation(mat: sp.spmatrix, perm: np.ndarray) -> sp.csc_matrix:
    """Symmetric permutation P A P^T with perm[new] = old."""
    csr = sp.csc_matrix(mat)
    return sp.csc_matrix(csr[perm][:, perm])


# ---------------------------------------------------------------------------
# Ordering selection (paper's acceptance rule + per-structure guidance)
# ---------------------------------------------------------------------------

_CANDIDATES: Dict[str, Callable] = {
    "partial_rcm": lambda A, s: rcm_ordering(A, s, partial=True),
    "rcm": lambda A, s: rcm_ordering(A, s, partial=False),
    "partial_amd": lambda A, s: amd_ordering(A, s, partial=True),
}


def best_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure, t: int,
                  candidates=None) -> OrderingResult:
    """Try candidate orderings; keep the best; reject if no fill improvement.

    Implements the paper's guidance table: partial RCM preferred for
    band-narrowing, AMD for irregular patterns, adaptive ND handled
    separately (it optimizes parallelism, not fill).
    """
    base_fill = tile_fill_in(pattern, structure, t, total=True)
    best_name, best_perm, best_fill = "identity", np.arange(structure.n, dtype=np.int64), base_fill
    for name in (candidates or _CANDIDATES):
        perm = _CANDIDATES[name](pattern, structure)
        permuted = apply_permutation(pattern, perm)
        new_struct = measure_arrowhead(permuted, arrow_hint=structure.arrow)
        fill = tile_fill_in(permuted, new_struct, t, total=True)
        if fill < best_fill:
            best_name, best_perm, best_fill = name, perm, fill
    return OrderingResult(best_name, best_perm, base_fill, best_fill,
                          accepted=best_name != "identity")


# ---------------------------------------------------------------------------
# Partition-plan extraction (the partitioned fused sweep's static input)
# ---------------------------------------------------------------------------

def detect_partition_plan(pattern: sp.spmatrix, structure: ArrowheadStructure,
                          t: int, min_tiles: int = 1,
                          sep_tiles: Optional[int] = None) -> PartitionPlan:
    """Find the independent band partitions of an (already ordered) matrix.

    A cut between diagonal tiles ``c-1`` and ``c`` is valid iff every band
    tile crossing it is structurally zero — then columns left and right of
    the cut never exchange data through the band (the arrow/corner, where
    an adaptive-ND separator lives, couples them only *after* the band
    sweep).  Scans the tile pattern for all valid cuts, keeps those
    leaving at least ``min_tiles`` tiles per partition, and returns the
    resulting :class:`PartitionPlan` (trivial when no cut exists — e.g. a
    plain arrowhead matrix, which dispatch then factorizes exactly as
    before).

    ``sep_tiles`` defaults to the structure's arrow tile count — under the
    paper's adaptive ND the moved separator *is* the trailing block.
    """
    grid = TileGrid(structure, t)
    tiles = tile_pattern_from_coo(pattern, grid)
    ndt, bt = grid.n_diag_tiles, grid.band_tiles
    if sep_tiles is None:
        sep_tiles = grid.n_arrow_tiles
    if ndt < 2:
        return PartitionPlan.trivial(ndt)
    band = np.asarray(tiles)[:ndt, :ndt]
    cuts = [0]
    for c in range(1, ndt):
        lo = max(0, c - bt)
        if not band[c:min(ndt, c + bt), lo:c].any() and c - cuts[-1] >= min_tiles:
            cuts.append(c)
    if ndt - cuts[-1] < min_tiles and len(cuts) > 1:
        cuts.pop()
    return PartitionPlan(boundaries=tuple(cuts) + (ndt,),
                         sep_tiles=int(sep_tiles))


def partition_plan_from_ordering(result: OrderingResult,
                                 structure: ArrowheadStructure,
                                 t: int) -> PartitionPlan:
    """Build the tile-level :class:`PartitionPlan` an accepted
    :func:`adaptive_nd_ordering` result induces.

    The ordering's ``partitions`` array labels each *element* of the new
    ordering with its partition id (-1 for separator/arrow rows moved to
    the end).  The partition runs are contiguous by construction; their
    element boundaries must land on tile boundaries for the kernel-level
    plan (pick ``n_parts`` so ``nd / n_parts`` is a multiple of ``t``, or
    fall back to :func:`detect_partition_plan` on the permuted pattern,
    which simply finds no cut at a misaligned boundary).  The separator +
    arrow tail maps to ``sep_tiles``.
    """
    if result.partitions is None or not result.accepted:
        grid = TileGrid(structure, t)
        return PartitionPlan.trivial(grid.n_diag_tiles)
    parts = np.asarray(result.partitions)
    body = parts[parts >= 0]
    n_body = len(body)
    if n_body % t:
        raise ValueError(
            f"partition body size {n_body} is not tile-aligned (t={t}); "
            "choose n_parts so partition boundaries land on tile edges, "
            "or run detect_partition_plan on the permuted pattern")
    ids, counts = np.unique(body, return_counts=True)
    order = np.argsort(ids)
    counts = counts[order]
    if (counts % t).any():
        raise ValueError(
            f"partition sizes {counts.tolist()} are not tile-aligned "
            f"(t={t}); choose n_parts so each partition is a whole number "
            "of tiles, or run detect_partition_plan instead")
    bounds = np.concatenate([[0], np.cumsum(counts // t)])
    n_tail = structure.n - n_body            # separator + arrow elements
    return PartitionPlan(boundaries=tuple(int(b) for b in bounds),
                         sep_tiles=int(np.ceil(n_tail / t)))
