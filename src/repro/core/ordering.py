"""Fill-reducing orderings for block-arrowhead matrices (paper §III-A).

Implements the three families the paper analyses — RCM, AMD, and Nested
Dissection — plus the paper's two structure-aware twists:

  * **partial** orderings that permute only the banded diagonal part and
    leave the dense arrowhead region untouched (Fig. 3: excluding the orange
    region cut fill-in by ~32.7% on their Matrix B);
  * the **adaptive ND** of §III-A: separator size = bandwidth (+ arrow
    columns), separator moved to the *end* of the matrix, preserving the
    arrowhead shape while exposing independent partitions (Fig. 4).

All orderings are evaluated with the paper's acceptance rule: "the number of
fill-ins is evaluated before and after the ordering; if there is no
improvement, the method is not used."
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np
import scipy.sparse as sp
from scipy.sparse import csgraph

from .structure import ArrowheadStructure, TileGrid, measure_arrowhead, tile_pattern_from_coo

__all__ = [
    "OrderingResult",
    "rcm_ordering",
    "amd_ordering",
    "adaptive_nd_ordering",
    "metis_like_nd_ordering",
    "best_ordering",
    "apply_permutation",
    "tile_fill_in",
]


@dataclasses.dataclass
class OrderingResult:
    name: str
    perm: np.ndarray            # new_index -> old_index
    fill_before: int
    fill_after: int
    accepted: bool
    partitions: Optional[np.ndarray] = None  # ND only: partition id per new index

    @property
    def improvement(self) -> float:
        if self.fill_before == 0:
            return 0.0
        return 1.0 - self.fill_after / max(1, self.fill_before)


# ---------------------------------------------------------------------------
# Fill-in evaluation (tile level — what sTiles actually allocates)
# ---------------------------------------------------------------------------

def _symbolic_elimination_tiles(tile_lower: np.ndarray) -> np.ndarray:
    """Tile-level symbolic Cholesky: returns the L tile pattern.

    Classic column elimination on the (small) tile graph: eliminating column
    k joins all its below-diagonal neighbours into a clique — restricted to
    the standard quotient-graph shortcut of only linking to the first
    neighbour's column (etree-based transitive reduction would be cheaper;
    tile counts are small so direct set propagation is fine).
    """
    nt = tile_lower.shape[0]
    patt = [set(np.nonzero(tile_lower[:, k])[0][np.nonzero(tile_lower[:, k])[0] > k])
            for k in range(nt)]
    for k in range(nt):
        nbrs = sorted(patt[k])
        if not nbrs:
            continue
        p = nbrs[0]  # etree parent: fill propagates to parent column
        patt[p].update(x for x in nbrs if x > p)
    L = np.zeros_like(tile_lower)
    for k in range(nt):
        L[k, k] = True
        for r in patt[k]:
            L[r, k] = True
    return L


def tile_fill_in(pattern: sp.spmatrix, structure: ArrowheadStructure, t: int,
                 total: bool = False) -> int:
    """Fill tiles created by factorization (|L_tiles| - |A_tiles|), or with
    ``total=True`` the factor's allocated tile count |L_tiles| — the quantity
    that decides storage and FLOPs (a scrambled matrix has *few* fill tiles
    because every tile is already dirty; |L| exposes that)."""
    grid = TileGrid(structure, t)
    a_tiles = tile_pattern_from_coo(pattern, grid)
    l_tiles = _symbolic_elimination_tiles(a_tiles)
    if total:
        return int(l_tiles.sum())
    return int(l_tiles.sum() - a_tiles.sum())


# ---------------------------------------------------------------------------
# Orderings
# ---------------------------------------------------------------------------

def _partial_wrap(perm_diag: np.ndarray, n: int, nd: int) -> np.ndarray:
    """Extend a permutation of the diagonal part with identity on the arrow."""
    perm = np.empty(n, dtype=np.int64)
    perm[:nd] = perm_diag
    perm[nd:] = np.arange(nd, n)
    return perm


def rcm_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                 partial: bool = True) -> np.ndarray:
    """(Partial) Reverse Cuthill-McKee.

    ``partial=True`` is the paper's recommended variant: RCM runs on the
    banded diagonal part only, the arrowhead block keeps its position.
    """
    n, nd = structure.n, structure.n_diag
    csr = sp.csr_matrix(pattern)
    if partial and structure.arrow > 0:
        sub = csr[:nd, :nd]
        perm_diag = np.asarray(csgraph.reverse_cuthill_mckee(sub, symmetric_mode=True),
                               dtype=np.int64)
        return _partial_wrap(perm_diag, n, nd)
    return np.asarray(csgraph.reverse_cuthill_mckee(csr, symmetric_mode=True), dtype=np.int64)


def amd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                 partial: bool = True) -> np.ndarray:
    """Approximate minimum degree (simplified quotient-graph AMD).

    Selects the node of (approximate) least external degree, eliminates it,
    and represents the resulting clique implicitly through element lists —
    the same mechanism AMD [Amestoy/Davis/Duff] uses, without supervariable
    detection (adequate for the moderate graph sizes sTiles preprocesses).
    """
    n, nd = structure.n, structure.n_diag
    csr = sp.csr_matrix(pattern)
    target = csr[:nd, :nd] if (partial and structure.arrow > 0) else csr
    m = target.shape[0]

    adj: list = [set(target.indices[target.indptr[i]:target.indptr[i + 1]]) - {i}
                 for i in range(m)]
    elements: list = [set() for _ in range(m)]  # elements adjacent to each var
    elem_members: Dict[int, set] = {}
    alive = np.ones(m, dtype=bool)
    degree = np.array([len(a) for a in adj], dtype=np.int64)
    order = np.empty(m, dtype=np.int64)

    import heapq
    heap = [(int(degree[i]), i) for i in range(m)]
    heapq.heapify(heap)
    stamp = 0
    for pos in range(m):
        while True:
            d, v = heapq.heappop(heap)
            if alive[v] and d == degree[v]:
                break
        order[pos] = v
        alive[v] = False
        # Build the new element (clique) = adj(v) U members of v's elements.
        clique = set(x for x in adj[v] if alive[x])
        for e in elements[v]:
            clique.update(x for x in elem_members[e] if alive[x])
        clique.discard(v)
        eid = stamp
        stamp += 1
        elem_members[eid] = clique
        for u in clique:
            adj[u].discard(v)
            elements[u] -= elements[v]
            elements[u].add(eid)
            # approximate degree: |adj| + sum of element sizes (upper bound)
            degree[u] = len([x for x in adj[u] if alive[x]]) + sum(
                len(elem_members[e]) for e in elements[u])
            heapq.heappush(heap, (int(degree[u]), u))
        for e in elements[v]:
            elem_members[e].discard(v)

    if partial and structure.arrow > 0:
        return _partial_wrap(order, n, nd)
    return order


def adaptive_nd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                         n_parts: int = 2) -> OrderingResult:
    """The paper's adaptive nested dissection (§III-A, Fig. 4).

    1. The separator size equals the bandwidth (arrow columns are already at
       the end and act as a global separator).
    2. The separator — the ``bandwidth`` columns straddling each partition
       boundary — is moved towards the end of the matrix, preserving the
       arrowhead shape and leaving ``n_parts`` independent diagonal
       partitions.
    """
    n, nd, bw = structure.n, structure.n_diag, structure.bandwidth
    if n_parts < 2 or nd <= n_parts * (bw + 1):
        ident = np.arange(n, dtype=np.int64)
        return OrderingResult("adaptive_nd", ident, 0, 0, accepted=False)

    cuts = [round(nd * p / n_parts) for p in range(1, n_parts)]
    sep_mask = np.zeros(nd, dtype=bool)
    for c in cuts:
        lo, hi = max(0, c - (bw + 1) // 2), min(nd, c + (bw + 1) // 2)
        sep_mask[lo:hi] = True

    part_idx = np.nonzero(~sep_mask)[0]
    sep_idx = np.nonzero(sep_mask)[0]
    perm = np.concatenate([part_idx, sep_idx, np.arange(nd, n)]).astype(np.int64)

    # partition ids in the *new* ordering (for distributed factorization)
    parts = np.full(n, -1, dtype=np.int64)
    bounds = [0] + cuts + [nd]
    pid_of_old = np.zeros(nd, dtype=np.int64)
    for p in range(n_parts):
        pid_of_old[bounds[p]:bounds[p + 1]] = p
    parts[:len(part_idx)] = pid_of_old[part_idx]
    return OrderingResult("adaptive_nd", perm, 0, 0, accepted=True, partitions=parts)


def metis_like_nd_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure,
                           levels: int = 2) -> np.ndarray:
    """Generic (METIS-style) recursive nested dissection via spectral-free
    BFS bisection, used as the baseline ND the paper compares against.

    Recursively: pick a pseudo-peripheral node, BFS-level the graph, take the
    median level as separator, recurse on the two halves, emit
    [left, right, separator].
    """
    csr = sp.csr_matrix(pattern)
    n = csr.shape[0]

    def dissect(nodes: np.ndarray, depth: int) -> np.ndarray:
        if depth == 0 or len(nodes) < 32:
            return nodes
        sub = csr[nodes][:, nodes]
        order = np.asarray(csgraph.reverse_cuthill_mckee(sub, symmetric_mode=True))
        # BFS-levelled order: separator = middle slice of width ~ sqrt degree
        mid = len(nodes) // 2
        width = max(1, int(np.sqrt(sub.nnz / max(1, len(nodes)))) * 4)
        lo, hi = max(0, mid - width), min(len(nodes), mid + width)
        left, sep, right = order[:lo], order[lo:hi], order[hi:]
        return np.concatenate([
            dissect(nodes[left], depth - 1),
            dissect(nodes[right], depth - 1),
            nodes[sep],
        ])

    return dissect(np.arange(n, dtype=np.int64), levels)


def apply_permutation(mat: sp.spmatrix, perm: np.ndarray) -> sp.csc_matrix:
    """Symmetric permutation P A P^T with perm[new] = old."""
    csr = sp.csc_matrix(mat)
    return sp.csc_matrix(csr[perm][:, perm])


# ---------------------------------------------------------------------------
# Ordering selection (paper's acceptance rule + per-structure guidance)
# ---------------------------------------------------------------------------

_CANDIDATES: Dict[str, Callable] = {
    "partial_rcm": lambda A, s: rcm_ordering(A, s, partial=True),
    "rcm": lambda A, s: rcm_ordering(A, s, partial=False),
    "partial_amd": lambda A, s: amd_ordering(A, s, partial=True),
}


def best_ordering(pattern: sp.spmatrix, structure: ArrowheadStructure, t: int,
                  candidates=None) -> OrderingResult:
    """Try candidate orderings; keep the best; reject if no fill improvement.

    Implements the paper's guidance table: partial RCM preferred for
    band-narrowing, AMD for irregular patterns, adaptive ND handled
    separately (it optimizes parallelism, not fill).
    """
    base_fill = tile_fill_in(pattern, structure, t, total=True)
    best_name, best_perm, best_fill = "identity", np.arange(structure.n, dtype=np.int64), base_fill
    for name in (candidates or _CANDIDATES):
        perm = _CANDIDATES[name](pattern, structure)
        permuted = apply_permutation(pattern, perm)
        new_struct = measure_arrowhead(permuted, arrow_hint=structure.arrow)
        fill = tile_fill_in(permuted, new_struct, t, total=True)
        if fill < best_fill:
            best_name, best_perm, best_fill = name, perm, fill
    return OrderingResult(best_name, best_perm, base_fill, best_fill,
                          accepted=best_name != "identity")
