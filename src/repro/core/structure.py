"""Structural description of block-arrowhead sparse matrices.

The paper (sTiles, §I / §III) targets symmetric positive-definite matrices
whose nonzeros live in (i) a band of variable width around the diagonal and
(ii) a dense "arrowhead" occupying the last ``arrow`` rows/columns.  This
module measures and represents that structure at both the element level and
the tile level; everything here is host-side numpy (the paper's
"preprocessing phase") — no jax.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp

__all__ = [
    "ArrowheadStructure",
    "TileGrid",
    "measure_arrowhead",
    "tile_pattern_from_coo",
    "banded_arrowhead_tile_pattern",
]


@dataclasses.dataclass(frozen=True)
class ArrowheadStructure:
    """Element-level description of a block-arrowhead SPD matrix.

    Attributes:
      n:          full matrix dimension.
      bandwidth:  max |i - j| over nonzeros with both i, j < n - arrow.
      arrow:      thickness of the dense trailing block ("arrowhead region").
    """

    n: int
    bandwidth: int
    arrow: int

    def __post_init__(self):
        if self.arrow < 0 or self.arrow > self.n:
            raise ValueError(f"arrow={self.arrow} out of range for n={self.n}")
        if self.bandwidth < 0:
            raise ValueError("bandwidth must be >= 0")

    @property
    def n_diag(self) -> int:
        """Size of the banded (non-arrow) leading part."""
        return self.n - self.arrow

    def density(self) -> float:
        """Fraction of nonzero elements implied by the structure (full sym)."""
        nd, b, a = self.n_diag, self.bandwidth, self.arrow
        band = sum(min(b, nd - 1 - i) for i in range(nd)) * 2 + nd
        arrowhead = 2 * a * nd + a * a
        return (band + arrowhead) / float(self.n * self.n)


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Tile-level view of an :class:`ArrowheadStructure` (paper §III-B).

    The tile size ``t`` is the paper's key performance knob (120 on CPU /
    600 on GPU there; multiples of 128 on TPU here — see DESIGN.md §2).
    The diagonal part is padded up to a whole number of tiles; the arrow part
    likewise.  Tiles are indexed by (row_tile, col_tile) over the padded
    matrix.
    """

    structure: ArrowheadStructure
    t: int  # tile size

    def __post_init__(self):
        if self.t <= 0:
            raise ValueError("tile size must be positive")

    @classmethod
    def from_tile_counts(cls, t: int, n_diag_tiles: int, band_tiles: int,
                         n_arrow_tiles: int) -> "TileGrid":
        """Construct the *tile-aligned* grid with exactly the given tile
        counts — the canonical-grid constructor of
        :mod:`repro.core.gridpolicy`.

        The underlying :class:`ArrowheadStructure` is chosen so every
        derived property round-trips (``n_diag = n_diag_tiles * t``,
        ``arrow = n_arrow_tiles * t``, ``bandwidth = band_tiles*t - 1``),
        i.e. ``padded_n == n`` and ``padded_index`` is the identity.  Two
        calls with equal tile counts produce equal (hashable) grids, which
        is what makes canonical grids usable as compile-cache keys.
        """
        if n_diag_tiles < 0 or n_arrow_tiles < 0 or band_tiles < 0:
            raise ValueError("tile counts must be >= 0")
        if n_diag_tiles == 0 and band_tiles > 0:
            raise ValueError("band_tiles > 0 needs a diagonal part")
        if n_diag_tiles > 0 and band_tiles > n_diag_tiles - 1:
            raise ValueError(
                f"band_tiles={band_tiles} exceeds n_diag_tiles-1="
                f"{n_diag_tiles - 1}")
        if n_diag_tiles > 1 and band_tiles == 0:
            # the band_tiles property maps any bandwidth >= 0 to >= 1 when
            # there is more than one diagonal tile, so bt=0 is representable
            # only for single-tile (or empty) diagonal parts
            raise ValueError("band_tiles=0 needs n_diag_tiles <= 1")
        structure = ArrowheadStructure(
            n=(n_diag_tiles + n_arrow_tiles) * t,
            bandwidth=max(band_tiles * t - 1, 0),
            arrow=n_arrow_tiles * t)
        grid = cls(structure, t)
        derived = (grid.n_diag_tiles, grid.band_tiles, grid.n_arrow_tiles)
        if derived != (n_diag_tiles, band_tiles, n_arrow_tiles):
            # the round-trip is what makes canonical grids trustworthy as
            # compile-cache keys — fail loudly even under `python -O`
            raise RuntimeError(
                f"tile-count round-trip failed: requested "
                f"{(n_diag_tiles, band_tiles, n_arrow_tiles)}, derived "
                f"{derived} (constructor bug)")
        return grid

    @property
    def n_diag_tiles(self) -> int:
        return max(1, math.ceil(self.structure.n_diag / self.t)) if self.structure.n_diag > 0 else 0

    @property
    def n_arrow_tiles(self) -> int:
        return math.ceil(self.structure.arrow / self.t) if self.structure.arrow > 0 else 0

    @property
    def n_tiles(self) -> int:
        return self.n_diag_tiles + self.n_arrow_tiles

    @property
    def band_tiles(self) -> int:
        """Number of sub-diagonal tile rows that can hold band nonzeros.

        An element pair (i, j) with i - j <= bandwidth maps to tiles whose
        row-tile/col-tile offset is at most ceil stated below; this is the
        `b` of the banded window backend.
        """
        if self.structure.n_diag == 0:
            return 0
        return min(self.n_diag_tiles - 1,
                   math.ceil((self.structure.bandwidth + 1) / self.t - 1e-12))

    @property
    def padded_n(self) -> int:
        return self.n_tiles * self.t

    def elem_to_tile(self, i: int, j: int) -> Tuple[int, int]:
        return i // self.t, j // self.t

    def padded_index(self, i: int) -> int:
        """Map an element index of the original matrix into the padded one.

        Diagonal part occupies [0, n_diag) -> [0, n_diag) (pad after), arrow
        part occupies [n_diag, n) -> [n_diag_tiles*t, ...).
        """
        s = self.structure
        if i < s.n_diag:
            return i
        return self.n_diag_tiles * self.t + (i - s.n_diag)


def measure_arrowhead(pattern: sp.spmatrix, arrow_hint: Optional[int] = None,
                      arrow_density_threshold: float = 0.5) -> ArrowheadStructure:
    """Measure bandwidth and arrow thickness of a sparse symmetric pattern.

    The paper's preprocessing "computes the bandwidth" (§III-A, proposed ND
    step 1).  Arrow thickness is detected as the largest trailing row block
    whose rows are denser than ``arrow_density_threshold`` relative to a
    dense row, unless ``arrow_hint`` is given (applications such as INLA know
    the number of fixed effects a priori).
    """
    coo = sp.coo_matrix(pattern)
    n = coo.shape[0]
    if coo.shape[0] != coo.shape[1]:
        raise ValueError("pattern must be square")
    rows, cols = coo.row, coo.col

    if arrow_hint is not None:
        arrow = int(arrow_hint)
    else:
        # Row nonzero counts; scan from the bottom while rows look dense.
        counts = np.bincount(rows, minlength=n)
        arrow = 0
        for i in range(n - 1, -1, -1):
            if counts[i] >= arrow_density_threshold * (i + 1):
                arrow += 1
            else:
                break
        arrow = min(arrow, n - 1)

    nd = n - arrow
    mask = (rows < nd) & (cols < nd)
    if mask.any():
        bandwidth = int(np.abs(rows[mask] - cols[mask]).max())
    else:
        bandwidth = 0
    return ArrowheadStructure(n=n, bandwidth=bandwidth, arrow=arrow)


def tile_pattern_from_coo(pattern: sp.spmatrix, grid: TileGrid) -> np.ndarray:
    """Boolean (n_tiles, n_tiles) lower-triangular tile nonzero map (CTSF map).

    Element (i, j) of the (symmetrized, lower) pattern marks tile
    (i//t, j//t); this is exactly the paper's Fig. 5 mapping.  Only tiles
    that receive at least one element are marked — sTiles allocates nothing
    for all-zero tiles.
    """
    coo = sp.coo_matrix(pattern)
    nt = grid.n_tiles
    out = np.zeros((nt, nt), dtype=bool)
    pi = np.vectorize(grid.padded_index, otypes=[np.int64])
    r = pi(np.maximum(coo.row, coo.col))
    c = pi(np.minimum(coo.row, coo.col))
    out[r // grid.t, c // grid.t] = True
    out[np.arange(nt), np.arange(nt)] = True  # diagonal tiles always exist
    return np.tril(out)


def banded_arrowhead_tile_pattern(grid: TileGrid) -> np.ndarray:
    """Dense-band tile pattern implied by the structure alone (no zeros inside
    the band). This is what the `window` backend factorizes; the difference
    between this and :func:`tile_pattern_from_coo` is the paper's
    'extra flops vs. regularity' trade (§I)."""
    nt, ndt, b = grid.n_tiles, grid.n_diag_tiles, grid.band_tiles
    out = np.zeros((nt, nt), dtype=bool)
    for k in range(ndt):
        out[k:min(ndt, k + b + 1), k] = True
    out[ndt:, :] = True  # arrow rows are dense
    return np.tril(out)
