"""Sparse tile Cholesky factorization — the paper's core (Algorithms 1–3).

Two numerical backends over the CTSF layouts:

* :func:`factorize_tasklist` — **paper-faithful**: executes the exact static
  task list from symbolic factorization (Algorithm 1 order = Algorithm 2's
  per-thread Task Assignment Tables, with XLA's static scheduler standing in
  for the progress table).  Operates on the general CTSF, touching only
  nonzero(+fill) tiles.  Optional tree reduction (Algorithm 3) groups each
  destination tile's accumulation chain.

* :func:`factorize_window` — **TPU-native** (beyond-paper, DESIGN.md §4):
  for the regular banded-arrowhead layout, the whole band + arrow
  factorization is one sweep-level primitive
  (``kernels.ops.band_cholesky_sweep``): on the Pallas backend a *single
  fused kernel launch* walks the band with the panel ring resident in
  VMEM (``sweep="fused"``); on the jnp backend a ring-buffer ``lax.scan``
  dispatches per-panel tile ops.  Corner Schur partial sums ride the
  sweep as tree-reduction chunks.

Both produce bit-comparable factors (tests assert allclose against
`jnp.linalg.cholesky` of the dense matrix).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import sweep_status
from repro.kernels.ring import band_col_to_row, band_row_to_col
from repro.runtime import telemetry
from .batching import LRUCache, bucketed_batched_call
from .ctsf import BandedCTSF, TileMatrix
from .robustness import (FactorInfo, RegularizePolicy, fold_corner_status,
                         run_ladder)
from .structure import TileGrid
from .symbolic import Task, TaskType
from .options import SolverOptions, UNSET, resolve_options
from .tree_reduction import chunked_tree_sum, should_use_tree, tree_combine

__all__ = ["factorize_tasklist", "factorize_window",
           "factorize_window_batched", "CholeskyFactor"]

_HI = jax.lax.Precision.HIGHEST


# ---------------------------------------------------------------------------
# Task-list backend (paper-faithful)
# ---------------------------------------------------------------------------

def _group_tasks_by_column(tasks: List[Task]):
    """Regroup Alg. 1's flat task list into per-column phases:
    (k, syrk_srcs, [(m, gemm_pairs, has_trsm)...]).
    """
    cols: Dict[int, dict] = {}
    for t in tasks:
        c = cols.setdefault(t.k, {"syrk": [], "panel": {}})
        if t.type == TaskType.SYRK:
            c["syrk"].append(t.n)
        elif t.type == TaskType.GEMM:
            c["panel"].setdefault(t.m, {"gemm": [], "trsm": False})
            c["panel"][t.m]["gemm"].append(t.n)
        elif t.type == TaskType.TRSM:
            c["panel"].setdefault(t.m, {"gemm": [], "trsm": False})
            c["panel"][t.m]["trsm"] = True
    return cols


class _StaticSpec:
    """Hashable wrapper for the (slot map, column-grouped task list)."""

    def __init__(self, slot, cols):
        self._key = (slot, cols)
        self.slot = dict(slot)
        self.cols = {k: {"syrk": list(s),
                         "panel": {m: {"gemm": list(g), "trsm": tr}
                                   for (m, g, tr) in panel}}
                     for (k, s, panel) in cols}

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _StaticSpec) and self._key == other._key

    def __iter__(self):  # unpack as (slot, cols)
        return iter((self.slot, self.cols))


@functools.partial(jax.jit, static_argnames=("tm_static", "impl", "tree_workers"))
def _factorize_tasklist_impl(tiles, tm_static, impl, tree_workers):
    slot, cols = tm_static
    for k in sorted(cols):
        col = cols[k]
        kk = slot[(k, k)]
        # --- SYRK accumulation chain on the diagonal tile ------------------
        srcs = [slot[(k, n)] for n in col["syrk"]]
        if srcs:
            if should_use_tree(len(srcs), tree_workers):
                gathered = tiles[jnp.asarray(srcs)]
                terms = jnp.einsum("nab,ncb->nac", gathered, gathered,
                                   precision=_HI)
                total = chunked_tree_sum(terms, tree_workers)
                tiles = tiles.at[kk].add(-total)
            else:
                for s in srcs:
                    tiles = tiles.at[kk].set(ops.syrk(tiles[kk], tiles[s], impl=impl))
        tiles = tiles.at[kk].set(ops.potrf(tiles[kk], impl=impl))
        # --- panel: GEMM chains + TRSM per below-diagonal tile -------------
        for m in sorted(col["panel"]):
            ent = col["panel"][m]
            mk = slot[(m, k)]
            pairs = [(slot[(m, n)], slot[(k, n)]) for n in ent["gemm"]]
            if pairs:
                if should_use_tree(len(pairs), tree_workers):
                    a = tiles[jnp.asarray([p[0] for p in pairs])]
                    b = tiles[jnp.asarray([p[1] for p in pairs])]
                    terms = jnp.einsum("nab,ncb->nac", a, b, precision=_HI)
                    total = chunked_tree_sum(terms, tree_workers)
                    tiles = tiles.at[mk].add(-total)
                else:
                    for sa, sb in pairs:
                        tiles = tiles.at[mk].set(
                            ops.gemm(tiles[mk], tiles[sa], tiles[sb], impl=impl))
            if ent["trsm"]:
                tiles = tiles.at[mk].set(ops.trsm(tiles[kk], tiles[mk], impl=impl))
    return tiles


def factorize_tasklist(tm: TileMatrix, impl: Optional[str] = None,
                       tree_reduction: bool = False,
                       tree_workers: int = 8) -> jnp.ndarray:
    """Run Algorithm 1/2 over the general CTSF.  Returns the L tile buffer
    (same slot map as ``tm``)."""
    cols = _group_tasks_by_column(tm.symbolic.tasks)
    # freeze python structures into hashable static arg
    frozen_cols = tuple(sorted(
        (k, tuple(v["syrk"]),
         tuple(sorted((m, tuple(e["gemm"]), e["trsm"])
                      for m, e in v["panel"].items())))
        for k, v in cols.items()))
    slot = tuple(sorted((k, v) for k, v in tm.slot.items()))
    static = _StaticSpec(slot, frozen_cols)
    workers = tree_workers if tree_reduction else 0
    return _factorize_tasklist_impl(tm.tiles, static, impl, workers)


# ---------------------------------------------------------------------------
# Window backend (TPU-native)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CholeskyFactor:
    """Factor L in banded-arrowhead CTSF layout.

    ``source_grid`` is set when the factor lives on a *canonical* grid
    (``core/gridpolicy.py``) but represents a problem measured on
    ``source_grid``: the CTSF arrays then hold ``blockdiag(I_prefix, L)``
    and the policy-aware solve/selinv entry points embed right-hand sides
    in and restrict results back automatically.  :meth:`restrict` strips
    the embedding when the raw factor is wanted.

    ``info`` is attached when the factorization ran under a
    ``regularize=`` policy: per-element numerical status (OK / RECOVERED
    with diagonal jitter / FAILED), attempts, applied jitter and minimum
    pivot — see :class:`~repro.core.robustness.FactorInfo`.  Serving
    callers should consult ``info`` instead of expecting exceptions; a
    FAILED element's factor is numerically unusable but never poisons its
    batch siblings.
    """
    ctsf: BandedCTSF
    source_grid: Optional[TileGrid] = None
    info: Optional[FactorInfo] = None

    def restrict(self) -> "CholeskyFactor":
        """Slice a canonical-grid factor back onto its source grid (no-op
        for factors that were never embedded)."""
        if self.source_grid is None:
            return self
        from .gridpolicy import restrict_factor
        return restrict_factor(self, self.source_grid)

    def logdet(self) -> jnp.ndarray:
        """log det A = 2 * sum log diag(L); padded diagonal entries are 1
        (including the identity prefix of a canonical-grid embedding, so
        embedded factors report the source problem's log-determinant).
        Leading batch axes (``factorize_window_batched`` /
        ``concurrent_factorize`` factors) broadcast: a batched factor
        returns a ``(batch,)`` vector."""
        g = self.ctsf.grid
        d0 = jnp.take(self.ctsf.Dr, 0, axis=-3)          # (..., ndt, t, t)
        db = jnp.diagonal(d0, axis1=-2, axis2=-1)        # (..., ndt, t)
        total = jnp.sum(jnp.log(jnp.abs(db)), axis=(-2, -1))
        if g.n_arrow_tiles > 0:
            ct = jnp.diagonal(self.ctsf.C, axis1=-4, axis2=-3)  # (..., t, t, nat)
            dc = jnp.diagonal(ct, axis1=-3, axis2=-2)           # (..., t, nat)
            total = total + jnp.sum(jnp.log(jnp.abs(dc)), axis=(-2, -1))
        return 2.0 * total


def _corner_dense_cholesky(c: jnp.ndarray, impl: Optional[str]) -> jnp.ndarray:
    """Blocked dense Cholesky of the (nat, nat, t, t) corner.

    Left-looking over columns as a single ``lax.fori_loop``: each step does
    one masked batched SYRK/GEMM contraction over the finalized columns plus
    a batched TRSM of the whole sub-diagonal panel.  Trace/compile size is
    O(nat) instead of the O(nat²) of the previous Python-unrolled tile
    loops — the difference between seconds and minutes of XLA compile for
    thick arrows — while tiny corners lower to the same handful of kernels.
    """
    nat, t = c.shape[0], c.shape[-1]
    rows = jnp.arange(nat)

    def col_step(k, c):
        done = (rows < k)[:, None, None]                # finalized columns j<k
        row_k = jax.lax.dynamic_slice(c, (k, 0, 0, 0), (1, nat, t, t))[0]
        rk = jnp.where(done, row_k, 0.0)                # L[k, :k], zero-padded
        ckk = jax.lax.dynamic_slice(c, (k, k, 0, 0), (1, 1, t, t))[0, 0]
        syrk_acc = jnp.einsum("jab,jcb->ac", rk, rk, precision=_HI)
        lkk = ops.potrf(ckk - syrk_acc, impl=impl)
        col_k = jax.lax.dynamic_slice(c, (0, k, 0, 0), (nat, 1, t, t))[:, 0]
        # masked rk zeroes the j>=k terms, so unfactorized columns of c
        # contribute nothing to the GEMM accumulation
        gemm_acc = jnp.einsum("mjab,jcb->mac", c, rk, precision=_HI)
        panel = ops.trsm(lkk, col_k - gemm_acc, impl=impl)
        new_col = jnp.where((rows > k)[:, None, None], panel,
                            jnp.where((rows == k)[:, None, None],
                                      lkk[None], col_k))
        return jax.lax.dynamic_update_slice(c, new_col[:, None], (0, k, 0, 0))

    return jax.lax.fori_loop(0, nat, col_step, c)


def _band_arrow_sweep_ring(Dr, R, grid, impl, tree_chunks: int = 1):
    """Band + arrow factorization through the sweep-level primitive
    (``kernels.ops.band_cholesky_sweep``) — the (Dr, R) -> (Dr_L, R_L,
    schur) entry point ``core/distributed.py`` vmaps over shards.  The
    per-chunk corner-Schur partial sums come straight from the sweep (the
    fused kernel accumulates them on the fly), so callers must not
    re-contract R_L.  ``impl="pallas"`` = one fused kernel launch;
    ``"ref"`` = the ring-buffer ``lax.scan``.  The sweep's breakdown
    status word is dropped here — the distributed path does its own
    health checks at the shard level."""
    panels, R_out, schur, _status = ops.band_cholesky_sweep(
        band_row_to_col(Dr), R, nchunks=tree_chunks, impl=impl)
    return band_col_to_row(panels), R_out, schur


def _band_arrow_sweep(Dr, R, grid, impl, start_tile=0):
    """The sequential panel sweep (thin critical path): factor the band and
    arrow rows, leaving the corner untouched.  Returns (Dr_L, R_L).

    ``start_tile`` skips the first rows of the sweep, leaving their input
    values in place — correct exactly when they are the identity-embedding
    prefix of a canonical grid (whose factor equals the input)."""
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    b1 = bt + 1

    # pad: bt trailing zero rows on Dr (window slack), bt leading on R
    Drp = jnp.pad(Dr, ((0, bt), (0, 0), (0, 0), (0, 0)))
    Rp = jnp.pad(R, ((bt, 0), (0, 0), (0, 0), (0, 0))) if nat else R

    erange = jnp.arange(b1)

    def panel_step(k, carry):
        Drp, Rp = carry
        w = jax.lax.dynamic_slice(Drp, (k, 0, 0, 0), (b1, b1, t, t))
        u = ops.band_update(w, impl=impl)                       # (b1, t, t)
        lkk = ops.potrf(w[0, 0] - u[0], impl=impl)
        # sub-diagonal panel tiles A[k+e, k] live on the window diagonal
        amk = w[erange[1:], erange[1:]] - u[1:]
        lmk = ops.trsm(lkk, amk, impl=impl)
        vals = jnp.concatenate([lkk[None], lmk], axis=0)
        Drp = Drp.at[k + erange, erange].set(vals)
        if nat:
            rwin = jax.lax.dynamic_slice(Rp, (k, 0, 0, 0), (bt, nat, t, t)) \
                if bt else jnp.zeros((0, nat, t, t), Rp.dtype)
            # V[i] = sum_{j=1..bt} R[k-j, i] @ L[k, k-j]^T ; rwin[bt-j] = R[k-j]
            w0rev = jnp.flip(w[0, 1:], axis=0) if bt else jnp.zeros((0, t, t), w.dtype)
            v = jnp.einsum("jiab,jcb->iac", rwin, w0rev, precision=_HI) \
                if bt else 0.0
            lak = ops.trsm(lkk, Rp[k + bt] - v, impl=impl)
            Rp = jax.lax.dynamic_update_slice(Rp, lak[None], (k + bt, 0, 0, 0))
        return (Drp, Rp)

    Drp, Rp = jax.lax.fori_loop(start_tile, ndt, panel_step, (Drp, Rp))
    Dr_out = Drp[:ndt]
    R_out = Rp[bt:] if nat else R
    return Dr_out, R_out


def _corner_schur(R_L: jnp.ndarray, tree_chunks: int) -> jnp.ndarray:
    """sum_n R[n] R[n]^T over all band columns — the paper's flagship
    accumulation chain, computed via Alg. 3's chunked tree."""
    ndt = R_L.shape[0]
    terms = jnp.einsum("niab,njcb->nijac", R_L, R_L, precision=_HI)
    chunks = tree_chunks if tree_chunks else 1
    if should_use_tree(ndt, chunks):
        return chunked_tree_sum(terms, chunks)
    return terms.sum(axis=0)


@functools.partial(jax.jit,
                   static_argnames=("grid", "impl", "tree_chunks", "sweep",
                                    "plan"))
def _factorize_window_impl(Dr, R, C, grid, impl, tree_chunks, sweep="auto",
                           start_tile=0, plan=None):
    """Window factorization with sweep-mode dispatch:

    * ``"auto"`` (default) — ``"partitioned"`` when ``plan`` (a
      :class:`~repro.core.ordering.PartitionPlan`) has more than one
      partition; else ``"fused"`` on the Pallas backend (native TPU or an
      explicit ``impl="pallas"``), else ``"ring"``: every caller
      (:func:`factorize_window`, :func:`factorize_window_batched`,
      ``concurrent_factorize``) rides the fused kernel wherever Pallas is
      the kernel backend.
    * ``"fused"`` — force the single-launch Pallas sweep
      (``kernels/band_cholesky.py``).
    * ``"ring"`` — force the ring-buffer ``lax.scan`` reference.
    * ``"window"`` — the legacy dynamic-slice window sweep
      (``kernels.band_update`` per panel), kept for comparison.
    * ``"partitioned"`` — the multi-partition fused sweep
      (``kernels.ops.band_cholesky_partitioned_sweep``): one 2D-grid
      launch over all of ``plan``'s independent band partitions, their
      per-partition corner-Schur leaves tree-combined before the shared
      corner factorization.  Requires a ``plan``; a trivial
      single-partition plan stays on the fused/ring path so its factor is
      bit-identical to a plan-less call.

    The fused/ring paths read the corner Schur complement from the sweep's
    per-chunk partial sums (accumulated on the fly in the fused kernel)
    instead of re-contracting R_out from HBM.

    ``start_tile`` declares the first band columns an identity-embedding
    prefix (``core/gridpolicy.py``); callers omit it on the plain path so
    the argument stays a trace-time constant 0 (keeping the static loop
    bounds), and pass a *traced* scalar on the canonical-grid path so
    distinct pad depths share one compilation per canonical grid.

    Returns ``(Dr_L, R_L, C_L, status)`` — ``status`` the (3,) float32
    breakdown word ``[min_pivot, nonfinite, first_bad]`` covering band
    *and* corner (a corner breakdown reports ``first_bad = ndt``).  It is
    carried in-graph with no host sync; the jitter ladder
    (``core/robustness.py``) is the consumer."""
    nat = grid.n_arrow_tiles
    if sweep not in ("auto", "fused", "ring", "window", "partitioned"):
        raise ValueError(f"unknown sweep {sweep!r} (want 'auto', 'fused', "
                         "'ring', 'window' or 'partitioned')")
    # "ring" is the jnp scan and "fused" the Pallas kernel by definition —
    # an explicit impl pointing the other way would silently run a
    # different backend than asked, so refuse the contradiction.
    if (sweep == "ring" and impl == "pallas") or \
            (sweep == "fused" and impl in ("ref", "unrolled")):
        raise ValueError(
            f"sweep={sweep!r} contradicts impl={impl!r}: the ring sweep is "
            "the jnp reference scan and the fused sweep is the Pallas "
            "kernel; use sweep='auto' to dispatch by impl")
    if sweep == "partitioned" and plan is None:
        raise ValueError(
            "sweep='partitioned' needs a partition plan: pass "
            "options=SolverOptions(partition_plan=...) (see "
            "core.ordering.detect_partition_plan)")
    if plan is not None and plan.n_tiles != grid.n_diag_tiles:
        raise ValueError(
            f"partition plan covers {plan.n_tiles} diagonal tiles but the "
            f"grid has {grid.n_diag_tiles}; rebuild the plan for this grid "
            "(PartitionPlan.shifted embeds a plan into a canonical grid)")
    mode = sweep
    if mode == "auto":
        if plan is not None and plan.n_partitions > 1:
            mode = "partitioned"
        else:
            mode = "fused" if (impl or ops.default_impl()) == "pallas" \
                else "ring"
    if mode == "partitioned":
        panels, R_out, schur, status = ops.band_cholesky_partitioned_sweep(
            band_row_to_col(Dr), R, plan.boundaries, start_tile=start_tile,
            impl=impl)
        Dr_out = band_col_to_row(panels)
        if nat:
            # one Schur leaf per partition: combine them with the Alg. 3
            # binary tree before the shared separator/corner factorization
            C_out = _corner_dense_cholesky(C - tree_combine(schur), impl)
        else:
            C_out = C
        return Dr_out, R_out, C_out, fold_corner_status(
            status, C_out, grid.n_diag_tiles, nat)
    if mode == "window":
        Dr_out, R_out = _band_arrow_sweep(Dr, R, grid, impl, start_tile)
        # legacy sweep predates the in-sweep status carry: fold the same
        # word from the emitted factor (row layout keeps diag at [:, 0],
        # which is all ref.sweep_status reads)
        status = sweep_status(Dr_out, R_out)
        if nat:
            C_out = _corner_dense_cholesky(
                C - _corner_schur(R_out, tree_chunks), impl)
        else:
            C_out = C
        return Dr_out, R_out, C_out, fold_corner_status(
            status, C_out, grid.n_diag_tiles, nat)

    sweep_impl = "pallas" if mode == "fused" else "ref"
    nchunks = max(1, min(tree_chunks or 1, grid.n_diag_tiles or 1))
    panels, R_out, schur, status = ops.band_cholesky_sweep(
        band_row_to_col(Dr), R, nchunks=nchunks, start_tile=start_tile,
        impl=sweep_impl)
    Dr_out = band_col_to_row(panels)
    if nat:
        # the chunks are the tree-reduction leaves; summing them is the
        # root combine of the paper's Alg. 3 chain
        C_out = _corner_dense_cholesky(C - jnp.sum(schur, axis=0), impl)
    else:
        C_out = C
    return Dr_out, R_out, C_out, fold_corner_status(
        status, C_out, grid.n_diag_tiles, nat)


def _embed_matrix(m: BandedCTSF, policy):
    """Canonical-grid embedding of a matrix (or matrix batch) for the
    factorization entry points — the matrix-side mirror of
    ``solve._resolve_embedding``.  Returns ``(embedded, source_grid,
    start_tile)`` with ``start_tile`` the *traced* identity-prefix depth,
    so every pad depth shares the canonical grid's compilation."""
    from .gridpolicy import embed_ctsf
    cgrid = policy.canonicalize(m.grid)
    start = jnp.asarray(cgrid.n_diag_tiles - m.grid.n_diag_tiles, jnp.int32)
    return embed_ctsf(m, cgrid), m.grid, start


def factorize_window(m: BandedCTSF, impl=UNSET,
                     tree_chunks: int = 8,
                     sweep=UNSET, policy=UNSET,
                     regularize=UNSET,
                     options: Optional[SolverOptions] = None) -> CholeskyFactor:
    """Banded-arrowhead factorization (window backend).

    ``options`` (a :class:`~repro.core.options.SolverOptions`) carries the
    solver knobs — backend, sweep mode, bucketing policy, regularization
    and the partition plan; the bare ``impl=``/``sweep=``/``policy=``/
    ``regularize=`` kwargs are deprecated aliases for the matching fields
    (legacy wins when both are given, with a ``DeprecationWarning``).

    With ``options.impl="pallas"`` (or running natively on TPU) the whole
    band + arrow block factorizes in **one fused Pallas launch**
    (``kernels.ops.band_cholesky_sweep``); ``options.sweep`` overrides the
    dispatch (see :func:`_factorize_window_impl`).  An
    ``options.partition_plan`` with more than one partition upgrades the
    launch to the 2D partition-parallel sweep — critical path
    O(max partition tiles) instead of O(ndt).

    With a :class:`~repro.core.gridpolicy.GridBucketPolicy` the matrix is
    first embedded into its canonical grid (identity-diagonal padding) and
    the sweep skips the prefix via its traced ``start_tile`` — mixed-size
    traffic then compiles once per canonical rung instead of once per
    grid.  The returned factor lives on the canonical grid with
    ``source_grid`` set; the solve/selinv entry points consume it
    transparently, or :meth:`CholeskyFactor.restrict` strips the
    embedding.

    ``regularize`` opts into numerical fault tolerance: ``True`` (default
    :class:`~repro.core.robustness.RegularizePolicy`) or a policy runs the
    escalating-jitter retry ladder on breakdown and attaches a
    :class:`~repro.core.robustness.FactorInfo` to the returned factor
    instead of ever raising; an SPD input factorizes on the first attempt
    and its factor is bit-identical to the unregularized call."""
    opts = resolve_options(options, _where="factorize_window", impl=impl,
                           sweep=sweep, policy=policy, regularize=regularize)
    with telemetry.span("factorize.window",
                        grid=telemetry.rung_tag(m.grid)) as sp:
        pol = RegularizePolicy.resolve(opts.regularize)
        plan = opts.partition_plan
        source = None
        if opts.policy is not None:
            src_ndt = m.grid.n_diag_tiles
            m, source, start = _embed_matrix(m, opts.policy)
            sp.tag(rung=telemetry.rung_tag(m.grid))
            if plan is not None:
                # the canonical-grid identity prefix joins partition 0;
                # the pad depth is a Python int, so each (rung, pad) pair
                # is one compilation — same as the plan-less policy path
                plan = plan.shifted(m.grid.n_diag_tiles - src_ndt)
            call = lambda dr, r, c: _factorize_window_impl(
                dr, r, c, m.grid, opts.impl, tree_chunks, opts.sweep, start,
                plan=plan)
        else:
            call = lambda dr, r, c: _factorize_window_impl(
                dr, r, c, m.grid, opts.impl, tree_chunks, opts.sweep,
                plan=plan)
        if pol is None:
            Dr, R, C, _status = call(m.Dr, m.R, m.C)
            info = None
        else:
            Dr, R, C, info = run_ladder(m.Dr, m.R, m.C, m.grid, call, pol)
        return CholeskyFactor(BandedCTSF(m.grid, Dr, R, C),
                              source_grid=source, info=info)


# ---------------------------------------------------------------------------
# Batched window factorization (INLA θ-sweep serving path)
# ---------------------------------------------------------------------------

# bounded so long-running serving processes cycling through many distinct
# grids cannot grow the traced-callable map without limit; an evicted key
# pays retrace + recompile on re-entry (core/batching.py)
_BATCHED_WINDOW_CACHE = LRUCache(maxsize=64, name="batched_window")


def _batched_window_fn(grid, opts: SolverOptions, tree_chunks,
                       use_start=False):
    """One vmapped+jitted window factorization per (grid,
    ``opts.compile_key()``, chunks) — cached on the Python side so
    repeated θ-sweeps reuse the same traced function object (and
    therefore XLA's compile cache).  Keying on the options object's
    compile-relevant subset means option-equal calls share an entry no
    matter which construction path (legacy kwargs, facade, replace())
    produced them.

    ``use_start=True`` (the canonical-grid path) adds a *traced*
    ``start_tile`` argument broadcast across the batch, so every source
    grid embedding into ``grid`` — whatever its pad depth — shares this
    one cache entry; the plain path keeps its static-zero trace."""
    key = (grid, opts.compile_key(), tree_chunks, use_start)
    impl, sweep, plan = opts.impl, opts.sweep, opts.partition_plan

    def build():
        if use_start:
            return jax.jit(jax.vmap(
                lambda dr, r, c, s: _factorize_window_impl(
                    dr, r, c, grid, impl, tree_chunks, sweep, s, plan=plan),
                in_axes=(0, 0, 0, None)))
        return jax.jit(jax.vmap(
            lambda dr, r, c: _factorize_window_impl(dr, r, c, grid, impl,
                                                    tree_chunks, sweep,
                                                    plan=plan)))

    return _BATCHED_WINDOW_CACHE.get_or_create(key, build)


def factorize_window_batched(batch, impl=UNSET,
                             tree_chunks: int = 8,
                             bucket: bool = True,
                             sweep=UNSET,
                             policy=UNSET,
                             regularize=UNSET,
                             start_tile=None,
                             options: Optional[SolverOptions] = None
                             ) -> CholeskyFactor:
    """Factorize a batch of same-grid matrices in one vmapped dispatch.

    ``options`` (a :class:`~repro.core.options.SolverOptions`) is the
    preferred way to pass the solver knobs; the bare ``impl=``/``sweep=``/
    ``policy=``/``regularize=`` kwargs are deprecated aliases (legacy
    wins, with a ``DeprecationWarning``).  ``tree_chunks``, ``bucket`` and
    ``start_tile`` are per-call arguments, not options.

    ``batch`` is either a list of :class:`BandedCTSF` or one whose arrays
    carry a leading batch axis (cf. ``concurrent.stack_ctsf``).  This is the
    INLA θ-sweep primitive: every hyperparameter candidate's arrowhead
    matrix rides the same ring sweep + corner Schur, so a sweep of B
    candidates costs one kernel launch sequence instead of B — and on the
    Pallas backend the whole band+arrow factorization of every candidate
    is one fused launch (``sweep`` as in :func:`factorize_window`).

    With ``bucket=True`` the batch is padded (by repeating the last matrix)
    to the next power of two before dispatch and the padding results are
    dropped — bounding XLA compiles per grid at log2(max batch) instead of
    one per distinct sweep size.  The vmapped callable itself is cached per
    (grid, impl, tree_chunks, sweep), so factorizing a new batch of a known
    shape costs zero retracing.

    ``policy`` (a :class:`~repro.core.gridpolicy.GridBucketPolicy`) extends
    the bucketing across *grids*: the batch is embedded into its canonical
    grid, the cache keys on that canonical grid, and the sweep skips the
    identity prefix via a traced ``start_tile`` — so mixed-size serving
    traffic compiles O(#canonical rungs) sweeps instead of one per distinct
    grid.  The returned factor carries ``source_grid`` (see
    :func:`factorize_window`).

    ``regularize`` (bool or :class:`~repro.core.robustness.RegularizePolicy`)
    runs the escalating-jitter ladder *per batch element*: retries
    refactorize the whole (bucketed) batch through the same compiled
    callable with only the failed elements' diagonals jittered, healthy
    elements keep their first-attempt factors bit-for-bit, and the
    returned ``factor.info`` carries ``(B,)`` status/attempts/tau vectors
    — one poisoned θ-candidate degrades to a flagged element instead of
    sinking the sweep.

    ``start_tile`` is for callers that did the canonical-grid embedding
    *themselves* (``gridpolicy.assemble_rung_batch`` — the rung server
    stacks mixed source grids before dispatch): it threads the shared
    identity-prefix depth through the sweep as a traced scalar, reusing
    the same ``use_start`` cache entry the ``policy`` path compiles,
    without re-embedding.  Mutually exclusive with ``policy`` (which
    computes its own start); the returned factor keeps ``source_grid``
    None — restriction stays with the caller who owns the embedding.
    """
    opts = resolve_options(options, _where="factorize_window_batched",
                           impl=impl, sweep=sweep, policy=policy,
                           regularize=regularize)
    if start_tile is not None and opts.policy is not None:
        raise ValueError(
            "start_tile= is for pre-embedded batches and the bucketing "
            "policy embeds itself; pass one or the other")
    if isinstance(batch, (list, tuple)):
        grid = batch[0].grid
        for m in batch:
            if m.grid != grid:
                raise ValueError(
                    "batched factorization needs equal structure; use "
                    "concurrent.stack_ctsf(policy=...) to embed mixed "
                    "grids onto a shared canonical rung first")
        Dr = jnp.stack([m.Dr for m in batch])
        R = jnp.stack([m.R for m in batch])
        C = jnp.stack([m.C for m in batch])
    else:
        grid = batch.grid
        Dr, R, C = batch.Dr, batch.R, batch.C
        if Dr.ndim != 5:
            raise ValueError(
                f"batched CTSF needs a leading batch axis, got Dr.ndim="
                f"{Dr.ndim}")
    with telemetry.span("factorize.window_batched", b=Dr.shape[0],
                        grid=telemetry.rung_tag(grid)) as sp:
        source = None
        if opts.policy is not None:
            src_ndt = grid.n_diag_tiles
            emb, source, start = _embed_matrix(BandedCTSF(grid, Dr, R, C),
                                               opts.policy)
            Dr, R, C, grid = emb.Dr, emb.R, emb.C, emb.grid
            sp.tag(rung=telemetry.rung_tag(grid))
            if opts.partition_plan is not None:
                opts = opts.replace(partition_plan=opts.partition_plan
                                    .shifted(grid.n_diag_tiles - src_ndt))
            fn = _batched_window_fn(grid, opts, tree_chunks, use_start=True)
            call = lambda dr, r, c: fn(dr, r, c, start)
        elif start_tile is not None:
            start = jnp.asarray(start_tile, jnp.int32)
            fn = _batched_window_fn(grid, opts, tree_chunks, use_start=True)
            call = lambda dr, r, c: fn(dr, r, c, start)
        else:
            call = _batched_window_fn(grid, opts, tree_chunks)
        pol = RegularizePolicy.resolve(opts.regularize)
        if pol is None:
            dr, r, c, _status = bucketed_batched_call(call, (Dr, R, C),
                                                      bucket)
            info = None
        else:
            # ladder inside the bucketed call: the pow2 padding elements
            # (copies of the last matrix) ride the retries and are stripped
            # with the other outputs; FactorInfo arrays flatten through the
            # stripper
            kept = []

            def ladder_call(dr_, r_, c_):
                d2, r2, c2, inf = run_ladder(dr_, r_, c_, grid, call, pol)
                kept.append(inf.matrix is not None)
                return (d2, r2, c2, inf.status, inf.attempts, inf.tau,
                        inf.min_pivot, inf.first_bad_tile)

            dr, r, c, st, at, ta, mp, fb = bucketed_batched_call(
                ladder_call, (Dr, R, C), bucket)
            # re-attach the *unpadded* original batch for the refinement path
            matrix = BandedCTSF(grid, Dr, R, C) if kept[-1] else None
            info = FactorInfo(status=st, attempts=at, tau=ta, min_pivot=mp,
                              first_bad_tile=fb, matrix=matrix)
        return CholeskyFactor(BandedCTSF(grid, dr, r, c), source_grid=source,
                              info=info)
