"""sTiles core: structured sparse Cholesky factorization in JAX."""
from .structure import (ArrowheadStructure, TileGrid, measure_arrowhead,
                        tile_pattern_from_coo, banded_arrowhead_tile_pattern)
from .symbolic import SymbolicFactorization, Task, TaskType, symbolic_factorize
from .ctsf import BandedCTSF, TileMatrix
from .options import SolverOptions, resolve_options
from .ordering import (OrderingResult, PartitionPlan, adaptive_nd_ordering,
                       detect_partition_plan, partition_plan_from_ordering)
from .cholesky import (CholeskyFactor, factorize_tasklist, factorize_window,
                       factorize_window_batched)
from .tree_reduction import chunked_tree_sum, should_use_tree, tree_combine
from .solve import (backward_solve, backward_solve_many, forward_solve,
                    forward_solve_many, logdet, marginal_variances,
                    sample_gmrf, sample_gmrf_many, solve, solve_many,
                    solve_many_batched)
from .selinv import SelectedInverse, selected_inverse, selinv_batched
from .concurrent import concurrent_factorize, concurrent_selinv
from .gridpolicy import (GridBucketPolicy, assemble_rung_batch,
                         assemble_rung_rhs, embed_ctsf, embed_rhs,
                         padded_flop_overhead, restrict_factor, restrict_rhs,
                         restrict_selinv)
from .robustness import (STATUS_FAILED, STATUS_OK, STATUS_RECOVERED,
                         STATUS_SHED, FactorInfo, RegularizePolicy)

__all__ = [
    "ArrowheadStructure", "TileGrid", "measure_arrowhead",
    "tile_pattern_from_coo", "banded_arrowhead_tile_pattern",
    "SymbolicFactorization", "Task", "TaskType", "symbolic_factorize",
    "BandedCTSF", "TileMatrix",
    "SolverOptions", "resolve_options",
    "OrderingResult", "PartitionPlan", "adaptive_nd_ordering",
    "detect_partition_plan", "partition_plan_from_ordering",
    "CholeskyFactor", "factorize_tasklist", "factorize_window",
    "factorize_window_batched",
    "chunked_tree_sum", "should_use_tree", "tree_combine",
    "backward_solve", "backward_solve_many", "forward_solve",
    "forward_solve_many", "logdet", "marginal_variances",
    "sample_gmrf", "sample_gmrf_many", "solve", "solve_many",
    "solve_many_batched",
    "SelectedInverse", "selected_inverse", "selinv_batched",
    "concurrent_factorize", "concurrent_selinv",
    "GridBucketPolicy", "assemble_rung_batch", "assemble_rung_rhs",
    "embed_ctsf", "embed_rhs", "padded_flop_overhead",
    "restrict_factor", "restrict_rhs", "restrict_selinv",
    "STATUS_FAILED", "STATUS_OK", "STATUS_RECOVERED", "STATUS_SHED",
    "FactorInfo", "RegularizePolicy",
]
