"""Distributed single-matrix factorization — the paper's future work, built.

Paper App. A: "the current approach could be extended to allow a single
Cholesky factorization to be distributed and computed across multiple nodes
using nested dissection ordering".  The adaptive-ND ordering (§III-A) makes
the diagonal partitions independent given the separator/arrow block, so:

  1. each device group factorizes its partition's band + arrow rows locally
     (`shard_map` over the chosen mesh axis — the sequential panel sweeps of
     all partitions run concurrently);
  2. each group computes its partial corner Schur complement
     Σ_{n∈partition} R_n R_nᵀ;
  3. partials are combined across the axis with the **GEADD binary tree**
     (`tree_allreduce`, Alg. 3 on ICI links);
  4. the (small) corner is factorized redundantly on every device —
     replicated compute beats a broadcast for ≤2 tiles.

Correctness requires true partition independence (no band coupling across
partition boundaries) — guaranteed by adaptive-ND ordering, and natively by
the paper's block-diagonal cases (Table II ids 1, 7, 10, 13, 16);
:func:`partition_banded` validates this on the host before sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sharding.collectives import tree_allreduce
from .cholesky import (CholeskyFactor, _band_arrow_sweep_ring,
                       _corner_dense_cholesky)
from .ctsf import BandedCTSF
from .structure import ArrowheadStructure, TileGrid

__all__ = ["partition_banded", "distributed_factorize", "PartitionedCTSF"]


@dataclasses.dataclass
class PartitionedCTSF:
    """A BandedCTSF split into p independent diagonal partitions.

    Dr: (p, ndt_p, bt+1, t, t);  R: (p, ndt_p, nat, t, t);  C: (nat, nat, t, t)
    """
    grid: TileGrid          # per-partition grid (ndt_p diag tiles)
    n_parts: int
    Dr: jnp.ndarray
    R: jnp.ndarray
    C: jnp.ndarray


def partition_banded(m: BandedCTSF, n_parts: int, atol: float = 0.0) -> PartitionedCTSF:
    """Split a block-independent BandedCTSF into ``n_parts`` partitions.

    Validates on host that no band tile couples two partitions (the
    adaptive-ND invariant); raises if the split would be incorrect.
    """
    g = m.grid
    ndt, bt = g.n_diag_tiles, g.band_tiles
    if ndt % n_parts:
        raise ValueError(f"n_diag_tiles={ndt} not divisible by {n_parts}")
    per = ndt // n_parts
    Dr = np.asarray(m.Dr)
    for p in range(1, n_parts):
        start = p * per
        # rows [start, start+bt) may reach columns < start via d > row-start
        for r in range(start, min(start + bt, ndt)):
            for d in range(r - start + 1, bt + 1):
                if np.abs(Dr[r, d]).max() > atol:
                    raise ValueError(
                        f"band tile ({r},{r - d}) crosses partition boundary "
                        f"{start}; reorder with adaptive ND first")
    sub_struct = ArrowheadStructure(
        n=per * g.t + g.structure.arrow, bandwidth=g.structure.bandwidth,
        arrow=g.structure.arrow)
    sub_grid = TileGrid(sub_struct, g.t)
    return PartitionedCTSF(
        sub_grid, n_parts,
        m.Dr.reshape((n_parts, per) + m.Dr.shape[1:]),
        m.R.reshape((n_parts, per) + m.R.shape[1:]),
        m.C)


def distributed_factorize(pm: PartitionedCTSF, mesh: Mesh, axis: str = "model",
                          impl: Optional[str] = None,
                          tree_chunks: int = 8) -> PartitionedCTSF:
    """Factorize one matrix across ``mesh[axis]`` devices (see module doc)."""
    grid = pm.grid
    nat = grid.n_arrow_tiles
    axis_size = mesh.shape[axis]
    if pm.n_parts % axis_size:
        raise ValueError(f"n_parts={pm.n_parts} not divisible by mesh axis "
                         f"{axis}={axis_size}")

    def local(dr, r, c):
        # dr: (parts_per_dev, ndt_p, bt+1, t, t) — sweep each local partition;
        # the sweep emits its own corner-Schur chunks (accumulated in-kernel
        # on the Pallas backend), so no re-contraction of r_l from HBM here
        sweep = jax.vmap(lambda d, rr: _band_arrow_sweep_ring(
            d, rr, grid, impl, tree_chunks))
        dr_l, r_l, sch = sweep(dr, r)
        if nat:
            partial = sch.sum(axis=(0, 1))             # parts x chunks
            schur = tree_allreduce(partial, axis)      # GEADD tree on ICI
            c_l = _corner_dense_cholesky(c - schur, impl)
        else:
            c_l = c
        return dr_l, r_l, c_l

    spec_part = P(axis)
    spec_rep = P()
    # check_vma=False: the ppermute GEADD tree yields replicated values, but
    # that can't be statically inferred (only psum can); we assert it in tests.
    try:
        fn = shard_map(local, mesh=mesh,
                       in_specs=(spec_part, spec_part, spec_rep),
                       out_specs=(spec_part, spec_part, spec_rep),
                       check_vma=False)
    except TypeError:  # older jax spelling
        fn = shard_map(local, mesh=mesh,
                       in_specs=(spec_part, spec_part, spec_rep),
                       out_specs=(spec_part, spec_part, spec_rep),
                       check_rep=False)
    dr, r, c = jax.jit(fn)(pm.Dr, pm.R, pm.C)
    return PartitionedCTSF(grid, pm.n_parts, dr, r, c)


def assemble_factor(pm: PartitionedCTSF, full_grid: TileGrid) -> CholeskyFactor:
    """Reassemble a partitioned factor into one BandedCTSF (host-side)."""
    p, per = pm.Dr.shape[0], pm.Dr.shape[1]
    dr = pm.Dr.reshape((p * per,) + pm.Dr.shape[2:])
    r = pm.R.reshape((p * per,) + pm.R.shape[2:])
    return CholeskyFactor(BandedCTSF(full_grid, dr, r, pm.C))
