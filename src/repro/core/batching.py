"""Shared batching utilities for the serving entry points.

Both batched serving paths — ``cholesky.factorize_window_batched`` and
``selinv.selinv_batched`` — dispatch a vmapped+jitted per-batch function
with the same two tricks:

* **pow2 bucketing** (:func:`bucketed_batched_call`): pad the leading
  batch axis (repeating the last element) up to the next power of two,
  call, drop the padding results — bounding XLA compiles per grid at
  log2(max batch) instead of one per distinct sweep size.
* **a bounded traced-callable cache** (:class:`LRUCache`): the vmapped
  function object is cached per (grid, impl, ...) key so repeated
  same-structure sweeps reuse the trace (and the jit wrapper's compiled
  executable).  The cache is LRU-bounded so a long-running serving
  process cycling through many distinct grids cannot grow it without
  limit.  Note eviction drops the ``jax.jit`` wrapper *including* its
  compiled-executable cache — re-entering an evicted key pays a full
  retrace + XLA compile — so ``maxsize`` trades memory against recompile
  cost for workloads hot on more than ``maxsize`` grids.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional, Tuple

import jax.numpy as jnp

__all__ = ["LRUCache", "bucketed_batched_call", "next_pow2"]


class LRUCache:
    """Tiny insertion/recency-ordered cache for traced callables.

    ``get`` refreshes recency; ``put`` evicts the least recently used
    entry beyond ``maxsize``.  Thread-safe: serving processes commonly
    fan requests over a thread pool, and a torn ``move_to_end`` /
    ``popitem`` under concurrent mutation corrupts the OrderedDict.  The
    lock covers only the bookkeeping — a cache miss may still trace the
    same callable twice in two threads (JAX tracing is outside the lock
    by design), which wastes a trace but stays correct: ``put`` is
    last-writer-wins."""

    def __init__(self, maxsize: int = 64):
        if maxsize <= 0:
            raise ValueError("maxsize must be positive")
        self.maxsize = maxsize
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.RLock()

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key not in self._entries:
                return None
            self._entries.move_to_end(key)
            return self._entries[key]

    def put(self, key: Hashable, value: Any) -> None:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self):
        """Current keys in least-to-most-recently-used order.  Each key is
        one traced+compiled callable, so benchmarks and tests count
        compiles by diffing snapshots of this set across a workload."""
        with self._lock:
            return list(self._entries.keys())


def next_pow2(b: int) -> int:
    return 1 << max(b - 1, 0).bit_length()


def bucketed_batched_call(fn: Callable, arrays: Tuple[jnp.ndarray, ...],
                          bucket: bool):
    """Dispatch a vmapped per-batch function with pow2 bucketing: pad the
    leading batch axis (repeating the last element) up to the next power of
    two, call, and drop the padding results — bounding XLA compiles per grid
    at log2(max batch).  Shared by the batched factorization and the batched
    selected inversion."""
    b = arrays[0].shape[0]
    nb = next_pow2(b) if bucket else b
    if nb != b:
        pad = nb - b
        arrays = tuple(jnp.concatenate([a, jnp.broadcast_to(
            a[-1:], (pad,) + a.shape[1:])]) for a in arrays)
    outs = fn(*arrays)
    if nb != b:
        outs = tuple(o[:b] for o in outs)
    return outs
