"""Shared batching utilities for the serving entry points.

Both batched serving paths — ``cholesky.factorize_window_batched`` and
``selinv.selinv_batched`` — dispatch a vmapped+jitted per-batch function
with the same two tricks:

* **pow2 bucketing** (:func:`bucketed_batched_call`): pad the leading
  batch axis (repeating the last element) up to the next power of two,
  call, drop the padding results — bounding XLA compiles per grid at
  log2(max batch) instead of one per distinct sweep size.
* **a bounded traced-callable cache** (:class:`LRUCache`): the vmapped
  function object is cached per (grid, impl, ...) key so repeated
  same-structure sweeps reuse the trace (and the jit wrapper's compiled
  executable).  The cache is LRU-bounded so a long-running serving
  process cycling through many distinct grids cannot grow it without
  limit.  Note eviction drops the ``jax.jit`` wrapper *including* its
  compiled-executable cache — re-entering an evicted key pays a full
  retrace + XLA compile — so ``maxsize`` trades memory against recompile
  cost for workloads hot on more than ``maxsize`` grids.

Named caches report hit/miss/eviction/duplicate-trace counters and a
trace-time histogram through :mod:`repro.runtime.telemetry` under
``cache.*{cache=<name>}``; :meth:`LRUCache.stats` exposes the same
numbers as a plain dict regardless of whether telemetry is enabled.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional, Tuple

import jax.numpy as jnp

from repro.runtime import telemetry

__all__ = ["LRUCache", "RungQueue", "RungQueueFull", "bucketed_batched_call",
           "next_pow2"]


class RungQueueFull(RuntimeError):
    """Raised by :meth:`RungQueue.push` when the queue is at ``maxlen``.

    The low-level half of serving admission control: the scheduler
    translates this into its typed backpressure signal
    (``launch.rung_server.RungOverloadError``) or — under a degradation
    policy — into shedding the lowest-slack queued request instead."""

    def __init__(self, depth: int, maxlen: int):
        super().__init__(f"rung queue full ({depth}/{maxlen})")
        self.depth = depth
        self.maxlen = maxlen


class LRUCache:
    """Tiny insertion/recency-ordered cache for traced callables.

    ``get`` refreshes recency; ``put`` evicts the least recently used
    entry beyond ``maxsize``.  Thread-safe: serving processes commonly
    fan requests over a thread pool, and a torn ``move_to_end`` /
    ``popitem`` under concurrent mutation corrupts the OrderedDict.  The
    lock covers only the bookkeeping — a cache miss may still trace the
    same callable twice in two threads (JAX tracing is outside the lock
    by design), which wastes a trace but stays correct: ``put`` is
    last-writer-wins, and the wasted trace is counted (``stats()``
    ``duplicate_traces``, telemetry ``cache.duplicate_trace``) rather
    than silently dropped.

    A ``name`` makes the cache visible to telemetry: hits, misses,
    evictions, duplicate traces, and :meth:`get_or_create` trace times
    are emitted under ``cache.*{cache=<name>}``.  Anonymous caches keep
    local ``stats()`` only."""

    def __init__(self, maxsize: int = 64, name: Optional[str] = None):
        if maxsize <= 0:
            raise ValueError("maxsize must be positive")
        self.maxsize = maxsize
        self.name = name
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._duplicate_traces = 0

    def _emit(self, metric: str, value: float = 1.0) -> None:
        if self.name is not None and telemetry.enabled():
            telemetry.inc(metric, value, cache=self.name)

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key not in self._entries:
                self._misses += 1
                hit = False
            else:
                self._entries.move_to_end(key)
                self._hits += 1
                hit = True
                value = self._entries[key]
        self._emit("cache.hit" if hit else "cache.miss")
        return value if hit else None

    def put(self, key: Hashable, value: Any) -> None:
        with self._lock:
            duplicate = key in self._entries
            if duplicate:
                # a second thread raced us through the same miss and
                # already traced this key — count the wasted trace
                self._duplicate_traces += 1
            self._entries[key] = value
            self._entries.move_to_end(key)
            evicted = 0
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                evicted += 1
            self._evictions += evicted
        if duplicate:
            self._emit("cache.duplicate_trace")
        if evicted:
            self._emit("cache.eviction", evicted)

    def get_or_create(self, key: Hashable, factory: Callable[[], Any]) -> Any:
        """``get`` or build-via-``factory``-then-``put``, timing the
        factory (the trace+jit-wrap cost) into ``cache.trace_seconds``.
        The factory runs outside the lock by design — see the class note
        on concurrent misses."""
        value = self.get(key)
        if value is not None:
            return value
        t0 = time.perf_counter()
        value = factory()
        dt = time.perf_counter() - t0
        if self.name is not None and telemetry.enabled():
            telemetry.observe("cache.trace_seconds", dt, cache=self.name)
        self.put(key, value)
        return value

    def stats(self) -> dict:
        """Point-in-time counters: hits/misses/evictions/duplicate_traces
        since construction plus current size/maxsize.  Read under the
        lock, so the numbers are mutually consistent."""
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "duplicate_traces": self._duplicate_traces,
                    "size": len(self._entries), "maxsize": self.maxsize}

    def clear(self) -> None:
        """Drop every entry.  Cumulative counters are kept (clearing is
        not an eviction); subsequent gets miss and re-trace."""
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self):
        """Current keys in least-to-most-recently-used order.  Each key is
        one traced+compiled callable, so benchmarks and tests count
        compiles by diffing snapshots of this set across a workload."""
        with self._lock:
            return list(self._entries.keys())


class RungQueue:
    """Host-side FIFO of pending requests for one canonical rung.

    The per-rung building block of the continuous-batching scheduler
    (``launch/rung_server.py``): items are appended in arrival order, each
    with the absolute ``flush_by`` time by which it must leave the queue
    (``min(arrival + max_delay, request deadline)``).  Deliberately *not*
    thread-safe and *not* clock-aware — the scheduler serializes access
    and injects every timestamp, which is what keeps the whole flush state
    machine replayable without threads or wall-clock sleeps.

    A ``maxlen`` bounds the queue: ``push`` beyond it raises
    :class:`RungQueueFull` (the admission-control hook — an unbounded
    rung queue under sustained overload turns every deadline into a miss
    before the server ever sheds).  ``remove_if`` / ``evict_min`` are the
    shedding primitives: drop expired requests, or make room by evicting
    the pending request with the least slack.
    """

    def __init__(self, maxlen: Optional[int] = None):
        if maxlen is not None and maxlen < 1:
            raise ValueError(f"maxlen must be >= 1 or None, got {maxlen}")
        self.maxlen = maxlen
        self._items: list = []          # (item, flush_by) in arrival order

    @property
    def full(self) -> bool:
        return self.maxlen is not None and len(self._items) >= self.maxlen

    def push(self, item: Any, flush_by: float) -> None:
        if self.full:
            raise RungQueueFull(len(self._items), self.maxlen)
        self._items.append((item, flush_by))

    def earliest_flush_by(self) -> float:
        """Earliest ``flush_by`` among pending items (``inf`` when empty) —
        the next deadline boundary the scheduler must tick at.  FIFO order
        does not guarantee monotone deadlines (a later arrival may carry a
        tighter explicit deadline), hence the min over all items."""
        if not self._items:
            return float("inf")
        return min(fb for _, fb in self._items)

    def pop(self, n: Optional[int] = None) -> list:
        """Remove and return the ``n`` oldest items (all items when None),
        preserving arrival order — the composition of one flushed batch."""
        if n is None or n >= len(self._items):
            taken, self._items = self._items, []
        else:
            taken, self._items = self._items[:n], self._items[n:]
        return [item for item, _ in taken]

    def remove_if(self, pred: Callable[[Any], bool]) -> list:
        """Remove and return every item with ``pred(item)`` true,
        preserving arrival order among both the removed and the kept —
        the deadline-expiry shedding sweep (expired requests leave as one
        shed batch; survivors keep their queue positions)."""
        taken = [(it, fb) for it, fb in self._items if pred(it)]
        if taken:
            self._items = [(it, fb) for it, fb in self._items
                           if not pred(it)]
        return [item for item, _ in taken]

    def evict_min(self, keyfn: Callable[[Any], float]) -> Any:
        """Remove and return the single item minimizing ``keyfn(item)``
        (first in arrival order on ties) — shed-lowest-slack-first under
        a degradation policy.  Raises on an empty queue."""
        if not self._items:
            raise IndexError("evict_min on empty RungQueue")
        idx = min(range(len(self._items)),
                  key=lambda i: keyfn(self._items[i][0]))
        item, _ = self._items.pop(idx)
        return item

    def __len__(self) -> int:
        return len(self._items)


def next_pow2(b: int) -> int:
    return 1 << max(b - 1, 0).bit_length()


def bucketed_batched_call(fn: Callable, arrays: Tuple[jnp.ndarray, ...],
                          bucket: bool):
    """Dispatch a vmapped per-batch function with pow2 bucketing: pad the
    leading batch axis (repeating the last element) up to the next power of
    two, call, and drop the padding results — bounding XLA compiles per grid
    at log2(max batch).  Shared by the batched factorization and the batched
    selected inversion."""
    b = arrays[0].shape[0]
    nb = next_pow2(b) if bucket else b
    if nb != b:
        pad = nb - b
        arrays = tuple(jnp.concatenate([a, jnp.broadcast_to(
            a[-1:], (pad,) + a.shape[1:])]) for a in arrays)
    outs = fn(*arrays)
    if nb != b:
        outs = tuple(o[:b] for o in outs)
    return outs
