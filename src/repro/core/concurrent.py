"""Concurrent Cholesky factorizations (paper Appendix A).

INLA's central-difference gradient needs 2n independent factorizations of
same-structure matrices; the paper runs them concurrently with NUMA-aware
core binding.  The TPU analogue: stack the matrices on a leading batch axis,
`vmap` the factorization, and shard the batch over the `data` mesh axis —
each device (group) owns whole factorizations, the device-local equivalent
of binding one factorization to one NUMA node.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .cholesky import CholeskyFactor, _factorize_window_impl
from .ctsf import BandedCTSF
from .structure import TileGrid

__all__ = ["stack_ctsf", "concurrent_factorize", "concurrent_logdet"]


def stack_ctsf(mats: list) -> BandedCTSF:
    """Stack same-structure BandedCTSF matrices on a leading batch axis."""
    grid = mats[0].grid
    for m in mats:
        assert m.grid == grid, "concurrent factorization needs equal structure"
    return BandedCTSF(
        grid,
        jnp.stack([m.Dr for m in mats]),
        jnp.stack([m.R for m in mats]),
        jnp.stack([m.C for m in mats]),
    )


def concurrent_factorize(batch: BandedCTSF, mesh: Optional[Mesh] = None,
                         axis: str = "data", impl: Optional[str] = None,
                         tree_chunks: int = 8) -> CholeskyFactor:
    """Factorize a batch of matrices concurrently.

    With ``mesh``, the batch axis is sharded over ``axis`` — one factorization
    never spans devices (App. A's within-NUMA binding); without, it is a
    plain vmap batch.
    """
    fn = jax.vmap(
        lambda dr, r, c: _factorize_window_impl(dr, r, c, batch.grid, impl,
                                                tree_chunks))
    if mesh is not None:
        spec = (NamedSharding(mesh, P(axis)),) * 3
        fn = jax.jit(fn, in_shardings=spec, out_shardings=spec)
    dr, r, c = fn(batch.Dr, batch.R, batch.C)
    return CholeskyFactor(BandedCTSF(batch.grid, dr, r, c))


def concurrent_logdet(factor: CholeskyFactor) -> jnp.ndarray:
    """Batched log-determinants from a batched factor (INLA's per-evaluation
    quantity)."""
    ctsf = factor.ctsf
    g = ctsf.grid
    diag_band = jnp.diagonal(ctsf.Dr[:, :, 0], axis1=-2, axis2=-1)
    total = jnp.sum(jnp.log(jnp.abs(diag_band)), axis=(-2, -1))
    if g.n_arrow_tiles > 0:
        ar = jnp.arange(g.n_arrow_tiles)
        dc = jnp.diagonal(ctsf.C[:, ar, ar], axis1=-2, axis2=-1)
        total = total + jnp.sum(jnp.log(jnp.abs(dc)), axis=(-2, -1))
    return 2.0 * total
