"""Concurrent Cholesky factorizations (paper Appendix A).

INLA's central-difference gradient needs 2n independent factorizations of
same-structure matrices; the paper runs them concurrently with NUMA-aware
core binding.  The TPU analogue: stack the matrices on a leading batch axis,
`vmap` the factorization, and shard the batch over the `data` mesh axis —
each device (group) owns whole factorizations, the device-local equivalent
of binding one factorization to one NUMA node.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .cholesky import (CholeskyFactor, _factorize_window_impl,
                       factorize_window_batched)
from .ctsf import BandedCTSF
from .options import UNSET, resolve_options
from .selinv import SelectedInverse, _selinv_impl, selinv_batched
from .structure import TileGrid

__all__ = ["stack_ctsf", "concurrent_factorize", "concurrent_logdet",
           "concurrent_quadratic_forms", "concurrent_selinv",
           "concurrent_solve"]


def stack_ctsf(mats: list, policy=None) -> BandedCTSF:
    """Stack BandedCTSF matrices on a leading batch axis.

    Without a policy all matrices must share one grid (unequal grids raise
    ``ValueError`` — a real validation, not a stripped-under-``-O`` bare
    assert).  With a :class:`~repro.core.gridpolicy.GridBucketPolicy`,
    matrices on *unequal* grids are first embedded onto their shared
    canonical rung (``policy.join``) with identity-diagonal padding, so a
    mixed-size batch can ride one vmapped factorization.  Note the stacked
    result is a plain canonical-grid matrix batch: factorize it with
    ``factorize_window_batched(..., policy=policy)`` (a no-op embedding,
    since every grid is already canonical) to get a factor whose solves
    restrict back to the canonical — not the per-matrix source — layout.
    """
    if not mats:
        raise ValueError("stack_ctsf needs at least one matrix")
    if policy is not None:
        from .gridpolicy import embed_ctsf
        cgrid = policy.join([m.grid for m in mats])
        mats = [embed_ctsf(m, cgrid) for m in mats]
    grid = mats[0].grid
    for m in mats:
        if m.grid != grid:
            raise ValueError(
                "concurrent factorization needs equal structure: got grids "
                f"with (ndt, bt, nat) = "
                f"{sorted({(x.grid.n_diag_tiles, x.grid.band_tiles, x.grid.n_arrow_tiles) for x in mats})}; "
                "pass a GridBucketPolicy (policy=) to embed them onto a "
                "shared canonical rung")
    return BandedCTSF(
        grid,
        jnp.stack([m.Dr for m in mats]),
        jnp.stack([m.R for m in mats]),
        jnp.stack([m.C for m in mats]),
    )


def concurrent_factorize(batch: BandedCTSF, mesh: Optional[Mesh] = None,
                         axis: str = "data", impl=UNSET,
                         tree_chunks: int = 8,
                         policy=UNSET, regularize=UNSET,
                         options=None) -> CholeskyFactor:
    """Factorize a batch of matrices concurrently.

    With ``mesh``, the batch axis is sharded over ``axis`` — one factorization
    never spans devices (App. A's within-NUMA binding); without, it delegates
    to the cached batched serving path (``factorize_window_batched``) so
    repeated same-structure sweeps never retrace.

    With a ``policy`` the batch is embedded onto its canonical grid first
    (``core/gridpolicy.py``) — the sharded sweep then runs on the
    canonical grid with its identity prefix skipped, and the returned
    factor carries ``source_grid`` for the policy-aware solve/selinv
    entry points.

    ``regularize`` (bool or :class:`~repro.core.robustness.RegularizePolicy`)
    enables per-element breakdown recovery: the escalating-jitter ladder
    retries only the failed elements (on the mesh path the retries ride
    the same sharded callable — the per-sweep status words are replicated
    host-side, everything else stays sharded) and the returned
    ``factor.info`` flags each element OK / RECOVERED / FAILED instead of
    one bad θ-candidate raising mid-sweep.
    """
    opts = resolve_options(options, _where="concurrent_factorize",
                           impl=impl, policy=policy, regularize=regularize)
    if mesh is None:
        return factorize_window_batched(batch, tree_chunks=tree_chunks,
                                        bucket=False, options=opts)
    from .robustness import RegularizePolicy, run_ladder
    pol = RegularizePolicy.resolve(opts.regularize)
    impl, sweep, plan = opts.impl, opts.sweep, opts.partition_plan
    source = None
    if opts.policy is not None:
        from .cholesky import _embed_matrix
        src_ndt = batch.grid.n_diag_tiles
        batch, source, start = _embed_matrix(batch, opts.policy)
        if plan is not None:
            plan = plan.shifted(batch.grid.n_diag_tiles - src_ndt)
        fn = jax.vmap(
            lambda dr, r, c: _factorize_window_impl(
                dr, r, c, batch.grid, impl, tree_chunks, sweep, start, plan))
    else:
        fn = jax.vmap(
            lambda dr, r, c: _factorize_window_impl(
                dr, r, c, batch.grid, impl, tree_chunks, sweep, 0, plan))
    spec = (NamedSharding(mesh, P(axis)),) * 3
    # the (B, 3) status words are tiny — replicate them so the ladder's
    # host readback never gathers factor data
    st_spec = NamedSharding(mesh, P())
    fn = jax.jit(fn, in_shardings=spec, out_shardings=spec + (st_spec,))
    if pol is None:
        dr, r, c, _status = fn(batch.Dr, batch.R, batch.C)
        info = None
    else:
        dr, r, c, info = run_ladder(batch.Dr, batch.R, batch.C, batch.grid,
                                    fn, pol)
    return CholeskyFactor(BandedCTSF(batch.grid, dr, r, c),
                          source_grid=source, info=info)


def concurrent_solve(factor: CholeskyFactor, B: jnp.ndarray,
                     impl=UNSET, policy=UNSET, options=None) -> jnp.ndarray:
    """Solve ``A_i X_i = B`` for every factor in the batch, one vmapped
    multi-RHS sweep.

    Args:
      factor: *batched* factor (leading batch axis on the CTSF arrays, as
        returned by ``factorize_window_batched`` / ``concurrent_factorize``).
      B: RHS shared across the batch, shape ``(padded_n,)`` or
        ``(padded_n, k)`` in the padded layout (zero rows in the padding
        region).
      impl: kernel backend for the sweeps; ``"pallas"`` vmaps the *fused*
        band-sweep kernels (``kernels.ops.band_forward_sweep`` /
        ``band_backward_sweep``) — the batch rides the kernel grid for free.

    Returns: ``(batch, padded_n)`` or ``(batch, padded_n, k)``.

    Combined with :func:`concurrent_factorize` this is the full batched
    serving path — a θ-sweep of factorizations amortized over a panel of
    RHS without ever leaving the device.  Recompiles once per
    ``(grid, impl, k, batch)``.

    Embedded factors (``factor.source_grid`` set, or ``policy`` given)
    take ``B`` and return ``X`` in the *source* layout; the canonical
    embedding and the identity-prefix skip ride the batched sweep.
    """
    from .solve import _embedded_panels, _merge_panels, _solve_panels, \
        _split_rhs
    opts = resolve_options(options, _where="concurrent_solve",
                           impl=impl, policy=policy)
    impl = opts.impl
    panel = B[:, None] if B.ndim == 1 else B
    ctsf, _, g, panel, start, restrict = _embedded_panels(factor, opts.policy,
                                                          panel)
    bd, ba = _split_rhs(g, panel)
    xd, xa = jax.vmap(
        lambda dr, r, c: _solve_panels(dr, r, c, bd, ba, g, impl, start))(
        ctsf.Dr, ctsf.R, ctsf.C)
    out = restrict(jax.vmap(_merge_panels)(xd, xa))
    return out[..., 0] if B.ndim == 1 else out


def concurrent_selinv(factor: CholeskyFactor, mesh: Optional[Mesh] = None,
                      axis: str = "data",
                      impl=UNSET, policy=UNSET,
                      options=None) -> SelectedInverse:
    """Selected inversion of a batch of factors concurrently.

    With ``mesh``, the batch axis is sharded over ``axis`` — one backward
    Takahashi sweep never spans devices, matching
    :func:`concurrent_factorize`'s placement so a θ-sweep's factors and
    their posterior marginals stay device-resident end to end; without, it
    delegates to the cached batched path (:func:`selinv_batched`).

    Embedded factors (``factor.source_grid`` set, or ``policy`` given)
    run the sweep on the canonical grid with the identity prefix skipped
    and return the selected inverse restricted to the source grid.
    """
    opts = resolve_options(options, _where="concurrent_selinv",
                           impl=impl, policy=policy)
    if mesh is None:
        return selinv_batched(factor, bucket=False, options=opts)
    from .solve import _resolve_embedding
    impl = opts.impl
    ctsf, src, pad = _resolve_embedding(factor, opts.policy)
    g = ctsf.grid
    if src is not None:
        start = jnp.asarray(pad, jnp.int32)
        fn = jax.vmap(
            lambda dr, r, c: _selinv_impl(dr, r, c, g, impl, start))
    else:
        fn = jax.vmap(lambda dr, r, c: _selinv_impl(dr, r, c, g, impl))
    spec = (NamedSharding(mesh, P(axis)),) * 3
    fn = jax.jit(fn, in_shardings=spec, out_shardings=spec)
    sd, sr, sc = fn(ctsf.Dr, ctsf.R, ctsf.C)
    out = SelectedInverse(g, sd, sr, sc)
    if src is not None:
        from .gridpolicy import restrict_selinv
        out = restrict_selinv(out, src)
    return out


def concurrent_quadratic_forms(factor: CholeskyFactor, y: jnp.ndarray,
                               impl=UNSET, policy=UNSET,
                               options=None) -> jnp.ndarray:
    """``y^T A_i^{-1} y`` for each factor in the batch.

    Uses ``‖L_i^{-1} y‖²`` — only the *forward* sweep, vmapped over the
    batch — which is half the work of a full solve and exactly the
    quadratic-form term INLA's objective needs per θ candidate.

    Embedded factors (``factor.source_grid`` set, or ``policy`` given)
    take ``y`` in the source layout; the identity-prefix rows of the
    embedded sweep are zero, so the squared norm needs no restriction.
    """
    from .solve import _embedded_panels, _forward_impl, _split_rhs
    opts = resolve_options(options, _where="concurrent_quadratic_forms",
                           impl=impl, policy=policy)
    impl = opts.impl
    ctsf, _, g, panel, start, _ = _embedded_panels(factor, opts.policy,
                                                   y.reshape(-1, 1))
    bd, ba = _split_rhs(g, panel)
    if start is not None:
        fn = jax.vmap(
            lambda dr, r, c: _forward_impl(dr, r, c, bd, ba, g, impl, start))
    else:
        fn = jax.vmap(
            lambda dr, r, c: _forward_impl(dr, r, c, bd, ba, g, impl))
    yd, ya = fn(ctsf.Dr, ctsf.R, ctsf.C)
    return (jnp.sum(yd * yd, axis=(1, 2, 3))
            + jnp.sum(ya * ya, axis=(1, 2, 3)))


def concurrent_logdet(factor: CholeskyFactor) -> jnp.ndarray:
    """Batched log-determinants from a batched factor (INLA's per-evaluation
    quantity)."""
    ctsf = factor.ctsf
    g = ctsf.grid
    diag_band = jnp.diagonal(ctsf.Dr[:, :, 0], axis1=-2, axis2=-1)
    total = jnp.sum(jnp.log(jnp.abs(diag_band)), axis=(-2, -1))
    if g.n_arrow_tiles > 0:
        ar = jnp.arange(g.n_arrow_tiles)
        dc = jnp.diagonal(ctsf.C[:, ar, ar], axis1=-2, axis2=-1)
        total = total + jnp.sum(jnp.log(jnp.abs(dc)), axis=(-2, -1))
    return 2.0 * total
