"""Canonical-grid bucketing for mixed-size serving traffic.

The paper's central tuning knob is tile size versus parallelism: larger
tiles raise algorithmic intensity but add padding flops (§III-B, Table
III).  A serving system faces the same trade one level up — every distinct
:class:`~repro.core.structure.TileGrid` that reaches the batched entry
points (``factorize_window_batched``, ``solve_many``, ``selinv_batched``,
``concurrent_*``) traces and XLA-compiles its own sweep, so traffic mixing
problem sizes from many users recompiles unboundedly and churns the
bounded LRU caches of :mod:`repro.core.batching`.

This module trades a little padded compute for a *bounded compile set*:

* :class:`GridBucketPolicy` maps any incoming grid to a small canonical
  set — ``n_diag_tiles`` rounds up pow2-style, ``band_tiles`` and
  ``n_arrow_tiles`` round up to policy rungs — so the compile count for a
  mixed-grid workload is O(#canonical rungs) instead of O(#distinct
  grids).
* :func:`embed_ctsf` pads a :class:`~repro.core.ctsf.BandedCTSF` onto the
  canonical grid with **identity diagonal tiles** and zero band/arrow
  slack.  The embedded matrix is ``blockdiag(I_prefix, A_padded)`` (plus
  an identity-extended corner), so its Cholesky factor, triangular
  solves, log-determinant and selected inverse are *exact* on the
  original entries — :func:`restrict_factor` / :func:`restrict_selinv` /
  :func:`restrict_rhs` slice them back out.
* The identity prefix occupies band tiles ``0 .. pad_diag-1``; the fused
  sweep kernels skip it via their traced ``start_tile`` machinery
  (``kernels/band_solve.py``, ``band_cholesky.py``, ``selinv.py``), so
  diagonal slack costs ~0 compute, not just correctness.  Band/arrow
  *widening* slack (extra zero tiles inside each visited panel) is merely
  masked by structural zeros and does cost flops —
  :func:`padded_flop_overhead` quantifies that, and the default rungs
  keep it small.

Embedding layout (source grid ``g`` -> canonical grid ``cg``)::

    pad_diag  = cg.n_diag_tiles  - g.n_diag_tiles   (identity prefix)
    pad_band  = cg.band_tiles    - g.band_tiles     (zero band slack)
    pad_arrow = cg.n_arrow_tiles - g.n_arrow_tiles  (identity corner tail)

    Dr_c[pad_diag + m, d] = Dr[m, d]    Dr_c[m < pad_diag, 0] = I
    R_c[pad_diag + k, i]  = R[k, i]     (zero for prefix rows / i >= nat)
    C_c[i, j] = C[i, j]                 C_c[i >= nat, i] = I

Everything here is host-side shape logic plus cheap ``jnp.pad``-class
array ops; the expensive sweeps stay inside the cached, canonically-keyed
callables of the serving entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.runtime import telemetry

from .batching import next_pow2
from .ctsf import BandedCTSF
from .structure import TileGrid

__all__ = ["GridBucketPolicy", "assemble_rung_batch", "assemble_rung_rhs",
           "embed_ctsf", "embed_rhs", "restrict_rhs", "restrict_factor",
           "restrict_selinv", "padded_flop_overhead"]


def _round_to_rungs(v: int, rungs: Sequence[int]) -> int:
    """Smallest rung >= v; beyond the top rung fall back to the next power
    of two so unusually large problems still canonicalize instead of
    failing (documented open-ended tail)."""
    for r in rungs:
        if r >= v:
            return r
    return next_pow2(v)


@dataclasses.dataclass(frozen=True)
class GridBucketPolicy:
    """Maps arbitrary tile grids onto a small canonical set.

    Attributes:
      band_rungs:  allowed canonical ``band_tiles`` values (ascending).
      arrow_rungs: allowed canonical ``n_arrow_tiles`` values (ascending).
      min_diag_tiles: floor for the pow2-rounded ``n_diag_tiles``.

    Canonical grids are built with :meth:`TileGrid.from_tile_counts`, so
    two source grids that land on the same rungs produce *equal* canonical
    grids — that equality is what collapses the per-grid compile caches.
    Values above the top rung round up to the next power of two (the
    policy never rejects a grid, it only stops deduplicating as tightly).
    """

    band_rungs: Tuple[int, ...] = (1, 2, 4, 8, 16)
    arrow_rungs: Tuple[int, ...] = (0, 1, 2, 4)
    min_diag_tiles: int = 4

    def __post_init__(self):
        for name in ("band_rungs", "arrow_rungs"):
            rungs = getattr(self, name)
            if not rungs or list(rungs) != sorted(set(rungs)):
                raise ValueError(f"{name} must be ascending and non-empty")
        if self.band_rungs[0] < 1:
            raise ValueError("band_rungs must start at >= 1 (a multi-tile "
                             "diagonal always has band_tiles >= 1)")
        if self.min_diag_tiles < 1:
            raise ValueError("min_diag_tiles must be >= 1")

    def rungs_for(self, grid: TileGrid) -> Tuple[int, int, int]:
        """Canonical (n_diag_tiles, band_tiles, n_arrow_tiles) for a grid."""
        ndt, bt, nat = grid.n_diag_tiles, grid.band_tiles, grid.n_arrow_tiles
        nat_c = _round_to_rungs(nat, self.arrow_rungs) if nat else 0
        if ndt == 0:
            return 0, 0, nat_c
        bt_c = _round_to_rungs(max(bt, 1), self.band_rungs)
        ndt_c = max(next_pow2(ndt), self.min_diag_tiles)
        while ndt_c - 1 < bt_c:          # from_tile_counts needs bt <= ndt-1
            ndt_c *= 2
        return ndt_c, bt_c, nat_c

    def canonicalize(self, grid: TileGrid) -> TileGrid:
        """The canonical grid a problem on ``grid`` embeds into (same tile
        size; only the tile counts are bucketed).

        When telemetry is enabled each call counts a hit on the chosen
        rung (``gridpolicy.rung_hit{rung=...}``) and observes the padded
        flop overhead of the embedding
        (``gridpolicy.padded_flop_overhead`` histogram) — the two numbers
        that say whether the policy's rung set fits the traffic."""
        ndt_c, bt_c, nat_c = self.rungs_for(grid)
        cgrid = TileGrid.from_tile_counts(grid.t, ndt_c, bt_c, nat_c)
        if telemetry.enabled():
            telemetry.inc("gridpolicy.rung_hit", rung=telemetry.rung_tag(cgrid))
            telemetry.observe("gridpolicy.padded_flop_overhead",
                              padded_flop_overhead(grid, cgrid))
        return cgrid

    def join(self, grids: Iterable[TileGrid]) -> TileGrid:
        """Smallest canonical grid every grid in ``grids`` embeds into —
        the shared rung ``concurrent.stack_ctsf`` uses to stack unequal
        structures.  All grids must share one tile size."""
        grids = list(grids)
        if not grids:
            raise ValueError("join needs at least one grid")
        ts = {g.t for g in grids}
        if len(ts) > 1:
            raise ValueError(f"cannot join grids with mixed tile sizes {sorted(ts)}")
        rungs = [self.rungs_for(g) for g in grids]
        # elementwise max of per-grid rungs is itself a valid rung triple:
        # bt_c > 0 implies some grid was banded, and that grid already
        # satisfied ndt_c_i - 1 >= bt_c, so the max does too
        ndt_c = max(r[0] for r in rungs)
        bt_c = max(r[1] for r in rungs)
        nat_c = max(r[2] for r in rungs)
        return TileGrid.from_tile_counts(grids[0].t, ndt_c, bt_c, nat_c)


def _check_embeddable(grid: TileGrid, cgrid: TileGrid) -> Tuple[int, int, int]:
    """Pad widths (diag, band, arrow) of the embedding, validating it is
    one.  A band-less (arrow-only) source embeds into a banded canonical
    grid too — its entire band part is identity prefix."""
    if grid.t != cgrid.t:
        raise ValueError(f"tile size mismatch: {grid.t} vs {cgrid.t}")
    pads = (cgrid.n_diag_tiles - grid.n_diag_tiles,
            cgrid.band_tiles - grid.band_tiles,
            cgrid.n_arrow_tiles - grid.n_arrow_tiles)
    if min(pads) < 0:
        raise ValueError(
            f"grid (ndt={grid.n_diag_tiles}, bt={grid.band_tiles}, "
            f"nat={grid.n_arrow_tiles}) does not embed into canonical "
            f"(ndt={cgrid.n_diag_tiles}, bt={cgrid.band_tiles}, "
            f"nat={cgrid.n_arrow_tiles})")
    return pads


def _lead_pad(arr, spec):
    """jnp.pad with the pad spec right-aligned (leading batch axes zero)."""
    lead = arr.ndim - len(spec)
    return jnp.pad(arr, [(0, 0)] * lead + list(spec))


def _embed_arrays(Dr, R, C, grid: TileGrid, cgrid: TileGrid):
    """Identity-diagonal embedding of (possibly batched) CTSF arrays —
    shared by :func:`embed_ctsf` (matrices *and* factors: the Cholesky
    factor of ``blockdiag(I, A)`` is ``blockdiag(I, L)``, so embedding
    commutes with factorization)."""
    pad_d, pad_b, pad_a = _check_embeddable(grid, cgrid)
    t = grid.t
    ident = jnp.eye(t, dtype=Dr.dtype)
    Dr_c = _lead_pad(Dr, [(pad_d, 0), (0, pad_b), (0, 0), (0, 0)])
    if pad_d:
        Dr_c = Dr_c.at[..., :pad_d, 0, :, :].set(ident)
    R_c = _lead_pad(R, [(pad_d, 0), (0, pad_a), (0, 0), (0, 0)])
    C_c = _lead_pad(C, [(0, pad_a), (0, pad_a), (0, 0), (0, 0)])
    if pad_a:
        tail = np.arange(grid.n_arrow_tiles, cgrid.n_arrow_tiles)
        C_c = C_c.at[..., tail, tail, :, :].set(ident)
    return Dr_c, R_c, C_c


def embed_ctsf(mat: BandedCTSF, cgrid: TileGrid) -> BandedCTSF:
    """Embed a banded-arrowhead matrix (or factor) into a canonical grid.

    The result represents ``blockdiag(I_prefix, A)`` with the corner
    extended by identity tiles: SPD iff ``A`` is, factor =
    ``blockdiag(I, L)``, ``logdet`` unchanged, ``Σ = blockdiag(I, A^{-1})``
    — so every downstream quantity of the embedded problem is exact on the
    original entries (extract with :func:`restrict_factor` /
    :func:`restrict_selinv` / :func:`restrict_rhs`).  Leading batch axes
    pass through untouched."""
    Dr, R, C = _embed_arrays(mat.Dr, mat.R, mat.C, mat.grid, cgrid)
    return BandedCTSF(cgrid, Dr, R, C)


def _restrict_arrays(Dr, R, C, cgrid: TileGrid, grid: TileGrid):
    pad_d, _, _ = _check_embeddable(grid, cgrid)
    ndt, b1, nat = grid.n_diag_tiles, grid.band_tiles + 1, grid.n_arrow_tiles
    return (Dr[..., pad_d:pad_d + ndt, :b1, :, :],
            R[..., pad_d:pad_d + ndt, :nat, :, :],
            C[..., :nat, :nat, :, :])


def restrict_factor(factor, grid: TileGrid = None):
    """Slice an embedded Cholesky factor back onto its source grid —
    the inverse of factorizing ``embed_ctsf(A, cgrid)``.  ``grid``
    defaults to ``factor.source_grid`` (set by the policy-aware
    factorization entry points)."""
    from .cholesky import CholeskyFactor
    grid = grid or factor.source_grid
    if grid is None:
        raise ValueError("restrict_factor needs a source grid (factor has "
                         "no source_grid and none was given)")
    ctsf = factor.ctsf
    Dr, R, C = _restrict_arrays(ctsf.Dr, ctsf.R, ctsf.C, ctsf.grid, grid)
    return CholeskyFactor(BandedCTSF(grid, Dr, R, C))


def restrict_selinv(sel, grid: TileGrid):
    """Slice an embedded selected inverse back onto its source grid.  The
    retained entries are exact entries of the original ``A^{-1}`` (the
    identity prefix is decoupled, so ``Σ_embedded = blockdiag(I, Σ)``)."""
    from .selinv import SelectedInverse
    Dr, R, C = _restrict_arrays(sel.Dr, sel.R, sel.C, sel.grid, grid)
    return SelectedInverse(grid, Dr, R, C)


def embed_rhs(B: jnp.ndarray, grid: TileGrid, cgrid: TileGrid) -> jnp.ndarray:
    """Lift an RHS panel from the source padded layout into the canonical
    one: band rows shift past the identity prefix (which solves to zero
    against zero RHS), arrow rows move past the band slack.  Rows live on
    axis ``-2`` (``(..., padded_n, k)``)."""
    pad_d, _, pad_a = _check_embeddable(grid, cgrid)
    t, ndt = grid.t, grid.n_diag_tiles
    if B.shape[-2] != grid.padded_n:
        raise ValueError(f"rhs panel rows {B.shape[-2]} != padded_n "
                         f"{grid.padded_n} of the source grid")
    bd, ba = B[..., :ndt * t, :], B[..., ndt * t:, :]
    zeros = lambda rows: jnp.zeros(B.shape[:-2] + (rows, B.shape[-1]), B.dtype)
    return jnp.concatenate(
        [zeros(pad_d * t), bd, ba, zeros(pad_a * t)], axis=-2)


def restrict_rhs(X: jnp.ndarray, grid: TileGrid, cgrid: TileGrid) -> jnp.ndarray:
    """Project a solution panel from the canonical layout back to the
    source padded layout (inverse of :func:`embed_rhs`)."""
    pad_d, _, _ = _check_embeddable(grid, cgrid)
    t, ndt, nat = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles
    off_a = cgrid.n_diag_tiles * t
    if X.shape[-2] != cgrid.padded_n:
        raise ValueError(f"solution panel rows {X.shape[-2]} != padded_n "
                         f"{cgrid.padded_n} of the canonical grid")
    return jnp.concatenate(
        [X[..., pad_d * t:(pad_d + ndt) * t, :],
         X[..., off_a:off_a + nat * t, :]], axis=-2)


def assemble_rung_batch(mats: Sequence[BandedCTSF],
                        cgrid: TileGrid) -> Tuple[BandedCTSF, int]:
    """Embed same-rung matrices (arbitrary source grids) onto ``cgrid``
    and stack them on a leading batch axis — the batch-assembly step of
    the continuous-batching rung server.

    Returns ``(batch, start_tile)``: ``start_tile`` is the *minimum*
    identity-prefix depth over the batch, the deepest shared skip that is
    correct for every element.  Elements with a deeper prefix have their
    rows between ``start_tile`` and their own pad depth *computed* rather
    than skipped, but those rows are exact identity tiles whose factor is
    themselves, so under-skipping never changes any element's factor —
    one traced start serves the whole mixed-depth batch.
    """
    if not mats:
        raise ValueError("assemble_rung_batch needs at least one matrix")
    embedded = [embed_ctsf(m, cgrid) for m in mats]
    start = min(cgrid.n_diag_tiles - m.grid.n_diag_tiles for m in mats)
    return BandedCTSF(cgrid,
                      jnp.stack([e.Dr for e in embedded]),
                      jnp.stack([e.R for e in embedded]),
                      jnp.stack([e.C for e in embedded])), start


def assemble_rung_rhs(panels: Sequence[jnp.ndarray],
                      grids: Sequence[TileGrid],
                      cgrid: TileGrid) -> jnp.ndarray:
    """Lift per-request RHS panels (each in its own source padded layout)
    into the canonical layout and stack: ``(B, cgrid.padded_n, k)``.  The
    RHS-side companion of :func:`assemble_rung_batch`; per-request results
    come back out through :func:`restrict_rhs`."""
    if len(panels) != len(grids):
        raise ValueError(f"{len(panels)} panels for {len(grids)} grids")
    if not panels:
        raise ValueError("assemble_rung_rhs needs at least one panel")
    return jnp.stack([embed_rhs(p, g, cgrid)
                      for p, g in zip(panels, grids)])


def _sweep_tile_matmuls(ndt: int, bt: int, nat: int) -> int:
    """Tile-matmul count model of one band+arrow factorization sweep (the
    left-looking band update, arrow update, panel substitutions and corner
    Schur) — the unit :func:`padded_flop_overhead` compares in."""
    band_update = bt * (bt + 1) // 2      # U[e] pairs per panel
    arrow_update = nat * bt               # V[i] pairs per panel
    subst = bt + nat                      # panel + arrow substitutions
    schur = nat * nat                     # corner Schur terms per panel
    return max(ndt, 1) * (band_update + arrow_update + subst + schur + 1)


def padded_flop_overhead(grid: TileGrid, cgrid: TileGrid) -> float:
    """Fractional extra tile-matmuls the canonical embedding pays over the
    source grid, *assuming the identity prefix is skipped* (the sweeps'
    ``start_tile`` fast path): only band/arrow widening costs compute, the
    ``pad_diag`` prefix rows do not.  0.0 means a zero-padding embedding
    (grid already on its rung)."""
    _check_embeddable(grid, cgrid)
    src = _sweep_tile_matmuls(grid.n_diag_tiles, grid.band_tiles,
                              grid.n_arrow_tiles)
    emb = _sweep_tile_matmuls(grid.n_diag_tiles, cgrid.band_tiles,
                              cgrid.n_arrow_tiles)
    return emb / src - 1.0
