"""Tree reduction of accumulation chains (paper §IV-A, Algorithm 3).

The left-looking factorization accumulates k GEMM/SYRK products into one
tile; executed sequentially that chain is the critical path (paper Table I:
time grows linearly in k).  Algorithm 3 splits the products into per-worker
chunks, each worker accumulates its chunk locally, and the partial tiles are
combined by a binary GEADD tree (Figs. 6–7).

On TPU the same reassociation appears at two levels:

* on-chip: the chunk axis becomes a parallel batch dimension (independent
  contractions XLA/MXU can overlap) and the log₂-depth pairwise GEADD tree
  is unrolled at trace time;
* cross-chip: partials live on different devices and the GEADD tree becomes
  a `ppermute` butterfly (see ``repro.sharding.collectives.tree_allreduce``).

The paper's enablement heuristic is kept verbatim: use the tree only when
the number of accumulations is at least twice the number of workers.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.kernels import ops

__all__ = ["should_use_tree", "tree_combine", "chunked_tree_sum"]


def should_use_tree(n_accumulations: int, n_workers: int) -> bool:
    """Paper §IV-A: 'at least 2 cores, and ... accumulations at least double
    the number of cores being used'."""
    return n_workers >= 2 and n_accumulations >= 2 * n_workers


def tree_combine(partials: jnp.ndarray,
                 add: Optional[Callable] = None) -> jnp.ndarray:
    """Binary-tree pairwise combine over the leading axis (log₂ depth).

    ``partials``: (c, ...) stacked partial results, returns their sum with
    tree association order — numerically the paper's GEADD hierarchy.
    """
    add = add or ops.geadd
    while partials.shape[0] > 1:
        c = partials.shape[0]
        half = c // 2
        combined = add(partials[0:2 * half:2], partials[1:2 * half:2])
        if c % 2:
            combined = jnp.concatenate([combined, partials[-1:]], axis=0)
        partials = combined
    return partials[0]


def chunked_tree_sum(terms: jnp.ndarray, n_chunks: int,
                     add: Optional[Callable] = None) -> jnp.ndarray:
    """Sum ``terms`` (K, ...) over axis 0 via Algorithm 3.

    K products are split into ``n_chunks`` contiguous ranges (the paper's
    ``start_range/end_range`` per worker); each chunk is accumulated
    sequentially (a worker's local loop) and chunk partials are combined by
    the GEADD tree.  Equivalent to ``terms.sum(0)`` up to fp reassociation.
    """
    k = terms.shape[0]
    n_chunks = max(1, min(n_chunks, k))
    pad = (-k) % n_chunks
    if pad:
        terms = jnp.concatenate(
            [terms, jnp.zeros((pad,) + terms.shape[1:], terms.dtype)], axis=0)
    per = terms.shape[0] // n_chunks
    partials = terms.reshape((n_chunks, per) + terms.shape[1:]).sum(axis=1)
    return tree_combine(partials, add=add)
