"""Symbolic factorization and static task-list generation (paper §II, §III-C).

Given a tile-level nonzero pattern, this module:

  1. computes the tile pattern of the Cholesky factor L (symbolic
     factorization — "identifies where the nonzero elements will be located,
     allowing for the allocation of storage for L");
  2. emits the exact task list of Algorithm 1 (left-looking sparse tile
     Cholesky) restricted to nonzero tiles — POTRF / SYRK / TRSM / GEMM
     with their {m, n, k} triples, in a valid left-looking order.

The task list plays the role of the paper's per-thread Task Assignment
Tables (Algorithm 2): it is fixed before any numerical work.  In the JAX
port the list is unrolled at trace time and XLA's static scheduler replaces
the progress table (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Tuple

import numpy as np

__all__ = ["TaskType", "Task", "SymbolicFactorization", "symbolic_factorize"]


class TaskType(enum.IntEnum):
    POTRF = 1
    SYRK = 2
    TRSM = 3
    GEMM = 4


@dataclasses.dataclass(frozen=True)
class Task:
    """One tile task. Semantics (lower-triangular storage, Alg. 1):

      POTRF: A[k,k]  <- chol(A[k,k])
      SYRK:  A[k,k]  <- A[k,k] - A[k,n] A[k,n]^T          (n < k)
      TRSM:  A[m,k]  <- A[m,k] A[k,k]^{-T}                (m > k)
      GEMM:  A[m,k]  <- A[m,k] - A[m,n] A[k,n]^T          (n < k < m)
    """
    type: TaskType
    k: int
    m: int = -1
    n: int = -1


@dataclasses.dataclass
class SymbolicFactorization:
    n_tiles: int
    a_pattern: np.ndarray          # (nt, nt) bool, lower, input tiles
    l_pattern: np.ndarray          # (nt, nt) bool, lower, factor tiles (incl. fill)
    tasks: List[Task]
    fill_tiles: int

    # --- cost model (used by benchmarks + roofline) -------------------------
    def flops(self, t: int) -> dict:
        """FLOP count per kernel type for tile size t (dense tile kernels)."""
        c = {TaskType.POTRF: 0, TaskType.SYRK: 0, TaskType.TRSM: 0, TaskType.GEMM: 0}
        for task in self.tasks:
            c[task.type] += 1
        return {
            "POTRF": c[TaskType.POTRF] * t ** 3 / 3.0,
            "SYRK": c[TaskType.SYRK] * t ** 3,
            "TRSM": c[TaskType.TRSM] * t ** 3,
            "GEMM": c[TaskType.GEMM] * 2.0 * t ** 3,
        }

    def total_flops(self, t: int) -> float:
        return float(sum(self.flops(t).values()))

    def accumulation_counts(self) -> np.ndarray:
        """Number of GEMM/SYRK accumulations per destination tile.

        This is the quantity the paper's tree-reduction heuristic consumes
        ("number of accumulations at least double the number of cores").
        """
        acc = np.zeros((self.n_tiles, self.n_tiles), dtype=np.int64)
        for task in self.tasks:
            if task.type == TaskType.SYRK:
                acc[task.k, task.k] += 1
            elif task.type == TaskType.GEMM:
                acc[task.m, task.k] += 1
        return acc

    def critical_path_length(self) -> int:
        """Length of the longest dependency chain in the task DAG (Fig. 2).

        Dependencies follow Algorithm 2's progress-table semantics.
        """
        depth: dict = {}

        def tile_ready(t):
            return depth.get(t, 0)

        for task in self.tasks:
            if task.type == TaskType.POTRF:
                d = tile_ready((task.k, task.k)) + 1
                depth[(task.k, task.k)] = d
            elif task.type == TaskType.SYRK:
                d = max(tile_ready((task.k, task.k)), tile_ready((task.k, task.n))) + 1
                depth[(task.k, task.k)] = d
            elif task.type == TaskType.TRSM:
                d = max(tile_ready((task.m, task.k)), tile_ready((task.k, task.k))) + 1
                depth[(task.m, task.k)] = d
            else:  # GEMM
                d = max(tile_ready((task.m, task.k)), tile_ready((task.m, task.n)),
                        tile_ready((task.k, task.n))) + 1
                depth[(task.m, task.k)] = d
        return max(depth.values()) if depth else 0

    def max_parallelism(self) -> int:
        """Max number of tasks at equal DAG depth (width of Fig. 2's DAG)."""
        depth: dict = {}
        level_count: dict = {}

        def tile_ready(t):
            return depth.get(t, 0)

        for task in self.tasks:
            if task.type == TaskType.POTRF:
                d = tile_ready((task.k, task.k)) + 1
                depth[(task.k, task.k)] = d
            elif task.type == TaskType.SYRK:
                d = max(tile_ready((task.k, task.k)), tile_ready((task.k, task.n))) + 1
                depth[(task.k, task.k)] = d
            elif task.type == TaskType.TRSM:
                d = max(tile_ready((task.m, task.k)), tile_ready((task.k, task.k))) + 1
                depth[(task.m, task.k)] = d
            else:
                d = max(tile_ready((task.m, task.k)), tile_ready((task.m, task.n)),
                        tile_ready((task.k, task.n))) + 1
                depth[(task.m, task.k)] = d
            level_count[d] = level_count.get(d, 0) + 1
        return max(level_count.values()) if level_count else 0


def symbolic_factorize(a_pattern: np.ndarray) -> SymbolicFactorization:
    """Tile symbolic factorization + Algorithm 1 task list.

    ``a_pattern`` is the boolean lower-triangular tile map (from
    :func:`repro.core.structure.tile_pattern_from_coo`).
    """
    nt = a_pattern.shape[0]
    a_pattern = np.tril(a_pattern.astype(bool))

    # ----- symbolic elimination: column pattern propagation -----------------
    cols: List[set] = [set(np.nonzero(a_pattern[:, k])[0]) for k in range(nt)]
    for k in range(nt):
        cols[k].add(k)
        below = sorted(x for x in cols[k] if x > k)
        if below:
            parent = below[0]
            cols[parent].update(x for x in below if x > parent)

    l_pattern = np.zeros_like(a_pattern)
    for k in range(nt):
        for r in cols[k]:
            if r >= k:
                l_pattern[r, k] = True

    # neighbors(k): m such that L[m,k] nonzero, m > k (paper's definition on
    # the *filled* pattern — updates flow through fill tiles too).
    nbr_below = [sorted(np.nonzero(l_pattern[:, k])[0][np.nonzero(l_pattern[:, k])[0] > k])
                 for k in range(nt)]
    nbr_left = [sorted(np.nonzero(l_pattern[k, :])[0][np.nonzero(l_pattern[k, :])[0] < k])
                for k in range(nt)]

    # ----- Algorithm 1 (left-looking), restricted to nonzero tiles ----------
    tasks: List[Task] = []
    for k in range(nt):
        for n in nbr_left[k]:                       # SYRK accumulations
            tasks.append(Task(TaskType.SYRK, k=k, n=n))
        tasks.append(Task(TaskType.POTRF, k=k))
        for m in nbr_below[k]:
            # GEMM accumulations: n in neighbors(k) ∩ neighbors(m), n < k
            common = set(nbr_left[k]).intersection(nbr_left[m])
            for n in sorted(common):
                tasks.append(Task(TaskType.GEMM, k=k, m=m, n=n))
            tasks.append(Task(TaskType.TRSM, k=k, m=m))

    fill = int(l_pattern.sum() - a_pattern.sum())
    return SymbolicFactorization(nt, a_pattern, l_pattern, tasks, fill)
