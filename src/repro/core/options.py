"""Unified solver options for every serving entry point.

Nine PRs of organic growth left the entry points threading the same small
set of knobs — ``policy=``, ``regularize=``, ``impl=``, ``sweep=``, and
``marginal_variances``'s oddly-named ``method=`` — through a dozen
signatures, and every new feature (the partitioned sweep's
``partition_plan`` being the motivating case) had to widen all of them
again.  :class:`SolverOptions` consolidates that surface: one frozen,
hashable dataclass accepted as a single ``options=`` kwarg by
``factorize_window(_batched)``, the ``solve_many`` family,
``selected_inverse``/``selinv_batched``, the ``concurrent_*`` wrappers
and the rung server.

Legacy per-kwarg signatures keep working through :func:`resolve_options`,
which folds them into an options object while emitting one
``DeprecationWarning`` per legacy kwarg actually passed — internal code
is fully migrated (CI runs the suite under ``-W
error::DeprecationWarning`` excluding the shim tests to prove it).

Hashability is load-bearing, not cosmetic: the batching compile caches
key on :meth:`SolverOptions.compile_key` — the compile-relevant subset of
the options — so option-equal calls share traced callables no matter
which construction path produced the object.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Union

from .gridpolicy import GridBucketPolicy
from .ordering import PartitionPlan
from .robustness import RegularizePolicy

__all__ = ["SolverOptions", "resolve_options", "UNSET"]


class _Unset:
    """Sentinel distinguishing "kwarg not passed" from an explicit None
    (``impl=None`` is a meaningful value: the per-backend default)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "<UNSET>"

    def __bool__(self):
        return False


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """How to factorize/solve — everything except the data itself.

    Fields:
      policy: a :class:`~repro.core.gridpolicy.GridBucketPolicy`
        canonical-grid bucketing policy, or None for exact-grid compiles.
      regularize: numerical fault tolerance — ``None``/``False`` off,
        ``True`` the default :class:`~repro.core.robustness.RegularizePolicy`,
        or an explicit policy (the escalating-jitter retry ladder).
      impl: kernel backend — ``"pallas"``, ``"ref"``, ``"unrolled"`` or
        None for the per-backend default (pallas on TPU, ref elsewhere).
      sweep: factorization sweep mode — ``"auto"`` (default), ``"fused"``,
        ``"ring"``, ``"window"`` or ``"partitioned"`` (see
        ``core.cholesky._factorize_window_impl``).
      partition_plan: a :class:`~repro.core.ordering.PartitionPlan` of
        independent band partitions; with >1 partition, ``sweep="auto"``
        dispatches the multi-partition fused sweep (2D Pallas grid, one
        parallel axis over partitions).
      method: marginal-variance path — None (= ``"selinv"``) or
        ``"panels"``; folds ``marginal_variances``'s old ``method=``
        kwarg into the shared options surface.

    Frozen and hashable (all fields are immutables or frozen dataclasses),
    so an options object can key compile caches directly.  Per-call data —
    RHS panels, ``start_tile`` prefixes, batch bucketing — stays out by
    design: options describe *how*, arguments describe *what*.
    """

    policy: Optional[GridBucketPolicy] = None
    regularize: Union[None, bool, RegularizePolicy] = None
    impl: Optional[str] = None
    sweep: str = "auto"
    partition_plan: Optional[PartitionPlan] = None
    method: Optional[str] = None

    def compile_key(self) -> "SolverOptions":
        """The compile-relevant subset, as a (hashable) options object.

        ``policy``, ``regularize`` and ``method`` never change what a
        traced sweep callable computes — the policy picks *which* grid is
        compiled (already part of every cache key), the ladder re-invokes
        the same callable, and ``method`` selects between entry points —
        so they are cleared here and option-equal calls share compile-
        cache entries across those axes."""
        return dataclasses.replace(self, policy=None, regularize=None,
                                   method=None)

    def replace(self, **changes) -> "SolverOptions":
        """`dataclasses.replace` as a method, for call-site brevity."""
        return dataclasses.replace(self, **changes)


def resolve_options(options: Optional[SolverOptions] = None, *,
                    _where: str = "this entry point",
                    _stacklevel: int = 3,
                    **legacy) -> SolverOptions:
    """Merge legacy per-kwarg arguments into a :class:`SolverOptions`.

    Every entry point calls this once: ``legacy`` maps field names to the
    caller's legacy kwarg values, with :data:`UNSET` marking "not
    passed".  Each legacy kwarg actually passed emits one
    ``DeprecationWarning`` naming the replacement, then overrides the
    corresponding field of ``options`` (legacy wins, so half-migrated
    call sites behave exactly as they read).  With no legacy kwargs the
    options object passes through untouched — the zero-warning path the
    ``-W error::DeprecationWarning`` CI leg locks in.
    """
    base = options if options is not None else SolverOptions()
    if not isinstance(base, SolverOptions):
        raise TypeError(
            f"options= must be a SolverOptions, got {type(base).__name__}")
    updates = {}
    for name, value in legacy.items():
        if value is UNSET:
            continue
        warnings.warn(
            f"{_where}: the `{name}=` kwarg is deprecated; pass "
            f"options=SolverOptions({name}=...) instead",
            DeprecationWarning, stacklevel=_stacklevel)
        updates[name] = value
    return dataclasses.replace(base, **updates) if updates else base
