"""Triangular solves, log-determinant and GMRF sampling from sTiles factors.

INLA (the paper's driving application) needs, per factorization: solves
``A x = b`` (posterior means), ``log det A`` (Laplace approximations) and
samples ``L^{-T} z`` (GMRF realizations).  All operate directly on the
banded-arrowhead CTSF factor without densification.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .cholesky import CholeskyFactor
from .ctsf import BandedCTSF

__all__ = ["forward_solve", "backward_solve", "solve", "logdet",
           "sample_gmrf", "marginal_variances"]

_HI = jax.lax.Precision.HIGHEST


def _split_rhs(ctsf: BandedCTSF, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g = ctsf.grid
    t, ndt, nat = g.t, g.n_diag_tiles, g.n_arrow_tiles
    b = b.reshape(-1)
    assert b.shape[0] == g.padded_n, f"rhs must be padded to {g.padded_n}"
    bd = b[: ndt * t].reshape(ndt, t)
    ba = b[ndt * t:].reshape(nat, t) if nat else jnp.zeros((0, t), b.dtype)
    return bd, ba


@functools.partial(jax.jit, static_argnames=("grid",))
def _forward_impl(Dr, R, C, bd, ba, grid):
    """Solve L y = b."""
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    yp = jnp.zeros((ndt + bt, t), bd.dtype)  # bt leading zeros

    def step(k, yp):
        # y_k = Lkk^{-1} (b_k - sum_{j=1..bt} L[k,k-j] y_{k-j})
        ywin = jax.lax.dynamic_slice(yp, (k, 0), (bt, t)) if bt else yp[:0]
        # ywin[bt - j] = y_{k-j}; Dr[k, j] = L[k, k-j]
        drk = jax.lax.dynamic_slice(Dr, (k, 0, 0, 0), (1, bt + 1, t, t))[0]
        acc = jnp.einsum("jab,jb->a", jnp.flip(drk[1:], axis=0), ywin,
                         precision=_HI) if bt else 0.0
        bk = jax.lax.dynamic_slice(bd, (k, 0), (1, t))[0]
        yk = jax.scipy.linalg.solve_triangular(drk[0], bk - acc, lower=True)
        return jax.lax.dynamic_update_slice(yp, yk[None], (k + bt, 0))

    yp = jax.lax.fori_loop(0, ndt, step, yp)
    yd = yp[bt:]

    if nat:
        # arrow rows: y_a = Lc^{-1} (b_a - sum_n R[n] y_n), block forward
        acc = jnp.einsum("niab,nb->ia", R, yd, precision=_HI)
        ya = jnp.zeros((nat, t), bd.dtype)
        for i in range(nat):
            rhs = ba[i] - acc[i]
            for j in range(i):
                rhs = rhs - jnp.dot(C[i, j], ya[j], precision=_HI)
            ya = ya.at[i].set(
                jax.scipy.linalg.solve_triangular(C[i, i], rhs, lower=True))
    else:
        ya = ba
    return yd, ya


@functools.partial(jax.jit, static_argnames=("grid",))
def _backward_impl(Dr, R, C, yd, ya, grid):
    """Solve L^T x = y."""
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles

    if nat:
        xa = jnp.zeros((nat, t), yd.dtype)
        for i in range(nat - 1, -1, -1):
            rhs = ya[i]
            for j in range(i + 1, nat):
                rhs = rhs - jnp.dot(C[j, i].T, xa[j], precision=_HI)
            xa = xa.at[i].set(jax.scipy.linalg.solve_triangular(
                C[i, i], rhs, lower=True, trans=1))
    else:
        xa = ya

    # band rows, reverse sweep:
    # x_k = Lkk^{-T}(y_k - sum_{j=1..bt} L[k+j,k]^T x_{k+j} - sum_i R[k,i]^T xa_i)
    Drp = jnp.pad(Dr, ((0, bt), (0, 0), (0, 0), (0, 0)))  # slack for k+j reads
    xp = jnp.zeros((ndt + bt, t), yd.dtype)

    jr = jnp.arange(bt)

    def step(i, xp):
        k = ndt - 1 - i
        wb = jax.lax.dynamic_slice(Drp, (k + 1, 0, 0, 0), (bt, bt + 1, t, t)) \
            if bt else Drp[:0]
        # L[k+j, k] = Drp[k+j, j]  -> wb[j-1, j]
        sub = wb[jr, jr + 1] if bt else wb[:, 0]
        xwin = jax.lax.dynamic_slice(xp, (k + 1, 0), (bt, t)) if bt else xp[:0]
        acc = jnp.einsum("jab,ja->b", sub, xwin, precision=_HI) if bt else 0.0
        if nat:
            rk = jax.lax.dynamic_slice(R, (k, 0, 0, 0), (1, nat, t, t))[0]
            acc = acc + jnp.einsum("iab,ia->b", rk, xa, precision=_HI)
        yk = jax.lax.dynamic_slice(yd, (k, 0), (1, t))[0]
        lkk = jax.lax.dynamic_slice(Dr, (k, 0, 0, 0), (1, 1, t, t))[0, 0]
        xk = jax.scipy.linalg.solve_triangular(lkk, yk - acc, lower=True, trans=1)
        return jax.lax.dynamic_update_slice(xp, xk[None], (k, 0))

    xp = jax.lax.fori_loop(0, ndt, step, xp)
    return xp[:ndt], xa


def forward_solve(factor: CholeskyFactor, b: jnp.ndarray) -> jnp.ndarray:
    ctsf = factor.ctsf
    bd, ba = _split_rhs(ctsf, b)
    yd, ya = _forward_impl(ctsf.Dr, ctsf.R, ctsf.C, bd, ba, ctsf.grid)
    return jnp.concatenate([yd.reshape(-1), ya.reshape(-1)])


def backward_solve(factor: CholeskyFactor, y: jnp.ndarray) -> jnp.ndarray:
    ctsf = factor.ctsf
    yd, ya = _split_rhs(ctsf, y)
    xd, xa = _backward_impl(ctsf.Dr, ctsf.R, ctsf.C, yd, ya, ctsf.grid)
    return jnp.concatenate([xd.reshape(-1), xa.reshape(-1)])


def solve(factor: CholeskyFactor, b: jnp.ndarray) -> jnp.ndarray:
    """A x = b via L L^T."""
    return backward_solve(factor, forward_solve(factor, b))


def logdet(factor: CholeskyFactor) -> jnp.ndarray:
    return factor.logdet()


def sample_gmrf(factor: CholeskyFactor, key: jax.Array) -> jnp.ndarray:
    """Draw x ~ N(0, A^{-1}) via x = L^{-T} z (the INLA sampling primitive)."""
    z = jax.random.normal(key, (factor.ctsf.grid.padded_n,), dtype=jnp.float32)
    return backward_solve(factor, z)


def marginal_variances(factor: CholeskyFactor,
                       indices: jnp.ndarray) -> jnp.ndarray:
    """Selected diagonal of A^{-1} — INLA's posterior marginal variances.

    (A^{-1})_{ii} = ‖L^{-1} e_i‖²; each selected index costs one forward
    band solve (O(n·b) — the factor is reused across all of INLA's
    per-latent marginals, which is why factorize-once matters there).
    """
    g = factor.ctsf.grid

    def one(i):
        e = jnp.zeros((g.padded_n,), jnp.float32).at[i].set(1.0)
        y = forward_solve(factor, e)
        return jnp.sum(y * y)

    return jax.lax.map(one, jnp.asarray(indices))
