"""Triangular solves, log-determinant and GMRF sampling from sTiles factors.

INLA (the paper's driving application) needs, per factorization: solves
``A x = b`` (posterior means), ``log det A`` (Laplace approximations) and
samples ``L^{-T} z`` (GMRF realizations).  All operate directly on the
banded-arrowhead CTSF factor without densification.

Batched serving path
--------------------
Every sweep here is a *multi-RHS panel* sweep: right-hand sides are shaped
``(padded_n, k)`` and the band step applies each ``(t, t)`` factor tile to a
``(t, k)`` panel — one matmul instead of k matvecs (cf. Ruipeng Li's
observation that sparse triangular solves only escape the latency/bandwidth
bound when RHS are blocked into panels).  The single-RHS API
(:func:`solve`, :func:`forward_solve`, ...) is the k=1 specialization of the
same code path; :func:`solve_many` exposes the panel form, and
:func:`marginal_variances` / :func:`sample_gmrf` ride one blocked sweep for
all selected indices / samples.

With ``impl="pallas"`` each band sweep is one *fused* kernel launch
(``kernels/band_solve.py``): a ring of the most recent ``band_tiles``
solved panels stays resident in VMEM across tile rows, removing the
per-tile HBM round-trips of the ``fori_loop``-of-``solve_panel`` reference
path (which remains the jnp oracle and the CPU default).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.runtime import telemetry
from .batching import LRUCache, bucketed_batched_call
from .cholesky import CholeskyFactor
from .ctsf import BandedCTSF
from .options import SolverOptions, UNSET, resolve_options

__all__ = ["forward_solve", "backward_solve", "solve", "logdet",
           "forward_solve_many", "backward_solve_many", "solve_many",
           "solve_many_batched", "sample_gmrf", "sample_gmrf_many",
           "marginal_variances"]

_HI = jax.lax.Precision.HIGHEST


def _split_rhs(g, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split an (padded_n, k) RHS panel into band (ndt, t, k) and arrow
    (nat, t, k) tile panels."""
    t, ndt, nat = g.t, g.n_diag_tiles, g.n_arrow_tiles
    # a real validation (bare asserts vanish under `python -O`)
    if b.ndim != 2 or b.shape[0] != g.padded_n:
        raise ValueError(
            f"rhs panel must be (padded_n={g.padded_n}, k), got {b.shape}")
    k = b.shape[1]
    bd = b[: ndt * t].reshape(ndt, t, k)
    ba = b[ndt * t:].reshape(nat, t, k) if nat else jnp.zeros((0, t, k), b.dtype)
    return bd, ba


@functools.partial(jax.jit, static_argnames=("grid", "impl"))
def _forward_impl(Dr, R, C, bd, ba, grid, impl=None, start_tile=0):
    """Solve L Y = B for an RHS panel: bd (ndt, t, k), ba (nat, t, k).

    The band part is one :func:`repro.kernels.ops.band_forward_sweep` —
    with ``impl="pallas"`` the whole sweep (and the arrow-RHS accumulation)
    is a single fused kernel launch; otherwise it is the per-tile
    ``fori_loop`` of ``solve_panel`` reference.

    ``start_tile`` exploits RHS sparsity: when every column of the panel is
    zero above band tile ``start_tile`` (e.g. the unit-vector panels of
    selected marginals), the band sweep may begin there — Y is provably zero
    above the first nonzero tile, which both backends encode by leaving
    those rows zero.  It is a *traced* scalar, so varying selections never
    retrace/recompile the sweep.
    """
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    k = bd.shape[-1]
    if ndt:
        yd, acc_a = ops.band_forward_sweep(Dr, R, bd, start_tile=start_tile,
                                           impl=impl)
    else:
        yd = jnp.zeros((0, t, k), bd.dtype)
        acc_a = jnp.zeros((nat, t, k), bd.dtype)

    if nat:
        # arrow rows: Y_a = Lc^{-1} (B_a - sum_n R[n] Y_n), block forward
        rhs0 = ba - acc_a
        iota = jnp.arange(nat)

        def corner_step(i, ya):
            # rhs_i = rhs0_i - sum_{j<i} C[i,j] Y_j  (masked full-row matmul)
            crow = jax.lax.dynamic_slice(C, (i, 0, 0, 0), (1, nat, t, t))[0]
            crow = jnp.where((iota < i)[:, None, None], crow, 0.0)
            contrib = jnp.einsum("jab,jbk->ak", crow, ya, precision=_HI)
            cii = jax.lax.dynamic_slice(C, (i, i, 0, 0), (1, 1, t, t))[0, 0]
            rhs = jax.lax.dynamic_slice(rhs0, (i, 0, 0), (1, t, k))[0] - contrib
            yi = ops.solve_panel(cii, rhs, impl=impl)
            return jax.lax.dynamic_update_slice(ya, yi[None], (i, 0, 0))

        ya = jax.lax.fori_loop(0, nat, corner_step,
                               jnp.zeros((nat, t, k), bd.dtype))
    else:
        ya = ba
    return yd, ya


@functools.partial(jax.jit, static_argnames=("grid", "impl"))
def _backward_impl(Dr, R, C, yd, ya, grid, impl=None, start_tile=0):
    """Solve L^T X = Y for an RHS panel: yd (ndt, t, k), ya (nat, t, k).

    Corner first (the arrow panel seeds the band rows), then the band part
    runs as one :func:`repro.kernels.ops.band_backward_sweep` — fused into
    a single kernel launch under ``impl="pallas"``.

    ``start_tile`` mirrors the forward sweep's traced fast path: rows
    below it (the identity prefix of a canonical-grid embedding,
    ``core/gridpolicy.py``) are decoupled with zero RHS, so the reverse
    sweep stops before reaching them and X stays zero there."""
    t, ndt, nat, bt = grid.t, grid.n_diag_tiles, grid.n_arrow_tiles, grid.band_tiles
    k = yd.shape[-1]

    if nat:
        iota = jnp.arange(nat)

        def corner_step(s, xa):
            i = nat - 1 - s
            # rhs_i = Y_i - sum_{j>i} C[j,i]^T X_j  (masked full-column matmul)
            ccol = jax.lax.dynamic_slice(C, (0, i, 0, 0), (nat, 1, t, t))[:, 0]
            ccol = jnp.where((iota > i)[:, None, None], ccol, 0.0)
            contrib = jnp.einsum("jba,jbk->ak", ccol, xa, precision=_HI)
            cii = jax.lax.dynamic_slice(C, (i, i, 0, 0), (1, 1, t, t))[0, 0]
            rhs = jax.lax.dynamic_slice(ya, (i, 0, 0), (1, t, k))[0] - contrib
            xi = ops.solve_panel(cii, rhs, trans=True, impl=impl)
            return jax.lax.dynamic_update_slice(xa, xi[None], (i, 0, 0))

        xa = jax.lax.fori_loop(0, nat, corner_step,
                               jnp.zeros((nat, t, k), yd.dtype))
    else:
        xa = ya

    # band rows, reverse sweep:
    # X_m = Lmm^{-T}(Y_m - sum_{j=1..bt} L[m+j,m]^T X_{m+j} - sum_i R[m,i]^T Xa_i)
    if ndt:
        xd = ops.band_backward_sweep(Dr, R, yd, xa, start_tile, impl=impl)
    else:
        xd = jnp.zeros((0, t, k), yd.dtype)
    return xd, xa


def _solve_panels(Dr, R, C, bd, ba, grid, impl=None, start_tile=None):
    """Full ``A X = B`` on split panels: forward then backward sweep.  The
    single source of truth shared by :func:`solve_many` and the vmapped
    ``concurrent_solve`` — layout changes (e.g. a fused Pallas band-solve)
    land here once.  ``start_tile=None`` keeps the static-zero traces;
    a (traced) value threads the canonical-grid prefix skip through both
    sweeps."""
    if start_tile is None:
        yd, ya = _forward_impl(Dr, R, C, bd, ba, grid, impl)
        return _backward_impl(Dr, R, C, yd, ya, grid, impl)
    yd, ya = _forward_impl(Dr, R, C, bd, ba, grid, impl, start_tile)
    return _backward_impl(Dr, R, C, yd, ya, grid, impl, start_tile)


def _resolve_embedding(factor: CholeskyFactor, policy=None):
    """Resolve the canonical-grid embedding of a factor for the solve-side
    entry points.

    Returns ``(ctsf, source_grid, start_tile)``: for a plain factor with no
    policy that is ``(factor.ctsf, None, None)`` (static-zero sweeps); for
    a factor already living on a canonical grid (``source_grid`` set by the
    policy-aware factorizations) the embedding is reused as-is; for a plain
    factor with a ``policy`` the factor itself is embedded on the fly — the
    Cholesky factor of ``blockdiag(I, A)`` is ``blockdiag(I, L)``, so
    identity-padding a *factor* is exact.  Note the on-the-fly path pads
    fresh arrays *per call*: a serving loop reusing one factor should pass
    the policy at factorization time instead, so the factor is embedded
    once and every solve reuses it."""
    ctsf, src = factor.ctsf, factor.source_grid
    if src is None and policy is not None:
        from .gridpolicy import embed_ctsf
        cgrid = policy.canonicalize(ctsf.grid)
        src, ctsf = ctsf.grid, embed_ctsf(ctsf, cgrid)
    if src is None:
        return ctsf, None, None
    return ctsf, src, ctsf.grid.n_diag_tiles - src.n_diag_tiles


def _embedded_panels(factor: CholeskyFactor, policy, B: jnp.ndarray):
    """The shared front half of every policy-aware RHS entry point:
    resolve the factor's canonical-grid embedding, lift the panel into the
    canonical layout, and hand back the restriction mapping results home.

    Returns ``(ctsf, source_grid, grid, panel, start_tile, restrict)``.
    For a plain factor without policy the panel passes through, ``start``
    is None (keeping the static-zero sweep traces) and ``restrict`` is the
    identity; otherwise ``start`` is the traced prefix depth and
    ``restrict`` slices a canonical-layout result panel (any leading batch
    axes) back to the source layout.  Every entry point writes the
    embed/restrict logic exactly once — here."""
    ctsf, src, pad = _resolve_embedding(factor, policy)
    g = ctsf.grid
    if src is None:
        return ctsf, src, g, B, None, lambda X: X
    from .gridpolicy import embed_rhs, restrict_rhs
    return (ctsf, src, g, embed_rhs(B, src, g),
            jnp.asarray(pad, jnp.int32),
            lambda X: restrict_rhs(X, src, g))


def _merge_panels(xd: jnp.ndarray, xa: jnp.ndarray) -> jnp.ndarray:
    """Rejoin band (ndt, t, k) and arrow (nat, t, k) tile panels into one
    (padded_n, k) RHS panel — the inverse of :func:`_split_rhs`.  Shapes are
    spelled out (no -1) so a k=0 panel round-trips."""
    k = xd.shape[-1]
    return jnp.concatenate([xd.reshape(xd.shape[0] * xd.shape[1], k),
                            xa.reshape(xa.shape[0] * xa.shape[1], k)])


def forward_solve_many(factor: CholeskyFactor, B: jnp.ndarray,
                       impl=UNSET,
                       start_tile: int = 0, policy=UNSET,
                       options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Solve ``L Y = B`` for a panel of right-hand sides in one blocked sweep.

    Args:
      factor: banded-arrowhead Cholesky factor (``factorize_window``).
      B: ``(padded_n, k)`` float32 panel in the *padded* layout of
        ``factor.ctsf.grid`` (band rows first, then padding, then arrow
        rows — see ``TileGrid.padded_index``).  Rows in the padding region
        must be zero; they solve against identity diagonal tiles.
      options: a :class:`~repro.core.options.SolverOptions` carrying the
        solver knobs.  ``options.impl="pallas"`` runs the whole band sweep
        as one fused kernel (``kernels.ops.band_forward_sweep``), ``"ref"``
        the per-tile ``fori_loop`` reference; ``None`` picks per backend
        (pallas on TPU, ref elsewhere).  The bare ``impl=``/``policy=``
        kwargs are deprecated aliases.
      start_tile: first band tile holding a nonzero (RHS-sparsity fast
        start).  The caller guarantees all rows above ``start_tile * t``
        are zero; the returned Y is identically zero there.

    Returns: ``(padded_n, k)`` solution panel Y.

    Recompilation: one compile per ``(grid, impl, k)``; ``start_tile`` is
    traced, so varying selections reuse the compiled sweep — but any
    nonzero ``start_tile`` uses a dynamic-bound loop variant on the ref
    path (not reverse-differentiable), so ``start_tile=0`` keeps its own
    static-bound compilation.

    Embedded factors (``factor.source_grid`` set, or ``policy`` given —
    see ``core/gridpolicy.py``) take and return panels in the *source*
    grid's padded layout; the canonical embedding, the identity-prefix
    fast start and the restriction are handled here, and ``start_tile``
    keeps its source-grid meaning.
    """
    opts = resolve_options(options, _where="forward_solve_many", impl=impl,
                           policy=policy)
    impl = opts.impl
    with telemetry.span("solve.forward_many", k=B.shape[-1]) as sp:
        ctsf, src, g, B, start, restrict = _embedded_panels(factor,
                                                            opts.policy, B)
        sp.tag(grid=telemetry.rung_tag(g))
        bd, ba = _split_rhs(g, B)
        if start is not None:
            # caller's start_tile is in source band-tile coordinates; the
            # embedded sweep starts past the identity prefix on top of it
            eff = start + min(int(start_tile), src.n_diag_tiles) \
                if start_tile else start
            yd, ya = _forward_impl(ctsf.Dr, ctsf.R, ctsf.C, bd, ba, g, impl,
                                   eff)
        elif start_tile:
            # traced loop bound: no recompile per distinct start, but the
            # sweep becomes a dynamic-bound while_loop (not
            # reverse-differentiable) — so the common start_tile=0 path
            # keeps its static bounds below.
            yd, ya = _forward_impl(ctsf.Dr, ctsf.R, ctsf.C, bd, ba, g,
                                   impl, start_tile)
        else:
            yd, ya = _forward_impl(ctsf.Dr, ctsf.R, ctsf.C, bd, ba, g, impl)
        return restrict(_merge_panels(yd, ya))


def backward_solve_many(factor: CholeskyFactor, Y: jnp.ndarray,
                        impl=UNSET,
                        policy=UNSET,
                        options: Optional[SolverOptions] = None
                        ) -> jnp.ndarray:
    """Solve ``L^T X = Y`` for an (padded_n, k) panel of right-hand sides in
    one blocked sweep.  Embedded factors take/return panels in the source
    layout (cf. :func:`forward_solve_many`).  ``impl=``/``policy=`` are
    deprecated aliases for the matching ``options`` fields."""
    opts = resolve_options(options, _where="backward_solve_many", impl=impl,
                           policy=policy)
    impl = opts.impl
    with telemetry.span("solve.backward_many", k=Y.shape[-1]) as sp:
        ctsf, _, g, Y, start, restrict = _embedded_panels(factor,
                                                          opts.policy, Y)
        sp.tag(grid=telemetry.rung_tag(g))
        yd, ya = _split_rhs(g, Y)
        if start is not None:
            xd, xa = _backward_impl(ctsf.Dr, ctsf.R, ctsf.C, yd, ya, g, impl,
                                    start)
        else:
            xd, xa = _backward_impl(ctsf.Dr, ctsf.R, ctsf.C, yd, ya, g, impl)
        return restrict(_merge_panels(xd, xa))


def _refine_panels(fDr, fR, fC, mDr, mR, mC, bd, ba, xd, xa, g, impl, start):
    """One residual-checked iterative-refinement step for jitter-recovered
    factors: the perturbed factor L (of ``A + tau I``) acts as a
    preconditioner for the *original* A.  ``r = B - A X``; ``dX =
    (L L^T)^{-1} r``; the correction is accepted per RHS column only where
    it does not increase the residual norm, so refinement can only help.
    All in-graph — no host sync rides the serving path."""
    from .robustness import ctsf_matvec
    Axd, Axa = ctsf_matvec(mDr, mR, mC, xd, xa, g)
    rd, ra = bd - Axd, ba - Axa
    n0 = jnp.sum(rd * rd, axis=(0, 1)) + jnp.sum(ra * ra, axis=(0, 1))
    dd, da = _solve_panels(fDr, fR, fC, rd, ra, g, impl, start)
    xd1, xa1 = xd + dd, xa + da
    A1d, A1a = ctsf_matvec(mDr, mR, mC, xd1, xa1, g)
    n1 = (jnp.sum((bd - A1d) ** 2, axis=(0, 1))
          + jnp.sum((ba - A1a) ** 2, axis=(0, 1)))
    take = (n1 <= n0)[None, None, :]
    return jnp.where(take, xd1, xd), jnp.where(take, xa1, xa)


def solve_many(factor: CholeskyFactor, B: jnp.ndarray,
               impl=UNSET, policy=UNSET,
               options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """``A X = B`` for a panel of right-hand sides via ``L L^T``.

    Equivalent to stacking k :func:`solve` calls but swept once: each band
    step is a ``(t, t) @ (t, k)`` matmul, so post-factorization serving cost
    is matmul-bound instead of k latency-bound substitution sweeps.

    Args:
      factor: banded-arrowhead Cholesky factor.
      B: ``(padded_n, k)`` panel in the padded layout (zero rows in the
        padding region; use ``grid.padded_index`` to place original-matrix
        entries).
      impl: ``"pallas"`` = fused forward+backward sweep kernels (one launch
        per sweep), ``"ref"`` = per-tile loops, ``None`` = backend default.

    Returns: ``(padded_n, k)`` solution panel X.

    Recompiles once per ``(grid, impl, k)`` — serving with a fixed panel
    width never retraces; pad k up to a bucket if widths vary.

    Embedded factors (``factor.source_grid`` set by the policy-aware
    factorizations, or ``policy`` given) take and return panels in the
    *source* grid's padded layout: the canonical-grid embedding keys the
    compile on the canonical grid — one compile per (canonical rung, k)
    across all source grids — and both sweeps skip the identity prefix
    via their traced ``start_tile``.

    Jitter-recovered factors (``factor.info`` with a retained original
    matrix and ``tau > 0`` — see ``regularize=`` on the factorizations)
    get one residual-checked iterative-refinement step against the
    *original* A, correcting most of the O(tau) bias the diagonal
    perturbation introduced; clean factors skip it entirely.
    """
    opts = resolve_options(options, _where="solve_many", impl=impl,
                           policy=policy)
    impl = opts.impl
    with telemetry.span("solve.solve_many", k=B.shape[-1]) as sp:
        ctsf, _, g, B, start, restrict = _embedded_panels(factor,
                                                          opts.policy, B)
        sp.tag(grid=telemetry.rung_tag(g))
        bd, ba = _split_rhs(g, B)
        xd, xa = _solve_panels(ctsf.Dr, ctsf.R, ctsf.C, bd, ba, g, impl,
                               start)
        info = factor.info
        if (info is not None and info.matrix is not None
                and info.matrix.grid == g and np.asarray(info.tau).ndim == 0
                and bool(np.asarray(info.tau) > 0)):
            m = info.matrix
            xd, xa = _refine_panels(ctsf.Dr, ctsf.R, ctsf.C, m.Dr, m.R, m.C,
                                    bd, ba, xd, xa, g, impl, start)
        return restrict(_merge_panels(xd, xa))


# bounded traced-callable cache for the batched solve/refine sweeps —
# keyed on (grid, impl, use_start[, "refine"]) but NOT on the panel width
# k or the batch size: k and batch land in XLA's shape-keyed compile
# cache under the one jit wrapper, so the Python-side key count stays
# O(#canonical rungs) for mixed serving traffic (cf. _BATCHED_WINDOW_CACHE)
_BATCHED_SOLVE_CACHE = LRUCache(maxsize=64, name="batched_solve")


def _batched_solve_fn(grid, opts: SolverOptions, use_start: bool):
    """One vmapped+jitted ``A X = B`` panel solve per (grid,
    ``opts.compile_key()``, has-start) — each batch element solves its
    *own* RHS panel, unlike ``concurrent_solve`` which shares one B
    across the batch.  ``use_start=True`` adds a traced identity-prefix
    depth broadcast across the batch (the rung-server canonical-grid
    path)."""
    key = (grid, opts.compile_key(), use_start)
    impl = opts.impl

    def build():
        if use_start:
            return jax.jit(jax.vmap(
                lambda dr, r, c, bd, ba, s: _solve_panels(
                    dr, r, c, bd, ba, grid, impl, s),
                in_axes=(0, 0, 0, 0, 0, None)))
        return jax.jit(jax.vmap(
            lambda dr, r, c, bd, ba: _solve_panels(dr, r, c, bd, ba, grid,
                                                   impl)))

    return _BATCHED_SOLVE_CACHE.get_or_create(key, build)


def _batched_refine_fn(grid, opts: SolverOptions, use_start: bool):
    """Vmapped per-element-masked refinement step for jitter-recovered
    batches: each element refines against its own original matrix, and
    the correction applies only where that element's ``tau > 0``.  Kept a
    *separate* dispatch from :func:`_batched_solve_fn` so clean batches
    never run it — and clean elements inside a recovered batch, whose
    corrections are masked off, stay bit-identical to an all-clean run."""
    key = (grid, opts.compile_key(), use_start, "refine")
    impl = opts.impl

    def build():
        def one(fdr, fr, fc, mdr, mr, mc, bd, ba, xd, xa, tau, s=None):
            xd1, xa1 = _refine_panels(fdr, fr, fc, mdr, mr, mc, bd, ba,
                                      xd, xa, grid, impl, s)
            use = tau > 0
            return jnp.where(use, xd1, xd), jnp.where(use, xa1, xa)

        if use_start:
            return jax.jit(jax.vmap(one, in_axes=(0,) * 11 + (None,)))
        return jax.jit(jax.vmap(
            lambda *a: one(*a), in_axes=(0,) * 11))

    return _BATCHED_SOLVE_CACHE.get_or_create(key, build)


def solve_many_batched(factor: CholeskyFactor, B: jnp.ndarray,
                       impl=UNSET,
                       start_tile=None, bucket: bool = True,
                       options: Optional[SolverOptions] = None
                       ) -> jnp.ndarray:
    """``A_i X_i = B_i`` for a batched factor with *per-element* RHS
    panels — the rung-batch execution primitive of
    ``launch/rung_server.py`` (``concurrent_solve`` is the other batched
    solve, sharing one B across the batch; serving requests each bring
    their own).

    Args:
      factor: batched banded-arrowhead factor (leading batch axis on the
        CTSF arrays, e.g. from ``factorize_window_batched``).
      B: ``(batch, padded_n, k)`` float32 panels in the padded layout of
        ``factor.ctsf.grid``.
      impl: kernel backend forwarded to the sweeps.
      start_tile: optional shared identity-prefix depth of a pre-embedded
        canonical batch (``gridpolicy.assemble_rung_batch``), threaded as
        a traced scalar so mixed pad depths share one compilation.
      bucket: pow2-pad the batch axis before dispatch (cf.
        ``factorize_window_batched``).

    Returns: ``(batch, padded_n, k)`` solution panels, still in the
    factor grid's layout — callers owning an embedding restrict each
    element with ``gridpolicy.restrict_rhs``.

    Jitter-recovered factors (``factor.info`` with per-element ``tau`` and
    a retained original matrix) get one residual-checked refinement pass
    as a separate vmapped dispatch, masked per element to ``tau > 0`` —
    clean siblings of a recovered element return solutions bit-identical
    to an uncontaminated batch.
    """
    opts = resolve_options(options, _where="solve_many_batched", impl=impl)
    ctsf = factor.ctsf
    g = ctsf.grid
    t, ndt, nat = g.t, g.n_diag_tiles, g.n_arrow_tiles
    if ctsf.Dr.ndim != 5:
        raise ValueError("solve_many_batched needs a batched factor "
                         f"(leading batch axis), got Dr.ndim={ctsf.Dr.ndim}")
    nb = ctsf.Dr.shape[0]
    if B.ndim != 3 or B.shape[0] != nb or B.shape[1] != g.padded_n:
        raise ValueError(
            f"rhs panels must be (batch={nb}, padded_n={g.padded_n}, k), "
            f"got {B.shape}")
    k = B.shape[2]
    with telemetry.span("solve.solve_many_batched", b=nb, k=k,
                        grid=telemetry.rung_tag(g)):
        bd = B[:, :ndt * t].reshape(nb, ndt, t, k)
        ba = B[:, ndt * t:].reshape(nb, nat, t, k)
        use_start = start_tile is not None
        fn = _batched_solve_fn(g, opts, use_start)
        if use_start:
            s = jnp.asarray(start_tile, jnp.int32)
            call = lambda dr, r, c, pd, pa: fn(dr, r, c, pd, pa, s)
        else:
            call = fn
        xd, xa = bucketed_batched_call(call, (ctsf.Dr, ctsf.R, ctsf.C,
                                              bd, ba), bucket)
        info = factor.info
        if (info is not None and info.matrix is not None
                and info.matrix.grid == g
                and np.asarray(info.tau).shape == (nb,)
                and bool(np.asarray(info.tau).max() > 0)):
            m = info.matrix
            rfn = _batched_refine_fn(g, opts, use_start)
            rcall = (lambda *a: rfn(*a, s)) if use_start else rfn
            xd, xa = bucketed_batched_call(
                rcall, (ctsf.Dr, ctsf.R, ctsf.C, m.Dr, m.R, m.C, bd, ba,
                        xd, xa, jnp.asarray(info.tau, jnp.float32)), bucket)
        return jnp.concatenate([xd.reshape(nb, ndt * t, k),
                                xa.reshape(nb, nat * t, k)], axis=1)


def forward_solve(factor: CholeskyFactor, b: jnp.ndarray,
                  impl=UNSET,
                  options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Solve ``L y = b`` (k=1 specialization of the panel sweep)."""
    opts = resolve_options(options, _where="forward_solve", impl=impl)
    return forward_solve_many(factor, b.reshape(-1, 1), options=opts)[:, 0]


def backward_solve(factor: CholeskyFactor, y: jnp.ndarray,
                   impl=UNSET,
                   options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Solve ``L^T x = y`` (k=1 specialization of the panel sweep)."""
    opts = resolve_options(options, _where="backward_solve", impl=impl)
    return backward_solve_many(factor, y.reshape(-1, 1), options=opts)[:, 0]


def solve(factor: CholeskyFactor, b: jnp.ndarray,
          impl=UNSET, policy=UNSET,
          options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """A x = b via L L^T."""
    opts = resolve_options(options, _where="solve", impl=impl, policy=policy)
    return solve_many(factor, b.reshape(-1, 1), options=opts)[:, 0]


def logdet(factor: CholeskyFactor) -> jnp.ndarray:
    return factor.logdet()


def _rhs_grid(factor: CholeskyFactor):
    """The grid whose padded layout RHS panels use: the *source* grid for
    canonical-grid embedded factors, the factor's own grid otherwise."""
    return factor.source_grid or factor.ctsf.grid


def sample_gmrf(factor: CholeskyFactor, key: jax.Array,
                impl=UNSET,
                options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Draw x ~ N(0, A^{-1}) via x = L^{-T} z (the INLA sampling primitive)."""
    opts = resolve_options(options, _where="sample_gmrf", impl=impl)
    z = jax.random.normal(key, (_rhs_grid(factor).padded_n,),
                          dtype=jnp.float32)
    return backward_solve(factor, z, options=opts)


def sample_gmrf_many(factor: CholeskyFactor, key: jax.Array, num: int,
                     impl=UNSET,
                     options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Draw ``num`` samples x ~ N(0, A^{-1}) as one (padded_n, num) panel.

    All samples share a single blocked backward sweep (fused into one
    kernel launch under ``impl="pallas"``) — the serving-path analogue of
    :func:`sample_gmrf`, amortizing the factor over the whole batch of
    posterior realizations.  Recompiles once per ``(grid, impl, num)``.
    For embedded factors ``z`` is drawn in the source layout, so a
    bucketed factor reproduces the unbucketed samples bit-for-bit per key.
    """
    opts = resolve_options(options, _where="sample_gmrf_many", impl=impl)
    with telemetry.span("solve.sample_gmrf_many", num=num):
        z = jax.random.normal(key, (_rhs_grid(factor).padded_n, num),
                              dtype=jnp.float32)
        return backward_solve_many(factor, z, options=opts)


def _validate_indices(grid, indices) -> np.ndarray:
    """Validate selected indices against the *original* matrix dimension and
    map them into the padded layout (arrow indices shift past the band
    padding).  Out-of-range indices raise instead of silently gathering
    garbage from padded rows; indices must therefore be concrete."""
    s = grid.structure
    idx = np.asarray(indices)
    if idx.ndim != 1:
        raise ValueError(f"indices must be 1-D, got shape {idx.shape}")
    if idx.size and (idx.min() < 0 or idx.max() >= s.n):
        bad = idx[(idx < 0) | (idx >= s.n)]
        raise ValueError(f"indices {bad.tolist()} out of range [0, {s.n})")
    return np.vectorize(grid.padded_index, otypes=[np.int64])(idx)


def marginal_variances(factor: CholeskyFactor, indices: jnp.ndarray,
                       method=UNSET,
                       impl=UNSET,
                       policy=UNSET,
                       options: Optional[SolverOptions] = None) -> jnp.ndarray:
    """Selected diagonal of A^{-1} — INLA's posterior marginal variances.

    Two paths over the same factor, selected by ``options.method`` (the
    bare ``method=`` kwarg — like ``impl=``/``policy=`` — is a deprecated
    alias folded into :class:`~repro.core.options.SolverOptions`):

    * ``method="selinv"`` (default, = ``options.method None``) — the
      blocked Takahashi recurrence
      (:func:`repro.core.selinv.selected_inverse`): one backward tile sweep
      computes the whole band + arrow block of Σ, cost independent of k,
      then the k selected diagonal entries are gathered.
    * ``method="panels"`` — (A^{-1})_{ii} = ‖L^{-1} e_i‖² with all k unit
      vectors riding a single multi-RHS forward sweep, started at the first
      nonzero band tile of the panel (the rows above the smallest selected
      index are identically zero).  Kept for validation/benchmarking, and
      cheaper when k is tiny relative to the bandwidth.

    Args:
      indices: 1-D concrete (host) array of element indices of the
        *original* matrix; out-of-range values raise, and arrow indices are
        remapped past the band padding rather than reading padded rows.
      method: ``"selinv"`` or ``"panels"`` as above.
      impl: kernel backend forwarded to the underlying sweep
        (``"pallas"`` / ``"ref"`` / ``None`` = backend default).

    Returns: ``(k,)`` variances, ordered like ``indices``.

    Recompilation: the selinv path compiles once per ``(grid, impl)``; the
    panels path once per ``(grid, impl, k)`` — the sweep's start tile is
    traced, so *which* indices are selected never forces a retrace, only
    how many.

    Indices always refer to the *source* matrix: for canonical-grid
    embedded factors (``factor.source_grid`` set, or ``policy`` given)
    both paths validate against the source structure and return the source
    problem's variances; the embedding/restriction rides the policy-aware
    machinery of :func:`repro.core.selinv.selected_inverse` /
    :func:`forward_solve_many`.
    """
    opts = resolve_options(options, _where="marginal_variances",
                           method=method, impl=impl, policy=policy)
    mth = opts.method or "selinv"
    g = _rhs_grid(factor)
    padded = _validate_indices(g, indices)
    with telemetry.span("solve.marginal_variances", method=mth,
                        k=len(padded), grid=telemetry.rung_tag(g)):
        if mth == "selinv":
            from .selinv import selected_inverse
            sigma = selected_inverse(factor, options=opts)
            return jnp.take(sigma.diagonal(padded=True), jnp.asarray(padded),
                            axis=-1)
        if mth == "panels":
            k = padded.shape[0]
            E = jnp.zeros((g.padded_n, k), jnp.float32)
            E = E.at[jnp.asarray(padded), jnp.arange(k)].set(1.0)
            # RHS sparsity: unit-vector panels are zero above the selected
            # row, so the band sweep starts at the first nonzero tile.
            start = min(int(padded.min()) // g.t, g.n_diag_tiles) if k else 0
            Y = forward_solve_many(factor, E, start_tile=start, options=opts)
            return jnp.sum(Y * Y, axis=0)
        raise ValueError(
            f"unknown method {mth!r} (want 'selinv' or 'panels')")


def _marginal_variances_map(factor: CholeskyFactor,
                            indices: jnp.ndarray) -> jnp.ndarray:
    """Pre-batching reference: one forward sweep per selected index via
    ``lax.map`` (k sequential O(n·b) solves).  Used by tests and
    ``benchmarks/bench_solve.py`` as the comparison baseline."""
    g = _rhs_grid(factor)

    def one(i):
        e = jnp.zeros((g.padded_n,), jnp.float32).at[i].set(1.0)
        y = forward_solve(factor, e)
        return jnp.sum(y * y)

    return jax.lax.map(one, jnp.asarray(_validate_indices(g, indices)))
