"""sTiles-powered banded-arrowhead curvature preconditioner.

This is the framework's first-class integration of the paper's solver into
LM training (DESIGN.md §5).  The idea: the curvature of a deep transformer,
restricted to a sketched per-layer subspace, is dominated by within-layer
and adjacent-layer terms, plus coupling of every layer to the shared
embedding/unembedding block — i.e. it is a **banded arrowhead matrix** over
layer blocks, exactly the paper's Fig. 1 pattern:

  * one r-dim sketch per layer (fixed random coordinate sample of the layer's
    gradient) -> "diagonal blocks";
  * EMA of cross-layer sketch outer products within a band -> "band";
  * EMA against the embedding-group sketch -> "arrowhead";

Every ``precond_every`` steps the (L+1)·r banded-arrowhead matrix is
factorized by the sTiles **window backend** (the tile size *is* the sketch
dim), and each step preconditions the gradient by two band solves:

    d = g  +  Pᵀ (A⁻¹ ĝ − ĝ)        (identity on the unsketched complement)

so with A = I the update reduces exactly to the raw gradient.  Factorizing a
few-thousand-dim structured matrix every few steps is the same workload INLA
generates (hundreds of factorizations per inference) — sTiles' target regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cholesky import _factorize_window_impl, CholeskyFactor
from repro.core.ctsf import BandedCTSF
from repro.core.solve import _backward_impl, _forward_impl
from repro.core.structure import ArrowheadStructure, TileGrid

__all__ = ["ArrowheadPrecond", "build_precond"]


def _group_leaves(params) -> Tuple[List[Tuple[str, Any]], List[Tuple[str, Any]]]:
    """Split params into stacked layer leaves and global ('arrow') leaves."""
    layer_leaves, arrow_leaves = [], []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        if any(seg in keys for seg in ("layers", "mamba", "enc_layers",
                                       "dec_layers")):
            layer_leaves.append((keys, leaf))
        else:
            arrow_leaves.append((keys, leaf))
    return layer_leaves, arrow_leaves


@dataclasses.dataclass
class ArrowheadPrecond:
    """Static description + jax state of the preconditioner."""
    r: int                    # sketch dim = sTiles tile size
    band: int                 # band width in layer blocks
    n_layers: int
    ema: float
    damping: float
    grid: TileGrid
    # host-side index plans: per layer-leaf (name, per-layer size, idx array)
    layer_plan: List[Tuple[str, np.ndarray]]
    arrow_plan: List[Tuple[str, np.ndarray]]

    def init_state(self) -> Dict[str, jnp.ndarray]:
        g = self.grid
        t, ndt, nat, bt = g.t, g.n_diag_tiles, g.n_arrow_tiles, g.band_tiles
        return {
            "Dr": jnp.zeros((ndt, bt + 1, t, t), jnp.float32),
            "R": jnp.zeros((ndt, nat, t, t), jnp.float32),
            "C": jnp.zeros((nat, nat, t, t), jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    # ---- sketching ---------------------------------------------------------

    def sketch(self, grads) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Project grads to per-layer sketches.

        Returns (layer_sketch (L, r), arrow_sketch (r,)).
        """
        layer_leaves, arrow_leaves = _group_leaves(grads)
        by_name = dict(layer_leaves)
        parts = []
        for name, idx in self.layer_plan:
            leaf = by_name[name]
            flat = leaf.reshape(self._stack_dim(leaf), -1).astype(jnp.float32)
            parts.append(flat[:, idx])                   # (L, r_leaf)
        lsk = jnp.concatenate(parts, axis=1)[:, : self.r]
        by_name_a = dict(arrow_leaves)
        aparts = []
        for name, idx in self.arrow_plan:
            leaf = by_name_a[name]
            aparts.append(leaf.reshape(-1).astype(jnp.float32)[idx])
        ask = jnp.concatenate(aparts)[: self.r]
        return lsk, ask

    def _stack_dim(self, leaf) -> int:
        return self.n_layers

    # ---- statistics --------------------------------------------------------

    def update_stats(self, state, grads):
        lsk, ask = self.sketch(grads)                    # (L, r), (r,)
        g = self.grid
        bt = g.band_tiles
        e = self.ema
        # band blocks: Dr[m, d] += lsk_m lsk_{m-d}^T
        lpad = jnp.pad(lsk, ((bt, 0), (0, 0)))
        wins = jnp.stack([lpad[bt - d: bt - d + self.n_layers] for d in range(bt + 1)],
                         axis=1)                          # (L, bt+1, r)
        dr_new = jnp.einsum("la,ldb->ldab", lsk, wins)
        r_new = jnp.einsum("la,b->lab", lsk, ask)[:, None]
        c_new = jnp.einsum("a,b->ab", ask, ask)[None, None]
        return {
            "Dr": e * state["Dr"] + (1 - e) * dr_new,
            "R": e * state["R"] + (1 - e) * r_new,
            "C": e * state["C"] + (1 - e) * c_new,
            "count": state["count"] + 1,
        }

    # ---- factorize + solve -------------------------------------------------

    def factorize(self, state) -> Dict[str, jnp.ndarray]:
        """Assemble A = stats + adaptive damping, factorize with sTiles.

        The band+arrow *truncation* of the PSD gradient-moment EMA is not
        itself PSD, so the diagonal damping is lifted per block row by the
        Frobenius mass of that row's off-diagonal blocks — block-Gershgorin
        diagonal dominance guarantees λ_min(A) ≥ damping > 0 (‖·‖₂ ≤ ‖·‖_F).
        """
        g = self.grid
        t, ndt, bt = g.t, g.n_diag_tiles, g.band_tiles
        eye = jnp.eye(t, dtype=jnp.float32)
        Dr0, R0, C0 = state["Dr"], state["R"], state["C"]

        def fro(x):
            return jnp.sqrt(jnp.sum(jnp.square(x), axis=(-2, -1)) + 1e-30)

        band_mass = fro(Dr0[:, 1:]) if bt else jnp.zeros((ndt, 0))
        upper = band_mass.sum(axis=1) if bt else jnp.zeros(ndt)
        lower = jnp.zeros(ndt)
        for d in range(1, bt + 1):
            if d < ndt:
                lower = lower.at[:ndt - d].add(band_mass[d:, d - 1])
        arrow_mass = fro(R0).sum(axis=1)
        row_damp = self.damping + upper + lower + arrow_mass
        corner_damp = self.damping + fro(R0).sum()
        dr = Dr0.at[:, 0].add(row_damp[:, None, None] * eye)
        c = C0.at[0, 0].add(corner_damp * eye)
        Dr, R, C, _status = _factorize_window_impl(dr, R0, c, g, None, 4)
        return {"Dr": Dr, "R": R, "C": C}

    def precondition(self, factor, grads):
        """d = g + lift(A^{-1} ĝ − ĝ)."""
        lsk, ask = self.sketch(grads)
        rhs = jnp.concatenate([lsk.reshape(-1), ask])    # ((L+1)·r,)
        g = self.grid
        # the solve sweeps take (tiles, t, k) RHS panels; this is the k=1 case
        bd = rhs[: g.n_diag_tiles * g.t].reshape(g.n_diag_tiles, g.t, 1)
        ba = rhs[g.n_diag_tiles * g.t:].reshape(g.n_arrow_tiles, g.t, 1)
        yd, ya = _forward_impl(factor["Dr"], factor["R"], factor["C"], bd, ba, g)
        xd, xa = _backward_impl(factor["Dr"], factor["R"], factor["C"], yd, ya, g)
        xd, xa = xd[..., 0], xa[..., 0]
        sol_l = xd.reshape(self.n_layers, self.r)
        sol_a = xa.reshape(-1)[: self.r]
        # scale correction so magnitudes stay gradient-like
        dl, da = sol_l - lsk, sol_a - ask
        return self._lift(grads, dl, da)

    def _lift(self, grads, dl, da):
        layer_leaves, arrow_leaves = _group_leaves(grads)
        by_name = dict(layer_leaves)
        by_name_a = dict(arrow_leaves)
        off = 0
        for name, idx in self.layer_plan:
            width = min(len(idx), self.r - off) if off < self.r else 0
            if width <= 0:
                continue
            leaf = by_name[name]
            flat = leaf.reshape(self.n_layers, -1)
            upd = dl[:, off: off + width].astype(flat.dtype)
            by_name[name] = flat.at[:, idx[:width]].add(upd).reshape(leaf.shape)
            off += width
        off = 0
        for name, idx in self.arrow_plan:
            width = min(len(idx), self.r - off) if off < self.r else 0
            if width <= 0:
                continue
            leaf = by_name_a[name]
            flat = leaf.reshape(-1)
            by_name_a[name] = flat.at[idx[:width]].add(
                da[off: off + width].astype(flat.dtype)).reshape(leaf.shape)
            off += width
        out = {**by_name, **by_name_a}
        # rebuild pytree in original structure
        paths = [("/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                           for p in path))
                 for path, _ in jax.tree_util.tree_leaves_with_path(grads)]
        leaves = [out[p] for p in paths]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(grads), leaves)


def build_precond(params, r: int = 32, band: int = 2, ema: float = 0.95,
                  damping: float = 1e-3, seed: int = 0) -> ArrowheadPrecond:
    """Host-side construction: sampling plans + the sTiles grid."""
    layer_leaves, arrow_leaves = _group_leaves(params)
    if not layer_leaves:
        raise ValueError("no stacked layer params found")
    n_layers = layer_leaves[0][1].shape[0]
    # normalize leaves stacked with >1 leading dims (zamba: (ns, per, ...))
    norm_layers = []
    for name, leaf in layer_leaves:
        if leaf.shape[0] != n_layers:
            pass
        norm_layers.append((name, leaf))
    rng = np.random.default_rng(seed)
    sizes = [(name, int(np.prod(leaf.shape)) // leaf.shape[0])
             for name, leaf in norm_layers]
    total = sum(s for _, s in sizes)
    layer_plan, acc = [], 0
    for name, s in sizes:
        k = max(1, round(r * s / total))
        k = min(k, s, r - acc)
        if k <= 0:
            continue
        layer_plan.append((name, rng.choice(s, size=k, replace=False)))
        acc += k
    # top up to exactly r from the largest leaf not yet in the plan order
    if acc < r:
        name, s = max(sizes, key=lambda x: x[1])
        extra = rng.choice(s, size=r - acc, replace=False)
        layer_plan.append((name, extra))
    asizes = [(name, int(np.prod(leaf.shape))) for name, leaf in arrow_leaves]
    atotal = sum(s for _, s in asizes)
    arrow_plan, acc = [], 0
    for name, s in asizes:
        k = max(1, round(r * s / atotal))
        k = min(k, s, r - acc)
        if k <= 0:
            continue
        arrow_plan.append((name, rng.choice(s, size=k, replace=False)))
        acc += k
    if acc < r and asizes:
        name, s = max(asizes, key=lambda x: x[1])
        arrow_plan.append((name, rng.choice(s, size=r - acc, replace=False)))

    struct = ArrowheadStructure(n=(n_layers + 1) * r, bandwidth=band * r - 1,
                                arrow=r)
    grid = TileGrid(struct, t=r)
    return ArrowheadPrecond(r=r, band=band, n_layers=n_layers, ema=ema,
                            damping=damping, grid=grid,
                            layer_plan=layer_plan, arrow_plan=arrow_plan)
