"""AdamW with global-norm clipping and cosine schedule (functional, pytree).

States are f32 and inherit the parameter sharding (GSPMD propagates specs to
same-shaped states), i.e. optimizer memory is ZeRO-sharded for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "clip_by_global_norm"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    m: Any
    v: Any
    count: jnp.ndarray

    def tree_flatten(self):
        return (self.m, self.v, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), n


def cosine_lr(step, base_lr: float, warmup: int = 100, total: int = 10_000,
              min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(grads, state: AdamWState, params, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.01):
    count = state.count + 1
    t = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        step_dir = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - lr * (step_dir + weight_decay
                                              * p.astype(jnp.float32))
        return m_new, v_new, p_new.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_p = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count)
