"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

At multi-pod scale the `pod` all-reduce crosses the slow fabric; int8
quantization cuts wire bytes 4× vs f32.  Error feedback (Seide et al. /
EF-SGD) keeps the compression unbiased over time: the residual of each
quantization is added back into the next step's gradient, so the training
trajectory converges to the uncompressed one.

Used by the explicit-collective trainer (`runtime.pod_parallel_train_step`),
which computes per-pod gradients under `shard_map` and reduces them with
``quantized_allreduce``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.collectives import quantized_allreduce

__all__ = ["ef_init", "ef_compress_allreduce"]


def ef_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_allreduce(grads, ef_state, axis_name: str, bits: int = 8
                          ) -> Tuple[Any, Any]:
    """Quantize (grad + residual), all-reduce int8 over ``axis_name``,
    return (mean_grads, new_residuals).  Call inside shard_map."""
    n = jax.lax.axis_size(axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.max(jnp.abs(x)) / qmax + 1e-30
        q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
        sent = q * scale                      # what the wire carries (dequant)
        new_e = x - sent                      # local quantization residual
        total = quantized_allreduce(x, axis_name, bits=bits) / n
        return total.astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in outs]),
            td.unflatten([o[1] for o in outs]))
