"""Generators for the paper's Table II matrix suite (INLA/GMRF precision
matrices).

The matrices "are generated within the context of statistical modeling and
can arise from Kronecker products of an inverse covariance matrix
representing temporal and spatial components" (§V-B).  We build them exactly
that way:

    K  = Q_t(rho) ⊗ I_ns  +  I_nt ⊗ Q_s          (spatio-temporal GMRF)
    Q  = [[K,  X], [X^T, D]]                      (+ dense fixed-effect arrow)

* ``Q_t`` — AR(1) tridiagonal temporal precision (rho=0 makes K block
  diagonal, reproducing the paper's observation for bandwidth 100/1000:
  "the diagonal part ... exhibits a block diagonal structure").
* ``Q_s`` — 1-D/2-D lattice Laplacian + tau·I spatial precision with spatial
  coupling radius controlling the within-block band.
* ``X``  — dense coupling of ``arrow`` fixed effects to all latents.
* ``D``  — chosen so the Schur complement stays SPD (diagonal dominance
  certificate, see below).

Every Table II (size, bandwidth, thickness) triple is reproducible via
:func:`table2_matrix`; tests use scaled-down versions through
:func:`make_arrowhead`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp

from repro.core.structure import ArrowheadStructure

__all__ = ["ar1_precision", "lattice_precision", "kronecker_st_precision",
           "make_arrowhead", "table2_matrix", "TABLE2"]


def ar1_precision(nt: int, rho: float = 0.7, tau: float = 1.0) -> sp.csc_matrix:
    """AR(1) precision: tridiagonal, SPD for |rho| < 1."""
    main = np.full(nt, 1.0 + rho * rho)
    if nt > 0:
        main[0] = main[-1] = 1.0
    off = np.full(max(nt - 1, 0), -rho)
    q = sp.diags([off, main, off], [-1, 0, 1], format="csc") * tau
    return q + sp.eye(nt, format="csc") * 1e-3


def lattice_precision(ns: int, coupling: float = 0.4, radius: int = 1,
                      tau: float = 1.0) -> sp.csc_matrix:
    """1-D lattice (path graph) precision with given coupling radius.

    Diagonally dominant by construction => SPD with margin tau·1e-3.
    """
    diags, offsets = [], []
    row_weight = np.zeros(ns)
    for r in range(1, radius + 1):
        w = coupling / r
        diags += [np.full(ns - r, -w)] * 2
        offsets += [-r, r]
        row_weight[:ns - r] += w
        row_weight[r:] += w
    main = row_weight + tau
    q = sp.diags([main] + diags, [0] + offsets, format="csc")
    return q


def kronecker_st_precision(nt: int, ns: int, rho: float = 0.7,
                           coupling: float = 0.4, radius: int = 1) -> sp.csc_matrix:
    """Spatio-temporal precision K = Q_t ⊗ I + I ⊗ Q_s (bandwidth = ns·|rho>0| + radius)."""
    qt = ar1_precision(nt, rho)
    qs = lattice_precision(ns, coupling, radius)
    k = sp.kron(qt, sp.eye(ns), format="csc") + sp.kron(sp.eye(nt), qs, format="csc")
    return sp.csc_matrix(k)


def make_arrowhead(n: int, bandwidth: int, arrow: int, rho: float = 0.7,
                   seed: int = 0, density_in_band: float = 1.0,
                   ) -> Tuple[sp.csc_matrix, ArrowheadStructure]:
    """Build an SPD block-arrowhead matrix with the requested structure.

    ``n`` total size, ``bandwidth`` of the leading part, ``arrow`` dense
    trailing rows — mirroring Table II's (Size, Bandwidth, Arrowhead
    Thickness) columns.  ``rho=0`` gives independent diagonal blocks (the
    paper's bandwidth-100/1000 cases).
    """
    rng = np.random.default_rng(seed)
    nd = n - arrow
    ns = max(1, bandwidth)
    nt = max(1, int(np.ceil(nd / ns)))
    k = kronecker_st_precision(nt, ns, rho=rho)[:nd, :nd]
    k = sp.csc_matrix(k)

    if arrow > 0:
        # dense coupling of fixed effects; SPD via Schur diagonal dominance
        x = rng.standard_normal((nd, arrow)) * (0.5 / np.sqrt(nd))
        lam_min_lb = 1e-3  # diag-dominance slack of K by construction
        c = float((x ** 2).sum() / lam_min_lb + 1.0)
        d = np.eye(arrow) * c
        q = sp.bmat([[k, sp.csc_matrix(x)],
                     [sp.csc_matrix(x.T), sp.csc_matrix(d)]], format="csc")
    else:
        q = k
    struct = ArrowheadStructure(n=n, bandwidth=bandwidth, arrow=arrow)
    return sp.csc_matrix(q), struct


# Table II of the paper: (id, size, bandwidth, arrow thickness).
TABLE2 = {
    1: (10_010, 100, 10), 2: (10_010, 200, 10), 3: (10_010, 300, 10),
    4: (10_200, 100, 200), 5: (10_200, 200, 200), 6: (10_200, 300, 200),
    7: (100_010, 1000, 10), 8: (100_010, 2000, 10), 9: (100_010, 3000, 10),
    10: (100_200, 1000, 200), 11: (100_200, 2000, 200), 12: (100_200, 3000, 200),
    13: (500_010, 1000, 10), 14: (500_010, 2000, 10), 15: (500_010, 3000, 10),
    16: (500_200, 1000, 200), 17: (500_200, 2000, 200), 18: (500_200, 3000, 200),
    19: (50_010, 15_000, 10), 20: (1_000_010, 3000, 10),
}

# rho=0 for the block-diagonal cases the paper calls out (IDs 1,7,10,13,16)
_BLOCK_DIAGONAL_IDS = {1, 4, 7, 10, 13, 16}


def table2_matrix(matrix_id: int, scale: float = 1.0, seed: int = 0
                  ) -> Tuple[sp.csc_matrix, ArrowheadStructure]:
    """Instantiate a Table II matrix, optionally scaled down (``scale < 1``)
    for CPU-budget benchmarks — structure ratios are preserved."""
    n, bw, arrow = TABLE2[matrix_id]
    n = max(64, int(n * scale))
    bw = max(4, int(bw * scale)) if scale < 1.0 else bw
    arrow = max(2, int(arrow * scale)) if scale < 1.0 else arrow
    rho = 0.0 if matrix_id in _BLOCK_DIAGONAL_IDS else 0.7
    return make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
