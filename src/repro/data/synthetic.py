"""Deterministic synthetic data pipeline.

Token generators:

* :func:`token_batch` — pure-hash tokens keyed by (seed, step): exactly
  reproducible on restart from any step, no state to checkpoint.  This is
  the replay-exact property the fault-tolerant loop relies on (the data
  pipeline *is* the step index).
* :class:`MarkovStream` — tokens from a fixed random first-order Markov
  chain: a learnable distribution (entropy strictly below uniform) used by
  the training examples so loss curves mean something.

Pathological-matrix generators (the numerical fault-injection suite for
``core/robustness.py``'s breakdown detection + jitter-ladder recovery):

* :func:`indefinite_arrowhead` — SPD arrowhead with a known negative shift
  applied to part of the diagonal (Cholesky breaks down at a predictable
  pivot);
* :func:`near_singular_arrowhead` — SPD with smallest eigenvalue driven to
  a requested tiny value (factorizable in exact arithmetic, pivots at the
  float32 cliff);
* :func:`nan_contaminated_arrowhead` — SPD with seeded NaN entries
  (symmetrically placed), the "silent NaN downstream" case detection must
  flag.

All are seeded and grid-parameterized like ``data.gmrf.make_arrowhead``
(same ``(csc_matrix, ArrowheadStructure)`` return), so tests and the
robustness benchmark can sweep them over the tier-1 grid cases.

Batches are emitted host-side as numpy and sharded by the caller's
`batch_specs`; for multi-host production each host would emit only its
addressable shard (same keyed-hash construction, per-host slice).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

__all__ = ["token_batch", "MarkovStream", "indefinite_arrowhead",
           "near_singular_arrowhead", "nan_contaminated_arrowhead",
           "block_separable_arrowhead", "request_stream"]


def _base_arrowhead(n, bandwidth, arrow, rho, seed):
    from .gmrf import make_arrowhead
    return make_arrowhead(n, bandwidth, arrow, rho=rho, seed=seed)


def indefinite_arrowhead(n: int, bandwidth: int, arrow: int,
                         rho: float = 0.7, seed: int = 0,
                         shift: float = 10.0, frac: float = 0.1):
    """SPD arrowhead made indefinite by subtracting ``shift * mean_diag``
    from a seeded random ``frac`` of the diagonal.  The negative Cholesky
    pivot lands near the first corrupted index, so tests can assert the
    detector's ``first_bad`` tile.  Returns ``(csc_matrix, structure)``."""
    A, st = _base_arrowhead(n, bandwidth, arrow, rho, seed)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
    k = max(1, int(frac * n))
    idx = np.sort(rng.choice(n, size=k, replace=False))
    A = sp.lil_matrix(A)
    d = A.diagonal()
    drop = shift * float(d.mean())
    for i in idx:
        A[i, i] = d[i] - drop
    return sp.csc_matrix(A), st


def near_singular_arrowhead(n: int, bandwidth: int, arrow: int,
                            rho: float = 0.7, seed: int = 0,
                            eig_min: float = 1e-6):
    """SPD arrowhead whose smallest eigenvalue is shifted down to
    ``eig_min`` (exact arithmetic keeps it factorizable; float32 pivots sit
    at the breakdown threshold — the case ``pivot_rtol`` exists for).
    Returns ``(csc_matrix, structure)``."""
    A, st = _base_arrowhead(n, bandwidth, arrow, rho, seed)
    lam_min = float(np.linalg.eigvalsh(A.toarray()).min())
    return sp.csc_matrix(A - sp.eye(n, format="csc")
                         * (lam_min - eig_min)), st


def nan_contaminated_arrowhead(n: int, bandwidth: int, arrow: int,
                               rho: float = 0.7, seed: int = 0,
                               count: int = 1):
    """SPD arrowhead with ``count`` seeded NaN entries placed symmetrically
    on existing structural nonzeros — the silent-corruption case (a bad
    DMA, a poisoned upstream reduction) the in-sweep ``nonfinite`` flag
    must catch without any host sync.  Returns ``(csc_matrix, structure)``."""
    A, st = _base_arrowhead(n, bandwidth, arrow, rho, seed)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 2]))
    A = sp.lil_matrix(A)
    rows, cols = A.nonzero()
    for pick in rng.choice(len(rows), size=min(count, len(rows)),
                           replace=False):
        i, j = int(rows[pick]), int(cols[pick])
        A[i, j] = np.nan
        A[j, i] = np.nan
    return sp.csc_matrix(A), st


def block_separable_arrowhead(n: int, bandwidth: int, arrow: int,
                              t: int, n_parts: int = 2,
                              rho: float = 0.7, seed: int = 0):
    """SPD arrowhead whose band splits into ``n_parts`` independent
    partitions at tile-aligned cuts — the post-adaptive-ND shape
    (paper §III-A, Fig. 4) the partitioned fused sweep exists for.

    Starts from :func:`~repro.data.gmrf.make_arrowhead` and zeroes every
    band entry coupling elements on opposite sides of the cuts at tiles
    ``round(ndt * p / n_parts)`` (cuts are chosen on the *tile* grid of
    size ``t``, so :func:`~repro.core.ordering.detect_partition_plan`
    certifies them exactly).  Zeroing off-diagonals only *increases*
    diagonal dominance, so the result stays SPD.  The dense arrow block —
    the moved separator — still couples all partitions.

    Returns ``(csc_matrix, structure, boundaries)`` with ``boundaries``
    the tile-boundary tuple a
    :class:`~repro.core.ordering.PartitionPlan` takes.
    """
    if t <= 0 or n_parts < 1:
        raise ValueError(f"need t > 0 and n_parts >= 1, got {t}, {n_parts}")
    A, st = _base_arrowhead(n, bandwidth, arrow, rho, seed)
    nd = st.n_diag
    ndt = -(-nd // t)
    cuts = sorted({min(ndt, max(1, round(ndt * p / n_parts)))
                   for p in range(1, n_parts)} - {ndt})
    A = A.tolil()
    for c in cuts:
        ce = c * t                     # element index of the cut
        lo = max(0, ce - bandwidth)
        hi = min(nd, ce + bandwidth)
        A[ce:hi, lo:ce] = 0
        A[lo:ce, ce:hi] = 0
    return sp.csc_matrix(A), st, tuple([0] + cuts + [ndt])


def token_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                extras: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """Stateless batch: tokens = hash(seed, step); labels = next-token."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if extras:
        out.update(extras)
    return out


def request_stream(seed: int, cases, num: int, rate: float = 1000.0,
                   k: int = 4, deadline_budget: Optional[float] = None,
                   burst_factor: float = 1.0, burst_len: float = 10e-3,
                   normal_len: float = 50e-3):
    """Seeded Poisson mixed-grid arrival stream for the serving harness.

    Emits ``num`` host-side request *specs* (no core imports, no arrays):
    dicts with ``arrival`` (absolute clock time; exponential
    inter-arrival gaps at ``rate`` requests per clock unit), ``case``
    (one of ``cases``, each an ``(n, bandwidth, arrow)`` triple drawn
    uniformly), ``seed`` (per-request matrix/RHS seed), ``k`` (RHS panel
    width) and ``deadline`` (``arrival + deadline_budget``, or None).
    Everything is derived from one ``SeedSequence([seed, ...])`` stream,
    so the same seed replays the identical arrival process — the
    determinism contract ``tests/test_serving.py`` and
    ``benchmarks/bench_serving.py`` are built on.

    **Burst/overload mode** (``burst_factor > 1``): arrivals follow a
    two-state Markov-modulated Poisson process — exponential sojourns of
    mean ``normal_len`` at ``rate`` alternate with sojourns of mean
    ``burst_len`` at ``rate * burst_factor``.  Implemented as a time
    change of the unit-rate process (each base exponential draw is
    integrated through the piecewise-constant rate, with state flips from
    an independent ``SeedSequence([seed, 17])`` stream), which is exact
    by memorylessness *and* leaves the base RNG draw sequence untouched:
    ``burst_factor=1`` reproduces today's stream bit for bit, so the
    serving benchmark's recorded arrivals never shift.  The chaos
    harness uses bursts to drive the server through its admission bounds
    and degradation ladder deterministically.
    """
    cases = [tuple(int(v) for v in c) for c in cases]
    if not cases:
        raise ValueError("request_stream needs at least one case")
    if num < 0 or rate <= 0:
        raise ValueError(f"need num >= 0 and rate > 0, got {num}, {rate}")
    burst = burst_factor != 1.0
    if burst and (burst_factor <= 0 or burst_len <= 0 or normal_len <= 0):
        raise ValueError(
            f"burst mode needs burst_factor > 0 and positive sojourn "
            f"means, got {burst_factor}, {burst_len}, {normal_len}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    if burst:
        mrng = np.random.default_rng(np.random.SeedSequence([seed, 17]))
        state = 0                                    # 0 = normal, 1 = burst
        flip_at = float(mrng.exponential(normal_len))
    out = []
    now = 0.0
    for i in range(num):
        gap = float(rng.exponential(1.0 / rate))
        if not burst:
            now += gap
        else:
            # integrate the unit-rate exponential through the
            # piecewise-constant modulated rate
            work = gap * rate
            while True:
                r = rate * (burst_factor if state else 1.0)
                dt = work / r
                if now + dt <= flip_at:
                    now += dt
                    break
                work -= (flip_at - now) * r
                now = flip_at
                state = 1 - state
                flip_at = now + float(mrng.exponential(
                    burst_len if state else normal_len))
        out.append({
            "arrival": now,
            "case": cases[int(rng.integers(len(cases)))],
            "seed": int(rng.integers(2 ** 31)),
            "k": int(k),
            "deadline": (now + deadline_budget
                         if deadline_budget is not None else None),
        })
    return out


class MarkovStream:
    """First-order Markov chain over ``vocab`` states, fixed by ``seed``.

    Perplexity floor ≈ exp(H(P_row)) — training should push loss towards it.
    """

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        logits = rng.standard_normal((vocab, vocab)) / concentration
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.P = p / p.sum(axis=1, keepdims=True)
        self.vocab = vocab
        self.seed = seed
        row_h = -(self.P * np.log(self.P + 1e-12)).sum(axis=1)
        self.entropy_floor = float(row_h.mean())

    def batch(self, step: int, batch: int, seq: int,
              extras: Optional[Dict] = None) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        toks = np.empty((batch, seq + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        # vectorized inverse-cdf sampling per step
        cdf = np.cumsum(self.P, axis=1)
        for t in range(seq):
            u = rng.random(batch)
            toks[:, t + 1] = (cdf[toks[:, t]] < u[:, None]).sum(axis=1)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if extras:
            out.update(extras)
        return out
