"""Deterministic synthetic data pipeline.

Two generators:

* :func:`token_batch` — pure-hash tokens keyed by (seed, step): exactly
  reproducible on restart from any step, no state to checkpoint.  This is
  the replay-exact property the fault-tolerant loop relies on (the data
  pipeline *is* the step index).
* :class:`MarkovStream` — tokens from a fixed random first-order Markov
  chain: a learnable distribution (entropy strictly below uniform) used by
  the training examples so loss curves mean something.

Batches are emitted host-side as numpy and sharded by the caller's
`batch_specs`; for multi-host production each host would emit only its
addressable shard (same keyed-hash construction, per-host slice).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["token_batch", "MarkovStream"]


def token_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                extras: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """Stateless batch: tokens = hash(seed, step); labels = next-token."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if extras:
        out.update(extras)
    return out


class MarkovStream:
    """First-order Markov chain over ``vocab`` states, fixed by ``seed``.

    Perplexity floor ≈ exp(H(P_row)) — training should push loss towards it.
    """

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        logits = rng.standard_normal((vocab, vocab)) / concentration
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.P = p / p.sum(axis=1, keepdims=True)
        self.vocab = vocab
        self.seed = seed
        row_h = -(self.P * np.log(self.P + 1e-12)).sum(axis=1)
        self.entropy_floor = float(row_h.mean())

    def batch(self, step: int, batch: int, seq: int,
              extras: Optional[Dict] = None) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        toks = np.empty((batch, seq + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        # vectorized inverse-cdf sampling per step
        cdf = np.cumsum(self.P, axis=1)
        for t in range(seq):
            u = rng.random(batch)
            toks[:, t + 1] = (cdf[toks[:, t]] < u[:, None]).sum(axis=1)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if extras:
            out.update(extras)
        return out
