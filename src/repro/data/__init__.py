from .gmrf import (TABLE2, ar1_precision, kronecker_st_precision,
                   lattice_precision, make_arrowhead, table2_matrix)
from .synthetic import (block_separable_arrowhead, indefinite_arrowhead,
                        nan_contaminated_arrowhead, near_singular_arrowhead,
                        request_stream)

__all__ = ["TABLE2", "ar1_precision", "kronecker_st_precision",
           "lattice_precision", "make_arrowhead", "table2_matrix",
           "block_separable_arrowhead", "indefinite_arrowhead",
           "nan_contaminated_arrowhead", "near_singular_arrowhead",
           "request_stream"]
