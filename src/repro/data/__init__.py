from .gmrf import (TABLE2, ar1_precision, kronecker_st_precision,
                   lattice_precision, make_arrowhead, table2_matrix)

__all__ = ["TABLE2", "ar1_precision", "kronecker_st_precision",
           "lattice_precision", "make_arrowhead", "table2_matrix"]
