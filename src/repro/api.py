"""Stable public facade for the solver stack.

Applications import from here — ``from repro import api`` (or
``from repro.api import ...``) — instead of reaching into submodules.
Everything in ``__all__`` is covered by the API-snapshot test
(``tests/test_options_api.py``): names are added deliberately and never
silently removed or re-signatured.

The surface is one matrix type (:class:`BandedCTSF` on a
:class:`TileGrid`), one knob object (:class:`SolverOptions`, accepted as
``options=`` by every entry point), and the entry points themselves:

* factorize: :func:`factorize_window` / :func:`factorize_window_batched`
  / :func:`concurrent_factorize`
* solve: :func:`solve` / :func:`solve_many` / :func:`solve_many_batched`
  (+ the triangular-sweep halves and GMRF sampling)
* selected inversion: :func:`selected_inverse` / :func:`selinv_batched`
  / :func:`marginal_variances`
* serving: :class:`RungServer` (+ :class:`SimClock` for deterministic
  replay)

Per-call ``impl=`` / ``policy=`` / ``regularize=`` / ``sweep=`` /
``method=`` kwargs on the entry points are deprecated shims; pass
``options=SolverOptions(...)``.
"""
from __future__ import annotations

from repro.core.cholesky import (CholeskyFactor, factorize_window,
                                 factorize_window_batched)
from repro.core.concurrent import (concurrent_factorize, concurrent_logdet,
                                   concurrent_quadratic_forms,
                                   concurrent_selinv, concurrent_solve,
                                   stack_ctsf)
from repro.core.ctsf import BandedCTSF
from repro.core.gridpolicy import GridBucketPolicy
from repro.core.options import SolverOptions
from repro.core.ordering import (PartitionPlan, adaptive_nd_ordering,
                                 detect_partition_plan,
                                 partition_plan_from_ordering)
from repro.core.robustness import (STATUS_FAILED, STATUS_OK, STATUS_RECOVERED,
                                   STATUS_SHED, FactorInfo, RegularizePolicy)
from repro.core.selinv import (SelectedInverse, selected_inverse,
                               selinv_batched)
from repro.core.solve import (backward_solve, backward_solve_many,
                              forward_solve, forward_solve_many, logdet,
                              marginal_variances, sample_gmrf,
                              sample_gmrf_many, solve, solve_many,
                              solve_many_batched)
from repro.core.structure import (ArrowheadStructure, TileGrid,
                                  measure_arrowhead)
from repro.launch.rung_server import RungServer, SimClock

__all__ = [
    # matrix + grid types
    "ArrowheadStructure",
    "BandedCTSF",
    "TileGrid",
    "measure_arrowhead",
    # the one knob object + its ingredients
    "SolverOptions",
    "GridBucketPolicy",
    "PartitionPlan",
    "RegularizePolicy",
    # orderings / partition detection
    "adaptive_nd_ordering",
    "detect_partition_plan",
    "partition_plan_from_ordering",
    # factorization
    "CholeskyFactor",
    "FactorInfo",
    "factorize_window",
    "factorize_window_batched",
    "concurrent_factorize",
    "stack_ctsf",
    # solves
    "solve",
    "solve_many",
    "solve_many_batched",
    "forward_solve",
    "forward_solve_many",
    "backward_solve",
    "backward_solve_many",
    "concurrent_solve",
    "concurrent_quadratic_forms",
    "logdet",
    "concurrent_logdet",
    "sample_gmrf",
    "sample_gmrf_many",
    # selected inversion
    "SelectedInverse",
    "selected_inverse",
    "selinv_batched",
    "concurrent_selinv",
    "marginal_variances",
    # per-element status codes on FactorInfo
    "STATUS_OK",
    "STATUS_RECOVERED",
    "STATUS_FAILED",
    "STATUS_SHED",
    # serving
    "RungServer",
    "SimClock",
]
