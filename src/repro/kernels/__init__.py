"""Pallas TPU tile kernels for the sTiles hot spots (POTRF/TRSM/SYRK/GEMM/
GEADD, the fused band-panel update, and the Takahashi selected-inversion
step), with pure-jnp oracles in ref.py."""
from . import ops, ref

__all__ = ["ops", "ref"]
