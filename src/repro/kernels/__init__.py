"""Pallas TPU tile kernels for the sTiles hot spots (POTRF/TRSM/SYRK/GEMM/
GEADD and the fused band-panel update), with pure-jnp oracles in ref.py."""
from . import ops, ref

__all__ = ["ops", "ref"]
