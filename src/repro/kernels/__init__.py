"""Pallas TPU kernels for the sTiles hot spots: tile primitives (POTRF/
TRSM/SYRK/GEMM/GEADD/solve_panel, the Takahashi selected-inversion step),
the fused band-panel update, and the fused single-launch sweeps — whole-band
solves (band_solve.py), the entire band+arrow Cholesky factorization
(band_cholesky.py) and the whole Takahashi selinv recurrence (selinv.py) —
sharing the VMEM-ring machinery in ring.py, with pure-jnp oracles in
ref.py."""
from . import ops, ref

__all__ = ["ops", "ref"]
