"""Pallas TPU kernels for the sTiles hot spots: tile primitives (POTRF/
TRSM/SYRK/GEMM/GEADD/solve_panel, the Takahashi selected-inversion step),
the fused band-panel update, and the fused whole-band solve sweeps
(band_solve.py), with pure-jnp oracles in ref.py."""
from . import ops, ref

__all__ = ["ops", "ref"]
