"""Pallas TPU kernel: fused Takahashi selected-inversion tile step.

One backward-recurrence step of the blocked Takahashi equations
(core/selinv.py) computes a whole column of the selected inverse as

    u[e] = sum_j  S[e, j] @ G[j]        e = 0..e_n-1

where ``S`` is the block row of already-computed Σ tiles visible from column
j (band window + arrow rows + corner) and ``G`` is the normalized factor
column ``G[k] = L[k, j] L[j, j]^{-1}``.  Like ``band_update``, the entire
accumulation chain feeding one output tile runs inside a single kernel whose
accumulator never leaves VMEM: grid = (e_n target tiles, j-blocks); each
target revisits its VMEM accumulator across j-blocks (the grid iterates the
last axis fastest) and emits one HBM write per output tile.

VMEM budget per step: (2·jb + 1)·t²·4B (S-row block, G block, accumulator)
— e.g. jb=8, t=128: ~1.1 MB, far under the ~16 MB/core of v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selinv_step_pallas"]


def _selinv_step_kernel(s_ref, g_ref, o_ref, acc_ref, *, jb: int, njb: int):
    jblk = pl.program_id(1)

    @pl.when(jblk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # s_ref: (1, jb, t, t) slice of Σ row e; g_ref: (jb, t, t) slice of G.
    # The wrapper zero-pads both inputs up to njb*jb, so padded-j terms
    # vanish on their own — no in-kernel masking needed.
    def jstep(jj, acc):
        s = s_ref[0, jj].astype(jnp.float32)
        g = g_ref[jj].astype(jnp.float32)
        return acc + jax.lax.dot_general(s, g, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)

    acc_ref[...] = jax.lax.fori_loop(0, jb, jstep, acc_ref[...])

    @pl.when(jblk == njb - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("jblock", "interpret"))
def selinv_step_pallas(s_row: jnp.ndarray, g_col: jnp.ndarray,
                       jblock: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Fused Takahashi tile step.  s_row: (e_n, j_n, t, t), g_col:
    (j_n, t, t) -> u: (e_n, t, t).

    Matches ``ref.selinv_step_ref`` bit-for-bit in float32.
    """
    e_n, j_n, t, _ = s_row.shape
    if e_n == 0 or j_n == 0:
        return jnp.zeros((e_n, t, t), s_row.dtype)
    jb = min(jblock, j_n)
    njb = pl.cdiv(j_n, jb)
    jpad = njb * jb
    sp = jnp.pad(s_row, ((0, 0), (0, jpad - j_n), (0, 0), (0, 0)))
    gp = jnp.pad(g_col, ((0, jpad - j_n), (0, 0), (0, 0)))
    return pl.pallas_call(
        functools.partial(_selinv_step_kernel, jb=jb, njb=njb),
        grid=(e_n, njb),
        in_specs=[
            pl.BlockSpec((1, jb, t, t), lambda e, j: (e, j, 0, 0)),
            pl.BlockSpec((jb, t, t), lambda e, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, t), lambda e, j: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e_n, t, t), s_row.dtype),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
        interpret=interpret,
    )(sp, gp)
