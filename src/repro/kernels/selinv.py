"""Pallas TPU kernels: Takahashi selected inversion (tile step + fused sweep).

``selinv_step_pallas`` — one backward-recurrence step of the blocked
Takahashi equations (core/selinv.py) computes a whole column of the
selected inverse as

    u[e] = sum_j  S[e, j] @ G[j]        e = 0..e_n-1

where ``S`` is the block row of already-computed Σ tiles visible from column
j (band window + arrow rows + corner) and ``G`` is the normalized factor
column ``G[k] = L[k, j] L[j, j]^{-1}``.  Like ``band_update``, the entire
accumulation chain feeding one output tile runs inside a single kernel whose
accumulator never leaves VMEM: grid = (e_n target tiles, j-blocks); each
target revisits its VMEM accumulator across j-blocks (the grid iterates the
last axis fastest) and emits one HBM write per output tile.

VMEM budget per step: (2·jb + 1)·t²·4B (S-row block, G block, accumulator)
— e.g. jb=8, t=128: ~1.1 MB, far under the ~16 MB/core of v5e.

``selinv_sweep_pallas`` — the *whole* backward Takahashi recurrence as one
launch (the ROADMAP's selinv-fusion item): driven column-at-a-time the
recurrence round-trips its Σ-column ring through HBM between ``lax.scan``
steps; here grid = (ndt,) walks columns j = ndt-1..0 with the ring of the
last ``bt`` computed Σ columns (plus the arrow ring) resident in VMEM
scratch (``kernels/ring.py``, the machinery shared with the band-solve and
band-Cholesky sweeps), the L_jj^{-1} seed solved in-kernel
(:func:`trsm.substitute_panel` against the identity) and the full corner
Σ_cc broadcast to every step.  VMEM budget per step: the Σ ring
bt·(bt+1)·t², the arrow ring bt·nat·t², the corner nat²·t² and the
(bt+1+nat)·t² blocks — e.g. bt=8, t=128, nat=2: ~6.1 MB, under the ~16
MB/core of v5e.

Both match their ``kernels/ref.py`` oracles to fp32 tolerance;
``kernels.ops.selinv_step`` / ``kernels.ops.selinv_sweep`` dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ring import eye_tile, identity_prefix_panel, ring_read, ring_write
from .trsm import substitute_panel

__all__ = ["selinv_step_pallas", "selinv_sweep_pallas"]


def _selinv_step_kernel(s_ref, g_ref, o_ref, acc_ref, *, jb: int, njb: int):
    jblk = pl.program_id(1)

    @pl.when(jblk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # s_ref: (1, jb, t, t) slice of Σ row e; g_ref: (jb, t, t) slice of G.
    # The wrapper zero-pads both inputs up to njb*jb, so padded-j terms
    # vanish on their own — no in-kernel masking needed.
    def jstep(jj, acc):
        s = s_ref[0, jj].astype(jnp.float32)
        g = g_ref[jj].astype(jnp.float32)
        return acc + jax.lax.dot_general(s, g, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)

    acc_ref[...] = jax.lax.fori_loop(0, jb, jstep, acc_ref[...])

    @pl.when(jblk == njb - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("jblock", "interpret"))
def selinv_step_pallas(s_row: jnp.ndarray, g_col: jnp.ndarray,
                       jblock: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Fused Takahashi tile step.  s_row: (e_n, j_n, t, t), g_col:
    (j_n, t, t) -> u: (e_n, t, t).

    Matches ``ref.selinv_step_ref`` bit-for-bit in float32.
    """
    e_n, j_n, t, _ = s_row.shape
    if e_n == 0 or j_n == 0:
        return jnp.zeros((e_n, t, t), s_row.dtype)
    jb = min(jblock, j_n)
    njb = pl.cdiv(j_n, jb)
    jpad = njb * jb
    sp = jnp.pad(s_row, ((0, 0), (0, jpad - j_n), (0, 0), (0, 0)))
    gp = jnp.pad(g_col, ((0, jpad - j_n), (0, 0), (0, 0)))
    return pl.pallas_call(
        functools.partial(_selinv_step_kernel, jb=jb, njb=njb),
        grid=(e_n, njb),
        in_specs=[
            pl.BlockSpec((1, jb, t, t), lambda e, j: (e, j, 0, 0)),
            pl.BlockSpec((jb, t, t), lambda e, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, t), lambda e, j: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e_n, t, t), s_row.dtype),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
        interpret=interpret,
    )(sp, gp)


# ---------------------------------------------------------------------------
# Fused backward sweep: the whole Takahashi recurrence in one launch
# ---------------------------------------------------------------------------

def _selinv_sweep_kernel(start_ref, lcol_ref, r_ref, sc_ref, p_ref, a_ref,
                         ring_ref, ringa_ref, *, ndt: int, bt: int):
    s = pl.program_id(0)
    j = ndt - 1 - s
    start = start_ref[0]
    t = lcol_ref.shape[-1]

    @pl.when(s == 0)
    def _init():
        ring_ref[...] = jnp.zeros_like(ring_ref)
        ringa_ref[...] = jnp.zeros_like(ringa_ref)

    eye = eye_tile(t)

    # Canonical-grid fast finish (core/gridpolicy.py): columns j < start
    # are the identity-embedding prefix — decoupled, so their Σ panel is
    # exactly the identity (Σ_embedded = blockdiag(I, Σ)).  The backward
    # walk reaches them last, nothing reads their ring slots afterwards,
    # and the whole seed/normalize/contract body is skipped.
    @pl.when(j < start)
    def _skip():
        p_ref[0] = identity_prefix_panel(bt, t).astype(p_ref.dtype)
        a_ref[0] = jnp.zeros_like(a_ref[0])

    @pl.when(j >= start)
    def _work():
        _selinv_sweep_body(lcol_ref, r_ref, sc_ref, p_ref, a_ref,
                           ring_ref, ringa_ref, eye, j, bt=bt)


def _selinv_sweep_body(lcol_ref, r_ref, sc_ref, p_ref, a_ref,
                       ring_ref, ringa_ref, eye, j, *, bt: int):
    t = lcol_ref.shape[-1]
    lc = lcol_ref[0].astype(jnp.float32)                  # (b1, t, t)
    rc = r_ref[0].astype(jnp.float32)                     # (nat_p, t, t)
    sc = sc_ref[...].astype(jnp.float32)                  # (nat_p, nat_p, t, t)

    # seed: winv = L_jj^{-1} (in-kernel substitution against the identity),
    # s0 = (L_jj L_jj^T)^{-1} = winv^T winv
    winv = substitute_panel(lc[0], eye)
    s0 = jax.lax.dot_general(winv, winv, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # normalized column: G_d = L_{j+d, j} L_jj^{-1}, arrow Ga_i = R[j,i] winv
    g = [jax.lax.dot_general(lc[d], winv, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         for d in range(1, bt + 1)]
    ga = jax.lax.dot_general(rc, winv, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # Σ columns j+1..j+bt from the VMEM rings (zeros past ndt-1 / from the
    # step-0 init); bt is static and small, so the d/e loops unroll.
    colp = [ring_read(ring_ref, j + d, bt) for d in range(1, bt + 1)]
    arow = [ring_read(ringa_ref, j + d, bt) for d in range(1, bt + 1)]

    # off-diagonal band targets:  Σ_{j+e, j} = -sum_{k>j} Σ_{j+e, k} G_{k, j}
    off = []
    for e in range(1, bt + 1):
        acc = jnp.zeros((t, t), jnp.float32)
        for d in range(1, bt + 1):
            if e >= d:
                # Σ_{j+e, j+d} lives in column j+d at offset e-d
                acc = acc + jax.lax.dot_general(
                    colp[d - 1][e - d], g[d - 1], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            else:
                # Σ_{j+e, j+d} = Σ_{j+d, j+e}^T, from column j+e
                acc = acc + jax.lax.dot_general(
                    colp[e - 1][d - e], g[d - 1], (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
        # arrow sources: sum_i Σ_{j+e, ndt+i} @ Ga_i = sum_i arow_e[i]^T Ga_i
        acc = acc + jax.lax.dot_general(
            arow[e - 1], ga, (((0, 1), (0, 1)), ((), ())),
            preferred_element_type=jnp.float32)
        off.append(-acc)

    # arrow targets:  Σ_{ndt+i, j} = -(sum_d Σ_{ndt+i, j+d} G_d
    #                                  + sum_i' Σ_cc[i, i'] Ga_i')
    ua = jax.lax.dot_general(sc, ga, (((1, 3), (0, 1)), ((), ())),
                             preferred_element_type=jnp.float32)
    for d in range(1, bt + 1):
        ua = ua + jax.lax.dot_general(
            arow[d - 1], g[d - 1], (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    acol = -ua

    # diagonal: Σ_jj = s0 - sum_{k>j} Σ_kj^T G_kj (the fresh off-diagonals)
    corr = jax.lax.dot_general(acol, ga, (((0, 1), (0, 1)), ((), ())),
                               preferred_element_type=jnp.float32)
    for e in range(1, bt + 1):
        corr = corr + jax.lax.dot_general(
            off[e - 1], g[e - 1], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    sjj = s0 - corr
    sjj = 0.5 * (sjj + sjj.T)

    panel = jnp.concatenate([sjj[None]] + [o[None] for o in off], axis=0)
    if bt:
        ring_write(ring_ref, j, bt, panel)
        ring_write(ringa_ref, j, bt, acol)
    p_ref[0] = panel.astype(p_ref.dtype)
    a_ref[0] = acol.astype(a_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selinv_sweep_pallas(lcol, R, sc_full, start_tile=0,
                        interpret: bool = True):
    """Fused backward Takahashi sweep.  lcol: (ndt, bt+1, t, t) column view
    of the factor (``lcol[j, d] = L[j+d, j]``, see ``ring.band_row_to_col``),
    R: (ndt, nat, t, t) arrow rows of the factor, sc_full: (nat, nat, t, t)
    full (symmetric) corner Σ seed ->

      panels (ndt, bt+1, t, t)  Σ column panels: panels[j, e] = Σ[j+e, j]
      acols  (ndt, nat, t, t)   arrow entries:   acols[j, i] = Σ[ndt+i, j]

    ``start_tile`` (traced SMEM scalar) declares columns ``j < start_tile``
    an identity-embedding prefix: they emit identity Σ panels without any
    recurrence work (``core/gridpolicy.py``).

    Matches ``ref.selinv_sweep_ref`` (the lax.scan oracle) to fp32 tolerance.
    """
    ndt, b1, t, _ = lcol.shape
    bt = b1 - 1
    nat = R.shape[1]
    if ndt == 0:
        return (jnp.zeros((0, b1, t, t), lcol.dtype),
                jnp.zeros((0, nat, t, t), lcol.dtype))
    nat_p = max(nat, 1)
    rp = R if nat else jnp.zeros((ndt, 1, t, t), lcol.dtype)
    scp = sc_full if nat else jnp.zeros((1, 1, t, t), lcol.dtype)
    start = jnp.reshape(jnp.asarray(start_tile, jnp.int32), (1,))
    panels, acols = pl.pallas_call(
        functools.partial(_selinv_sweep_kernel, ndt=ndt, bt=bt),
        grid=(ndt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b1, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
            pl.BlockSpec((nat_p, nat_p, t, t), lambda s: (0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b1, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ndt, b1, t, t), lcol.dtype),
            jax.ShapeDtypeStruct((ndt, nat_p, t, t), lcol.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(bt, 1), b1, t, t), jnp.float32),
            pltpu.VMEM((max(bt, 1), nat_p, t, t), jnp.float32),
        ],
        interpret=interpret,
    )(start, lcol, rp, scp)
    return panels, acols[:, :nat]

