"""Jitted public wrappers for the tile kernels, with backend dispatch.

``impl`` selects between the Pallas TPU kernels (``"pallas"`` — validated on
CPU through interpret mode, compiled natively on TPU) and the pure-jnp
references (``"ref"`` — what XLA fuses itself; the default on CPU where
interpret-mode Python execution would dominate).  The factorization code
calls these and is oblivious to the backend; tests assert the two agree.
"""
from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from . import ref
from .potrf import potrf_pallas
from .trsm import solve_panel_pallas, trsm_pallas
from .gemm import gemm_pallas, syrk_pallas, geadd_pallas
from .band_update import band_update_pallas
from .band_cholesky import (band_cholesky_partitioned_sweep_pallas,
                            band_cholesky_sweep_pallas)
from .band_solve import band_backward_sweep_pallas, band_forward_sweep_pallas
from .selinv import selinv_step_pallas, selinv_sweep_pallas

__all__ = ["potrf", "trsm", "solve_panel", "syrk", "gemm", "geadd",
           "band_update", "selinv_step", "band_forward_sweep",
           "band_backward_sweep", "band_cholesky_sweep",
           "band_cholesky_partitioned_sweep", "selinv_sweep",
           "default_impl"]

Impl = Literal["ref", "pallas", "unrolled"]

_VALID_IMPLS = ("ref", "pallas", "unrolled")


def default_impl() -> Impl:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env is not None:
        if env not in _VALID_IMPLS:
            raise ValueError(
                f"REPRO_KERNEL_IMPL={env!r} is not a valid kernel backend; "
                f"expected one of {list(_VALID_IMPLS)} (unset the variable "
                "to let the per-backend default apply: pallas on TPU, ref "
                "elsewhere)")
        return env  # type: ignore[return-value]
    # Pallas natively on TPU; jnp-fused path on CPU (interpret mode is for
    # validation, not production CPU perf).
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def potrf(a: jnp.ndarray, impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return potrf_pallas(a, interpret=_interp())
    return ref.potrf_ref(a) if a.ndim == 2 else jax.vmap(ref.potrf_ref)(
        a.reshape((-1,) + a.shape[-2:])).reshape(a.shape)


def trsm(l_kk: jnp.ndarray, a_mk: jnp.ndarray, impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return trsm_pallas(l_kk, a_mk, interpret=_interp())
    if a_mk.ndim == 2:
        return ref.trsm_ref(l_kk, a_mk)
    flat = a_mk.reshape((-1,) + a_mk.shape[-2:])
    return jax.vmap(lambda x: ref.trsm_ref(l_kk, x))(flat).reshape(a_mk.shape)


def solve_panel(l_kk: jnp.ndarray, b_panel: jnp.ndarray, trans: bool = False,
                impl: Impl | None = None) -> jnp.ndarray:
    """Multi-RHS triangular solve ``L X = B`` (``trans`` -> ``L^T X = B``)
    for a (t, k) RHS panel — the tile primitive of the batched serving path
    (`core.solve.solve_many` / one-sweep marginal variances)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return solve_panel_pallas(l_kk, b_panel, trans=trans, interpret=_interp())
    return ref.solve_panel_ref(l_kk, b_panel, trans=trans)


def syrk(c_kk: jnp.ndarray, a_kn: jnp.ndarray, impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return syrk_pallas(c_kk, a_kn, interpret=_interp())
    return ref.syrk_ref(c_kk, a_kn)


def gemm(c_mk: jnp.ndarray, a_mn: jnp.ndarray, b_kn: jnp.ndarray,
         impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return gemm_pallas(c_mk, a_mn, b_kn, interpret=_interp())
    return ref.gemm_ref(c_mk, a_mn, b_kn)


def geadd(a: jnp.ndarray, b: jnp.ndarray, impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return geadd_pallas(a, b, interpret=_interp())
    return ref.geadd_ref(a, b)


def selinv_step(s_row: jnp.ndarray, g_col: jnp.ndarray,
                impl: Impl | None = None) -> jnp.ndarray:
    """One Takahashi selected-inversion tile step: ``u[e] = sum_j
    s_row[e, j] @ g_col[j]`` — the accumulation chain feeding one column of
    Σ = A^{-1} in ``core.selinv``'s backward recurrence (registered alongside
    :func:`solve_panel` as a serving-path tile primitive)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return selinv_step_pallas(s_row, g_col, interpret=_interp())
    return ref.selinv_step_ref(s_row, g_col)


def band_forward_sweep(Dr: jnp.ndarray, R: jnp.ndarray, bd: jnp.ndarray,
                       start_tile=0, impl: Impl | None = None):
    """Whole-band multi-RHS forward sweep: solve ``L Y = B`` over all band
    tile rows and accumulate the arrow-RHS correction ``sum_m R[m] @ Y_m``
    in the same pass.  The sweep-level serving primitive: ``"pallas"`` runs
    one fused kernel (ring of recent panels in VMEM — no per-tile HBM
    round-trips), ``"ref"`` the per-tile ``fori_loop`` of
    :func:`solve_panel`.  ``start_tile`` may be traced (RHS-sparsity fast
    start; rows above it stay zero on both backends)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return band_forward_sweep_pallas(Dr, R, bd, start_tile,
                                         interpret=_interp())
    return ref.band_forward_sweep_ref(Dr, R, bd, start_tile)


def band_backward_sweep(Dr: jnp.ndarray, R: jnp.ndarray, yd: jnp.ndarray,
                        xa: jnp.ndarray, start_tile=0,
                        impl: Impl | None = None) -> jnp.ndarray:
    """Whole-band multi-RHS backward sweep: solve ``L^T X = Y - R^T Xa``
    over all band tile rows in reverse — the transpose counterpart of
    :func:`band_forward_sweep`, with the same backend split.
    ``start_tile`` (traced) skips the identity-embedding prefix rows of a
    canonical grid, leaving X zero there."""
    impl = impl or default_impl()
    if impl == "pallas":
        return band_backward_sweep_pallas(Dr, R, yd, xa, start_tile,
                                          interpret=_interp())
    return ref.band_backward_sweep_ref(Dr, R, yd, xa, start_tile)


def band_cholesky_sweep(Ac: jnp.ndarray, R: jnp.ndarray, nchunks: int = 1,
                        start_tile=0, impl: Impl | None = None):
    """Whole band+arrow Cholesky factorization as one sweep-level primitive:
    ``Ac (ndt, bt+1, t, t)`` column-band tiles and ``R (ndt, nat, t, t)``
    arrow rows -> ``(panels, R_out, schur, status)`` column panels of L,
    factored arrow rows, per-chunk corner-Schur partial sums (``nchunks``
    chunks — the tree-reduction leaves for the corner factorization), and
    the (3,) float32 breakdown status word ``[min_pivot, nonfinite,
    first_bad]`` (see ``ref.sweep_status``) — detection rides the sweep
    with no host sync on either backend, so callers (the jitter ladder in
    ``core/robustness.py``) decide host-side whether to retry without the
    factorization ever raising mid-batch.

    ``"pallas"`` runs one fused kernel for the entire factorization (VMEM
    ring of the last band_tiles panels + arrow ring, in-kernel potrf/trsm,
    Schur accumulated on the fly); ``"ref"`` the ring-buffer ``lax.scan``
    that dispatches per-panel tile ops.  This is what
    ``core.cholesky._factorize_window_impl`` rides on every backend.

    ``start_tile`` (traced) declares the first ``start_tile`` columns an
    identity-embedding prefix (``core/gridpolicy.py``): both backends emit
    identity panels / zero arrow rows for them, and the fused kernel skips
    their compute entirely."""
    impl = impl or default_impl()
    if impl == "pallas":
        return band_cholesky_sweep_pallas(Ac, R, nchunks=nchunks,
                                          start_tile=start_tile,
                                          interpret=_interp())
    return ref.band_cholesky_sweep_ref(Ac, R, nchunks=nchunks,
                                       start_tile=start_tile)


def band_cholesky_partitioned_sweep(Ac: jnp.ndarray, R: jnp.ndarray,
                                    boundaries, start_tile=0,
                                    impl: Impl | None = None):
    """Partition-parallel band+arrow Cholesky: every independent partition
    of a block-separable band factorizes in ONE launch.

    ``boundaries`` is the static tile-boundary tuple of a
    :class:`~repro.core.ordering.PartitionPlan` (``(0, c_1, ..., ndt)``,
    hashable — the kernels layer takes the raw tuple so it stays
    decoupled from core's plan type); the input must be block-separable
    across those cuts (no band tile crossing a boundary —
    ``detect_partition_plan`` certifies it).  Returns ``(panels, R_out,
    schur, status)`` like :func:`band_cholesky_sweep`, except ``schur``
    is ``(P, nat, nat, t, t)`` — one corner-Schur tree-reduction leaf per
    partition — and ``status.first_bad`` is already global.

    ``"pallas"`` runs the 2D-grid fused kernel (parallel partition axis ×
    sequential per-partition axis: critical path O(max partition tiles)
    instead of O(ndt)); ``"ref"`` runs the per-partition ``lax.scan``
    oracle.  A trivial single-partition ``boundaries=(0, ndt)`` is valid
    but pointless — ``core.cholesky`` routes that case to
    :func:`band_cholesky_sweep` to keep it bit-identical to the
    unpartitioned sweep."""
    impl = impl or default_impl()
    boundaries = tuple(int(b) for b in boundaries)
    if impl == "pallas":
        return band_cholesky_partitioned_sweep_pallas(
            Ac, R, boundaries, start_tile=start_tile, interpret=_interp())
    return ref.band_cholesky_partitioned_sweep_ref(
        Ac, R, boundaries, start_tile=start_tile)


def selinv_sweep(lcol: jnp.ndarray, R: jnp.ndarray, sc_full: jnp.ndarray,
                 start_tile=0, impl: Impl | None = None):
    """Whole backward Takahashi recurrence as one sweep-level primitive:
    ``lcol (ndt, bt+1, t, t)`` column view of the factor, ``R`` its arrow
    rows and ``sc_full (nat, nat, t, t)`` the dense corner Σ seed ->
    ``(panels, acols)`` Σ column panels and arrow entries.

    ``"pallas"`` runs one fused kernel for the whole recurrence (Σ-column
    ring resident in VMEM across columns — the ROADMAP's selinv-fusion
    item); ``"ref"`` the per-column ``lax.scan`` of ``selinv_step``
    contractions.  Backs ``core.selinv.selected_inverse`` on every
    backend.  ``start_tile`` (traced) skips the identity-embedding prefix
    columns of a canonical grid, emitting identity Σ panels there."""
    impl = impl or default_impl()
    if impl == "pallas":
        return selinv_sweep_pallas(lcol, R, sc_full, start_tile,
                                   interpret=_interp())
    return ref.selinv_sweep_ref(lcol, R, sc_full, start_tile)


def band_update(w: jnp.ndarray, impl: Impl | None = None) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return band_update_pallas(w, interpret=_interp())
    if impl == "unrolled" or (impl == "ref" and w.shape[0] <= 6):
        # small bands: skip structurally-zero (e, j) pairs entirely
        return ref.band_update_unrolled_ref(w)
    return ref.band_update_ref(w)
