"""Pallas TPU kernels: tile GEMM / SYRK / GEADD accumulation updates.

GEMM: ``C - A @ B^T`` — the dominant FLOP sink of the factorization (the
paper's cublasDgemm calls).  SYRK is GEMM with A==B.  GEADD is the
tree-reduction combine.  Tiles up to 256×256 fit VMEM whole; larger tiles
block over the contraction dim with a float32 VMEM accumulator (revisiting
the output block across the k-grid axis, writing on the last step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gemm_pallas", "syrk_pallas", "geadd_pallas"]


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (batch, k_blocks): accumulate -A@B^T over k in VMEM, emit once."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = c_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    acc_ref[...] -= jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kblock", "interpret"))
def gemm_pallas(c_mk: jnp.ndarray, a_mn: jnp.ndarray, b_kn: jnp.ndarray,
                kblock: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Tile update C - A @ B^T, batched over leading dims."""
    t = c_mk.shape[-1]
    batch_shape = c_mk.shape[:-2]
    c3 = c_mk.reshape((-1, t, t))
    a3 = jnp.broadcast_to(a_mn, batch_shape + (t, t)).reshape((-1, t, t))
    b3 = jnp.broadcast_to(b_kn, batch_shape + (t, t)).reshape((-1, t, t))
    nb = c3.shape[0]
    kb = min(kblock, t)
    nk = pl.cdiv(t, kb)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((1, t, t), lambda bidx, k: (bidx, 0, 0)),
            pl.BlockSpec((1, t, kb), lambda bidx, k: (bidx, 0, k)),
            pl.BlockSpec((1, t, kb), lambda bidx, k: (bidx, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, t, t), lambda bidx, k: (bidx, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, t, t), c_mk.dtype),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
        interpret=interpret,
    )(c3, a3, b3)
    return out.reshape(batch_shape + (t, t))


@functools.partial(jax.jit, static_argnames=("kblock", "interpret"))
def syrk_pallas(c_kk: jnp.ndarray, a_kn: jnp.ndarray,
                kblock: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Symmetric rank-t tile update C - A @ A^T."""
    return gemm_pallas(c_kk, a_kn, a_kn, kblock=kblock, interpret=interpret)


def _geadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def geadd_pallas(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Generalized tile addition (tree-reduction combine, paper Alg. 3)."""
    t = a.shape[-1]
    batch_shape = a.shape[:-2]
    a3 = a.reshape((-1, t, t))
    b3 = b.reshape((-1, t, t))
    nb = a3.shape[0]
    out = pl.pallas_call(
        _geadd_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, t, t), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, t, t), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, t, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, t, t), a.dtype),
        interpret=interpret,
    )(a3, b3)
    return out.reshape(batch_shape + (t, t))
