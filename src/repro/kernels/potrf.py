"""Pallas TPU kernel: single-tile Cholesky factorization (POTRF).

One (t, t) SPD tile is loaded into VMEM once, factorized in-register with a
masked right-looking column loop, and written back once.  On the MXU the
surrounding SYRK/GEMM traffic dominates (O(ndt·b²) matmuls vs O(ndt) POTRFs,
same as cuSOLVER's role in the paper) so this kernel optimizes for a single
HBM round-trip rather than peak FLOPs.

The column loop uses only masked vector ops (no dynamic scatters), which maps
cleanly onto the VPU's (8, 128) lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["potrf_pallas", "factorize_tile"]


def factorize_tile(a: jnp.ndarray, return_status: bool = False):
    """In-kernel dense Cholesky of one (t, t) SPD tile via a masked
    right-looking column loop (only masked vector ops — no dynamic
    scatters — so it lowers inside a Pallas kernel body).  Shared by
    :func:`potrf_pallas` and the fused band-Cholesky sweep in
    ``kernels/band_cholesky.py``.  Operates in and returns float32.

    ``return_status=True`` additionally returns the minimum *raw* pivot
    encountered by the column loop — the true (possibly negative) value of
    ``a[j, j]`` after trailing updates, before ``rsqrt`` destroys its sign.
    A breakdown therefore reports *how* indefinite the tile was, which is
    what sizes the jitter ladder in ``core/robustness.py`` (the sweep-level
    status word derives its pivots from the emitted factor instead, so
    both kernel backends agree bit-for-bit — see ``ref.sweep_status``)."""
    t = a.shape[-1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    rvec = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)

    def step(j, carry):
        a, min_piv = carry
        # pivot = a[j, j]
        pivot = jnp.sum(jnp.where((rows == j) & (cols == j), a, 0.0))
        min_piv = jnp.minimum(min_piv, pivot)
        dinv = jax.lax.rsqrt(pivot)
        # column j, scaled: L[i, j] = a[i, j] / sqrt(pivot), rows >= j
        col = jnp.sum(jnp.where(cols == j, a, 0.0), axis=1) * dinv
        col = jnp.where(rvec >= j, col, 0.0)
        # trailing update: a[i, m] -= col[i] * col[m] for i > j, m > j
        trailing = (rows > j) & (cols > j)
        a = a - jnp.where(trailing, col[:, None] * col[None, :], 0.0)
        # write the finished column j
        a = jnp.where(cols == j, col[:, None], a)
        return a, min_piv

    a, min_piv = jax.lax.fori_loop(0, t, step, (a, jnp.float32(jnp.inf)))
    a = jnp.where(rows >= cols, a, 0.0)
    if return_status:
        return a, min_piv
    return a


def _potrf_kernel(a_ref, o_ref):
    o_ref[0] = factorize_tile(a_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf_pallas(a: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Cholesky of one (t, t) tile (or a batch (..., t, t) via grid)."""
    batch_shape = a.shape[:-2]
    t = a.shape[-1]
    a3 = a.reshape((-1, t, t))
    nb = a3.shape[0]
    out = pl.pallas_call(
        _potrf_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, t, t), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, t, t), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, t, t), a.dtype),
        interpret=interpret,
    )(a3)
    return out.reshape(batch_shape + (t, t))
