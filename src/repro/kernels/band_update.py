"""Pallas TPU kernel: fused left-looking band-panel update (hot spot).

This is the TPU rethink of the paper's left-looking accumulation insight
("the GEMM operations behave as an accumulator", §II): instead of one task
per (SYRK|GEMM) with an HBM round-trip each, the *entire* update feeding
panel k is computed by one kernel whose accumulator never leaves VMEM:

    u[e] = sum_{j=1..b-e}  w[e, e+j] @ w[0, j]^T      e = 0..b

where ``w`` is the (b+1, b+1, t, t) row-band window (w[e, d] =
L_tile[k+e, k+e-d]).  e == 0 is the diagonal SYRK chain; e > 0 are the GEMM
chains.  Grid = (b+1 target tiles, j-blocks); each target revisits its VMEM
accumulator across j-blocks (grid iterates the last axis fastest), emitting
one HBM write per output tile.

VMEM budget per step: (2·jb + 1)·t²·4B  (A-row block, B-row block, acc)
— e.g. jb=8, t=128: ~1.1 MB, far under the ~16 MB/core of v5e, leaving
room for the pipelined next block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["band_update_pallas"]


def _band_update_kernel(a_ref, b_ref, o_ref, acc_ref, *, b1: int, jb: int, njb: int):
    e = pl.program_id(0)
    jblk = pl.program_id(1)

    @pl.when(jblk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # a_ref: (1, jb, t, t) slice of the shifted row e; entries are
    # w[e, e + jblk*jb + jj].  b_ref: (1, jb, t, t) slice w[0, jblk*jb + jj].
    def jstep(jj, acc):
        j = jblk * jb + jj  # global j index (0-based; j==0 masked: term j>=1)
        a = a_ref[0, jj].astype(jnp.float32)
        b = b_ref[0, jj].astype(jnp.float32)
        term = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        valid = (j >= 1) & (e + j <= b1 - 1)
        return acc + jnp.where(valid, term, 0.0)

    acc_ref[...] = jax.lax.fori_loop(0, jb, jstep, acc_ref[...])

    @pl.when(jblk == njb - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("jblock", "interpret"))
def band_update_pallas(w: jnp.ndarray, jblock: int = 8,
                       interpret: bool = True) -> jnp.ndarray:
    """Fused band-panel update.  w: (b+1, b+1, t, t) -> u: (b+1, t, t).

    Matches ``ref.band_update_ref`` bit-for-bit in float32.
    """
    b1, _, t, _ = w.shape
    b = b1 - 1
    jb = min(jblock, b1)
    njb = pl.cdiv(b1, jb)
    jpad = njb * jb

    # Pre-shift on the host side of the kernel: wsh[e, j] = w[e, e+j]
    # (clamped gather; masked inside the kernel).  The gather is a cheap
    # O(b²t²) copy; the contraction is O(b²t³).
    e_idx = jnp.arange(b1)[:, None]
    j_idx = jnp.arange(jpad)[None, :]
    gather = jnp.clip(e_idx + j_idx, 0, b)
    wsh = jnp.take_along_axis(
        jnp.pad(w, ((0, 0), (0, max(0, jpad - b1)), (0, 0), (0, 0))),
        gather[:, :, None, None], axis=1)
    w0 = jnp.pad(w[0:1], ((0, 0), (0, max(0, jpad - b1)), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_band_update_kernel, b1=b1, jb=jb, njb=njb),
        grid=(b1, njb),
        in_specs=[
            pl.BlockSpec((1, jb, t, t), lambda e, j: (e, j, 0, 0)),
            pl.BlockSpec((1, jb, t, t), lambda e, j: (0, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, t), lambda e, j: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b1, t, t), w.dtype),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
        interpret=interpret,
    )(wsh, w0)
    return out
