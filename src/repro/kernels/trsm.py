"""Pallas TPU kernel: off-diagonal tile triangular solve (TRSM).

Computes ``X = A @ L^{-T}`` for one (t, t) tile against the freshly
factorized diagonal tile L (lower).  Forward substitution over columns with
masked vector ops; the whole tile lives in VMEM for the duration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["trsm_pallas", "solve_panel_pallas", "substitute_panel",
           "substitute_right"]


def substitute_panel(l: jnp.ndarray, b: jnp.ndarray,
                     trans: bool = False) -> jnp.ndarray:
    """In-kernel multi-RHS substitution: solve ``L X = B`` (``trans`` ->
    ``L^T X = B``) for one (t, t) lower-triangular tile against a (t, k)
    panel, using only masked vector ops (no gather/scatter) so it lowers
    inside a Pallas kernel body.  Shared by :func:`solve_panel_pallas` and
    the fused band sweeps in ``kernels/band_solve.py``.  Operates in and
    returns float32."""
    t, k = l.shape[-1], b.shape[-1]
    lrows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    lcols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    prows = jax.lax.broadcasted_iota(jnp.int32, (t, k), 0)
    rvec = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)

    def step(s, x):
        j = (t - 1 - s) if trans else s
        if trans:
            # row j of U = L^T is column j of L; only i > j contribute
            lj = jnp.sum(jnp.where(lcols == j, l, 0.0), axis=1)
            lj_m = jnp.where(rvec > j, lj, 0.0)
        else:
            lj = jnp.sum(jnp.where(lrows == j, l, 0.0), axis=0)
            lj_m = jnp.where(rvec < j, lj, 0.0)
        ljj = jnp.sum(jnp.where(rvec == j, lj, 0.0))
        bj = jnp.sum(jnp.where(prows == j, b, 0.0), axis=0)         # B[j, :]
        xrow = (bj - jnp.dot(lj_m, x, precision=jax.lax.Precision.HIGHEST)) / ljj
        return jnp.where(prows == j, xrow[None, :], x)

    return jax.lax.fori_loop(0, t, step, jnp.zeros((t, k), jnp.float32))


def substitute_right(l: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """In-kernel right triangular substitution: solve ``X L^T = A`` (i.e.
    ``X = A L^{-T}``, the TRSM of the tile Cholesky) for a ``(..., t, t)``
    batch of tiles A against one (t, t) lower tile L, using only masked
    vector ops.  Shared by :func:`trsm_pallas` and the fused band-Cholesky
    sweep in ``kernels/band_cholesky.py`` (which substitutes its whole
    sub-diagonal panel + arrow rows in one batched call).  Operates in and
    returns float32."""
    t = l.shape[-1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    cvec = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)

    def step(j, x):
        # X[..., j] = (A[..., j] - X[..., :j] @ L[j, :j]^T) / L[j, j]
        lrow = jnp.sum(jnp.where(rows == j, l, 0.0), axis=0)       # L[j, :]
        lrow_m = jnp.where(cvec < j, lrow, 0.0)
        ljj = jnp.sum(jnp.where(cvec == j, lrow, 0.0))
        acol = jnp.sum(jnp.where(cols == j, a, 0.0), axis=-1)      # A[..., j]
        xcol = (acol - jnp.dot(x, lrow_m, precision=jax.lax.Precision.HIGHEST)) / ljj
        return jnp.where(cols == j, xcol[..., None], x)

    return jax.lax.fori_loop(0, t, step, jnp.zeros(a.shape, jnp.float32))


def _trsm_kernel(l_ref, a_ref, o_ref):
    x = substitute_right(l_ref[0].astype(jnp.float32),
                         a_ref[0].astype(jnp.float32))
    o_ref[0] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsm_pallas(l_kk: jnp.ndarray, a_mk: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Batched tile TRSM: broadcasting L over a batch of A tiles."""
    t = a_mk.shape[-1]
    batch_shape = a_mk.shape[:-2]
    a3 = a_mk.reshape((-1, t, t))
    nb = a3.shape[0]
    l3 = jnp.broadcast_to(l_kk, (nb, t, t)) if l_kk.ndim == 2 else l_kk.reshape((-1, t, t))
    out = pl.pallas_call(
        _trsm_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, t, t), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, t, t), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, t, t), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, t, t), a_mk.dtype),
        interpret=interpret,
    )(l3, a3)
    return out.reshape(batch_shape + (t, t))


def _solve_panel_kernel(l_ref, b_ref, o_ref, *, trans):
    """Multi-RHS substitution: solve L X = B (or L^T X = B) for one (t, k)
    panel.  Each step updates a whole row of X — a (t,) x (t, k) contraction
    — so the k right-hand sides ride one sweep instead of k."""
    x = substitute_panel(l_ref[0].astype(jnp.float32),
                         b_ref[0].astype(jnp.float32), trans=trans)
    o_ref[0] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("trans", "interpret"))
def solve_panel_pallas(l_kk: jnp.ndarray, b_panel: jnp.ndarray,
                       trans: bool = False,
                       interpret: bool = True) -> jnp.ndarray:
    """Batched multi-RHS panel solve, broadcasting L over leading dims of B."""
    t, k = b_panel.shape[-2], b_panel.shape[-1]
    batch_shape = b_panel.shape[:-2]
    b3 = b_panel.reshape((-1, t, k))
    nb = b3.shape[0]
    l3 = jnp.broadcast_to(l_kk, (nb, t, t)) if l_kk.ndim == 2 \
        else l_kk.reshape((-1, t, t))
    out = pl.pallas_call(
        functools.partial(_solve_panel_kernel, trans=trans),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, t, t), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, t, k), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, t, k), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, t, k), b_panel.dtype),
        interpret=interpret,
    )(l3, b3)
    return out.reshape(batch_shape + (t, k))
