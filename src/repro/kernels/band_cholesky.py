"""Pallas TPU kernel: the entire banded-arrowhead Cholesky in one launch.

After the solve sweeps were fused (``band_solve.py``), the factorization
itself was the last per-panel dispatcher: the ring sweep in
``core/cholesky.py`` ran one ``potrf`` + ``trsm`` + ``band_update`` launch
per band panel through a ``lax.scan``, round-tripping the (bt+1, t, t)
panel ring and the arrow ring through HBM on every step.  This kernel is
the factorization analogue of the fused solves — the whole band + arrow
factorization as one sequential-grid launch, in the spirit of tiled
Cholesky's "keep the active window resident" insight (Buttari et al.) and
the paper's left-looking accumulator reading of GEMM chains (§II):

* grid = (ndt,) — one sequential step per band *column* panel k; the TPU
  grid iteration order carries the factorization's critical path;
* a VMEM ring of the last ``bt`` finalized column panels plus an
  arrow-row ring (``kernels/ring.py``, shared with the solve and selinv
  sweeps) feeds the left-looking update

      U[e] = sum_{j=1..bt} L[k+e, k-j] @ L[k, k-j]^T

  entirely from VMEM — the ``band_update`` contraction with no HBM reads;
* the diagonal tile factorizes in-kernel (:func:`potrf.factorize_tile`,
  shared with the single-tile POTRF kernel) and the whole sub-diagonal
  panel + arrow rows substitute in one batched
  :func:`trsm.substitute_right` call (shared with the TRSM kernel);
* the corner Schur complement rides the sweep: partial sums
  ``sum_k L_a[k] L_a[k]^T`` accumulate in a VMEM scratch and emit once
  per chunk, so the corner factorization reads a precomputed
  (nchunks, nat, nat, t, t) buffer instead of re-contracting the whole
  arrow block from HBM (and the chunked layout preserves the paper's
  Alg. 3 tree-reduction association).

VMEM budget per step: the panel ring bt·(bt+1)·t², the arrow ring
bt·nat·t², the Schur accumulator nat²·t² and the (bt+1+nat)·t² in/out
blocks — e.g. bt=8, t=128, nat=2: ~6.1 MB, under the ~16 MB/core of v5e.

Matches ``ref.band_cholesky_sweep_ref`` (the lax.scan oracle) to fp32
tolerance; ``kernels.ops.band_cholesky_sweep`` dispatches between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .potrf import factorize_tile
from .ring import chunk_layout, identity_prefix_panel, ring_read, ring_write
from .trsm import substitute_right

__all__ = ["band_cholesky_sweep_pallas", "band_cholesky_partitioned_sweep_pallas"]


def _band_cholesky_kernel(start_ref, ac_ref, r_ref, p_ref, ro_ref, sch_ref,
                          st_ref, ring_ref, ringa_ref, sacc_ref,
                          *, bt: int, nat_p: int, csz: int):
    k = pl.program_id(0)
    start = start_ref[0]
    t = ac_ref.shape[-1]

    @pl.when(k == 0)
    def _init():
        ring_ref[...] = jnp.zeros_like(ring_ref)
        ringa_ref[...] = jnp.zeros_like(ringa_ref)
        # breakdown status carry [min_pivot, nonfinite, first_bad]: the
        # (1, 3) output block's index map is constant, so it stays VMEM
        # resident across the sequential grid and doubles as the carry
        st_ref[0, 0] = jnp.float32(jnp.inf)
        st_ref[0, 1] = jnp.float32(0.0)
        st_ref[0, 2] = jnp.float32(-1.0)

    @pl.when(jax.lax.rem(k, csz) == 0)
    def _chunk_init():
        sacc_ref[...] = jnp.zeros_like(sacc_ref)

    # Canonical-grid fast start (core/gridpolicy.py): columns k < start
    # are the identity-embedding prefix, whose factor is known — an
    # identity panel with zero arrow rows — so the whole update/potrf/trsm
    # body is skipped.  The prefix forms a contiguous head of the walk and
    # its ring slots keep the step-0 zeros; later columns read rhs_j =
    # panel_{k-j}[j], an off-diagonal slot that is zero for identity
    # panels, so skipping the ring writes is exact.
    @pl.when(k < start)
    def _skip():
        p_ref[0] = identity_prefix_panel(bt, t).astype(p_ref.dtype)
        ro_ref[0] = jnp.zeros_like(ro_ref[0])
        sch_ref[0] = sacc_ref[...].astype(sch_ref.dtype)
        # identity panel: pivot 1, finite — same fold ref.sweep_status
        # applies to the emitted identity column
        st_ref[0, 0] = jnp.minimum(st_ref[0, 0], jnp.float32(1.0))

    @pl.when(k >= start)
    def _work():
        # The last bt finalized column panels from the VMEM rings (zeros
        # for k-j < 0 from the step-0 init).  bt is small and static, so
        # the j/e loops unroll — every pair is one MXU matmul with no
        # gather/masking.
        prev = [ring_read(ring_ref, k - j, bt) for j in range(1, bt + 1)]
        preva = [ring_read(ringa_ref, k - j, bt) for j in range(1, bt + 1)]
        # rhs_j = L[k, k-j] = panel_{k-j}[j]
        rhs = [prev[j - 1][j] for j in range(1, bt + 1)]

        # left-looking band update: U[e] = sum_j L[k+e, k-j] @ L[k, k-j]^T
        # (e = 0 is the SYRK chain, e > 0 the GEMM chains; e+j > bt pairs
        # are structurally outside the band)
        u = []
        for e in range(bt + 1):
            acc = jnp.zeros((t, t), jnp.float32)
            for j in range(1, bt + 1 - e):
                acc = acc + jax.lax.dot_general(
                    prev[j - 1][e + j], rhs[j - 1], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
            u.append(acc)

        # arrow-row update: V[i] = sum_j L[ndt+i, k-j] @ L[k, k-j]^T
        va = jnp.zeros((nat_p, t, t), jnp.float32)
        for j in range(1, bt + 1):
            va = va + jax.lax.dot_general(
                preva[j - 1], rhs[j - 1], (((2,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

        # diagonal tile, then the whole sub-diagonal panel + arrow rows in
        # one batched right-substitution against the fresh L_kk
        lkk = factorize_tile(ac_ref[0, 0].astype(jnp.float32) - u[0])
        band_rhs = [ac_ref[0, e].astype(jnp.float32) - u[e]
                    for e in range(1, bt + 1)]
        arrow_rhs = r_ref[0].astype(jnp.float32) - va
        stack = jnp.concatenate([jnp.stack(band_rhs), arrow_rhs], axis=0) \
            if bt else arrow_rhs
        sol = substitute_right(lkk, stack)                # (bt+nat_p, t, t)
        panel = jnp.concatenate([lkk[None], sol[:bt]], axis=0)
        la = sol[bt:]

        if bt:
            ring_write(ring_ref, k, bt, panel)
            ring_write(ringa_ref, k, bt, la)

        # corner-Schur partial sums on the fly: sacc[i,j] += La[i] @ La[j]^T
        ss = jax.lax.dot_general(la, la, (((2,), (2,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sacc_ref[...] += jnp.transpose(ss, (0, 2, 1, 3))
        sch_ref[0] = sacc_ref[...].astype(sch_ref.dtype)

        p_ref[0] = panel.astype(p_ref.dtype)
        ro_ref[0] = la.astype(ro_ref.dtype)

        # in-sweep breakdown detection: fold this column into the status
        # carry — the same per-column update ``ref.sweep_status`` applies
        # to the emitted factor, so both backends report identical words.
        # Masked 2-D reductions only (no 1-D iota/vectors on TPU).
        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        dmask = rows == cols
        dsq = jnp.where(dmask, lkk * lkk, jnp.float32(jnp.inf))
        fin_d = jnp.all(jnp.isfinite(jnp.where(dmask, lkk, 0.0)))
        piv = jnp.where(fin_d, jnp.min(dsq), jnp.float32(jnp.inf))
        fin = jnp.all(jnp.isfinite(panel)) & jnp.all(jnp.isfinite(la))
        bad = jnp.logical_not(fin) | (piv <= 0.0)
        st_ref[0, 0] = jnp.minimum(st_ref[0, 0], piv)
        st_ref[0, 1] = jnp.maximum(st_ref[0, 1], jnp.where(fin, 0.0, 1.0))
        st_ref[0, 2] = jnp.where((st_ref[0, 2] < 0.0) & bad,
                                 k.astype(jnp.float32), st_ref[0, 2])


@functools.partial(jax.jit, static_argnames=("nchunks", "interpret"))
def band_cholesky_sweep_pallas(Ac, R, nchunks: int = 1, start_tile=0,
                               interpret: bool = True):
    """Fused band+arrow Cholesky sweep.  Ac: (ndt, bt+1, t, t) column-band
    tiles (``Ac[k, e] = A[k+e, k]``, see ``ring.band_row_to_col``), R:
    (ndt, nat, t, t) arrow rows ->

      panels (ndt, bt+1, t, t)      column panels of L: panels[k, e] = L[k+e, k]
      R_out  (ndt, nat, t, t)       factored arrow rows L[ndt+i, k]
      schur  (nch, nat, nat, t, t)  per-chunk partial sums of R_out·R_outᵀ
                                    (``nch = chunk_layout(ndt, nchunks)[1]``)
      status (3,) float32           breakdown word [min_pivot, nonfinite,
                                    first_bad] accumulated *in-kernel* as
                                    the sweep runs (a VMEM-resident carry —
                                    no extra HBM pass, no host sync);
                                    matches ``ref.sweep_status`` exactly

    ``start_tile`` (traced SMEM scalar) declares columns ``k < start_tile``
    an identity-embedding prefix (``core/gridpolicy.py``): they emit
    identity panels / zero arrow rows without any update, potrf or trsm
    work, so canonical-grid diagonal slack costs ~0 compute.

    Matches ``ref.band_cholesky_sweep_ref`` to fp32 tolerance.
    """
    ndt, b1, t, _ = Ac.shape
    bt = b1 - 1
    nat = R.shape[1]
    csz, nch = chunk_layout(ndt, nchunks)
    if ndt == 0:
        from .ref import empty_sweep_status
        return (jnp.zeros((0, b1, t, t), Ac.dtype),
                jnp.zeros((0, nat, t, t), Ac.dtype),
                jnp.zeros((nch, nat, nat, t, t), Ac.dtype),
                empty_sweep_status())
    # zero-width arrow blocks break BlockSpecs: pad to one all-zero arrow
    # tile row (its factor and Schur terms vanish) and slice the output back.
    nat_p = max(nat, 1)
    rp = R if nat else jnp.zeros((ndt, 1, t, t), Ac.dtype)
    start = jnp.reshape(jnp.asarray(start_tile, jnp.int32), (1,))
    panels, ro, schur, st = pl.pallas_call(
        functools.partial(_band_cholesky_kernel, bt=bt, nat_p=nat_p, csz=csz),
        grid=(ndt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b1, t, t), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda k: (k, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b1, t, t), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, nat_p, t, t),
                         lambda k: (k // csz, 0, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ndt, b1, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((ndt, nat_p, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((nch, nat_p, nat_p, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((1, 3), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(bt, 1), b1, t, t), jnp.float32),
            pltpu.VMEM((max(bt, 1), nat_p, t, t), jnp.float32),
            pltpu.VMEM((nat_p, nat_p, t, t), jnp.float32),
        ],
        interpret=interpret,
    )(start, Ac, rp)
    return panels, ro[:, :nat], schur[:, :nat, :nat], st[0]


def _band_cholesky_partitioned_kernel(bounds_ref, start_ref, ac_ref, r_ref,
                                      p_ref, ro_ref, sch_ref, st_ref,
                                      ring_ref, ringa_ref, sacc_ref,
                                      *, bt: int, nat_p: int):
    p = pl.program_id(0)
    k = pl.program_id(1)                       # local step within partition p
    s0 = bounds_ref[p]
    size = bounds_ref[p + 1] - s0
    g = s0 + k                                 # global column index
    start = start_ref[0]
    active = k < size
    t = ac_ref.shape[-1]

    @pl.when(k == 0)
    def _init():
        # fresh partition: its rings, Schur accumulator and per-partition
        # status word all reset — partitions share no state, which is what
        # lets the leading grid axis carry "parallel" semantics
        ring_ref[...] = jnp.zeros_like(ring_ref)
        ringa_ref[...] = jnp.zeros_like(ringa_ref)
        sacc_ref[...] = jnp.zeros_like(sacc_ref)
        st_ref[0, 0] = jnp.float32(jnp.inf)
        st_ref[0, 1] = jnp.float32(0.0)
        st_ref[0, 2] = jnp.float32(-1.0)

    # Steps k >= size are padding of the rectangular (P, max_tiles) grid:
    # they touch nothing — the clamped index maps revisit the partition's
    # last blocks, which persist unchanged.
    @pl.when(active & (g < start))
    def _skip():
        # canonical-grid identity prefix (contiguous global head, so within
        # a partition the skips precede all work steps) — same contract as
        # the unpartitioned kernel
        p_ref[0] = identity_prefix_panel(bt, t).astype(p_ref.dtype)
        ro_ref[0] = jnp.zeros_like(ro_ref[0])
        sch_ref[0] = sacc_ref[...].astype(sch_ref.dtype)
        st_ref[0, 0] = jnp.minimum(st_ref[0, 0], jnp.float32(1.0))

    @pl.when(active & (g >= start))
    def _work():
        # identical left-looking step to _band_cholesky_kernel, with the
        # *local* index k driving the rings (panel k-j of this partition;
        # k-j < 0 reads the step-0 zeros, exactly the cross-boundary
        # zeros block-separability guarantees)
        prev = [ring_read(ring_ref, k - j, bt) for j in range(1, bt + 1)]
        preva = [ring_read(ringa_ref, k - j, bt) for j in range(1, bt + 1)]
        rhs = [prev[j - 1][j] for j in range(1, bt + 1)]

        u = []
        for e in range(bt + 1):
            acc = jnp.zeros((t, t), jnp.float32)
            for j in range(1, bt + 1 - e):
                acc = acc + jax.lax.dot_general(
                    prev[j - 1][e + j], rhs[j - 1], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
            u.append(acc)

        va = jnp.zeros((nat_p, t, t), jnp.float32)
        for j in range(1, bt + 1):
            va = va + jax.lax.dot_general(
                preva[j - 1], rhs[j - 1], (((2,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

        lkk = factorize_tile(ac_ref[0, 0].astype(jnp.float32) - u[0])
        band_rhs = [ac_ref[0, e].astype(jnp.float32) - u[e]
                    for e in range(1, bt + 1)]
        arrow_rhs = r_ref[0].astype(jnp.float32) - va
        stack = jnp.concatenate([jnp.stack(band_rhs), arrow_rhs], axis=0) \
            if bt else arrow_rhs
        sol = substitute_right(lkk, stack)
        panel = jnp.concatenate([lkk[None], sol[:bt]], axis=0)
        la = sol[bt:]

        if bt:
            ring_write(ring_ref, k, bt, panel)
            ring_write(ringa_ref, k, bt, la)

        # one Schur chunk per partition: the tree-reduction leaf this
        # partition contributes to the shared corner factorization
        ss = jax.lax.dot_general(la, la, (((2,), (2,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sacc_ref[...] += jnp.transpose(ss, (0, 2, 1, 3))
        sch_ref[0] = sacc_ref[...].astype(sch_ref.dtype)

        p_ref[0] = panel.astype(p_ref.dtype)
        ro_ref[0] = la.astype(ro_ref.dtype)

        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        dmask = rows == cols
        dsq = jnp.where(dmask, lkk * lkk, jnp.float32(jnp.inf))
        fin_d = jnp.all(jnp.isfinite(jnp.where(dmask, lkk, 0.0)))
        piv = jnp.where(fin_d, jnp.min(dsq), jnp.float32(jnp.inf))
        fin = jnp.all(jnp.isfinite(panel)) & jnp.all(jnp.isfinite(la))
        bad = jnp.logical_not(fin) | (piv <= 0.0)
        st_ref[0, 0] = jnp.minimum(st_ref[0, 0], piv)
        st_ref[0, 1] = jnp.maximum(st_ref[0, 1], jnp.where(fin, 0.0, 1.0))
        # first_bad is recorded in *global* columns, so the per-partition
        # words fold with ref.combine_sweep_status directly
        st_ref[0, 2] = jnp.where((st_ref[0, 2] < 0.0) & bad,
                                 g.astype(jnp.float32), st_ref[0, 2])


@functools.partial(jax.jit, static_argnames=("boundaries", "interpret"))
def band_cholesky_partitioned_sweep_pallas(Ac, R, boundaries, start_tile=0,
                                           interpret: bool = True):
    """Partition-parallel fused band+arrow Cholesky: one launch over all
    ND partitions.

    Same input layout as :func:`band_cholesky_sweep_pallas`, plus the
    static ``boundaries`` tuple ``(0, c_1, ..., ndt)`` of a
    :class:`~repro.core.ordering.PartitionPlan` certifying that no band
    tile crosses a cut (block-separable input — the adaptive-ND ordering's
    independent partitions).  The grid becomes 2D:

      grid = (P, max_tiles) — the leading axis walks partitions with
      ``parallel`` dimension semantics (partitions share no state: rings,
      Schur accumulator and status word all reset at each partition's step
      0), the trailing axis is the per-partition sequential factorization.
      The critical path drops from O(ndt) sequential steps to
      O(max partition tiles).

    Partition sizes are ragged; the rectangular grid is padded and the
    per-column index maps clamp to the partition's last tile, where the
    padding steps are pure no-ops.  ``boundaries`` rides scalar prefetch
    (`pltpu.PrefetchScalarGridSpec`) so the index maps can look the
    partition's tile range up dynamically.

    Output layout matches ``ref.band_cholesky_partitioned_sweep_ref``:
    panels/R_out as usual, ``schur (P, nat, nat, t, t)`` with one
    tree-reduction leaf per partition, and the global (3,) status word.
    """
    from .ref import combine_sweep_status, empty_sweep_status

    ndt, b1, t, _ = Ac.shape
    bt = b1 - 1
    nat = R.shape[1]
    bounds = tuple(int(b) for b in boundaries)
    if len(bounds) < 2 or bounds[0] != 0 or bounds[-1] != ndt or \
            any(b1_ <= b0_ for b0_, b1_ in zip(bounds, bounds[1:])):
        raise ValueError(
            f"boundaries {bounds!r} must be strictly increasing from 0 "
            f"to ndt={ndt}")
    P = len(bounds) - 1
    maxk = max(b1_ - b0_ for b0_, b1_ in zip(bounds, bounds[1:]))
    if ndt == 0:
        return (jnp.zeros((0, b1, t, t), Ac.dtype),
                jnp.zeros((0, nat, t, t), Ac.dtype),
                jnp.zeros((P, nat, nat, t, t), Ac.dtype),
                empty_sweep_status())
    nat_p = max(nat, 1)
    rp = R if nat else jnp.zeros((ndt, 1, t, t), Ac.dtype)
    bounds_arr = jnp.asarray(bounds, jnp.int32)
    start = jnp.reshape(jnp.asarray(start_tile, jnp.int32), (1,))

    def col(p, k, bounds_ref, start_ref):
        # partition p's column s0+k, clamped to its last tile for padding
        return (jnp.minimum(bounds_ref[p] + k, bounds_ref[p + 1] - 1),
                0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P, maxk),
        in_specs=[
            pl.BlockSpec((1, b1, t, t), col),
            pl.BlockSpec((1, nat_p, t, t), col),
        ],
        out_specs=[
            pl.BlockSpec((1, b1, t, t), col),
            pl.BlockSpec((1, nat_p, t, t), col),
            pl.BlockSpec((1, nat_p, nat_p, t, t),
                         lambda p, k, b, s: (p, 0, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda p, k, b, s: (p, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(bt, 1), b1, t, t), jnp.float32),
            pltpu.VMEM((max(bt, 1), nat_p, t, t), jnp.float32),
            pltpu.VMEM((nat_p, nat_p, t, t), jnp.float32),
        ],
    )
    panels, ro, schur, st = pl.pallas_call(
        functools.partial(_band_cholesky_partitioned_kernel,
                          bt=bt, nat_p=nat_p),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((ndt, b1, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((ndt, nat_p, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((P, nat_p, nat_p, t, t), Ac.dtype),
            jax.ShapeDtypeStruct((P, 3), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(bounds_arr, start, Ac, rp)
    return (panels, ro[:, :nat], schur[:, :nat, :nat],
            combine_sweep_status(st))
