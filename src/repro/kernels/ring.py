"""Shared VMEM-ring machinery for the sequential-sweep Pallas kernels.

Every fused sweep in this repo — the band-solve forward/backward sweeps
(``band_solve.py``), the whole-factorization band-Cholesky sweep
(``band_cholesky.py``) and the fused selinv Takahashi sweep
(``selinv.py``) — follows the same discipline: a sequential ``(ndt,)``
grid walks tile rows/columns in dependence order while a *ring* of the
last ``band_tiles`` finalized panels stays resident in VMEM scratch, so
the bounded-history recurrence

    out[row] = f(inputs[row], out[row - 1], ..., out[row - depth])

never round-trips recent panels through HBM.  This module is the single
home of that ring index math (plus the row-band <-> column-band layout
converters every sweep wrapper needs), so the kernels share one
implementation instead of copy-pasting modular arithmetic.

In-kernel helpers (operate on VMEM scratch refs):
  :func:`ring_read` / :func:`ring_write` — modular slot addressing.
  :func:`ring_accumulate` — the j = 1..depth accumulation loop over ring
  entries that forms each sweep's bounded-history contraction.

Host-side helpers (plain jnp, used by the kernel wrappers and the ref
oracles):
  :func:`band_row_to_col` / :func:`band_col_to_row` — the shifted-gather
  between row-band storage (``Dr[m, d] = T[m, m-d]``, what ``BandedCTSF``
  stores) and column-band panels (``P[k, e] = T[k+e, k]``, what the
  column-walking sweeps consume/emit).
  :func:`chunk_layout` — the (chunk size, chunk count) split used by the
  band-Cholesky sweep's on-the-fly corner-Schur partial sums.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ring_read", "ring_write", "ring_accumulate",
           "band_row_to_col", "band_col_to_row", "chunk_layout",
           "eye_tile", "identity_prefix_panel"]


def eye_tile(t: int, dtype=jnp.float32) -> jnp.ndarray:
    """A (t, t) identity tile built from 2-D iotas — safe inside Pallas
    TPU kernels (where 1-D iota does not lower) and identical to
    ``jnp.eye`` everywhere else."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return jnp.where(rows == cols, 1.0, 0.0).astype(dtype)


def identity_prefix_panel(bt: int, t: int, dtype=jnp.float32) -> jnp.ndarray:
    """The (bt+1, t, t) column panel an identity-embedding prefix column
    contributes to every sweep (``core/gridpolicy.py``): the identity at
    offset 0, zeros below.  Single definition shared by the fused kernels'
    ``start_tile`` skip branches and the ref oracles' masked scans, so the
    prefix contract cannot drift between backends."""
    eye = eye_tile(t, dtype)
    if not bt:
        return eye[None]
    return jnp.concatenate([eye[None], jnp.zeros((bt, t, t), dtype)], axis=0)


# ---------------------------------------------------------------------------
# In-kernel ring-scratch helpers
# ---------------------------------------------------------------------------

def ring_read(ring_ref, row, depth: int):
    """Read the panel for absolute row index ``row`` from a depth-``depth``
    VMEM ring.  Valid for ``row >= -depth`` (the modular shift keeps the
    slot index nonnegative); slots for rows the sweep has not visited hold
    the zero panels written by the ``step == 0`` initialization."""
    return ring_ref[jax.lax.rem(row + depth, depth)]


def ring_write(ring_ref, row, depth: int, panel):
    """Store ``panel`` as absolute row ``row`` in the ring, overwriting the
    entry ``depth`` rows back (which no later step can need)."""
    ring_ref[jax.lax.rem(row + depth, depth)] = panel


def ring_accumulate(ring_ref, row, depth: int, init, term, step: int = -1):
    """The bounded-history accumulation every sweep kernel performs:

        init + sum_{j=1..depth} term(j, ring[row + step*j])

    ``term(j, panel)`` maps the ring entry ``step*j`` rows away (``step=-1``
    for forward sweeps, ``+1`` for backward sweeps) to its contribution —
    typically one MXU ``dot_general`` against a factor tile.  ``depth == 0``
    returns ``init`` unchanged (single-tile band); unvisited rows contribute
    the ring's zero-initialized panels, so callers need no masking beyond
    structural zeros in their inputs."""
    if not depth:
        return init

    def jstep(j, acc):
        return acc + term(j, ring_read(ring_ref, row + step * j, depth))

    return jax.lax.fori_loop(1, depth + 1, jstep, init)


# ---------------------------------------------------------------------------
# Host-side band-layout converters (shared by sweep wrappers and ref oracles)
# ---------------------------------------------------------------------------

def band_row_to_col(Dr: jnp.ndarray) -> jnp.ndarray:
    """Row-band storage -> column-band panels.

    Input ``Dr (ndt, bt+1, t, t)`` with ``Dr[m, d] = T[m, m-d]`` (zero for
    ``d > m``); output ``P (ndt, bt+1, t, t)`` with ``P[k, e] = T[k+e, k]``
    (zero for ``k+e >= ndt``, from the pad slack).  The gather is a cheap
    O(ndt·bt·t²) copy next to the O(ndt·bt·t³) sweeps that consume it."""
    ndt, b1 = Dr.shape[:2]
    bt = b1 - 1
    drp = jnp.pad(Dr, ((0, bt), (0, 0), (0, 0), (0, 0)))
    kk, ee = jnp.meshgrid(jnp.arange(ndt), jnp.arange(b1), indexing="ij")
    return drp[kk + ee, ee]


def band_col_to_row(panels: jnp.ndarray) -> jnp.ndarray:
    """Column-band panels -> row-band storage (inverse of
    :func:`band_row_to_col`): ``Dr[m, d] = P[m-d, d]``, zero where
    ``m - d < 0`` (above the diagonal)."""
    ndt, b1 = panels.shape[:2]
    mm, dd = jnp.meshgrid(jnp.arange(ndt), jnp.arange(b1), indexing="ij")
    return jnp.where(((mm - dd) >= 0)[:, :, None, None],
                     panels[jnp.clip(mm - dd, 0, max(ndt - 1, 0)), dd], 0.0)


def chunk_layout(n: int, nchunks: int) -> Tuple[int, int]:
    """Split ``n`` sweep steps into ``<= nchunks`` contiguous chunks:
    returns ``(chunk_size, actual_chunks)``.  Both the fused kernel's
    per-chunk Schur emission and the ref oracle's chunked einsum use this,
    so their output shapes agree by construction."""
    if n <= 0:
        return 1, 1
    csz = math.ceil(n / max(nchunks, 1))
    return csz, math.ceil(n / csz)
