"""Pallas TPU kernels: fused multi-RHS band-solve sweeps (forward/backward).

The post-factorization triangular sweeps are the serving hot path (every
INLA evaluation runs one forward + one backward sweep per factorization).
Driven tile-at-a-time — one ``kernels.ops.solve_panel`` launch per band tile
through a ``lax.fori_loop`` — they are latency-bound: each step round-trips
its (t, k) panel through HBM before the next step may start (cf. Ruipeng
Li's analysis of GPU sparse triangular solves).  These kernels instead
execute an *entire* band sweep in one launch, the solve-phase analogue of
``band_update``'s fused factorization window:

* grid = (ndt,) — one sequential grid step per band tile row; TPU grid
  iteration order makes the recurrence dependence explicit and legal;
* a ring of the last ``bt`` solved (t, k) panels lives in VMEM scratch
  (``kernels/ring.py`` — the ring discipline shared with the fused
  band-Cholesky and selinv sweeps), so the ``L[m, m-j] @ Y_{m-j}``
  (t, t) @ (t, k) MXU accumulations never touch HBM;
* the per-tile triangular solve is :func:`kernels.trsm.substitute_panel`,
  shared with the ``solve_panel`` kernel;
* forward only: the arrow-row contributions ``sum_m R[m, i] @ Y_m`` are
  accumulated into a VMEM scratch as the sweep passes each row and emitted
  once at the end — the arrow RHS correction comes for free.

VMEM budget per step: (bt+1)·t² + (bt + 2·nat)·t·k floats — e.g. bt=8,
t=128, k=64, nat=2: ~1.1 MB, far under the ~16 MB/core of v5e.

``start_tile`` (forward) supports the RHS-sparsity path of
``marginal_variances(method="panels")``: it is a *traced* scalar (SMEM
input), steps with ``m < start_tile`` write zero panels, so varying
selections never recompile the sweep and the grid stays static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ring import band_row_to_col, ring_accumulate, ring_read, ring_write
from .trsm import substitute_panel

# ring_read/ring_write are re-exported for backward compatibility; the
# canonical home of the ring machinery is kernels/ring.py.
__all__ = ["band_forward_sweep_pallas", "band_backward_sweep_pallas",
           "ring_read", "ring_write"]


# ---------------------------------------------------------------------------
# Forward sweep: L Y = B over the band, + on-the-fly arrow accumulation
# ---------------------------------------------------------------------------

def _band_forward_kernel(start_ref, dr_ref, r_ref, b_ref, y_ref, acca_ref,
                         ring_ref, arr_ref, *, ndt: int, bt: int):
    m = pl.program_id(0)
    start = start_ref[0]
    t = dr_ref.shape[-1]
    k = b_ref.shape[-1]

    @pl.when(m == 0)
    def _init():
        ring_ref[...] = jnp.zeros_like(ring_ref)
        arr_ref[...] = jnp.zeros_like(arr_ref)

    # RHS-sparsity fast start: rows above start_tile are identically zero
    # (matching the fori_loop reference, which never visits them), so the
    # whole step body is skipped — masked steps form a contiguous prefix,
    # hence their ring slots still hold the step-0 zeros and contribute
    # nothing to later rows.
    @pl.when(m < start)
    def _skip():
        y_ref[0] = jnp.zeros_like(y_ref[0])

    @pl.when(m >= start)
    def _work():
        # acc = sum_{j=1..bt} L[m, m-j] @ Y_{m-j}; Dr[m, j] = L[m, m-j] is
        # structurally zero for j > m and ring slots for unvisited rows hold
        # zeros, so no masking is needed beyond the zero-init.
        acc = ring_accumulate(
            ring_ref, m, bt, jnp.zeros((t, k), jnp.float32),
            lambda j, yprev: jax.lax.dot_general(
                dr_ref[0, j].astype(jnp.float32), yprev,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32),
            step=-1)

        rhs = b_ref[0].astype(jnp.float32) - acc
        ym = substitute_panel(dr_ref[0, 0].astype(jnp.float32), rhs)
        y_ref[0] = ym.astype(y_ref.dtype)
        if bt:
            ring_write(ring_ref, m, bt, ym)

        # arrow rows ride the sweep: arr[i] += R[m, i] @ Y_m
        r = r_ref[0].astype(jnp.float32)                 # (nat_p, t, t)
        arr_ref[...] += jax.lax.dot_general(
            r, ym, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(m == ndt - 1)
    def _emit():
        acca_ref[...] = arr_ref[...].astype(acca_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def band_forward_sweep_pallas(Dr, R, bd, start_tile=0, interpret: bool = True):
    """Fused forward band sweep.  Dr: (ndt, bt+1, t, t) row-band factor
    tiles, R: (ndt, nat, t, t) arrow rows, bd: (ndt, t, k) RHS panel ->
    (yd (ndt, t, k), acc_a (nat, t, k)) with ``L Y = B`` on the band and
    ``acc_a[i] = sum_m R[m, i] @ Y_m`` (the arrow-RHS correction).

    Matches ``ref.band_forward_sweep_ref`` to fp32 tolerance.
    """
    ndt, b1, t, _ = Dr.shape
    bt = b1 - 1
    nat = R.shape[1]
    k = bd.shape[-1]
    if ndt == 0 or k == 0:
        return (jnp.zeros((ndt, t, k), bd.dtype),
                jnp.zeros((nat, t, k), bd.dtype))
    # zero-width arrow blocks break BlockSpecs: pad to one all-zero arrow
    # tile row (its contribution vanishes) and slice the output back.
    nat_p = max(nat, 1)
    rp = R if nat else jnp.zeros((ndt, 1, t, t), Dr.dtype)
    start = jnp.reshape(jnp.asarray(start_tile, jnp.int32), (1,))
    yd, acca = pl.pallas_call(
        functools.partial(_band_forward_kernel, ndt=ndt, bt=bt),
        grid=(ndt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b1, t, t), lambda m: (m, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda m: (m, 0, 0, 0)),
            pl.BlockSpec((1, t, k), lambda m: (m, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, k), lambda m: (m, 0, 0)),
            pl.BlockSpec((nat_p, t, k), lambda m: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ndt, t, k), bd.dtype),
            jax.ShapeDtypeStruct((nat_p, t, k), bd.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((max(bt, 1), t, k), jnp.float32),
                        pltpu.VMEM((nat_p, t, k), jnp.float32)],
        interpret=interpret,
    )(start, Dr, rp, bd)
    return yd, acca[:nat]


# ---------------------------------------------------------------------------
# Backward sweep: L^T X = Y over the band, arrow term folded in per step
# ---------------------------------------------------------------------------

def _band_backward_kernel(start_ref, lcol_ref, r_ref, y_ref, xa_ref, x_ref,
                          ring_ref, *, ndt: int, bt: int):
    s = pl.program_id(0)
    m = ndt - 1 - s
    start = start_ref[0]
    t = lcol_ref.shape[-1]
    k = y_ref.shape[-1]

    @pl.when(s == 0)
    def _init():
        ring_ref[...] = jnp.zeros_like(ring_ref)

    # Canonical-grid fast finish (the mirror of the forward sweep's fast
    # start): rows below start_tile are the identity-embedding prefix with
    # zero RHS, decoupled from the rest — they solve to zero, and since
    # they form a contiguous suffix of this reverse walk nothing reads
    # them afterwards, so the whole step body is skipped.
    @pl.when(m < start)
    def _skip():
        x_ref[0] = jnp.zeros_like(x_ref[0])

    @pl.when(m >= start)
    def _work():
        # acc = sum_{j=1..bt} L[m+j, m]^T @ X_{m+j}; lcol[m, j] = L[m+j, m]
        # is zero-padded past ndt and unvisited ring slots hold zeros.
        acc = ring_accumulate(
            ring_ref, m, bt, jnp.zeros((t, k), jnp.float32),
            lambda j, xnext: jax.lax.dot_general(
                lcol_ref[0, j].astype(jnp.float32), xnext,
                (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32),
            step=1)

        # arrow term: sum_i R[m, i]^T @ Xa_i (contract arrow tile + row dims)
        r = r_ref[0].astype(jnp.float32)                 # (nat_p, t, t)
        xa = xa_ref[...].astype(jnp.float32)             # (nat_p, t, k)
        acc2 = acc + jax.lax.dot_general(
            r, xa, (((0, 1), (0, 1)), ((), ())),
            preferred_element_type=jnp.float32)

        rhs = y_ref[0].astype(jnp.float32) - acc2
        xm = substitute_panel(lcol_ref[0, 0].astype(jnp.float32), rhs,
                              trans=True)
        x_ref[0] = xm.astype(x_ref.dtype)
        if bt:
            ring_write(ring_ref, m, bt, xm)


@functools.partial(jax.jit, static_argnames=("interpret",))
def band_backward_sweep_pallas(Dr, R, yd, xa, start_tile=0,
                               interpret: bool = True):
    """Fused backward band sweep.  Dr: (ndt, bt+1, t, t), R: (ndt, nat, t, t),
    yd: (ndt, t, k) forward-solved panel, xa: (nat, t, k) already-solved
    arrow panel -> xd (ndt, t, k) with ``L^T X = Y - R^T Xa`` on the band.

    ``start_tile`` (traced SMEM scalar, like the forward sweep's) skips
    rows ``m < start_tile`` — the identity prefix of a canonical-grid
    embedding — leaving X identically zero there.

    Matches ``ref.band_backward_sweep_ref`` to fp32 tolerance.
    """
    ndt, b1, t, _ = Dr.shape
    bt = b1 - 1
    nat = R.shape[1]
    k = yd.shape[-1]
    if ndt == 0 or k == 0:
        return jnp.zeros((ndt, t, k), yd.dtype)
    # column view of the factor: lcol[m, j] = Dr[m+j, j] = L[m+j, m]
    # (cheap O(ndt·bt·t²) gather; the contraction is O(ndt·bt·t²·k))
    lcol = band_row_to_col(Dr)
    nat_p = max(nat, 1)
    rp = R if nat else jnp.zeros((ndt, 1, t, t), Dr.dtype)
    xap = xa if nat else jnp.zeros((1, t, k), yd.dtype)
    start = jnp.reshape(jnp.asarray(start_tile, jnp.int32), (1,))
    return pl.pallas_call(
        functools.partial(_band_backward_kernel, ndt=ndt, bt=bt),
        grid=(ndt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b1, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
            pl.BlockSpec((1, nat_p, t, t), lambda s: (ndt - 1 - s, 0, 0, 0)),
            pl.BlockSpec((1, t, k), lambda s: (ndt - 1 - s, 0, 0)),
            pl.BlockSpec((nat_p, t, k), lambda s: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, k), lambda s: (ndt - 1 - s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ndt, t, k), yd.dtype),
        scratch_shapes=[pltpu.VMEM((max(bt, 1), t, k), jnp.float32)],
        interpret=interpret,
    )(start, lcol, rp, yd, xap)
