"""Pure-jnp reference oracles for every tile kernel.

These define the exact semantics the Pallas kernels must match
(``tests/test_kernels_*`` sweeps shapes/dtypes and asserts allclose).
All operate on single dense (t, t) tiles in the lower-triangular Cholesky
convention of Algorithm 1 (see core/symbolic.py Task docstring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["potrf_ref", "trsm_ref", "solve_panel_ref", "syrk_ref",
           "gemm_ref", "geadd_ref", "band_update_ref", "selinv_step_ref"]

_HI = jax.lax.Precision.HIGHEST


def potrf_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Cholesky of one diagonal tile: L lower with A = L L^T."""
    return jnp.linalg.cholesky(a)


def trsm_ref(l_kk: jnp.ndarray, a_mk: jnp.ndarray) -> jnp.ndarray:
    """Off-diagonal panel solve: returns L_mk = A_mk L_kk^{-T}.

    (X L^T = A  <=>  L X^T = A^T, lower forward substitution.)
    """
    xt = jax.scipy.linalg.solve_triangular(l_kk, a_mk.T, lower=True, trans=0)
    return xt.T


def solve_panel_ref(l_kk: jnp.ndarray, b_panel: jnp.ndarray,
                    trans: bool = False) -> jnp.ndarray:
    """Multi-RHS triangular panel solve: ``L X = B`` (or ``L^T X = B``).

    ``B`` is a (t, k) panel of k right-hand sides — one (t, t) @ (t, k)
    substitution sweep instead of k matvec sweeps, which is what makes the
    batched serving path matmul-bound.
    """
    return jax.scipy.linalg.solve_triangular(
        l_kk, b_panel, lower=True, trans=1 if trans else 0)


def syrk_ref(c_kk: jnp.ndarray, a_kn: jnp.ndarray) -> jnp.ndarray:
    """Symmetric rank-t update of a diagonal tile: C - A A^T."""
    return c_kk - jnp.dot(a_kn, a_kn.T, precision=_HI)


def gemm_ref(c_mk: jnp.ndarray, a_mn: jnp.ndarray, b_kn: jnp.ndarray) -> jnp.ndarray:
    """Off-diagonal accumulation: C - A B^T."""
    return c_mk - jnp.dot(a_mn, b_kn.T, precision=_HI)


def geadd_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Generalized addition (tree-reduction combine step, paper Fig. 6)."""
    return a + b


def selinv_step_ref(s_row: jnp.ndarray, g_col: jnp.ndarray) -> jnp.ndarray:
    """One Takahashi tile step: block row of Σ times normalized factor column.

    Input:  s_row (e_n, j_n, t, t) — already-computed Σ tiles Σ[i_e, k_j]
            g_col (j_n, t, t)      — normalized column G[k_j] = L[k_j, j] L[j,j]^{-1}
    Output: u (e_n, t, t) with

        u[e] = sum_j  s_row[e, j] @ g_col[j]

    so that Σ[i_e, j] = -u[e] (core/selinv.py's backward recurrence).  Every
    accumulation feeding one selected-inverse column rides this single
    batched contraction — the selected-inversion analogue of
    :func:`band_update_ref`.
    """
    return jnp.einsum("ejab,jbc->eac", s_row, g_col, precision=_HI)


def band_update_unrolled_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Loop-free band update for small bands: only the structurally nonzero
    (e, j) pairs are computed (no gather, no masked-zero FLOPs).

    For band b this is b·(b+1)/2 tile matmuls vs the masked einsum's b·(b+1)
    — a 2x FLOP cut that maps to 2x fewer MXU ops on TPU.  Preferred when
    b is small (the arrowhead regime); the einsum/Pallas path wins for wide
    bands where one big contraction amortizes better.
    """
    b1 = w.shape[0]
    b = b1 - 1
    t = w.shape[-1]
    outs = []
    for e in range(b1):
        acc = jnp.zeros((t, t), jnp.float32)
        for j in range(1, b1 - e):
            acc = acc + jnp.dot(w[e, e + j], w[0, j].T, precision=_HI)
        outs.append(acc.astype(w.dtype))
    return jnp.stack(outs)


def band_update_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Fused left-looking band-panel update (the `window` backend hot spot).

    Input:  w  (b+1, b+1, t, t) — band-window rows k..k+b of the row-band
            storage: w[e, d] = L_tile[k+e, k+e-d] (zero where out of band).
    Output: u  (b+1, t, t) with

        u[e] = sum_{j=1..b-e}  w[e, e+j] @ w[0, j]^T

    i.e. every SYRK (e=0) and GEMM (e>0) accumulation feeding panel k, in
    one batched contraction.  Entries with e+j > b contribute zero.
    """
    b1 = w.shape[0]
    b = b1 - 1
    # shifted gather: wsh[e, j] = w[e, e+j] (clamped; masked beyond band)
    e_idx = jnp.arange(b1)[:, None]
    j_idx = jnp.arange(b1)[None, :]
    gather = jnp.clip(e_idx + j_idx, 0, b)
    mask = ((e_idx + j_idx) <= b) & (j_idx >= 1)
    wsh = jnp.take_along_axis(w, gather[:, :, None, None], axis=1)
    wsh = jnp.where(mask[:, :, None, None], wsh, 0.0)
    rhs = jnp.where((j_idx[0] >= 1)[:, None, None], w[0], 0.0)
    return jnp.einsum("ejab,jcb->eac", wsh, rhs, precision=_HI)
