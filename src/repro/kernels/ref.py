"""Pure-jnp reference oracles for every tile kernel.

These define the exact semantics the Pallas kernels must match
(``tests/test_kernels_*`` sweeps shapes/dtypes and asserts allclose).
All operate on single dense (t, t) tiles in the lower-triangular Cholesky
convention of Algorithm 1 (see core/symbolic.py Task docstring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["potrf_ref", "trsm_ref", "solve_panel_ref", "syrk_ref",
           "gemm_ref", "geadd_ref", "band_update_ref", "selinv_step_ref",
           "band_forward_sweep_ref", "band_backward_sweep_ref",
           "band_cholesky_sweep_ref", "band_cholesky_partitioned_sweep_ref",
           "selinv_sweep_ref", "sweep_status", "empty_sweep_status",
           "combine_sweep_status"]

_HI = jax.lax.Precision.HIGHEST


def empty_sweep_status() -> jnp.ndarray:
    """The healthy/empty status word: ``[+inf, 0, -1]``."""
    return jnp.array([jnp.inf, 0.0, -1.0], jnp.float32)


def sweep_status(panels: jnp.ndarray, R_out: jnp.ndarray) -> jnp.ndarray:
    """Per-sweep breakdown status word, derived from the emitted factor.

    Input:  panels (ndt, b1, t, t) column panels (``panels[k, 0]`` the
            diagonal tile L_kk), R_out (ndt, nat, t, t) factored arrow rows.
    Output: (3,) float32 ``[min_pivot, nonfinite, first_bad]`` with

    * ``min_pivot`` — min over columns of ``min(diag(L_kk)^2)`` (the
      smallest Cholesky pivot), taken over columns whose diagonal is
      finite (+inf if none are);
    * ``nonfinite`` — 1.0 iff any emitted panel/arrow entry is NaN/inf;
    * ``first_bad`` — index of the first column whose output is
      non-finite or whose pivot is <= 0 (-1.0 when the sweep is clean).

    Deriving the word from the *emitted* factor (not the in-loop pivots of
    ``potrf.factorize_tile``) is what makes both kernel backends agree: the
    jnp scan's ``jnp.linalg.cholesky`` NaN-poisons on breakdown instead of
    yielding finite negative pivots, but the emitted tiles are the same
    story on both paths.  The fused Pallas kernel folds exactly this
    per-column update into a VMEM status carry as the sweep runs; this
    helper is the jnp oracle for it (and serves the post-hoc "window"
    legacy path, whose index is then a *row* index — NaNs propagate
    forward, so the first bad row and first bad column coincide).

    jit-safe, no host sync, vmap/batch friendly: all three entries are
    data-dependent scalars with static shapes.
    """
    ndt = panels.shape[0]
    if ndt == 0:
        return empty_sweep_status()
    t = panels.shape[-1]
    diag = jnp.diagonal(panels[:, 0], axis1=-2, axis2=-1)      # (ndt, t)
    fin_diag = jnp.all(jnp.isfinite(diag), axis=-1)            # (ndt,)
    piv = jnp.where(fin_diag, jnp.min(diag * diag, axis=-1), jnp.inf)
    fin = (jnp.all(jnp.isfinite(panels), axis=(1, 2, 3))
           & jnp.all(jnp.isfinite(R_out), axis=(1, 2, 3)))     # (ndt,)
    bad = ~fin | (piv <= 0.0)
    first = jnp.min(jnp.where(bad, jnp.arange(ndt), ndt))
    first = jnp.where(first == ndt, -1, first)
    return jnp.stack([jnp.min(piv),
                      jnp.max(jnp.where(fin, 0.0, 1.0)),
                      first.astype(jnp.float32)])


def potrf_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Cholesky of one diagonal tile: L lower with A = L L^T."""
    return jnp.linalg.cholesky(a)


def trsm_ref(l_kk: jnp.ndarray, a_mk: jnp.ndarray) -> jnp.ndarray:
    """Off-diagonal panel solve: returns L_mk = A_mk L_kk^{-T}.

    (X L^T = A  <=>  L X^T = A^T, lower forward substitution.)
    """
    xt = jax.scipy.linalg.solve_triangular(l_kk, a_mk.T, lower=True, trans=0)
    return xt.T


def solve_panel_ref(l_kk: jnp.ndarray, b_panel: jnp.ndarray,
                    trans: bool = False) -> jnp.ndarray:
    """Multi-RHS triangular panel solve: ``L X = B`` (or ``L^T X = B``).

    ``B`` is a (t, k) panel of k right-hand sides — one (t, t) @ (t, k)
    substitution sweep instead of k matvec sweeps, which is what makes the
    batched serving path matmul-bound.
    """
    return jax.scipy.linalg.solve_triangular(
        l_kk, b_panel, lower=True, trans=1 if trans else 0)


def syrk_ref(c_kk: jnp.ndarray, a_kn: jnp.ndarray) -> jnp.ndarray:
    """Symmetric rank-t update of a diagonal tile: C - A A^T."""
    return c_kk - jnp.dot(a_kn, a_kn.T, precision=_HI)


def gemm_ref(c_mk: jnp.ndarray, a_mn: jnp.ndarray, b_kn: jnp.ndarray) -> jnp.ndarray:
    """Off-diagonal accumulation: C - A B^T."""
    return c_mk - jnp.dot(a_mn, b_kn.T, precision=_HI)


def geadd_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Generalized addition (tree-reduction combine step, paper Fig. 6)."""
    return a + b


def selinv_step_ref(s_row: jnp.ndarray, g_col: jnp.ndarray) -> jnp.ndarray:
    """One Takahashi tile step: block row of Σ times normalized factor column.

    Input:  s_row (e_n, j_n, t, t) — already-computed Σ tiles Σ[i_e, k_j]
            g_col (j_n, t, t)      — normalized column G[k_j] = L[k_j, j] L[j,j]^{-1}
    Output: u (e_n, t, t) with

        u[e] = sum_j  s_row[e, j] @ g_col[j]

    so that Σ[i_e, j] = -u[e] (core/selinv.py's backward recurrence).  Every
    accumulation feeding one selected-inverse column rides this single
    batched contraction — the selected-inversion analogue of
    :func:`band_update_ref`.
    """
    return jnp.einsum("ejab,jbc->eac", s_row, g_col, precision=_HI)


def band_forward_sweep_ref(Dr: jnp.ndarray, R: jnp.ndarray, bd: jnp.ndarray,
                           start_tile=0):
    """Multi-RHS forward band sweep: solve ``L Y = B`` over the band rows,
    one ``solve_panel`` per tile row through a ``lax.fori_loop`` — the
    per-tile-looped semantics the fused Pallas sweep must match.

    Input:  Dr (ndt, bt+1, t, t) row-band factor tiles, Dr[m, j] = L[m, m-j]
            R  (ndt, nat, t, t)  arrow rows, R[m, i] = L[ndt+i, m]
            bd (ndt, t, k)       RHS tile panel
    Output: yd (ndt, t, k)       with L Y = B on the band
            acc_a (nat, t, k)    = sum_m R[m, i] @ Y_m  (arrow-RHS correction)

    ``start_tile`` may be a traced scalar (RHS-sparsity fast start): rows
    above it are left identically zero, and the loop becomes a dynamic-bound
    ``while_loop`` (not reverse-differentiable) only when it is nonzero.
    """
    ndt, b1, t, _ = Dr.shape
    bt = b1 - 1
    k = bd.shape[-1]
    yp = jnp.zeros((ndt + bt, t, k), bd.dtype)  # bt leading zeros

    def step(m, yp):
        # Y_m = Lmm^{-1} (B_m - sum_{j=1..bt} L[m,m-j] Y_{m-j})
        ywin = jax.lax.dynamic_slice(yp, (m, 0, 0), (bt, t, k)) if bt else yp[:0]
        # ywin[bt - j] = Y_{m-j}; Dr[m, j] = L[m, m-j]
        drm = jax.lax.dynamic_slice(Dr, (m, 0, 0, 0), (1, bt + 1, t, t))[0]
        acc = jnp.einsum("jab,jbk->ak", jnp.flip(drm[1:], axis=0), ywin,
                         precision=_HI) if bt else 0.0
        bm = jax.lax.dynamic_slice(bd, (m, 0, 0), (1, t, k))[0]
        ym = solve_panel_ref(drm[0], bm - acc)
        return jax.lax.dynamic_update_slice(yp, ym[None], (m + bt, 0, 0))

    yp = jax.lax.fori_loop(start_tile, ndt, step, yp) if ndt else yp
    yd = yp[bt:]
    acc_a = jnp.einsum("niab,nbk->iak", R, yd, precision=_HI)
    return yd, acc_a


def band_backward_sweep_ref(Dr: jnp.ndarray, R: jnp.ndarray, yd: jnp.ndarray,
                            xa: jnp.ndarray, start_tile=0) -> jnp.ndarray:
    """Multi-RHS backward band sweep: solve ``L^T X = Y - R^T Xa`` over the
    band rows in reverse, one ``solve_panel(trans=True)`` per tile row —
    the per-tile-looped reference for the fused Pallas backward sweep.

    Input:  Dr (ndt, bt+1, t, t), R (ndt, nat, t, t) as in the forward sweep
            yd (ndt, t, k)  forward-solved band panel
            xa (nat, t, k)  already-solved arrow panel
    Output: xd (ndt, t, k) with
            X_m = Lmm^{-T}(Y_m - sum_j L[m+j,m]^T X_{m+j} - sum_i R[m,i]^T Xa_i)

    ``start_tile`` mirrors the forward sweep's RHS-sparsity fast path for
    canonical-grid embeddings (``core/gridpolicy.py``): rows
    ``m < start_tile`` are an identity-diagonal prefix decoupled from the
    rest with zero RHS, so the sweep stops before them and leaves X zero
    there.  May be traced (the loop bound turns dynamic).
    """
    ndt, b1, t, _ = Dr.shape
    bt = b1 - 1
    nat = R.shape[1]
    k = yd.shape[-1]
    Drp = jnp.pad(Dr, ((0, bt), (0, 0), (0, 0), (0, 0)))  # slack for m+j reads
    xp = jnp.zeros((ndt + bt, t, k), yd.dtype)
    jr = jnp.arange(bt)

    def step(i, xp):
        m = ndt - 1 - i
        wb = jax.lax.dynamic_slice(Drp, (m + 1, 0, 0, 0), (bt, bt + 1, t, t)) \
            if bt else Drp[:0]
        # L[m+j, m] = Drp[m+j, j]  -> wb[j-1, j]
        sub = wb[jr, jr + 1] if bt else wb[:, 0]
        xwin = jax.lax.dynamic_slice(xp, (m + 1, 0, 0), (bt, t, k)) if bt else xp[:0]
        acc = jnp.einsum("jab,jak->bk", sub, xwin, precision=_HI) if bt else 0.0
        if nat:
            rm = jax.lax.dynamic_slice(R, (m, 0, 0, 0), (1, nat, t, t))[0]
            acc = acc + jnp.einsum("iab,iak->bk", rm, xa, precision=_HI)
        ym = jax.lax.dynamic_slice(yd, (m, 0, 0), (1, t, k))[0]
        lmm = jax.lax.dynamic_slice(Dr, (m, 0, 0, 0), (1, 1, t, t))[0, 0]
        xm = solve_panel_ref(lmm, ym - acc, trans=True)
        return jax.lax.dynamic_update_slice(xp, xm[None], (m, 0, 0))

    # the sweep walks m = ndt-1 .. start_tile; skipped prefix rows stay zero
    xp = jax.lax.fori_loop(0, ndt - start_tile, step, xp) if ndt else xp
    return xp[:ndt]


def band_cholesky_sweep_ref(Ac: jnp.ndarray, R: jnp.ndarray,
                            nchunks: int = 1, start_tile=0):
    """Whole band+arrow Cholesky sweep: the ring-buffer ``lax.scan``
    (originally ``core/cholesky.py``'s ring sweep) — the per-panel-looped
    semantics the fused Pallas sweep must match.

    Input:  Ac (ndt, bt+1, t, t) column-band tiles, Ac[k, e] = A[k+e, k]
            R  (ndt, nat, t, t)  arrow rows, R[k, i] = A[ndt+i, k]
    Output: panels (ndt, bt+1, t, t)      column panels of L
            R_out  (ndt, nat, t, t)       factored arrow rows
            schur  (nch, nat, nat, t, t)  per-chunk sums of R_out·R_outᵀ
                   (``nch = ring.chunk_layout(ndt, nchunks)[1]`` — the
                   tree-reduction leaves of the corner Schur complement)
            status (3,) float32           breakdown word (:func:`sweep_status`)

    Panel k only ever reads the last bt panels' outputs, so the scan
    carries a (bt, bt+1, t, t) ring of recent panels (plus the arrow
    ring): an O(b²·t²) working set, no scatters.

    ``start_tile`` (may be traced) declares columns ``k < start_tile`` an
    identity-diagonal prefix (the canonical-grid embedding of
    ``core/gridpolicy.py``): their input is *assumed* to be the identity
    embedding column and their output is its factor — an identity panel
    with zero arrow row — without reading the input, matching the fused
    kernel's compute-skip exactly.
    """
    from .ring import chunk_layout

    ndt, b1, t, _ = Ac.shape
    bt = b1 - 1
    nat = R.shape[1]
    skip = not (isinstance(start_tile, int) and start_tile == 0)

    # shifted-gather indices for the ring contraction: for ring slot j-1
    # (panel k-j) pair (offset e+j with offset j)
    jj = jnp.arange(1, bt + 1)                            # (bt,)
    e_idx = jnp.arange(b1)
    src = jnp.clip(e_idx[None, :] + jj[:, None], 0, max(bt, 0))
    valid = (e_idx[None, :] + jj[:, None]) <= bt

    def trsm_batched(lkk, a):
        return jax.vmap(lambda x: trsm_ref(lkk, x))(a)

    def body(carry, xs):
        ring, ring_a = carry                              # (bt,b1,t,t), (bt,nat,t,t)
        if skip:
            # prefix columns: replace the input by the identity embedding
            # column, whose factor the normal step computes NaN-free
            # (potrf(I)=I, trsm(I, 0)=0)
            from .ring import identity_prefix_panel
            a_col, r_col, kk = xs
            id_col = identity_prefix_panel(bt, t, Ac.dtype)
            a_col = jnp.where(kk < start_tile, id_col, a_col)
            r_col = jnp.where(kk < start_tile, jnp.zeros_like(r_col), r_col)
        else:
            a_col, r_col = xs                             # (b1,t,t), (nat,t,t)
        if bt:
            shifted = jnp.take_along_axis(
                ring, src[:, :, None, None], axis=1)      # (bt,b1,t,t)
            shifted = jnp.where(valid[:, :, None, None], shifted, 0.0)
            rhs = ring[jnp.arange(bt), jj]                # (bt,t,t) = P_{k-j}[j]
            u = jnp.einsum("jeab,jcb->eac", shifted, rhs, precision=_HI)
        else:
            u = jnp.zeros_like(a_col)
        lkk = potrf_ref(a_col[0] - u[0])
        lmk = trsm_batched(lkk, a_col[1:] - u[1:]) if bt else a_col[1:]
        panel = jnp.concatenate([lkk[None], lmk], axis=0)
        if nat:
            v = jnp.einsum("jiab,jcb->iac", ring_a, rhs, precision=_HI) \
                if bt else 0.0
            la = trsm_batched(lkk, r_col - v)
        else:
            la = r_col
        if bt:
            ring = jnp.concatenate([panel[None], ring[:-1]], axis=0)
            if nat:
                ring_a = jnp.concatenate([la[None], ring_a[:-1]], axis=0)
        return (ring, ring_a), (panel, la)

    ring0 = jnp.zeros((bt, b1, t, t), Ac.dtype)
    ring_a0 = jnp.zeros((bt, nat, t, t), Ac.dtype)
    xs = (Ac, R, jnp.arange(ndt)) if skip else (Ac, R)
    if ndt:
        _, (panels, R_out) = jax.lax.scan(body, (ring0, ring_a0), xs)
    else:
        panels, R_out = Ac, R

    # per-chunk corner-Schur partial sums (same layout as the fused kernel)
    csz, nch = chunk_layout(ndt, nchunks)
    rpad = jnp.pad(R_out, ((0, nch * csz - ndt), (0, 0), (0, 0), (0, 0)))
    rchunk = rpad.reshape((nch, csz) + R_out.shape[1:])
    schur = jnp.einsum("nkiab,nkjcb->nijac", rchunk, rchunk, precision=_HI)
    return panels, R_out, schur, sweep_status(panels, R_out)


def combine_sweep_status(words: jnp.ndarray) -> jnp.ndarray:
    """Fold per-partition status words into one global word.

    Input:  words (P, 3) — one :func:`sweep_status` word per partition,
            ``first_bad`` already in *global* column indices.
    Output: (3,) — min over pivots, max over nonfinite flags, and the
            smallest non-negative ``first_bad`` (-1 when every partition
            is clean).  An empty stack folds to :func:`empty_sweep_status`.
    """
    if words.shape[0] == 0:
        return empty_sweep_status()
    first = words[:, 2]
    best = jnp.min(jnp.where(first >= 0, first, jnp.inf))
    return jnp.stack([jnp.min(words[:, 0]),
                      jnp.max(words[:, 1]),
                      jnp.where(jnp.isfinite(best), best, -1.0)])


def band_cholesky_partitioned_sweep_ref(Ac: jnp.ndarray, R: jnp.ndarray,
                                        boundaries, start_tile=0):
    """Partition-parallel band+arrow Cholesky sweep — the oracle for the
    2D-grid fused Pallas kernel.

    ``boundaries`` is the static tuple ``(0, c_1, ..., ndt)`` of a
    :class:`~repro.core.ordering.PartitionPlan`: partition ``p`` owns
    diagonal tiles ``[boundaries[p], boundaries[p+1])``, and the input is
    assumed block-separable across those cuts (every band tile crossing a
    boundary is zero — :func:`~repro.core.ordering.detect_partition_plan`
    certifies exactly this).  Each partition then factorizes
    independently: this oracle runs :func:`band_cholesky_sweep_ref` on
    each slice with one Schur chunk per partition and concatenates.

    Output: panels (ndt, b1, t, t), R_out (ndt, nat, t, t) — same layout
            as the unpartitioned sweep;
            schur (P, nat, nat, t, t) — one corner-Schur partial sum per
            partition (the tree-reduction leaves);
            status (3,) — partition words folded by
            :func:`combine_sweep_status`, ``first_bad`` global.

    ``start_tile`` (may be traced) keeps the canonical-grid prefix
    semantics: globally, columns ``k < start_tile`` are the identity
    prefix, so partition ``p`` skips its first
    ``max(0, start_tile - boundaries[p])`` columns.
    """
    ndt = Ac.shape[0]
    bounds = tuple(int(b) for b in boundaries)
    if len(bounds) < 2 or bounds[0] != 0 or bounds[-1] != ndt or \
            any(b1_ <= b0_ for b0_, b1_ in zip(bounds, bounds[1:])):
        raise ValueError(
            f"boundaries {bounds!r} must be strictly increasing from 0 "
            f"to ndt={ndt}")
    static_start = isinstance(start_tile, int)
    panels, r_out, schurs, words = [], [], [], []
    for s0, s1 in zip(bounds, bounds[1:]):
        local_start = max(0, start_tile - s0) if static_start \
            else jnp.maximum(0, start_tile - s0)
        p, r, sch, w = band_cholesky_sweep_ref(
            Ac[s0:s1], R[s0:s1], nchunks=1, start_tile=local_start)
        panels.append(p)
        r_out.append(r)
        schurs.append(sch[0])
        words.append(w.at[2].set(jnp.where(w[2] >= 0, w[2] + s0, -1.0)))
    return (jnp.concatenate(panels, axis=0),
            jnp.concatenate(r_out, axis=0),
            jnp.stack(schurs, axis=0),
            combine_sweep_status(jnp.stack(words, axis=0)))


def selinv_sweep_ref(lcol: jnp.ndarray, R: jnp.ndarray,
                     sc_full: jnp.ndarray, start_tile=0):
    """Whole backward Takahashi recurrence: the Σ-column ring ``lax.scan``
    (originally ``core/selinv.py``'s backward sweep) — the per-column-looped
    semantics the fused Pallas selinv sweep must match.

    Input:  lcol (ndt, bt+1, t, t) column view of the factor,
            lcol[j, d] = L[j+d, j] (zero past ndt)
            R (ndt, nat, t, t) arrow rows, R[j, i] = L[ndt+i, j]
            sc_full (nat, nat, t, t) full (symmetric) corner Σ seed
    Output: panels (ndt, bt+1, t, t)  Σ columns: panels[j, e] = Σ[j+e, j]
            acols  (ndt, nat, t, t)   arrow entries: acols[j, i] = Σ[ndt+i, j]

    Each step contracts the Σ block row visible from column j (band window
    + arrow rows + corner) against the normalized factor column
    G_kj = L_kj L_jj^{-1} (one :func:`selinv_step_ref`), walking columns
    j = ndt-1..0 with a ring of the last bt computed Σ columns.

    ``start_tile`` (may be traced) declares columns ``j < start_tile`` an
    identity-diagonal prefix (canonical-grid embedding): their factor
    column is *assumed* to be the identity embedding column, so their Σ
    panel is the identity (``Σ = blockdiag(I, Σ_orig)``) — emitted without
    reading the input, matching the fused kernel's compute-skip.
    """
    ndt, b1, t, _ = lcol.shape
    bt = b1 - 1
    nat = R.shape[1]
    eye = jnp.eye(t, dtype=lcol.dtype)
    e_i = jnp.arange(1, bt + 1)[:, None]
    d_i = jnp.arange(1, bt + 1)[None, :]
    skip = not (isinstance(start_tile, int) and start_tile == 0)

    def body(carry, xs):
        # ring[s, e'] = Σ_{(j+1+s)+e', j+1+s}; ring_a[s, i] = Σ_{ndt+i, j+1+s}
        ring, ring_a = carry
        if skip:
            # prefix columns (walked last): feed the identity embedding
            # column through the normal step — winv = I, G = 0, so the
            # emitted Σ panel is exactly the identity panel
            from .ring import identity_prefix_panel
            lc, rc, jj = xs
            id_col = identity_prefix_panel(bt, t, lcol.dtype)
            lc = jnp.where(jj < start_tile, id_col, lc)
            rc = jnp.where(jj < start_tile, jnp.zeros_like(rc), rc)
        else:
            lc, rc = xs                                   # (b1,t,t), (nat,t,t)
        ljj = lc[0]
        winv = solve_panel_ref(ljj, eye)                  # L_jj^{-1}
        s0 = jnp.dot(winv.T, winv, precision=_HI)         # (L_jj L_jj^T)^{-1}
        # normalized column: G_d = L_{j+d,j} L_jj^{-1}; arrow Ga_i = R[j,i] L_jj^{-1}
        g = jnp.einsum("dab,bc->dac", lc[1:], winv, precision=_HI)
        ga = jnp.einsum("iab,bc->iac", rc, winv, precision=_HI) if nat \
            else rc
        gcat = jnp.concatenate([g, ga], axis=0)           # (bt+nat, t, t)

        # Σ block row visible from column j, rows (j+1..j+bt, arrow):
        #   band e, band d:  e>=d -> ring[d-1, e-d]; e<d -> ring[e-1, d-e]^T
        #   band e, arrow i: ring_a[e-1, i]^T
        #   arrow i, band d: ring_a[d-1, i];  arrow i, arrow i': Σ_cc[i, i']
        if bt:
            lower = ring[d_i - 1, jnp.clip(e_i - d_i, 0, bt)]
            upper = jnp.swapaxes(ring[e_i - 1, jnp.clip(d_i - e_i, 0, bt)],
                                 -1, -2)
            swin = jnp.where((e_i >= d_i)[:, :, None, None], lower, upper)
            row_band = jnp.concatenate(
                [swin, jnp.swapaxes(ring_a, -1, -2)], axis=1) if nat else swin
        else:
            row_band = jnp.zeros((0, bt + nat, t, t), lcol.dtype)
        if nat:
            row_arr = jnp.concatenate(
                [ring_a.transpose(1, 0, 2, 3), sc_full], axis=1)
            srow = jnp.concatenate([row_band, row_arr], axis=0)
        else:
            srow = row_band

        off = -selinv_step_ref(srow, gcat)                # (bt+nat, t, t)
        # diagonal: Σ_jj = s0 - Σ_{k>j} Σ_kj^T G_kj  (off = the fresh Σ_kj)
        corr = jnp.einsum("kba,kbc->ac", off, gcat, precision=_HI)
        sjj = s0 - corr
        sjj = 0.5 * (sjj + sjj.T)
        panel = jnp.concatenate([sjj[None], off[:bt]], axis=0)   # (b1, t, t)
        acol = off[bt:]                                          # (nat, t, t)
        if bt:
            ring = jnp.concatenate([panel[None], ring[:-1]], axis=0)
            if nat:
                ring_a = jnp.concatenate([acol[None], ring_a[:-1]], axis=0)
        return (ring, ring_a), (panel, acol)

    if ndt == 0:
        return lcol, R
    ring0 = jnp.zeros((bt, b1, t, t), lcol.dtype)
    ring_a0 = jnp.zeros((bt, nat, t, t), lcol.dtype)
    xs = (jnp.flip(lcol, 0), jnp.flip(R, 0))
    if skip:
        xs = xs + (jnp.flip(jnp.arange(ndt)),)
    _, (panels_rev, acols_rev) = jax.lax.scan(body, (ring0, ring_a0), xs)
    return jnp.flip(panels_rev, 0), jnp.flip(acols_rev, 0)


def band_update_unrolled_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Loop-free band update for small bands: only the structurally nonzero
    (e, j) pairs are computed (no gather, no masked-zero FLOPs).

    For band b this is b·(b+1)/2 tile matmuls vs the masked einsum's b·(b+1)
    — a 2x FLOP cut that maps to 2x fewer MXU ops on TPU.  Preferred when
    b is small (the arrowhead regime); the einsum/Pallas path wins for wide
    bands where one big contraction amortizes better.
    """
    b1 = w.shape[0]
    b = b1 - 1
    t = w.shape[-1]
    outs = []
    for e in range(b1):
        acc = jnp.zeros((t, t), jnp.float32)
        for j in range(1, b1 - e):
            acc = acc + jnp.dot(w[e, e + j], w[0, j].T, precision=_HI)
        outs.append(acc.astype(w.dtype))
    return jnp.stack(outs)


def band_update_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Fused left-looking band-panel update (the `window` backend hot spot).

    Input:  w  (b+1, b+1, t, t) — band-window rows k..k+b of the row-band
            storage: w[e, d] = L_tile[k+e, k+e-d] (zero where out of band).
    Output: u  (b+1, t, t) with

        u[e] = sum_{j=1..b-e}  w[e, e+j] @ w[0, j]^T

    i.e. every SYRK (e=0) and GEMM (e>0) accumulation feeding panel k, in
    one batched contraction.  Entries with e+j > b contribute zero.
    """
    b1 = w.shape[0]
    b = b1 - 1
    # shifted gather: wsh[e, j] = w[e, e+j] (clamped; masked beyond band)
    e_idx = jnp.arange(b1)[:, None]
    j_idx = jnp.arange(b1)[None, :]
    gather = jnp.clip(e_idx + j_idx, 0, b)
    mask = ((e_idx + j_idx) <= b) & (j_idx >= 1)
    wsh = jnp.take_along_axis(w, gather[:, :, None, None], axis=1)
    wsh = jnp.where(mask[:, :, None, None], wsh, 0.0)
    rhs = jnp.where((j_idx[0] >= 1)[:, None, None], w[0], 0.0)
    return jnp.einsum("ejab,jcb->eac", wsh, rhs, precision=_HI)
