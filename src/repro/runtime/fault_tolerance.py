"""Fault-tolerant training loop: retry, checkpoint-restart, straggler watch,
elastic re-meshing.

Failure model for thousands of nodes (DESIGN.md §7):

* **transient step failure** (preempted host, flaky ICI link, data glitch):
  retry the step up to ``max_step_retries`` times — the deterministic
  step-keyed data pipeline makes a retry bit-identical;
* **hard failure**: restore the latest atomic checkpoint and replay — with
  step-keyed data, replay is exact (no data skew across restarts);
* **stragglers**: per-step wall times tracked against a running median; a
  step slower than ``straggler_factor``× median is recorded and surfaced —
  at fleet scale this feeds the scheduler that drains slow hosts (SPMD can't
  locally outrun its slowest chip — mitigation is *detect and replace*,
  plus the static-schedule load balance sTiles itself exemplifies);
* **elastic re-scale**: checkpoints restore onto a different mesh via
  target shardings (Checkpointer.restore), so a pod can drop out between
  runs without invalidating state.

`FailureInjector` drives the tests: deterministic exceptions at chosen steps.
`NumericalFaultInjector` is its sibling for *numerical* faults: instead of
raising, it corrupts chosen elements of a CTSF matrix batch (indefinite
shift or NaN poke) so the breakdown-detection + jitter-ladder machinery in
``core/robustness.py`` can be exercised deterministically end to end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["FailureInjector", "NumericalFaultInjector", "StragglerMonitor",
           "TrainLoop"]


class FailureInjector:
    """Raises RuntimeError at listed (step, attempt) pairs — test hook."""

    def __init__(self, fail_at: Optional[Dict[int, int]] = None):
        self.fail_at = dict(fail_at or {})   # step -> #failures to inject
        self.injected: List[int] = []

    def maybe_fail(self, step: int):
        if self.fail_at.get(step, 0) > 0:
            self.fail_at[step] -= 1
            self.injected.append(step)
            raise RuntimeError(f"injected failure at step {step}")


class NumericalFaultInjector:
    """Deterministically corrupts elements of a CTSF matrix batch — the
    numerical sibling of :class:`FailureInjector`.  Where FailureInjector
    models *process* faults (raise, retry the step), this models *data*
    faults that would otherwise sail through silently: an indefinite
    diagonal (model misconfiguration, a θ-candidate outside the SPD cone)
    or a NaN (bad DMA, poisoned upstream reduction).  The corruption is
    seeded and recorded, so tests and ``benchmarks/bench_robustness.py``
    can assert exactly which elements the detector must flag and the
    jitter ladder must recover or degrade gracefully.

    ``corrupt(batch, modes)`` takes a batched :class:`BandedCTSF` (leading
    batch axis) and a dict ``{element_index: mode}`` with mode
    ``"indefinite"`` (subtract a large multiple of the mean diagonal from
    one seeded diagonal tile) or ``"nan"`` (poke NaN into one seeded band
    entry); it returns a new batch and appends ``(index, mode, tile)``
    records to ``injected``.
    """

    def __init__(self, seed: int = 0, shift: float = 10.0):
        self.seed = seed
        self.shift = shift
        self.injected: List[tuple] = []

    def corrupt(self, batch, modes: Dict[int, str]):
        import jax.numpy as jnp
        rng = np.random.default_rng(self.seed)
        Dr = batch.Dr
        g = batch.grid
        t = g.t
        ndt = g.n_diag_tiles
        for idx in sorted(modes):
            mode = modes[idx]
            tile = int(rng.integers(0, max(1, ndt)))
            if mode == "indefinite":
                diag = jnp.diagonal(Dr[idx, :, 0], axis1=-2, axis2=-1)
                drop = self.shift * jnp.mean(jnp.abs(diag))
                Dr = Dr.at[idx, tile, 0].add(-drop * jnp.eye(t, dtype=Dr.dtype))
            elif mode == "nan":
                a, b = int(rng.integers(0, t)), int(rng.integers(0, t))
                Dr = Dr.at[idx, tile, 0, a, b].set(jnp.nan)
            else:
                raise ValueError(
                    f"unknown corruption mode {mode!r} for element {idx} "
                    "(want 'indefinite' or 'nan')")
            self.injected.append((idx, mode, tile))
        return type(batch)(g, Dr, batch.R, batch.C)

    def corrupt_one(self, mat, mode: str):
        """Corrupt a single *unbatched* CTSF matrix — the per-request form
        the serving tests use (``tests/test_serving.py``) to poison chosen
        requests before they enter a rung batch.  Same seeded tile/entry
        choice as :meth:`corrupt` on a singleton batch."""
        g = mat.grid
        batch = type(mat)(g, mat.Dr[None], mat.R[None], mat.C[None])
        out = self.corrupt(batch, {0: mode})
        return type(mat)(g, out.Dr[0], out.R[0], out.C[0])


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.flagged: List[tuple] = []

    def record(self, step: int, dt: float):
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            if dt > self.factor * med:
                self.flagged.append((step, dt, med))
        self.times.append(dt)

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


@dataclasses.dataclass
class TrainLoop:
    """Drives (state, batch) -> (state, metrics) with fault tolerance."""
    step_fn: Callable
    batch_fn: Callable                       # step -> host batch
    checkpointer: Checkpointer
    checkpoint_every: int = 50
    max_step_retries: int = 2
    injector: Optional[FailureInjector] = None
    straggler: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)
    state_shardings: Optional[Any] = None
    log_every: int = 10
    log_fn: Callable = print

    def run(self, state: Any, start_step: int, num_steps: int) -> Any:
        step = start_step
        history = []
        while step < start_step + num_steps:
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            try:
                new_state, metrics = self._try_step(state, batch, step)
            except Exception as exc:  # hard failure -> restore & replay
                self.log_fn(f"[ft] step {step}: hard failure ({exc}); "
                            f"restoring latest checkpoint")
                restored = self.checkpointer.latest_step()
                if restored is None:
                    raise
                state = self.checkpointer.restore(
                    state, shardings=self.state_shardings)
                step = restored
                continue
            dt = time.perf_counter() - t0
            self.straggler.record(step, dt)
            state = new_state
            history.append(metrics)
            if self.log_every and step % self.log_every == 0:
                self.log_fn(f"step {step}: " + ", ".join(
                    f"{k}={float(v):.4f}" for k, v in metrics.items()))
            step += 1
            if step % self.checkpoint_every == 0:
                self.checkpointer.save(step, state)
        self.checkpointer.save(step, state, block=True)
        self.history = history
        return state

    def _try_step(self, state, batch, step):
        last = None
        for attempt in range(self.max_step_retries + 1):
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                return self.step_fn(state, batch)
            except Exception as exc:
                last = exc
                self.log_fn(f"[ft] step {step} attempt {attempt} failed: {exc}")
        raise last
