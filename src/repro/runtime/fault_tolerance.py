"""Fault-tolerant training loop: retry, checkpoint-restart, straggler watch,
elastic re-meshing.

Failure model for thousands of nodes (DESIGN.md §7):

* **transient step failure** (preempted host, flaky ICI link, data glitch):
  retry the step up to ``max_step_retries`` times — the deterministic
  step-keyed data pipeline makes a retry bit-identical;
* **hard failure**: restore the latest atomic checkpoint and replay — with
  step-keyed data, replay is exact (no data skew across restarts);
* **stragglers**: per-step wall times tracked against a running median; a
  step slower than ``straggler_factor``× median is recorded and surfaced —
  at fleet scale this feeds the scheduler that drains slow hosts (SPMD can't
  locally outrun its slowest chip — mitigation is *detect and replace*,
  plus the static-schedule load balance sTiles itself exemplifies);
* **elastic re-scale**: checkpoints restore onto a different mesh via
  target shardings (Checkpointer.restore), so a pod can drop out between
  runs without invalidating state.

`FailureInjector` drives the tests: deterministic exceptions at chosen steps.
`NumericalFaultInjector` is its sibling for *numerical* faults: instead of
raising, it corrupts chosen elements of a CTSF matrix batch (indefinite
shift or NaN poke) so the breakdown-detection + jitter-ladder machinery in
``core/robustness.py`` can be exercised deterministically end to end.
`DispatchFaultInjector` is the *serving* sibling: seeded dispatch raises
(transient or permanent) and injected stragglers keyed on the batch
composition itself, so a rung-server chaos schedule replays bit-identically
(``benchmarks/bench_chaos.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["FailureInjector", "NumericalFaultInjector",
           "InjectedDispatchError", "DispatchFaultInjector",
           "StragglerMonitor", "TrainLoop"]


class FailureInjector:
    """Raises RuntimeError at listed (step, attempt) pairs — test hook."""

    def __init__(self, fail_at: Optional[Dict[int, int]] = None):
        self.fail_at = dict(fail_at or {})   # step -> #failures to inject
        self.injected: List[int] = []

    def maybe_fail(self, step: int):
        if self.fail_at.get(step, 0) > 0:
            self.fail_at[step] -= 1
            self.injected.append(step)
            raise RuntimeError(f"injected failure at step {step}")


class NumericalFaultInjector:
    """Deterministically corrupts elements of a CTSF matrix batch — the
    numerical sibling of :class:`FailureInjector`.  Where FailureInjector
    models *process* faults (raise, retry the step), this models *data*
    faults that would otherwise sail through silently: an indefinite
    diagonal (model misconfiguration, a θ-candidate outside the SPD cone)
    or a NaN (bad DMA, poisoned upstream reduction).  The corruption is
    seeded and recorded, so tests and ``benchmarks/bench_robustness.py``
    can assert exactly which elements the detector must flag and the
    jitter ladder must recover or degrade gracefully.

    ``corrupt(batch, modes)`` takes a batched :class:`BandedCTSF` (leading
    batch axis) and a dict ``{element_index: mode}`` with mode
    ``"indefinite"`` (subtract a large multiple of the mean diagonal from
    one seeded diagonal tile) or ``"nan"`` (poke NaN into one seeded band
    entry); it returns a new batch and appends ``(index, mode, tile)``
    records to ``injected``.
    """

    def __init__(self, seed: int = 0, shift: float = 10.0):
        self.seed = seed
        self.shift = shift
        self.injected: List[tuple] = []

    def corrupt(self, batch, modes: Dict[int, str]):
        import jax.numpy as jnp
        rng = np.random.default_rng(self.seed)
        Dr = batch.Dr
        g = batch.grid
        t = g.t
        ndt = g.n_diag_tiles
        for idx in sorted(modes):
            mode = modes[idx]
            tile = int(rng.integers(0, max(1, ndt)))
            if mode == "indefinite":
                diag = jnp.diagonal(Dr[idx, :, 0], axis1=-2, axis2=-1)
                drop = self.shift * jnp.mean(jnp.abs(diag))
                Dr = Dr.at[idx, tile, 0].add(-drop * jnp.eye(t, dtype=Dr.dtype))
            elif mode == "nan":
                a, b = int(rng.integers(0, t)), int(rng.integers(0, t))
                Dr = Dr.at[idx, tile, 0, a, b].set(jnp.nan)
            else:
                raise ValueError(
                    f"unknown corruption mode {mode!r} for element {idx} "
                    "(want 'indefinite' or 'nan')")
            self.injected.append((idx, mode, tile))
        return type(batch)(g, Dr, batch.R, batch.C)

    def corrupt_one(self, mat, mode: str):
        """Corrupt a single *unbatched* CTSF matrix — the per-request form
        the serving tests use (``tests/test_serving.py``) to poison chosen
        requests before they enter a rung batch.  Same seeded tile/entry
        choice as :meth:`corrupt` on a singleton batch."""
        g = mat.grid
        batch = type(mat)(g, mat.Dr[None], mat.R[None], mat.C[None])
        out = self.corrupt(batch, {0: mode})
        return type(mat)(g, out.Dr[0], out.R[0], out.C[0])


class InjectedDispatchError(RuntimeError):
    """The exception :class:`DispatchFaultInjector` raises in place of a
    real dispatch failure (compile OOM, device loss, runtime abort).  A
    resilient executor must treat it exactly like any other throwing
    dispatch — retry, bisect, quarantine — which is what makes the chaos
    harness a faithful drill of the production failure paths."""

    def __init__(self, kind: str, tag: str, rids: Tuple[int, ...],
                 attempt: int):
        super().__init__(f"injected {kind} dispatch fault "
                         f"(rung={tag}, rids={rids}, attempt={attempt})")
        self.kind = kind
        self.tag = tag
        self.rids = rids
        self.attempt = attempt


class DispatchFaultInjector:
    """Seeded *dispatch*-level fault injection for the rung server — the
    process-fault sibling of :class:`NumericalFaultInjector`.  Where that
    one corrupts matrix entries (exercising the in-sweep jitter ladder),
    this one makes the executor itself misbehave, in three seeded modes:

    * **transient** — ``before_dispatch`` raises for a seeded fraction of
      batches, but only for attempts ``< transient_attempts``: a retry
      ladder must recover these without any request noticing;
    * **permanent** — raises on *every* attempt for batches containing a
      poisoned request id (``poison_rids``) or landing on a poisoned rung
      tag (``poison_rungs``): bisection must quarantine the poison and a
      circuit breaker must stop feeding the rung;
    * **straggler** — ``straggler_extra_for`` returns extra device
      seconds for a seeded fraction of batches, which the executor burns
      through its injected clock (``SimClock.advance`` offline,
      ``time.sleep`` on the wall) so the straggler monitor and the
      degradation policy see it.

    Every decision hashes ``(seed, rung tag, member rids)`` — never a
    call counter or wall clock — so the same schedule replayed through
    the same injector makes identical decisions in any order, which is
    the bit-identical-replay contract ``benchmarks/bench_chaos.py``
    gates.  Raises and straggler grants are recorded in ``injected``.
    """

    def __init__(self, seed: int = 0, transient_rate: float = 0.0,
                 transient_attempts: int = 1,
                 poison_rids: Iterable[int] = (),
                 poison_rungs: Iterable[str] = (),
                 straggler_rate: float = 0.0,
                 straggler_extra: float = 0.05):
        if not 0.0 <= transient_rate <= 1.0:
            raise ValueError(f"transient_rate must be in [0, 1], "
                             f"got {transient_rate}")
        if not 0.0 <= straggler_rate <= 1.0:
            raise ValueError(f"straggler_rate must be in [0, 1], "
                             f"got {straggler_rate}")
        self.seed = seed
        self.transient_rate = transient_rate
        self.transient_attempts = transient_attempts
        self.poison_rids = frozenset(int(r) for r in poison_rids)
        self.poison_rungs = frozenset(str(r) for r in poison_rungs)
        self.straggler_rate = straggler_rate
        self.straggler_extra = straggler_extra
        self.injected: List[tuple] = []

    def _draw(self, salt: int, tag: str, rids: Tuple[int, ...]) -> float:
        """Uniform [0,1) deterministic in (seed, salt, tag, rids) only."""
        tag_key = [ord(c) for c in tag[:16]]
        seq = np.random.SeedSequence([self.seed, salt, len(rids),
                                      *[int(r) for r in rids], *tag_key])
        return float(np.random.default_rng(seq).random())

    def before_dispatch(self, tag: str, rids, attempt: int) -> None:
        """Call at the top of every dispatch attempt; raises
        :class:`InjectedDispatchError` when this (batch, attempt) draws a
        fault.  ``tag`` is the canonical rung tag, ``rids`` the member
        request ids in batch order."""
        rids = tuple(int(r) for r in rids)
        if tag in self.poison_rungs or self.poison_rids & set(rids):
            self.injected.append(("permanent", tag, rids, attempt))
            raise InjectedDispatchError("permanent", tag, rids, attempt)
        if (self.transient_rate > 0.0 and attempt < self.transient_attempts
                and self._draw(11, tag, rids) < self.transient_rate):
            self.injected.append(("transient", tag, rids, attempt))
            raise InjectedDispatchError("transient", tag, rids, attempt)

    def straggler_extra_for(self, tag: str, rids) -> float:
        """Extra device seconds to inject for this batch (0.0 for most)."""
        rids = tuple(int(r) for r in rids)
        if (self.straggler_rate > 0.0
                and self._draw(13, tag, rids) < self.straggler_rate):
            self.injected.append(("straggler", tag, rids,
                                  self.straggler_extra))
            return float(self.straggler_extra)
        return 0.0


class StragglerMonitor:
    """Per-step/per-batch wall-time watchdog: a recording slower than
    ``factor`` x the running median (over the last ``window`` records,
    once ``min_history`` records exist) is flagged.  Used by the training
    loop (per-step wall times) and the rung server (per-batch
    clock-accounted device times feeding the degradation policy)."""

    def __init__(self, factor: float = 3.0, window: int = 50,
                 min_history: int = 5):
        self.factor = factor
        self.window = window
        self.min_history = min_history
        self.times: List[float] = []
        self.flagged: List[tuple] = []

    def record(self, step: int, dt: float) -> bool:
        """Record one duration; returns True when it was flagged."""
        hit = False
        if len(self.times) >= self.min_history:
            med = float(np.median(self.times[-self.window:]))
            if dt > self.factor * med:
                self.flagged.append((step, dt, med))
                hit = True
        self.times.append(dt)
        return hit

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


@dataclasses.dataclass
class TrainLoop:
    """Drives (state, batch) -> (state, metrics) with fault tolerance."""
    step_fn: Callable
    batch_fn: Callable                       # step -> host batch
    checkpointer: Checkpointer
    checkpoint_every: int = 50
    max_step_retries: int = 2
    injector: Optional[FailureInjector] = None
    straggler: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)
    state_shardings: Optional[Any] = None
    log_every: int = 10
    log_fn: Callable = print

    def run(self, state: Any, start_step: int, num_steps: int) -> Any:
        step = start_step
        history = []
        while step < start_step + num_steps:
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            try:
                new_state, metrics = self._try_step(state, batch, step)
            except Exception as exc:  # hard failure -> restore & replay
                self.log_fn(f"[ft] step {step}: hard failure ({exc}); "
                            f"restoring latest checkpoint")
                restored = self.checkpointer.latest_step()
                if restored is None:
                    raise
                state = self.checkpointer.restore(
                    state, shardings=self.state_shardings)
                step = restored
                continue
            dt = time.perf_counter() - t0
            self.straggler.record(step, dt)
            state = new_state
            history.append(metrics)
            if self.log_every and step % self.log_every == 0:
                self.log_fn(f"step {step}: " + ", ".join(
                    f"{k}={float(v):.4f}" for k, v in metrics.items()))
            step += 1
            if step % self.checkpoint_every == 0:
                self.checkpointer.save(step, state)
        self.checkpointer.save(step, state, block=True)
        self.history = history
        return state

    def _try_step(self, state, batch, step):
        last = None
        for attempt in range(self.max_step_retries + 1):
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                return self.step_fn(state, batch)
            except Exception as exc:
                last = exc
                self.log_fn(f"[ft] step {step} attempt {attempt} failed: {exc}")
        raise last
