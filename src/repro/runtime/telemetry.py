"""Process-wide telemetry for the factorize/solve/selinv serving stack.

The paper's performance story rests on *seeing* the execution: sTiles
analyzes its static scheduler with per-task execution traces and balances
tile size against algorithmic intensity with per-kernel flop/byte counts
(§III-B, Table III).  This module is that layer for the serving stack —
two halves:

**Dynamic half** — a process-wide, thread-safe registry of

* **counters** (monotonic, e.g. cache hits per compile cache),
* **gauges** (last-write-wins point-in-time values),
* **histograms** (count/sum/min/max plus p50/p90/p99 over a bounded
  sample reservoir), and
* **nestable wall-clock spans** (per-thread stacks; every finished span
  records its parent, so exporters can rebuild the call tree).

Recording happens at *dispatch* level only — the Python host code around
``jax.jit`` boundaries — never inside traced computations, following the
PR 6 status-word pattern: anything that must be observed from inside a
traced sweep is carried out as a regular array output (the breakdown
status word of ``kernels.ops.band_cholesky_sweep``) and recorded here
after the host reads it back.  ``inc``/``observe`` coerce their value
with ``float(...)``, so accidentally passing a tracer fails loudly at the
call site instead of silently burying a host sync in a jitted function.

Telemetry is **disabled by default** (enable with :func:`enable`, the
``REPRO_TELEMETRY=1`` environment variable, or the :func:`capture`
context manager).  Every recording function bails on one flag check when
disabled, and :func:`span` returns a shared no-op context manager — the
tier-1 guard test asserts the disabled-mode cost of a fully instrumented
``solve_many`` dispatch stays under 5%.

**Serving resilience metrics** — the rung server's failure domains
(``launch/rung_server.py``) report through this registry so chaos runs
and production traces read identically.  Alongside the baseline serving
metrics (``serving.requests``, ``serving.flush {reason=}``,
``serving.batch_size``, ``serving.queue_wait``, ``serving.queue_depth
{rung=}``, ``serving.completed {outcome=ok|recovered|failed|shed}``,
``serving.request_seconds`` and the ``serving.dispatch`` /
``serving.finalize`` spans), the resilience layer emits counters
``serving.shed {detail=}`` (one per explicitly shed request, labeled
with the shed reason), ``serving.overload_reject {scope=rung|global}``
(typed admission rejections), ``serving.retry {rung=}`` /
``serving.bisect {rung=}`` / ``serving.quarantine {rung=}`` (the
recovery ladder), ``serving.dispatch_failure {kind=, rung=}``,
``serving.breaker_transition {state=, rung=}``, ``serving.straggler
{rung=}`` and ``serving.degradation_step {direction=up|down}``; gauges
``serving.degradation_level`` and ``serving.straggler_seconds {rung=}``;
and the per-batch device-time histogram ``serving.device_seconds
{rung=}`` that feeds the straggler monitor.

**Static half** — :func:`kernel_report` inspects a function *without
running it*: it traces to a jaxpr, counts ``pallas_call`` launch sites
(:func:`count_pallas_launches`, the gate behind ``BENCH_cholesky.json``),
and — given a :class:`~repro.core.structure.TileGrid` — attaches the
analytic per-sweep FLOP / bytes-moved estimates of :func:`sweep_cost`
plus the roofline terms of the hardware model shared with
``benchmarks/roofline.py`` (:data:`PEAK_FLOPS` / :data:`HBM_BW` live
here as the single source of truth).  Launch/intensity regressions are
therefore checkable from unit tests, not just benchmark runs.

Exporters:

* :func:`snapshot` — plain nested dict (counters, gauges, histogram
  summaries, finished spans);
* :func:`to_prometheus_text` — Prometheus text exposition (counters,
  gauges, histograms as summaries with quantile labels);
* :func:`to_chrome_trace` — spans as Chrome trace-event JSON ("X"
  complete events), viewable in Perfetto / ``chrome://tracing``; wired
  into ``benchmarks/run.py --telemetry <path>``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import os
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Telemetry", "KernelReport", "get_registry", "enable", "disable",
    "enabled", "reset", "inc", "gauge", "observe", "span", "capture",
    "hist_summary", "snapshot", "to_prometheus_text", "to_chrome_trace",
    "rung_tag",
    "count_pallas_launches", "sweep_cost", "kernel_report",
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
]

# Hardware model (TPU v5e) — the roofline terms' denominators.  Single
# source of truth shared with benchmarks/roofline.py (which imports these
# rather than re-declaring them).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # HBM bytes/s per chip
ICI_BW = 50e9                # bytes/s per ICI link (1 link, conservative)


def rung_tag(grid) -> str:
    """Canonical label for a tile grid — the rung/grid tag spans and the
    rung-hit counters share, so traces and metrics join on one string."""
    return (f"ndt{grid.n_diag_tiles}.bt{grid.band_tiles}."
            f"nat{grid.n_arrow_tiles}.t{grid.t}")


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

class _NoopSpan:
    """Shared do-nothing span returned while telemetry is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def tag(self, **tags):
        return self


_NOOP_SPAN = _NoopSpan()


class _Span:
    """A live span: context manager that pushes onto the per-thread stack
    on entry (capturing its parent) and records itself on exit."""
    __slots__ = ("_reg", "name", "tags", "id", "parent", "t0")

    def __init__(self, reg: "Telemetry", name: str, tags: Dict[str, Any]):
        self._reg = reg
        self.name = name
        self.tags = tags
        self.id = None
        self.parent = None
        self.t0 = None

    def tag(self, **tags) -> "_Span":
        """Attach tags discovered mid-span (e.g. the canonical rung after
        policy resolution)."""
        self.tags.update(tags)
        return self

    def __enter__(self) -> "_Span":
        stack = self._reg._span_stack()
        self.parent = stack[-1].id if stack else None
        self.id = next(self._reg._ids)
        stack.append(self)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        stack = self._reg._span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._reg._finish_span(self, t1)
        return False


# ---------------------------------------------------------------------------
# Histograms
# ---------------------------------------------------------------------------

class _Hist:
    """Count/sum/min/max plus a bounded sample reservoir for quantiles.

    Samples beyond ``cap`` are counted (in ``count``/``sum``/extrema) but
    not stored; quantiles then describe the first ``cap`` observations and
    the summary carries ``samples_dropped`` so readers know."""
    __slots__ = ("count", "total", "vmin", "vmax", "samples", "dropped",
                 "cap")

    def __init__(self, cap: int):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: List[float] = []
        self.dropped = 0
        self.cap = cap

    def add(self, v: float):
        self.count += 1
        self.total += v
        self.vmin = v if v < self.vmin else self.vmin
        self.vmax = v if v > self.vmax else self.vmax
        if len(self.samples) < self.cap:
            self.samples.append(v)
        else:
            self.dropped += 1

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile over the stored samples: the value at
        rank ``ceil(q * n)`` (1-based), so p50 of [1..100] is 50 and p99
        is 99 — exact and deterministic for test-sized data."""
        if not self.samples:
            return float("nan")
        s = sorted(self.samples)
        idx = max(int(-(-q * len(s) // 1)) - 1, 0)      # ceil(q*n) - 1
        return s[min(idx, len(s) - 1)]

    def summary(self) -> Dict[str, float]:
        out = {"count": self.count, "sum": self.total,
               "min": self.vmin if self.count else float("nan"),
               "max": self.vmax if self.count else float("nan"),
               "mean": self.total / self.count if self.count else float("nan"),
               "p50": self.quantile(0.50),
               "p90": self.quantile(0.90),
               "p99": self.quantile(0.99)}
        if self.dropped:
            out["samples_dropped"] = self.dropped
        return out


def _labels_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_key(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class Telemetry:
    """Thread-safe metric + span registry.

    One instance (:func:`get_registry`) backs the module-level functions;
    independent instances are constructible for tests.  All mutation is
    guarded by one lock held only for the bookkeeping (never across user
    code or JAX dispatch); span stacks are per-thread so concurrent
    serving threads nest independently.
    """

    def __init__(self, enabled: bool = False, max_spans: int = 100_000,
                 max_samples: int = 8192):
        if max_spans <= 0 or max_samples <= 0:
            raise ValueError("max_spans and max_samples must be positive")
        self._enabled = bool(enabled)
        self.max_spans = max_spans
        self.max_samples = max_samples
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], float] = {}
        self._gauges: Dict[Tuple[str, tuple], float] = {}
        self._hists: Dict[Tuple[str, tuple], _Hist] = {}
        self._spans: List[Dict[str, Any]] = []
        self._spans_dropped = 0
        self._local = threading.local()
        self._ids = itertools.count(1)
        self._epoch = time.perf_counter_ns()

    # -- lifecycle ----------------------------------------------------------

    def enable(self):
        self._enabled = True

    def disable(self):
        self._enabled = False

    def enabled(self) -> bool:
        return self._enabled

    def reset(self):
        """Drop all recorded metrics and finished spans (the enabled flag
        and the span-id counter are untouched; live spans finish into the
        cleared buffers)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._spans.clear()
            self._spans_dropped = 0
            self._epoch = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels):
        if not self._enabled:
            return
        v = float(value)            # tracers fail loudly here (jit-safety)
        key = (name, _labels_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + v

    def gauge(self, name: str, value: float, **labels):
        if not self._enabled:
            return
        v = float(value)
        key = (name, _labels_key(labels))
        with self._lock:
            self._gauges[key] = v

    def observe(self, name: str, value: float, **labels):
        if not self._enabled:
            return
        v = float(value)
        key = (name, _labels_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Hist(self.max_samples)
            h.add(v)

    def span(self, name: str, **tags):
        """Open a nestable wall-clock span (use as a context manager).
        Returns the shared no-op span while disabled."""
        if not self._enabled:
            return _NOOP_SPAN
        return _Span(self, name, tags)

    def hist_summary(self, name: str, **labels) -> Optional[Dict[str, float]]:
        """Summary (count/sum/min/max/mean/p50/p90/p99) of one histogram
        by exact name + labels, or None if never observed — the typed
        accessor ``benchmarks/bench_serving.py`` reads request-latency
        percentiles through, instead of string-matching rendered
        ``snapshot()`` keys."""
        key = (name, _labels_key(labels))
        with self._lock:
            h = self._hists.get(key)
            return h.summary() if h is not None else None

    def _span_stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _finish_span(self, span: _Span, t1: int):
        rec = {"name": span.name, "id": span.id, "parent": span.parent,
               "ts_us": (span.t0 - self._epoch) / 1e3,
               "dur_us": (t1 - span.t0) / 1e3,
               "tid": threading.get_ident(), "tags": dict(span.tags)}
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(rec)
            else:
                self._spans_dropped += 1

    # -- exporters ----------------------------------------------------------

    def snapshot(self, include_spans: bool = True) -> Dict[str, Any]:
        """Plain-dict view of everything recorded so far: ``counters`` and
        ``gauges`` keyed ``name{label=value,...}``, ``histograms`` mapped
        to their summaries (count/sum/min/max/mean/p50/p90/p99), and (by
        default) the finished ``spans`` with parent ids intact."""
        with self._lock:
            out: Dict[str, Any] = {
                "enabled": self._enabled,
                "counters": {_render_key(*k): v
                             for k, v in sorted(self._counters.items())},
                "gauges": {_render_key(*k): v
                           for k, v in sorted(self._gauges.items())},
                "histograms": {_render_key(*k): h.summary()
                               for k, h in sorted(self._hists.items())},
            }
            if include_spans:
                out["spans"] = [dict(s, tags=dict(s["tags"]))
                                for s in self._spans]
                out["spans_dropped"] = self._spans_dropped
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition: counters and gauges verbatim,
        histograms as summaries (``quantile`` labels + ``_sum``/``_count``
        series).  Metric names are prefixed ``repro_`` and sanitized."""
        lines: List[str] = []
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: h.summary() for k, h in self._hists.items()}
        for kind, data in (("counter", counters), ("gauge", gauges)):
            seen = set()
            for (name, labels), v in sorted(data.items()):
                pname = _prom_name(name)
                if pname not in seen:
                    lines.append(f"# TYPE {pname} {kind}")
                    seen.add(pname)
                lines.append(f"{pname}{_prom_labels(labels)} {_prom_num(v)}")
        seen = set()
        for (name, labels), s in sorted(hists.items()):
            pname = _prom_name(name)
            if pname not in seen:
                lines.append(f"# TYPE {pname} summary")
                seen.add(pname)
            for q in ("0.5", "0.9", "0.99"):
                ql = labels + (("quantile", q),)
                val = s[{"0.5": "p50", "0.9": "p90", "0.99": "p99"}[q]]
                lines.append(f"{pname}{_prom_labels(ql)} {_prom_num(val)}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} "
                         f"{_prom_num(s['sum'])}")
            lines.append(f"{pname}_count{_prom_labels(labels)} "
                         f"{_prom_num(s['count'])}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Spans as Chrome trace-event JSON (``ph="X"`` complete events,
        microsecond timestamps) — ``json.dump`` the result and open it in
        Perfetto (ui.perfetto.dev) or ``chrome://tracing``.  Span/parent
        ids ride in ``args`` so the tree survives the export."""
        pid = os.getpid()
        with self._lock:
            spans = [dict(s, tags=dict(s["tags"])) for s in self._spans]
        events = [{
            "name": s["name"],
            "cat": s["name"].split(".", 1)[0],
            "ph": "X",
            "ts": s["ts_us"],
            "dur": s["dur_us"],
            "pid": pid,
            "tid": s["tid"],
            "args": {**s["tags"], "span_id": s["id"],
                     "parent_id": s["parent"]},
        } for s in spans]
        return {"traceEvents": events, "displayTimeUnit": "ms"}


def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


def _prom_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    esc = lambda v: str(v).replace("\\", r"\\").replace('"', r"\"")
    body = ",".join(
        f'{re.sub(r"[^a-zA-Z0-9_]", "_", k)}="{esc(v)}"' for k, v in labels)
    return "{" + body + "}"


def _prom_num(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# ---------------------------------------------------------------------------
# Default registry + module-level API
# ---------------------------------------------------------------------------

_DEFAULT = Telemetry(
    enabled=os.environ.get("REPRO_TELEMETRY", "") not in ("", "0"))


def get_registry() -> Telemetry:
    return _DEFAULT


def enable():
    _DEFAULT.enable()


def disable():
    _DEFAULT.disable()


def enabled() -> bool:
    return _DEFAULT._enabled


def reset():
    _DEFAULT.reset()


def inc(name: str, value: float = 1.0, **labels):
    if _DEFAULT._enabled:
        _DEFAULT.inc(name, value, **labels)


def gauge(name: str, value: float, **labels):
    if _DEFAULT._enabled:
        _DEFAULT.gauge(name, value, **labels)


def observe(name: str, value: float, **labels):
    if _DEFAULT._enabled:
        _DEFAULT.observe(name, value, **labels)


def span(name: str, **tags):
    if not _DEFAULT._enabled:
        return _NOOP_SPAN
    return _Span(_DEFAULT, name, tags)


def hist_summary(name: str, **labels) -> Optional[Dict[str, float]]:
    return _DEFAULT.hist_summary(name, **labels)


def snapshot(include_spans: bool = True) -> Dict[str, Any]:
    return _DEFAULT.snapshot(include_spans=include_spans)


def to_prometheus_text() -> str:
    return _DEFAULT.to_prometheus_text()


def to_chrome_trace() -> Dict[str, Any]:
    return _DEFAULT.to_chrome_trace()


@contextlib.contextmanager
def capture():
    """Enable the default registry for the duration of a block, yielding
    it; the previous enabled state is restored on exit (recorded data is
    kept — call :func:`reset` to drop it)."""
    prev = _DEFAULT._enabled
    _DEFAULT.enable()
    try:
        yield _DEFAULT
    finally:
        _DEFAULT._enabled = prev


# ---------------------------------------------------------------------------
# Static kernel inspection: launch counting + analytic sweep costs
# ---------------------------------------------------------------------------

def count_pallas_launches(closed_jaxpr) -> int:
    """Count pallas_call sites in a (closed) jaxpr, descending into
    sub-jaxprs; scan/while bodies multiply by their trip count where it is
    statically known (``scan`` carries ``length``), so a per-panel kernel
    loop is charged once per panel.

    This is the library home of the counter that gates
    ``BENCH_cholesky.json`` (``benchmarks/bench_cholesky.py`` imports it
    from here): the fused sweeps must trace to exactly one launch each."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        mult = eqn.params.get("length", 1) \
            if eqn.primitive.name == "scan" else 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                total += mult * count_pallas_launches(v)
            elif isinstance(v, (list, tuple)):
                total += mult * sum(count_pallas_launches(b)
                                    for b in v if hasattr(b, "jaxpr"))
    return total


def sweep_cost(grid, sweep: str, k: int = 1,
               dtype_bytes: int = 4) -> Dict[str, float]:
    """Analytic FLOP / bytes-moved estimate of one banded-arrowhead sweep
    on ``grid`` — the tile-granular model the paper tunes tile size with
    (flops from tile-matmul counts, bytes from CTSF array traffic).

    Sweeps: ``"cholesky"`` (band+arrow factorization incl. the dense
    corner), ``"forward"`` / ``"backward"`` (one triangular band solve of
    a width-``k`` RHS panel), ``"solve"`` (forward + backward), and
    ``"selinv"`` (the blocked Takahashi recurrence).

    The FLOP side of the cholesky model is shared with
    ``core.gridpolicy.padded_flop_overhead`` (same tile-matmul counter),
    so the padding-overhead metric and these absolute estimates cannot
    drift apart.  Bytes assume each CTSF array crosses HBM once per read
    and once per write — the fused single-launch kernels' traffic, which
    is the floor the VMEM rings were built to hit.  Returns ``{"flops",
    "bytes", "intensity"}`` (intensity in flops/byte)."""
    t, ndt = grid.t, grid.n_diag_tiles
    bt, nat = grid.band_tiles, grid.n_arrow_tiles
    mm = 2.0 * t ** 3                    # one (t,t)@(t,t) tile matmul
    pmm = 2.0 * t * t * k                # one (t,t)@(t,k) panel matmul
    factor_bytes = float((ndt * (bt + 1) + ndt * nat + nat * nat)
                         * t * t * dtype_bytes)
    panel_bytes = float((ndt + nat) * t * k * dtype_bytes)
    corner_n = nat * t
    if sweep == "cholesky":
        from repro.core.gridpolicy import _sweep_tile_matmuls
        flops = _sweep_tile_matmuls(ndt, bt, nat) * mm \
            + corner_n ** 3 / 3.0        # dense corner Cholesky
        byts = 2.0 * factor_bytes        # read A tiles, write L tiles
    elif sweep in ("forward", "backward"):
        panel_ops = max(ndt, 0) * (bt + nat + 1) + nat * (nat + 1) / 2.0
        flops = panel_ops * pmm
        byts = factor_bytes + 2.0 * panel_bytes
    elif sweep == "solve":
        f = sweep_cost(grid, "forward", k, dtype_bytes)
        b = sweep_cost(grid, "backward", k, dtype_bytes)
        flops = f["flops"] + b["flops"]
        byts = f["bytes"] + b["bytes"]
    elif sweep == "selinv":
        # per column: (bt+1) band panels + nat arrow rows, each contracting
        # over the (bt + nat)-deep trailing ring, plus the diagonal seed
        tiles = max(ndt, 0) * ((bt + 1 + nat) * (bt + nat) + 1)
        flops = tiles * mm + float(corner_n) ** 3   # corner seed L^-1, L^-T L^-1
        byts = 2.0 * factor_bytes        # read L tiles, write Sigma tiles
    else:
        raise ValueError(f"unknown sweep {sweep!r} (want 'cholesky', "
                         "'forward', 'backward', 'solve' or 'selinv')")
    return {"flops": float(flops), "bytes": float(byts),
            "intensity": float(flops) / max(byts, 1.0)}


@dataclasses.dataclass(frozen=True)
class KernelReport:
    """Static inspection result of :func:`kernel_report`.

    ``pallas_launches`` is exact (jaxpr traversal); the cost fields are
    the analytic :func:`sweep_cost` estimates (``None`` without a grid),
    with ``t_compute_s`` / ``t_memory_s`` the roofline terms under the
    module's hardware model and ``bound`` naming the larger one."""
    pallas_launches: int
    sweep: Optional[str] = None
    flops: Optional[float] = None
    bytes_moved: Optional[float] = None
    intensity: Optional[float] = None
    t_compute_s: Optional[float] = None
    t_memory_s: Optional[float] = None
    bound: Optional[str] = None

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def kernel_report(fn: Callable, *args, grid=None, sweep: Optional[str] = None,
                  k: int = 1, dtype_bytes: int = 4,
                  **make_jaxpr_kwargs) -> KernelReport:
    """Statically inspect ``fn(*args)`` without executing it: trace to a
    jaxpr, count ``pallas_call`` launch sites, and (when ``grid`` and
    ``sweep`` are given) attach the analytic per-sweep FLOP / bytes-moved
    estimates and roofline terms.

    ``args`` may be concrete arrays or ``jax.ShapeDtypeStruct``s — only
    shapes/dtypes matter.  Extra keyword arguments are forwarded to
    ``jax.make_jaxpr`` (e.g. ``static_argnums``).  This is how tests gate
    launch/intensity regressions without running a benchmark::

        rep = kernel_report(lambda a, r: ops.band_cholesky_sweep(
            a, r, impl="pallas"), Ac, R, grid=grid, sweep="cholesky")
        assert rep.pallas_launches == 1
    """
    import jax
    closed = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    launches = count_pallas_launches(closed)
    if grid is None or sweep is None:
        return KernelReport(pallas_launches=launches, sweep=sweep)
    cost = sweep_cost(grid, sweep, k=k, dtype_bytes=dtype_bytes)
    t_c = cost["flops"] / PEAK_FLOPS
    t_m = cost["bytes"] / HBM_BW
    return KernelReport(
        pallas_launches=launches, sweep=sweep, flops=cost["flops"],
        bytes_moved=cost["bytes"], intensity=cost["intensity"],
        t_compute_s=t_c, t_memory_s=t_m,
        bound="compute" if t_c >= t_m else "memory")


def write_trace(path: str, registry: Optional[Telemetry] = None):
    """Dump the registry's Chrome trace (plus a ``metrics`` key holding
    the span-free snapshot — Perfetto ignores unknown top-level keys) to
    ``path`` as JSON.  The ``benchmarks/run.py --telemetry`` exit hook."""
    reg = registry or _DEFAULT
    trace = reg.to_chrome_trace()
    trace["metrics"] = reg.snapshot(include_spans=False)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
