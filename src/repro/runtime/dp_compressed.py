"""Explicit data-parallel trainer with int8 error-feedback gradient
compression on the cross-pod axis.

The jit/GSPMD trainer (launch/train.py) lets XLA insert the gradient
all-reduce, which cannot be intercepted for wire compression.  This variant
makes the reduction explicit: params replicated across the ``pod`` axis,
batch sharded, per-pod gradients reduced by ``ef_compress_allreduce``
(int8 on the wire + error feedback).  Used when RunConfig.pod_grad_compression
is set and by the fault-tolerance/compression tests.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.optim.adamw import adamw_update, clip_by_global_norm
from repro.optim.compress import ef_compress_allreduce, ef_init

__all__ = ["make_compressed_dp_step"]


def make_compressed_dp_step(loss_fn: Callable, mesh: Mesh, axis: str = "data",
                            lr: float = 1e-3, weight_decay: float = 0.0,
                            grad_clip: float = 1.0, bits: int = 8):
    """loss_fn(params, batch) -> scalar.  Returns (step_fn, ef_init_fn).

    step_fn((params, opt_state, ef_state), batch) -> (state', metrics);
    params replicated, batch sharded on ``axis``.
    """

    def local_step(params, opt, ef, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        grads, ef = ef_compress_allreduce(grads, ef, axis, bits=bits)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = adamw_update(grads, opt, params, lr,
                                           weight_decay=weight_decay)
        return new_params, new_opt, ef, {"loss": loss, "grad_norm": gnorm}

    rep = P()
    shd = P(axis)

    def batch_specs(batch):
        return jax.tree.map(lambda _: shd, batch)

    def step(state, batch):
        params, opt, ef = state
        specs_b = batch_specs(batch)
        try:
            fn = shard_map(local_step, mesh=mesh,
                           in_specs=(rep, rep, rep, specs_b),
                           out_specs=(rep, rep, rep, rep), check_vma=False)
        except TypeError:
            fn = shard_map(local_step, mesh=mesh,
                           in_specs=(rep, rep, rep, specs_b),
                           out_specs=(rep, rep, rep, rep), check_rep=False)
        p, o, e, m = jax.jit(fn)(params, opt, ef, batch)
        return (p, o, e), m

    return step, ef_init
