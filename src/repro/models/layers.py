"""Shared model building blocks: norms, rotary, chunked (flash-style)
attention with GQA, gated MLPs.

All pure functions over plain dict pytrees (no framework dependency).
Long-context memory discipline: attention never materializes the full
(S, S) score matrix — query blocks are scanned and key/value blocks stream
through an online-softmax accumulator, so `prefill_32k` lowers with
O(S · kv_chunk) live scores per chip.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "embed_init", "rms_norm", "layer_norm", "apply_rope",
    "chunked_attention", "decode_attention", "attention_params",
    "attention_apply", "mlp_params", "mlp_apply", "norm_params", "norm_apply",
    "chunked_cross_entropy", "scan_or_unroll",
]

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=_F32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), _F32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=_F32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), _F32) * 0.02).astype(dtype)


def norm_params(d: int, kind: str = "rms") -> Dict[str, jnp.ndarray]:
    p = {"scale": jnp.ones((d,), _F32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), _F32)
    return p


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(_F32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(_F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale + bias).astype(dt)


def norm_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray, kind: str = "rms"):
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=_F32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(_F32) * freqs[None, None, :]   # (B,S,half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(_F32), x[..., half:].astype(_F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _chunk_size(total: int, want: int) -> int:
    c = min(want, total)
    while total % c:
        c -= 1
    return max(1, c)


def _flash_fwd_blocks(qs, ks, vs, causal: bool, q_offset: int):
    """Forward over pre-chunked blocks.

    qs: (nq, B, qc, KV, G, D) pre-scaled; ks/vs: (nk, B, kc, KV, D).
    Returns outs (nq, B, qc, KV, G, D) f32-accumulated (cast by caller) and
    lse (nq, B, KV, G, qc) — the only O(S) softmax residual.
    """
    nq, B, qc, KV, G, D = qs.shape
    nk, _, kc = vs.shape[:3]
    q_iota = jnp.arange(qc)
    k_iota = jnp.arange(kc)

    def q_block(_, xs):
        qi, qblk = xs

        def kv_step(carry, kv_xs):
            m, l, acc = carry
            ki, kblk, vblk = kv_xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=_F32)
            if causal:
                qpos = qi * qc + q_offset + q_iota
                kpos = ki * kc + k_iota
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk, preferred_element_type=_F32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, qc), -1e30, _F32),
                jnp.zeros((B, KV, G, qc), _F32),
                jnp.zeros((B, KV, G, qc, D), _F32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), ks, vs))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)   # (B,qc,KV,G,D)
        return None, (out, m + jnp.log(l))

    _, (outs, lse) = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    return outs, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal: bool, q_chunk: int, kv_chunk: int, q_offset: int):
    """Flash attention core (q pre-scaled).  O(S) residuals via custom VJP:
    the backward pass recomputes score blocks instead of saving them (the
    score tensor never exists at O(S²) — forward or backward)."""
    out, _ = _flash_vjp_fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return out


def _flash_vjp_fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = _chunk_size(Sq, q_chunk)
    kc = _chunk_size(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc
    qs = q.reshape(B, nq, qc, KV, G, D).swapaxes(0, 1)
    ks = k.reshape(B, nk, kc, KV, D).swapaxes(0, 1)
    vs = v.reshape(B, nk, kc, KV, D).swapaxes(0, 1)
    outs, lse = _flash_fwd_blocks(qs, ks, vs, causal, q_offset)
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, q_chunk, kv_chunk, q_offset, res, do):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = _chunk_size(Sq, q_chunk)
    kc = _chunk_size(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc
    qs = q.reshape(B, nq, qc, KV, G, D).swapaxes(0, 1)
    ks = k.reshape(B, nk, kc, KV, D).swapaxes(0, 1)
    vs = v.reshape(B, nk, kc, KV, D).swapaxes(0, 1)
    dos = do.reshape(B, nq, qc, KV, G, D).swapaxes(0, 1)
    outs = out.reshape(B, nq, qc, KV, G, D).swapaxes(0, 1)
    # delta_i = rowsum(dO * O)  -> (nq, B, KV, G, qc)
    delta = jnp.einsum("nbqhgd,nbqhgd->nbhgq", dos.astype(_F32),
                       outs.astype(_F32))
    q_iota = jnp.arange(qc)
    k_iota = jnp.arange(kc)

    def q_block(carry, xs):
        dk, dv = carry
        qi, qblk, doblk, lse_i, delta_i = xs

        def kv_step(carry2, kv_xs):
            dq_i, dk, dv = carry2
            ki, kblk, vblk = kv_xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=_F32)
            if causal:
                qpos = qi * qc + q_offset + q_iota
                kpos = ki * kc + k_iota
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            p = jnp.exp(s - lse_i[..., None])                      # (B,KV,G,qc,kc)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk, vblk,
                            preferred_element_type=_F32)
            ds = p * (dp - delta_i[..., None])
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk,
                                     preferred_element_type=_F32)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk,
                              preferred_element_type=_F32)
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, doblk,
                              preferred_element_type=_F32)
            dk = dk.at[ki].add(dk_j)
            dv = dv.at[ki].add(dv_j)
            return (dq_i, dk, dv), None

        init = (jnp.zeros((B, qc, KV, G, D), _F32), dk, dv)
        (dq_i, dk, dv), _ = jax.lax.scan(kv_step, init,
                                         (jnp.arange(nk), ks, vs))
        return (dk, dv), dq_i

    dk0 = jnp.zeros((nk, B, kc, KV, D), _F32)
    dv0 = jnp.zeros((nk, B, kc, KV, D), _F32)
    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0), (jnp.arange(nq), qs, dos, lse, delta))
    dq = dqs.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dk.swapaxes(0, 1).reshape(B, Skv, KV, D).astype(k.dtype)
    dv = dv.swapaxes(0, 1).reshape(B, Skv, KV, D).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _attention_blocked_unrolled(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    """Same blocked math with Python-level loops (no lax.scan).  Used by the
    roofline harness: XLA cost analysis does not multiply while-loop bodies
    by trip count, so analysis lowerings must contain no loops."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = _chunk_size(Sq, q_chunk)
    kc = _chunk_size(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc
    q5 = q.reshape(B, nq, qc, KV, G, D)
    k4 = k.reshape(B, nk, kc, KV, D)
    v4 = v.reshape(B, nk, kc, KV, D)
    outs = []
    for qi in range(nq):
        m = jnp.full((B, KV, G, qc), -1e30, _F32)
        l = jnp.zeros((B, KV, G, qc), _F32)
        acc = jnp.zeros((B, KV, G, qc, D), _F32)
        for ki in range(nk):
            if causal and ki * kc > qi * qc + q_offset + qc - 1:
                continue  # fully masked block: skip (saves the extra flops)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q5[:, qi], k4[:, ki],
                           preferred_element_type=_F32)
            if causal:
                qpos = qi * qc + q_offset + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v4[:, ki], preferred_element_type=_F32)
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, D))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, q_chunk: int = 512,
                      kv_chunk: int = 1024, q_offset: int = 0,
                      unroll: bool = False) -> jnp.ndarray:
    """Flash attention.  q: (B,Sq,H,D), k/v: (B,Skv,KV,D) -> (B,Sq,H,D).

    KV blocks stream through an online-softmax accumulator; the custom VJP
    recomputes score blocks in the backward pass, so live score memory is
    (B, KV, G, qc, kc) in *both* directions and the only O(S) extras are the
    log-sum-exp statistics.  ``unroll=True`` emits loop-free HLO (and skips
    fully-masked causal blocks) for the cost-analysis harness.
    """
    scale = q.shape[-1] ** -0.5
    if unroll:
        return _attention_blocked_unrolled(q * scale, k, v, causal,
                                           q_chunk, kv_chunk, q_offset)
    return _flash(q * scale, k, v, causal, q_chunk, kv_chunk, q_offset)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    """Single-token attention against a (possibly partially filled) cache.

    q: (B, 1, H, D); caches: (B, T, KV, D); cache_len: () or (B,) valid length
    (the new token's position is cache_len, attended inclusively).
    """
    B, _, H, D = q.shape
    _, T, KV, _ = k_cache.shape
    G = H // KV
    q5 = (q * D ** -0.5).reshape(B, KV, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", q5, k_cache, preferred_element_type=_F32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(T)
    valid = pos[None, :] <= jnp.asarray(cache_len).reshape(-1, 1)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache, preferred_element_type=_F32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + apply)
# ---------------------------------------------------------------------------

def attention_params(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     bias: bool = False, qk_norm: bool = False,
                     d_kv_model: Optional[int] = None) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    dkv = d_kv_model or d_model
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim),
        "wk": dense_init(ks[1], dkv, n_kv * head_dim),
        "wv": dense_init(ks[2], dkv, n_kv * head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), _F32)
        p["bk"] = jnp.zeros((n_kv * head_dim,), _F32)
        p["bv"] = jnp.zeros((n_kv * head_dim,), _F32)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), _F32)
        p["k_norm"] = jnp.ones((head_dim,), _F32)
    return p


def _project_qkv(p, x, kv_x, n_heads, n_kv, head_dim, dtype):
    B, S, _ = x.shape
    Skv = kv_x.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dh->bsh", kv_x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dh->bsh", kv_x, p["wv"].astype(dtype))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dtype), k + p["bk"].astype(dtype), v + p["bv"].astype(dtype)
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, Skv, n_kv, head_dim)
    v = v.reshape(B, Skv, n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def attention_apply(p: Dict[str, Any], x: jnp.ndarray, *,
                    n_heads: int, n_kv: int, head_dim: int,
                    positions: Optional[jnp.ndarray] = None,
                    rope_theta: float = 10_000.0, use_rope: bool = True,
                    causal: bool = True, kv_x: Optional[jnp.ndarray] = None,
                    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache_len: Optional[jnp.ndarray] = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    unroll: bool = False,
                    constrain=None) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Full attention block.  Returns (out, new_cache).

    Modes:
      * training/prefill: cache=None -> chunked causal attention; if
        ``cache_len`` is given the computed k/v are returned for caching.
      * decode: cache=(k,v) -> append one token at ``cache_len``, attend.
      * cross: kv_x set, causal=False, use_rope=False (whisper decoder).
    """
    dtype = x.dtype
    kv_src = kv_x if kv_x is not None else x
    q, k, v = _project_qkv(p, x, kv_src, n_heads, n_kv, head_dim, dtype)
    if constrain is not None:
        q, k, v = constrain(q, "qkv"), constrain(k, "kv"), constrain(v, "kv")

    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        pos = jnp.asarray(cache_len)
        if use_rope:
            q = apply_rope(q, pos.reshape(1, 1) * jnp.ones((1, 1), jnp.int32),
                           rope_theta)
            k = apply_rope(k, pos.reshape(1, 1) * jnp.ones((1, 1), jnp.int32),
                           rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos.astype(jnp.int32), 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos.astype(jnp.int32), 0, 0))
        out = decode_attention(q, k_cache.astype(dtype), v_cache.astype(dtype), pos)
        new_cache = (k_cache, v_cache)
    else:
        if use_rope:
            S = x.shape[1]
            positions = positions if positions is not None else jnp.arange(S)
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
        out = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, unroll=unroll)
        if cache_len is not None:           # prefill: hand k/v to the caller
            new_cache = (k, v)
    out = out.reshape(out.shape[0], out.shape[1], n_heads * head_dim)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, d_model: int, d_ff: int, act: str = "silu",
               bias: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff),
         "wo": dense_init(ks[1], d_ff, d_model)}
    if act == "silu":
        p["wg"] = dense_init(ks[2], d_model, d_ff)
    if bias:
        p["bi"] = jnp.zeros((d_ff,), _F32)
        p["bo"] = jnp.zeros((d_model,), _F32)
    return p


def chunked_cross_entropy(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
                          softcap: float = 0.0, chunk: int = 512,
                          transpose_w: bool = False) -> jnp.ndarray:
    """Mean next-token CE without materializing full (B, S, V) logits.

    h: (B, S, D); w: (D, V) (or (V, D) with transpose_w); labels: (B, S),
    -1 = masked.  Scans sequence chunks; each chunk's logits are a rematted
    temporary, bounding live logit memory to (B, chunk, V).
    """
    B, S, D = h.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    hs = h.reshape(B, nc, c, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hb, lb = xs
        if transpose_w:
            logits = jnp.einsum("bsd,vd->bsv", hb, w.astype(hb.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", hb, w.astype(hb.dtype))
        logits = logits.astype(_F32)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(lb, 0)[..., None],
                                  axis=-1)[..., 0]
        mask = (lb >= 0).astype(_F32)
        tot, cnt = carry
        return (tot + jnp.sum((logz - tgt) * mask), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), _F32), jnp.zeros((), _F32)),
                                 (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def scan_or_unroll(body, carry, xs, *, scan: bool, remat: str):
    """Run `body(carry, xs_slice)` over the leading axis of ``xs`` — either as
    a `lax.scan` (small HLO; production) or a Python unroll (used by the
    roofline harness where while-loop cost accounting would undercount).
    """
    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda x: x[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def mlp_apply(p: Dict[str, Any], x: jnp.ndarray, act: str = "silu",
              constrain=None) -> jnp.ndarray:
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    if "bi" in p:
        h = h + p["bi"].astype(dtype)
    if act == "silu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    if constrain is not None:
        h = constrain(h, "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))
    if "bo" in p:
        out = out + p["bo"].astype(dtype)
    return out
