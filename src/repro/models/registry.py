"""Unified model API over the architecture families.

Every family exposes: init(key, cfg, max_seq), loss(params, batch, cfg, run),
prefill(params, batch, cfg, run), decode_step(params, caches, token, pos,
cfg, run), init_cache(cfg, batch, max_len) — resolved here by cfg.family.
`input_specs` builds the ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from . import mamba2, transformer, whisper, zamba2

__all__ = ["ModelAPI", "get_model", "input_specs", "supports_shape"]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _transformer_api() -> ModelAPI:
    def _init(key, cfg, max_seq=0):
        return transformer.init(key, cfg)

    def _prefill(params, batch, cfg, run, constrain=None):
        if isinstance(batch, dict):
            return transformer.prefill(params, batch["tokens"], cfg, run,
                                       image_embeds=batch.get("image_embeds"),
                                       constrain=constrain)
        return transformer.prefill(params, batch, cfg, run, constrain=constrain)

    return ModelAPI(_init, transformer.loss, _prefill, transformer.decode_step,
                    transformer.init_cache)


def _mamba_api() -> ModelAPI:
    def _init(key, cfg, max_seq=0):
        return mamba2.init(key, cfg)

    def _prefill(params, batch, cfg, run, constrain=None):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return mamba2.prefill(params, tokens, cfg, run, constrain=constrain)

    return ModelAPI(_init, mamba2.loss, _prefill, mamba2.decode_step,
                    mamba2.init_cache)


def _zamba_api() -> ModelAPI:
    def _init(key, cfg, max_seq=0):
        return zamba2.init(key, cfg)

    def _prefill(params, batch, cfg, run, constrain=None):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return zamba2.prefill(params, tokens, cfg, run, constrain=constrain)

    return ModelAPI(_init, zamba2.loss, _prefill, zamba2.decode_step,
                    zamba2.init_cache)


def _whisper_api() -> ModelAPI:
    return ModelAPI(whisper.init, whisper.loss, whisper.prefill,
                    whisper.decode_step, whisper.init_cache)


_FAMILIES = {
    "dense": _transformer_api,
    "moe": _transformer_api,
    "vlm": _transformer_api,
    "ssm": _mamba_api,
    "hybrid": _zamba_api,
    "encdec": _whisper_api,
}


def get_model(cfg: ModelConfig) -> ModelAPI:
    return _FAMILIES[cfg.family]()


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Returns a skip-reason string, or None if the (arch, shape) cell runs.

    Per the assignment: ``long_500k`` needs sub-quadratic attention — run for
    SSM/hybrid, skip for pure full-attention archs (the dense 500k KV cache
    per layer is the blow-up the skip rule exists for).
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("full-attention arch: 500k-token dense KV cache per layer "
                "(see DESIGN.md §6)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32, f32, bf16 = jnp.int32, jnp.float32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), bf16)
        if cfg.family == "encdec":
            batch["frame_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), bf16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), bf16)
        if cfg.family == "encdec":
            batch["frame_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), bf16)
        return batch
    # decode: one new token against a seq_len cache
    return {"token": sds((B, 1), i32), "pos": sds((), i32)}
