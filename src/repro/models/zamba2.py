"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention/MLP block
applied every k SSM layers (arXiv:2411.15242).

Faithful structure, with one recorded simplification (DESIGN.md §6): the
shared block consumes concat([hidden, initial_embedding]) (the Zamba "global
residual" trick, width 2d), runs full attention + gated MLP on 2d, and
projects back to d; per-application LoRA adapters are omitted.

Layers are scanned as superblocks of ``shared_attn_every`` Mamba2 layers,
each preceded by one application of the shared block (weights closed over —
the scan sees them as loop constants, exactly the weight-sharing semantics).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import layers as L
from .mamba2 import (init_mamba_cache, mamba_apply, mamba_decode, mamba_params)

__all__ = ["init", "init_cache", "loss", "prefill", "decode_step"]

_F32 = jnp.float32


def _shared_block_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    d2 = 2 * cfg.d_model
    ka, km, kp = jax.random.split(key, 3)
    return {
        "ln1": L.norm_params(d2, "rms"),
        "attn": L.attention_params(ka, d2, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "ln2": L.norm_params(d2, "rms"),
        "mlp": L.mlp_params(km, d2, cfg.d_ff, "silu"),
        "proj_out": L.dense_init(kp, d2, cfg.d_model),
    }


def _n_super(cfg: ModelConfig) -> int:
    if cfg.n_layers % cfg.shared_attn_every != 0:
        raise ValueError(
            f"n_layers={cfg.n_layers} must be divisible by "
            f"shared_attn_every={cfg.shared_attn_every}")
    return cfg.n_layers // cfg.shared_attn_every


def init(key, cfg: ModelConfig, max_seq: int = 0) -> Dict[str, Any]:
    ke, ku, kl, ks = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.n_layers)
    per = cfg.shared_attn_every
    mamba = jax.vmap(lambda k: mamba_params(k, cfg))(lkeys)
    # reshape stacked layers into (n_super, per, ...)
    mamba = jax.tree.map(
        lambda x: x.reshape((_n_super(cfg), per) + x.shape[1:]), mamba)
    return {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model),
        "shared": _shared_block_init(ks, cfg),
        "mamba": mamba,
        "final_norm": L.norm_params(cfg.d_model, "rms"),
        "unembed": L.dense_init(ku, cfg.d_model, cfg.vocab_padded),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    ns = _n_super(cfg)
    ssm = init_mamba_cache(cfg, batch, n_layers=cfg.n_layers, dtype=dtype)
    kv_shape = (ns, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"ssm": ssm, "k": jnp.zeros(kv_shape, dtype),
            "v": jnp.zeros(kv_shape, dtype)}


def _shared_apply(sp, h, h0, cfg: ModelConfig, run: RunConfig, *,
                  cache=None, cache_len=None, constrain=None):
    x = jnp.concatenate([h, h0], axis=-1)
    a, new_cache = L.attention_apply(
        sp["attn"], L.norm_apply(sp["ln1"], x, "rms"),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, cache=cache, cache_len=cache_len,
        q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, unroll=run.unroll_attn,
        constrain=constrain)
    x = x + a
    m = L.mlp_apply(sp["mlp"], L.norm_apply(sp["ln2"], x, "rms"), "silu",
                    constrain=constrain)
    x = x + m
    out = jnp.einsum("bsd,dk->bsk", x, sp["proj_out"].astype(h.dtype))
    return h + out, new_cache


def _forward(params, h, cfg, run, *, caches=None, cache_len=None,
             fill_cache=False, constrain=None, decode=False):
    h0 = h

    def super_body(h, xs):
        mp = xs
        h, kv = _shared_apply(params["shared"], h, h0, cfg, run,
                              cache_len=cache_len if fill_cache else None,
                              constrain=constrain)

        def mamba_body(h, lp):
            h, st = mamba_apply(lp, h, cfg, chunk=run.ssd_chunk,
                                constrain=constrain, return_state=fill_cache)
            if constrain is not None:
                h = constrain(h, "act")
            return h, st

        # per-layer remat INSIDE the superblock: bounds the recompute window
        # to one mamba layer's intra-chunk tensors instead of six
        h, states = L.scan_or_unroll(mamba_body, h, mp,
                                     scan=run.scan_layers,
                                     remat=run.remat if not fill_cache else "none")
        return h, (states, kv)

    if decode:
        ns = _n_super(cfg)
        per = cfg.shared_attn_every
        states = caches["ssm"]["state"].reshape(
            (ns, per) + caches["ssm"]["state"].shape[1:])
        convs = caches["ssm"]["conv"].reshape(
            (ns, per) + caches["ssm"]["conv"].shape[1:])

        def dec_super(carry, xs):
            h, states, convs, kc, vc = carry
            mp, i = xs
            kc_i = jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
            vc_i = jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
            h, (nk, nv) = _shared_apply(params["shared"], h, h0, cfg, run,
                                        cache=(kc_i, vc_i),
                                        cache_len=cache_len,
                                        constrain=constrain)
            kc = jax.lax.dynamic_update_index_in_dim(kc, nk, i, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, nv, i, 0)
            st_i = jax.lax.dynamic_index_in_dim(states, i, 0, keepdims=False)
            cv_i = jax.lax.dynamic_index_in_dim(convs, i, 0, keepdims=False)

            def mamba_body(h, mxs):
                lp, st, cv = mxs
                h, nc = mamba_decode(lp, h, {"state": st, "conv": cv}, cfg)
                return h, (nc["state"], nc["conv"])

            h, (nst, ncv) = L.scan_or_unroll(mamba_body, h, (mp, st_i, cv_i),
                                             scan=run.scan_layers, remat="none")
            states = jax.lax.dynamic_update_index_in_dim(states, nst, i, 0)
            convs = jax.lax.dynamic_update_index_in_dim(convs, ncv, i, 0)
            return (h, states, convs, kc, vc), None

        (h, states, convs, kc, vc), _ = L.scan_or_unroll(
            dec_super, (h, states, convs, caches["k"], caches["v"]),
            (params["mamba"], jnp.arange(ns)),
            scan=run.scan_layers, remat="none")
        flat = lambda x: x.reshape((cfg.n_layers,) + x.shape[2:])
        new_caches = {"ssm": {"state": flat(states), "conv": flat(convs)},
                      "k": kc, "v": vc}
        return h, new_caches

    h, ys = L.scan_or_unroll(super_body, h, params["mamba"],
                             scan=run.scan_layers, remat=run.remat)
    if fill_cache:
        states, kv = ys
        ssm_state, conv_tail = states
        flat = lambda x: x.reshape((cfg.n_layers,) + x.shape[2:])
        new_caches = {"ssm": {"state": flat(ssm_state),
                              "conv": flat(conv_tail)},
                      "k": kv[0], "v": kv[1]}
        return h, new_caches
    return h, None


def _lm_head(params, h, cfg, dtype):
    h = L.rms_norm(h, params["final_norm"]["scale"])
    return jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(dtype))


def loss(params, batch, cfg: ModelConfig, run: RunConfig, constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    tokens, labels = batch["tokens"], batch["labels"]
    h = params["embed"][tokens].astype(dtype)
    if constrain is not None:
        h = constrain(h, "act")
    h, _ = _forward(params, h, cfg, run, constrain=constrain)
    h = L.rms_norm(h, params["final_norm"]["scale"])
    return L.chunked_cross_entropy(h, params["unembed"], labels,
                                   chunk=run.loss_chunk)


def prefill(params, tokens, cfg: ModelConfig, run: RunConfig,
            image_embeds=None, constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    S = tokens.shape[1]
    h = params["embed"][tokens].astype(dtype)
    h, caches = _forward(params, h, cfg, run, cache_len=S, fill_cache=True,
                         constrain=constrain)
    logits = _lm_head(params, h[:, -1:], cfg, dtype)
    caches["ssm"]["conv"] = caches["ssm"]["conv"].astype(dtype)
    caches["k"] = caches["k"].astype(dtype)
    caches["v"] = caches["v"].astype(dtype)
    return logits[:, 0].astype(_F32), caches


def decode_step(params, caches, token, pos, cfg: ModelConfig, run: RunConfig,
                constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    h = params["embed"][token].astype(dtype)
    h, new_caches = _forward(params, h, cfg, run, caches=caches,
                             cache_len=pos, decode=True, constrain=constrain)
    logits = _lm_head(params, h, cfg, dtype)
    return logits[:, 0].astype(_F32), new_caches
