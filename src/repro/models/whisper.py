"""Whisper-medium-style encoder-decoder backbone (arXiv:2212.04356).

The audio conv frontend is a STUB per the assignment: `input_specs` provides
precomputed frame embeddings (B, enc_seq, d) in place of the two mel
convolutions.  Everything downstream is faithful: learned positions,
pre-LayerNorm blocks with biases, GELU MLPs, decoder with causal self-attn +
cross-attn to the encoder output.  Decode shapes exercise the decoder
(whisper is enc-dec, not encoder-only, so decode applies).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import layers as L

__all__ = ["init", "init_cache", "loss", "prefill", "decode_step", "encode"]

_F32 = jnp.float32


def _enc_layer_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": L.norm_params(cfg.d_model, "layernorm"),
        "attn": L.attention_params(ka, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, bias=True),
        "ln2": L.norm_params(cfg.d_model, "layernorm"),
        "mlp": L.mlp_params(km, cfg.d_model, cfg.d_ff, "gelu", bias=True),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    ka, kc, km = jax.random.split(key, 3)
    p = _enc_layer_init(key, cfg)
    p["ln_cross"] = L.norm_params(cfg.d_model, "layernorm")
    p["cross"] = L.attention_params(kc, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, bias=True)
    return p


def init(key, cfg: ModelConfig, max_seq: int = 4096) -> Dict[str, Any]:
    ke, kd, kp, ku, kep, kdp = jax.random.split(key, 6)
    enc_keys = jax.random.split(kep, cfg.encoder_layers)
    dec_keys = jax.random.split(kdp, cfg.n_layers)
    return {
        "enc_pos": jax.random.normal(kp, (cfg.encoder_seq, cfg.d_model), _F32) * 0.01,
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": L.norm_params(cfg.d_model, "layernorm"),
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model),
        "dec_pos": jax.random.normal(kd, (max_seq, cfg.d_model), _F32) * 0.01,
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "dec_norm": L.norm_params(cfg.d_model, "layernorm"),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype)}


def encode(params, frame_embeds: jnp.ndarray, cfg: ModelConfig,
           run: RunConfig, constrain=None) -> jnp.ndarray:
    dtype = jnp.dtype(run.compute_dtype)
    h = frame_embeds.astype(dtype) + params["enc_pos"][None].astype(dtype)

    def body(h, lp):
        a, _ = L.attention_apply(
            lp["attn"], L.norm_apply(lp["ln1"], h, "layernorm"),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
            use_rope=False, causal=False, q_chunk=run.q_chunk,
            kv_chunk=run.kv_chunk, unroll=run.unroll_attn, constrain=constrain)
        h = h + a
        h = h + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], h, "layernorm"),
                            "gelu", constrain=constrain)
        return h, None

    h, _ = L.scan_or_unroll(body, h, params["enc_layers"],
                            scan=run.scan_layers, remat=run.remat)
    return L.norm_apply(params["enc_norm"], h, "layernorm")


def _dec_layer(lp, h, enc_out, cfg, run, *, positions=None, cache=None,
               cache_len=None, xcache=None, constrain=None):
    """One decoder layer: self-attn (+cache), cross-attn, MLP."""
    a, new_cache = L.attention_apply(
        lp["attn"], L.norm_apply(lp["ln1"], h, "layernorm"),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        use_rope=False, positions=positions, cache=cache, cache_len=cache_len,
        q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, unroll=run.unroll_attn,
        constrain=constrain)
    h = h + a
    hn = L.norm_apply(lp["ln_cross"], h, "layernorm")
    if xcache is not None:
        # decode: cross k/v precomputed
        q, _, _ = h, None, None
        dtype = h.dtype
        B, S, _ = h.shape
        qv = jnp.einsum("bsd,dh->bsh", hn, lp["cross"]["wq"].astype(dtype))
        qv = (qv + lp["cross"]["bq"].astype(dtype)).reshape(
            B, S, cfg.n_heads, cfg.hd)
        xk, xv = xcache
        out = L.decode_attention(qv, xk.astype(dtype), xv.astype(dtype),
                                 jnp.asarray(xk.shape[1] - 1))
        out = out.reshape(B, S, cfg.n_heads * cfg.hd)
        x = jnp.einsum("bsh,hd->bsd", out, lp["cross"]["wo"].astype(dtype))
    else:
        x, _ = L.attention_apply(
            lp["cross"], hn, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, use_rope=False, causal=False, kv_x=enc_out,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk,
            unroll=run.unroll_attn, constrain=constrain)
    h = h + x
    h = h + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], h, "layernorm"),
                        "gelu", constrain=constrain)
    return h, new_cache


def _decoder(params, tokens, enc_out, cfg, run, *, pos_offset=0,
             caches=None, cache_len=None, fill_cache=False, constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    B, S = tokens.shape
    h = params["embed"][tokens].astype(dtype)
    pos = jax.lax.dynamic_slice(params["dec_pos"],
                                (jnp.asarray(pos_offset), 0),
                                (S, cfg.d_model)) if caches is not None else \
        params["dec_pos"][:S]
    h = h + pos[None].astype(dtype)

    if caches is not None:
        def body(carry, xs):
            h, kc, vc = carry
            lp, xk, xv, i = xs
            kc_l = jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
            vc_l = jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
            h, (nk, nv) = _dec_layer(lp, h, None, cfg, run,
                                     cache=(kc_l, vc_l), cache_len=cache_len,
                                     xcache=(xk, xv), constrain=constrain)
            kc = jax.lax.dynamic_update_index_in_dim(kc, nk, i, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, nv, i, 0)
            return (h, kc, vc), None

        nl = jax.tree.leaves(params["dec_layers"])[0].shape[0]
        (h, kc, vc), _ = L.scan_or_unroll(
            body, (h, caches["k"], caches["v"]),
            (params["dec_layers"], caches["xk"], caches["xv"], jnp.arange(nl)),
            scan=run.scan_layers, remat="none")
        h = L.norm_apply(params["dec_norm"], h, "layernorm")
        return h, (kc, vc)

    def body(h, lp):
        h, kv = _dec_layer(lp, h, enc_out, cfg, run,
                           cache_len=cache_len if fill_cache else None,
                           constrain=constrain)
        return h, kv

    h, ys = L.scan_or_unroll(body, h, params["dec_layers"],
                             scan=run.scan_layers, remat=run.remat)
    h = L.norm_apply(params["dec_norm"], h, "layernorm")
    return h, ys


def loss(params, batch, cfg: ModelConfig, run: RunConfig, constrain=None):
    enc_out = encode(params, batch["frame_embeds"], cfg, run, constrain)
    h, _ = _decoder(params, batch["tokens"], enc_out, cfg, run,
                    constrain=constrain)
    return L.chunked_cross_entropy(h, params["embed"], batch["labels"],
                                   chunk=run.loss_chunk, transpose_w=True)


def _cross_kv(params, enc_out, cfg, run):
    """Precompute per-layer cross-attention K/V from the encoder output."""

    def body(_, lp):
        dtype = enc_out.dtype
        B, S, _ = enc_out.shape
        k = (jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wk"].astype(dtype))
             + lp["cross"]["bk"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        v = (jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wv"].astype(dtype))
             + lp["cross"]["bv"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        return None, (k, v)

    _, (xk, xv) = L.scan_or_unroll(body, None, params["dec_layers"],
                                   scan=run.scan_layers, remat="none")
    return xk, xv


def prefill(params, batch, cfg: ModelConfig, run: RunConfig, constrain=None):
    """batch: dict(tokens, frame_embeds). Returns (last logits, caches)."""
    tokens = batch["tokens"] if isinstance(batch, dict) else batch
    frames = batch["frame_embeds"]
    enc_out = encode(params, frames, cfg, run, constrain)
    S = tokens.shape[1]
    h, kv = _decoder(params, tokens, enc_out, cfg, run, cache_len=S,
                     fill_cache=True, constrain=constrain)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], params["embed"].astype(h.dtype))
    xk, xv = _cross_kv(params, enc_out, cfg, run)
    caches = {"k": kv[0], "v": kv[1], "xk": xk, "xv": xv}
    return logits.astype(_F32), caches


def decode_step(params, caches, token, pos, cfg: ModelConfig, run: RunConfig,
                constrain=None):
    h, ys = _decoder(params, token, None, cfg, run, pos_offset=pos,
                     caches=caches, cache_len=pos, constrain=constrain)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    new_caches = {"k": ys[0], "v": ys[1], "xk": caches["xk"], "xv": caches["xv"]}
    return logits[:, 0].astype(_F32), new_caches
