"""Mixture-of-Experts MLP (granite-moe style): top-k routing with
capacity-bounded sort-based dispatch.

FLOP-optimal dispatch: the (tokens × top_k) assignments are sorted by expert
id, truncated to a static per-expert capacity C = ceil(T·k·cf / E), gathered
into an (E, C, D) buffer, run through a batched expert einsum, and
scatter-added back with router gates.  Total MLP FLOPs = active-expert FLOPs
× capacity factor (vs. the dense-all-experts approach's E/k× blow-up).

Expert parallelism: when the expert count divides the `model` axis the
(E, C, D) buffer and expert weights are sharded on E (true EP — XLA inserts
the all-to-all); otherwise expert weights are TP-sharded on d_ff
(granite-moe-3b's 40 experts vs 16-way axis).  See sharding/partition.py.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["moe_params", "moe_apply"]

_F32 = jnp.float32


def moe_params(key, d_model: int, d_ff: int, n_experts: int,
               pad_to: int = 0) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    ep = pad_to or n_experts          # padded weight count (EP divisibility)
    return {
        "router": dense_init(ks[0], d_model, n_experts),
        "wi": (jax.random.normal(ks[1], (ep, d_model, d_ff), _F32) * scale),
        "wg": (jax.random.normal(ks[2], (ep, d_model, d_ff), _F32) * scale),
        "wo": (jax.random.normal(ks[3], (ep, d_ff, d_model), _F32)
               / math.sqrt(d_ff)),
    }


def _dispatch_one(xt, logits, top_k: int, cap: int, E: int):
    """Per-sequence dispatch (vmapped over batch so the batch dim — and with
    it every dispatch tensor — stays sharded over DP; a global dispatch
    would force GSPMD to all-gather all tokens onto every data shard)."""
    S, D = xt.shape
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    sk = S * top_k
    expert_idx = idx.reshape(sk)
    token_idx = jnp.repeat(jnp.arange(S), top_k)
    gate_w = gates.reshape(sk)

    order = jnp.argsort(expert_idx)                      # stable
    se, st, sg = expert_idx[order], token_idx[order], gate_w[order]

    # position-in-expert: running index since the last expert boundary
    pos_all = jnp.arange(sk)
    seg_start = jnp.where(se != jnp.roll(se, 1), pos_all, 0)
    seg_start = seg_start.at[0].set(0)
    last_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    pos_in_expert = pos_all - last_start

    keep = pos_in_expert < cap
    dest = jnp.where(keep, se * cap + pos_in_expert, E * cap)  # overflow slot

    buf = jnp.zeros((E * cap + 1, D), xt.dtype)
    buf = buf.at[dest].set(xt[st])
    return buf[:-1].reshape(E, cap, D), (keep, dest, st, sg)


def _combine_one(out, dispatch_info, S: int, D: int, E: int, cap: int, dtype):
    keep, dest, st, sg = dispatch_info
    flat = out.reshape(E * cap, D)
    y_assign = jnp.where(keep[:, None], flat[jnp.clip(dest, 0, E * cap - 1)], 0.0)
    y_assign = y_assign * sg[:, None].astype(dtype)
    return jnp.zeros((S, D), dtype).at[st].add(y_assign)


def moe_apply(p: Dict[str, Any], x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25, constrain=None) -> jnp.ndarray:
    dtype = x.dtype
    B, S, D = x.shape
    E = p["router"].shape[1]          # routable experts
    Ep = p["wi"].shape[0]             # allocated (possibly padded) experts
    cap = max(1, int(math.ceil(S * top_k * capacity_factor / E)))
    cap = (cap + 3) // 4 * 4                             # lane-friendly

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dtype)).astype(_F32)
    buf, info = jax.vmap(
        lambda xt, lg: _dispatch_one(xt, lg, top_k, cap, Ep))(x, logits)
    # buf: (B, E, cap, D) — B stays on DP, E on the model axis when EP divides
    if constrain is not None:
        buf = constrain(buf, "experts")

    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dtype))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    if constrain is not None:
        h = constrain(h, "experts_ff")
    out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dtype))

    y = jax.vmap(
        lambda o, i: _combine_one(o, i, S, D, Ep, cap, dtype))(out, info)
    return y.reshape(B, S, D)
