"""Dense / MoE / VLM decoder-only transformer (qwen2, qwen3, command-r,
granite-moe, phi-3-vision backbones).

Functional model: `init` builds a param pytree with layer params stacked on
a leading L axis; `loss`/`prefill`/`decode_step` run a `lax.scan` over that
axis (one compiled layer body — keeps HLO small and lets XLA prefetch the
next layer's FSDP all-gather during the current layer's compute).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import layers as L
from .moe import moe_apply, moe_params

__all__ = ["init", "init_cache", "loss", "prefill", "decode_step"]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    ka, km, k1, k2 = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_params(cfg.d_model, cfg.norm),
        "attn": L.attention_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": L.norm_params(cfg.d_model, cfg.norm),
    }
    if cfg.family == "moe":
        p["moe"] = moe_params(km, cfg.d_model, cfg.d_ff, cfg.n_experts,
                              pad_to=cfg.expert_pad_to)
    else:
        p["mlp"] = L.mlp_params(km, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init(key, cfg: ModelConfig) -> Dict[str, Any]:
    ke, ku, kl = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model),
        "final_norm": L.norm_params(cfg.d_model, cfg.norm),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(lkeys),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ku, cfg.d_model, cfg.vocab_padded)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_apply(lp, h, cfg: ModelConfig, run: RunConfig, *, positions=None,
                 cache=None, cache_len=None, constrain=None):
    a, new_cache = L.attention_apply(
        lp["attn"], L.norm_apply(lp["ln1"], h, cfg.norm),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        positions=positions, rope_theta=cfg.rope_theta,
        cache=cache, cache_len=cache_len, q_chunk=run.q_chunk,
        kv_chunk=run.kv_chunk, unroll=run.unroll_attn, constrain=constrain)
    h = h + a
    hn = L.norm_apply(lp["ln2"], h, cfg.norm)
    if cfg.family == "moe":
        m = moe_apply(lp["moe"], hn, top_k=cfg.top_k,
                      capacity_factor=cfg.capacity_factor, constrain=constrain)
    else:
        m = L.mlp_apply(lp["mlp"], hn, cfg.act, constrain=constrain)
    h = h + m
    if constrain is not None:
        h = constrain(h, "act")   # keep the residual stream SP-sharded
    return h, new_cache


def _embed(params, tokens, cfg: ModelConfig, dtype,
           image_embeds: Optional[jnp.ndarray] = None):
    h = params["embed"][tokens].astype(dtype)
    if cfg.n_image_tokens and image_embeds is not None:
        # VLM stub: precomputed patch embeddings occupy the first positions
        n = cfg.n_image_tokens
        h = jnp.concatenate([image_embeds.astype(dtype), h[:, n:]], axis=1)
    return h


def _stack_forward(params, h, cfg: ModelConfig, run: RunConfig, *,
                   positions=None, caches=None, cache_len=None,
                   constrain=None, fill_cache: bool = False):
    """Scan over stacked layers. Returns (h, new_caches)."""

    if caches is not None:
        # decode: caches ride the carry and are updated in place per layer —
        # XLA aliases the (donated) buffer instead of double-buffering ys.
        def body(carry, xs):
            h, kc, vc = carry
            lp, i = xs
            kc_l = jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
            vc_l = jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
            h, (nk, nv) = _layer_apply(lp, h, cfg, run, positions=positions,
                                       cache=(kc_l, vc_l), cache_len=cache_len,
                                       constrain=constrain)
            kc = jax.lax.dynamic_update_index_in_dim(kc, nk, i, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, nv, i, 0)
            return (h, kc, vc), None

        nl = jax.tree.leaves(params["layers"])[0].shape[0]
        (h, kc, vc), _ = L.scan_or_unroll(
            body, (h, caches["k"], caches["v"]),
            (params["layers"], jnp.arange(nl)),
            scan=run.scan_layers, remat="none")
        return h, {"k": kc, "v": vc}

    def body(h, lp):
        h, kv = _layer_apply(lp, h, cfg, run, positions=positions,
                             cache_len=cache_len if fill_cache else None,
                             constrain=constrain)
        return h, kv

    h, ys = L.scan_or_unroll(body, h, params["layers"],
                             scan=run.scan_layers, remat=run.remat)
    new_caches = None
    if fill_cache and ys is not None:
        new_caches = {"k": ys[0], "v": ys[1]}
    return h, new_caches


def _logits(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
         run: RunConfig, constrain=None) -> jnp.ndarray:
    """Mean next-token cross-entropy.  batch: tokens (B,S) int32,
    labels (B,S) int32 (-1 = masked), optional image_embeds."""
    dtype = jnp.dtype(run.compute_dtype)
    tokens, labels = batch["tokens"], batch["labels"]
    h = _embed(params, tokens, cfg, dtype, batch.get("image_embeds"))
    if constrain is not None:
        h = constrain(h, "act")
    h, _ = _stack_forward(params, h, cfg, run, constrain=constrain)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.chunked_cross_entropy(h, w, labels, softcap=cfg.logit_softcap,
                                   chunk=run.loss_chunk,
                                   transpose_w=cfg.tie_embeddings)


def prefill(params, tokens: jnp.ndarray, cfg: ModelConfig, run: RunConfig,
            image_embeds: Optional[jnp.ndarray] = None,
            constrain=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Process a full prompt; returns (last-position logits, filled caches)."""
    dtype = jnp.dtype(run.compute_dtype)
    S = tokens.shape[1]
    h = _embed(params, tokens, cfg, dtype, image_embeds)
    h, caches = _stack_forward(params, h, cfg, run, cache_len=S,
                               fill_cache=True, constrain=constrain)
    h = L.norm_apply(params["final_norm"], h[:, -1:], cfg.norm)
    logits = _logits(params, h, cfg)
    return logits[:, 0].astype(jnp.float32), caches


def decode_step(params, caches: Dict[str, Any], token: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig, run: RunConfig,
                constrain=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One autoregressive step. token: (B, 1) int32; pos: scalar cache length."""
    dtype = jnp.dtype(run.compute_dtype)
    h = _embed(params, token, cfg, dtype)
    if constrain is not None:
        h = constrain(h, "act")
    h, new_caches = _stack_forward(params, h, cfg, run, caches=caches,
                                   cache_len=pos, constrain=constrain)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = _logits(params, h, cfg)
    return logits[:, 0].astype(jnp.float32), new_caches
