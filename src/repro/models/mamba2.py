"""Mamba2 (SSD — state-space duality) blocks, chunked-scan formulation.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks of Q tokens; within a chunk the recurrence is computed as
a (masked, decay-weighted) quadratic attention-like contraction; across
chunks a small (H, P, N) state is carried by a `lax.scan`.  Decode keeps the
recurrent form: O(1) state update per token — this is why the `long_500k`
shape runs for the SSM/hybrid architectures and is skipped for full
attention.

Block layout (mamba2-1.3b): in_proj -> [z | x | B | C | dt], short causal
depthwise conv on (x|B|C), SSD core, gated RMSNorm, out_proj.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import layers as L

__all__ = ["mamba_params", "mamba_apply", "mamba_decode", "init_mamba_cache",
           "ssd_chunked", "ssd_decode", "init", "loss", "prefill", "decode_step",
           "init_cache"]

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, a_log, bmat, cmat, d_skip, chunk: int = 64):
    """SSD forward.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a_log: (H,);
    bmat/cmat: (B, S, G, N); d_skip: (H,).  Returns (y, final_state) with
    final_state (B, G, HG, P, N).
    """
    B, S, H, P = x.shape
    G, N = bmat.shape[2], bmat.shape[3]
    HG = H // G
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    A = -jnp.exp(a_log.astype(_F32))                    # (H,) negative
    a = dt.astype(_F32) * A                              # (B,S,H)
    ag = a.reshape(B, nc, Q, G, HG)
    cum = jnp.cumsum(ag, axis=2)                         # (B,nc,Q,G,HG)

    xg = x.reshape(B, nc, Q, G, HG, P).astype(_F32)
    dtg = dt.reshape(B, nc, Q, G, HG).astype(_F32)
    dtx = xg * dtg[..., None]
    bg = bmat.reshape(B, nc, Q, G, N).astype(_F32)
    cg = cmat.reshape(B, nc, Q, G, N).astype(_F32)

    # ---- intra-chunk (quadratic within Q) -------------------------------
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", cg, bg)
    seg = cum[:, :, :, None] - cum[:, :, None]           # (B,nc,Q,Q,G,HG)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    att = scores[..., None] * decay                      # (B,nc,Q,Q,G,HG)
    y_intra = jnp.einsum("bcqkgh,bckghp->bcqghp", att, dtx)

    # ---- chunk states ----------------------------------------------------
    last = cum[:, :, -1:]                                # (B,nc,1,G,HG)
    w = jnp.exp(last - cum)                              # decay to chunk end
    state_c = jnp.einsum("bckghp,bckgh,bckgn->bcghpn", dtx, w, bg)

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0])                 # (B,nc,G,HG)

    def step(h, xs):
        dec, s = xs
        h_new = h * dec[..., None, None] + s
        return h_new, h                                   # emit state *before*

    h0 = jnp.zeros((B, G, HG, P, N), _F32)
    final, h_prev = jax.lax.scan(
        step, h0, (chunk_decay.swapaxes(0, 1), state_c.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                        # (B,nc,G,HG,P,N)

    y_inter = jnp.einsum("bcqgn,bcqgh,bcghpn->bcqghp", cg, jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + x.astype(_F32) * d_skip.astype(_F32)[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_decode(state, x, dt, a_log, bvec, cvec, d_skip):
    """One-token SSD update.  x: (B,H,P); dt: (B,H); b/c: (B,G,N);
    state: (B,G,HG,P,N)."""
    B, H, P = x.shape
    G, N = bvec.shape[1], bvec.shape[2]
    HG = H // G
    A = -jnp.exp(a_log.astype(_F32))
    ag = (dt.astype(_F32) * A).reshape(B, G, HG)
    xg = x.reshape(B, G, HG, P).astype(_F32)
    dtx = xg * dt.reshape(B, G, HG)[..., None]
    new_state = (state * jnp.exp(ag)[..., None, None]
                 + jnp.einsum("bghp,bgn->bghpn", dtx, bvec.astype(_F32)))
    y = jnp.einsum("bgn,bghpn->bghp", cvec.astype(_F32), new_state)
    y = y.reshape(B, H, P) + x.astype(_F32) * d_skip.astype(_F32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "ln": L.norm_params(d, "rms"),
        "w_in": L.dense_init(ks[0], d, 2 * di + 2 * g * n + h),
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), _F32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), _F32),
        "a_log": jnp.zeros((h,), _F32),
        "d_skip": jnp.ones((h,), _F32),
        "dt_bias": jnp.full((h,), -2.0, _F32),
        "gate_norm": jnp.ones((di,), _F32),
        "w_out": L.dense_init(ks[3], di, d),
    }


def _split_proj(proj, cfg: ModelConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di: di + di + 2 * g * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc, kernel, bias):
    """Depthwise causal conv, width w: sum of shifted copies (w is 4)."""
    w = kernel.shape[0]
    out = xbc * kernel[-1]
    for i in range(1, w):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * kernel[-1 - i]
    return out + bias


def mamba_apply(p, h, cfg: ModelConfig, chunk: int = 64, constrain=None,
                return_state: bool = False):
    """Full-sequence Mamba2 block (training / prefill)."""
    dtype = h.dtype
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    B, S, _ = h.shape
    hn = L.rms_norm(h, p["ln"]["scale"])
    proj = jnp.einsum("bsd,dk->bsk", hn, p["w_in"].astype(dtype))
    z, xbc, dt = _split_proj(proj, cfg)
    conv_tail = xbc[:, -cfg.ssm_conv:]          # raw inputs for decode carry
    xbc = _causal_conv(xbc, p["conv"].astype(dtype), p["conv_b"].astype(dtype))
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :di].reshape(B, S, nh, cfg.ssm_head_dim)
    bmat = xbc[..., di: di + g * n].reshape(B, S, g, n)
    cmat = xbc[..., di + g * n:].reshape(B, S, g, n)
    dt = jax.nn.softplus(dt.astype(_F32) + p["dt_bias"])
    if constrain is not None:
        x = constrain(x, "ssm_x")
    y, final_state = ssd_chunked(x, dt, p["a_log"], bmat, cmat, p["d_skip"],
                                 chunk=chunk)
    y = y.reshape(B, S, di)
    y = L.rms_norm(y * jax.nn.silu(z.astype(_F32)).astype(dtype), p["gate_norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(dtype))
    if return_state:
        return h + out, (final_state, conv_tail)
    return h + out, None


def mamba_decode(p, h, cache, cfg: ModelConfig):
    """One-token Mamba2 step.  h: (B, 1, d); cache: dict(state, conv)."""
    dtype = h.dtype
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    B = h.shape[0]
    hn = L.rms_norm(h[:, 0], p["ln"]["scale"])
    proj = jnp.einsum("bd,dk->bk", hn, p["w_in"].astype(dtype))
    z, xbc, dt = _split_proj(proj, cfg)
    # conv over the rolling buffer
    conv_buf = jnp.concatenate([cache["conv"][:, 1:], xbc[:, None]], axis=1)
    kernel = p["conv"].astype(dtype)
    xbc = (conv_buf * kernel[None]).sum(axis=1) + p["conv_b"].astype(dtype)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :di].reshape(B, nh, cfg.ssm_head_dim)
    bvec = xbc[..., di: di + g * n].reshape(B, g, n)
    cvec = xbc[..., di + g * n:].reshape(B, g, n)
    dt = jax.nn.softplus(dt.astype(_F32) + p["dt_bias"])
    y, new_state = ssd_decode(cache["state"], x, dt, p["a_log"], bvec, cvec,
                              p["d_skip"])
    y = y.reshape(B, di)
    y = L.rms_norm(y * jax.nn.silu(z.astype(_F32)).astype(dtype),
                   p["gate_norm"])
    out = jnp.einsum("bk,kd->bd", y, p["w_out"].astype(dtype))
    new_cache = {"state": new_state, "conv": conv_buf}
    return h + out[:, None], new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, n_layers: Optional[int] = None,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    nl = n_layers if n_layers is not None else cfg.n_layers
    g, n = cfg.ssm_groups, cfg.ssm_state
    hg = cfg.ssm_heads // g
    conv_ch = cfg.d_inner + 2 * g * n
    return {
        "state": jnp.zeros((nl, batch, g, hg, cfg.ssm_head_dim, n), _F32),
        "conv": jnp.zeros((nl, batch, cfg.ssm_conv, conv_ch), dtype),
    }


# ---------------------------------------------------------------------------
# full mamba2 LM (attention-free)
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig, max_seq: int = 0) -> Dict[str, Any]:
    ke, ku, kl = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model),
        "final_norm": L.norm_params(cfg.d_model, "rms"),
        "layers": jax.vmap(lambda k: mamba_params(k, cfg))(lkeys),
        "unembed": L.dense_init(ku, cfg.d_model, cfg.vocab_padded),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return init_mamba_cache(cfg, batch, dtype=dtype)


def _forward(params, h, cfg, run, constrain=None):
    def body(h, lp):
        h, _ = mamba_apply(lp, h, cfg, chunk=run.ssd_chunk,
                           constrain=constrain)
        if constrain is not None:
            h = constrain(h, "act")
        return h, None

    h, _ = L.scan_or_unroll(body, h, params["layers"],
                            scan=run.scan_layers, remat=run.remat)
    return h


def loss(params, batch, cfg: ModelConfig, run: RunConfig, constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    tokens, labels = batch["tokens"], batch["labels"]
    h = params["embed"][tokens].astype(dtype)
    if constrain is not None:
        h = constrain(h, "act")
    h = _forward(params, h, cfg, run, constrain)
    h = L.rms_norm(h, params["final_norm"]["scale"])
    return L.chunked_cross_entropy(h, params["unembed"], labels,
                                   chunk=run.loss_chunk)


def prefill(params, tokens, cfg: ModelConfig, run: RunConfig,
            image_embeds=None, constrain=None):
    """Prefill = full forward, collecting final SSM state per layer."""
    dtype = jnp.dtype(run.compute_dtype)
    B, S = tokens.shape
    h = params["embed"][tokens].astype(dtype)

    def body(h, lp):
        h, (state, conv_tail) = mamba_apply(lp, h, cfg, chunk=run.ssd_chunk,
                                            constrain=constrain,
                                            return_state=True)
        return h, (state, conv_tail)

    h, (states, conv_tails) = L.scan_or_unroll(
        body, h, params["layers"], scan=run.scan_layers, remat=run.remat)
    h = L.rms_norm(h[:, -1:], params["final_norm"]["scale"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(dtype))
    cache = {"state": states, "conv": conv_tails.astype(dtype)}
    return logits[:, 0].astype(_F32), cache


def decode_step(params, caches, token, pos, cfg: ModelConfig, run: RunConfig,
                constrain=None):
    dtype = jnp.dtype(run.compute_dtype)
    h = params["embed"][token].astype(dtype)

    def body(carry, xs):
        h, states, convs = carry
        lp, i = xs
        cache_l = {"state": jax.lax.dynamic_index_in_dim(states, i, 0, False),
                   "conv": jax.lax.dynamic_index_in_dim(convs, i, 0, False)}
        h, nc = mamba_decode(lp, h, cache_l, cfg)
        states = jax.lax.dynamic_update_index_in_dim(states, nc["state"], i, 0)
        convs = jax.lax.dynamic_update_index_in_dim(convs, nc["conv"], i, 0)
        return (h, states, convs), None

    (h, states, convs), _ = L.scan_or_unroll(
        body, (h, caches["state"], caches["conv"]),
        (params["layers"], jnp.arange(cfg.n_layers)),
        scan=run.scan_layers, remat="none")
    h = L.rms_norm(h, params["final_norm"]["scale"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(dtype))
    return logits[:, 0].astype(_F32), {"state": states, "conv": convs}
