"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block every 6
layers (concat global-residual input, width 2d). [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=160,           # shared block runs on 2*d = 5120
    ssm_state=64, ssm_expand=2, ssm_head_dim=80, ssm_groups=1, ssm_conv=4,
    shared_attn_every=6,
)
