"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064, head_dim=96, act="silu", norm="rms",
    rope_theta=10_000.0, n_image_tokens=256,
)
