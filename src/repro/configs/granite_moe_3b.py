"""granite-moe-3b-a800m [moe]: 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64, n_experts=40, top_k=8,
    expert_pad_to=48,   # EP-friendly: 48 %% 16 == 0 (8 dead experts)
)
