"""whisper-medium [audio]: enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356;
unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, norm="layernorm", act="gelu",
    encoder_layers=24, encoder_seq=1500,
)
