"""Architecture config registry: ``get("qwen2-7b")`` etc."""
from .base import ModelConfig, RunConfig, ShapeConfig, SHAPES

_MODULES = {
    "phi-3-vision-4.2b": "phi_3_vision_4p2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen3-14b": "qwen3_14b",
    "qwen2-72b": "qwen2_72b",
    "qwen2-7b": "qwen2_7b",
    "command-r-plus-104b": "command_r_plus_104b",
    "whisper-medium": "whisper_medium",
}

ARCH_IDS = list(_MODULES)


def get(name: str) -> ModelConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ModelConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get"]
