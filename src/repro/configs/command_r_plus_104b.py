"""command-r-plus-104b [dense]: GQA kv=8, no bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-plus; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab=256000, head_dim=128, tie_embeddings=True, rope_theta=75_000.0,
)
