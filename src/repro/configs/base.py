"""Config system: architecture + run configuration dataclasses.

Every assigned architecture gets one ``configs/<id>.py`` exporting
``CONFIG``; ``repro.configs.get(name)`` resolves them.  Shapes (the assigned
seq_len × global_batch cells) live here too.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "RunConfig"]


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact values from the assignment)."""
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rms"                # rms | layernorm
    act: str = "silu"                # silu (gated) | gelu (plain)
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    expert_pad_to: int = 0           # pad expert WEIGHTS to this count so EP
                                     # divides the model axis (dead experts
                                     # are never routed; +mem, zero flops)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0               # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64           # P
    ssm_groups: int = 1              # G
    ssm_conv: int = 4
    # --- hybrid (zamba2-style shared attention block) ---
    shared_attn_every: int = 0       # apply shared block every k ssm layers
    # --- encoder-decoder (whisper-style) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # stub frame count (precomputed embeddings)
    # --- VLM (phi-3-vision-style) ---
    n_image_tokens: int = 0          # stub patch-embedding count
    # --- attention shape policy ---
    attn_kind: str = "full"          # full | none (ssm)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 for clean TP sharding (MaxText-style)."""
        return _ceil_to(self.vocab, 256)

    @property
    def n_experts_padded(self) -> int:
        return self.expert_pad_to or self.n_experts

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hd, h, kv = self.hd, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.act == "silu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            mlp *= self.n_experts
            mlp += d * self.n_experts  # router
        per_layer = attn + mlp + 2 * d
        if self.family in ("ssm", "hybrid"):
            di, g, n, hs = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            ssm = (d * 2 * di              # xz
                   + d * 2 * g * n         # B, C
                   + d * hs                # dt
                   + self.ssm_conv * (di + 2 * g * n)
                   + di * d + 2 * hs + di)  # out, A/D, norm
            if self.family == "ssm":
                per_layer = ssm + 2 * d
            else:  # hybrid: ssm layers + one shared attention block on 2d
                d2 = 2 * d
                shared = (d2 * h * hd + 2 * d2 * kv * hd + h * hd * d
                          + 3 * d2 * f + 2 * d2)
                return emb + self.n_layers * (ssm + 2 * d) + shared
        total = emb + self.n_layers * per_layer
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            enc = self.encoder_layers * (attn + mlp + 2 * d)
            cross = self.n_layers * (attn)  # cross-attn per decoder layer
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D convention)."""
        if self.family != "moe":
            return self.param_count()
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hd, h, kv = self.hd, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * f * self.top_k + d * self.n_experts
        return emb + self.n_layers * (attn + mlp + 2 * d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run knobs (parallelism, numerics, fault tolerance)."""
    activation_sharding: str = "sequence"   # sequence | replicated
    remat: str = "full"                     # none | full | dots
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"                # adamw | arrowhead
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # sTiles arrowhead preconditioner
    precond_proj_dim: int = 32
    precond_band: int = 2
    precond_every: int = 10
    # distributed-optimization tricks
    pod_grad_compression: bool = False      # int8 error-feedback on pod axis
    # fault tolerance
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    max_step_retries: int = 2
    # attention chunking
    q_chunk: int = 512
    kv_chunk: int = 1024
    # SSD (mamba2) chunk length: intra-chunk memory scales with B*S*chunk
    ssd_chunk: int = 64
    # shard SSD heads over the model axis (head-parallel scans/convs)
    ssm_head_shard: bool = False
    # loss chunking (bounds (B, chunk, V) logits temps)
    loss_chunk: int = 512
    # loop-free attention for the cost-analysis harness
    unroll_attn: bool = False
    # gradient accumulation: process the global batch in this many sequential
    # microbatches (activation peak scales ~1/grad_accum; grads accumulate f32)
    grad_accum: int = 1
    # scan vs unrolled layers: scan keeps HLO/compile small (production);
    # unrolled is used by the roofline harness (XLA cost analysis does not
    # multiply while-loop bodies by trip count)
    scan_layers: bool = True
