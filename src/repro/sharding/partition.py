"""Parallelism rules: DP / FSDP / TP / EP / SP partition specs.

Mesh axes (launch/mesh.py): single-pod ``(data, model)``, multi-pod
``(pod, data, model)``.  Policy (DESIGN.md §7):

* batch            -> (pod, data)                      [DP]
* weights          -> input dim on `data` (FSDP/ZeRO-3), output/TP dim on
                      `model` (Megatron column/row)    [FSDP × TP]
* MoE experts      -> expert dim on `model` when divisible (EP), else
                      per-expert d_ff on `model`       [EP]
* activations      -> sequence dim on `model` when run.activation_sharding
                      == "sequence" (Megatron-SP)      [SP]
* decode KV caches -> batch on (pod, data) when divisible, else sequence on
                      `model` (flash-decoding style — sidesteps GQA
                      head-divisibility entirely)

Param specs are derived from leaf *names* (path patterns) + dimensionality,
so every architecture family shares one rule set.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig

__all__ = ["MeshAxes", "Rules", "make_rules"]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]        # ("pod", "data") or ("data",)
    fsdp: str = "data"
    tp: str = "model"

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        dp = tuple(n for n in names if n in ("pod", "data"))
        return cls(dp=dp)


# --- param-name pattern -> (base_ndim, base_spec builder) -------------------

def _param_base_spec(path: str, ndim: int, ax: MeshAxes, cfg: ModelConfig):
    tp, fsdp = ax.tp, ax.fsdp
    name = path.split("/")[-1]
    under_moe = "/moe/" in path or path.endswith("/moe")
    if under_moe and name in ("wi", "wg", "wo"):
        # experts (E, d_in, d_out)
        ep = cfg.n_experts > 0
        # EP if expert count divides the tp axis (checked at mesh-apply time
        # via divisibility of the actual axis; here optimistic — granite-1b
        # E=32 % 16 == 0; granite-3b E=40 -> fallback TP-in-expert)
        if name == "wo":
            return 3, (("E",), (None,), (fsdp,))
        return 3, (("E",), (fsdp,), ("F",))
    if name in ("embed",):
        return 2, ((tp,), (fsdp,))
    if name in ("unembed",):
        return 2, ((fsdp,), (tp,))
    if name in ("wq", "wk", "wv", "wi", "wg", "w_in"):
        return 2, ((fsdp,), (tp,))
    if name in ("wo", "w_out", "proj_out"):
        return 2, ((tp,), (fsdp,))
    if name in ("router",):
        return 2, ((fsdp,), (None,))
    if name in ("enc_pos", "dec_pos"):
        return 2, ((None,), (fsdp,))
    if name in ("conv",):
        return 2, ((None,), (tp,))
    return 1, ((None,),)


class Rules:
    """Bound to a mesh: produces NamedShardings / applies constraints."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig, run: RunConfig,
                 shape: Optional[ShapeConfig] = None):
        self.mesh = mesh
        self.cfg = cfg
        self.run = run
        self.shape = shape
        self.ax = MeshAxes.from_mesh(mesh)
        self.dp_total = 1
        for a in self.ax.dp:
            self.dp_total *= mesh.shape[a]
        self.tp_size = mesh.shape[self.ax.tp]
        self.ep = (cfg.n_experts > 0
                   and cfg.n_experts_padded % self.tp_size == 0)
        # Sequence sharding measured best for ALL families, ssm/hybrid
        # included (EXPERIMENTS.md §Perf zamba track: a DP-only variant
        # tripled the HLO-bytes memory term; forced seq-sharding restored
        # it).  "dp_only" remains as an ablation knob.
        self.seq_sharded = run.activation_sharding in ("sequence",
                                                       "sequence_all")

    # ---- parameters --------------------------------------------------------

    def _resolve(self, entry):
        """Map symbolic axis tags to mesh axes for this config/mesh."""
        out = []
        for dims in entry:
            d = dims[0]
            if d == "E":
                out.append(self.ax.tp if self.ep else None)
            elif d == "F":
                out.append(None if self.ep else self.ax.tp)
            else:
                out.append(d)
        return out

    def param_pspec(self, path: str, leaf) -> P:
        base_ndim, entry = _param_base_spec(path, leaf.ndim, self.ax, self.cfg)
        base = self._resolve(entry)
        extra = leaf.ndim - base_ndim
        if extra < 0:   # e.g. unstacked scalar params
            return P()
        spec = [None] * extra + base
        # drop sharding on axes that don't divide
        for i, s in enumerate(spec):
            if s is None:
                continue
            size = self.mesh.shape[s]
            if leaf.shape[i] % size:
                spec[i] = None
        return P(*spec)

    def param_specs(self, params) -> Any:
        def walk(path, leaf):
            keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
            return self.param_pspec("/".join(str(k) for k in keys), leaf)
        return jax.tree_util.tree_map_with_path(walk, params)

    def param_shardings(self, params) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(params))

    # ---- activations -------------------------------------------------------

    def constrain(self, x, kind: str):
        spec = self.act_pspec(kind, x.ndim)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def act_pspec(self, kind: str, ndim: int) -> Optional[P]:
        dp = self.ax.dp
        tp = self.ax.tp
        sp = tp if self.seq_sharded else None
        if kind == "act" and ndim == 3:          # (B, S, D)
            return P(dp, sp, None)
        if kind == "ff" and ndim == 3:           # (B, S, F)
            return P(dp, None, tp)
        if kind == "experts" and ndim == 4:      # (B, E, C, D)
            return P(dp, tp if self.ep else None, None, None)
        if kind == "experts_ff" and ndim == 4:   # (B, E, C, F)
            return P(dp, tp, None, None) if self.ep else P(dp, None, None, tp)
        if kind == "ssm_x" and ndim == 4:        # (B, S, H, P)
            if self.run.ssm_head_shard:
                return P(dp, None, tp, None)     # head-parallel SSD
            return P(dp, sp, None, None)
        return None

    # ---- run inputs --------------------------------------------------------

    def batch_specs(self, batch) -> Any:
        dp = self.ax.dp

        def spec(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] % self.dp_total == 0 \
                    and leaf.shape[0] >= self.dp_total:
                return NamedSharding(self.mesh, P(dp, *(None,) * (leaf.ndim - 1)))
            return NamedSharding(self.mesh, P(*(None,) * leaf.ndim))
        return jax.tree.map(spec, batch)

    def cache_pspec(self, path: str, leaf) -> P:
        """KV / SSM cache sharding for decode: batch over DP when it divides,
        *and* sequence (KV caches) / heads (SSM state) over the model axis —
        flash-decoding style, which sidesteps GQA head divisibility."""
        dp, tp = self.ax.dp, self.ax.tp
        name = path.split("/")[-1]
        if leaf.ndim >= 2:
            batch = leaf.shape[1]   # (L, B, ...)
            bspec = dp if (batch % self.dp_total == 0) else None
            if name in ("k", "v", "xk", "xv") and leaf.ndim == 5 \
                    and leaf.shape[2] % self.tp_size == 0:
                # (L, B, T, KV, hd): sequence-shard the cache
                return P(None, bspec, tp, None, None)
            if name == "state" and leaf.ndim == 6 \
                    and leaf.shape[3] % self.tp_size == 0:
                # (L, B, G, HG, P, N): shard SSD heads
                return P(None, bspec, None, tp, None, None)
            if name == "conv" and leaf.ndim == 4 \
                    and leaf.shape[3] % self.tp_size == 0:
                return P(None, bspec, None, tp)
            return P(None, bspec, *(None,) * (leaf.ndim - 2))
        return P(*(None,) * leaf.ndim)

    def cache_shardings(self, caches) -> Any:
        def walk(path, leaf):
            keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
            return NamedSharding(self.mesh, self.cache_pspec("/".join(keys), leaf))
        return jax.tree_util.tree_map_with_path(walk, caches)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def make_rules(mesh: Mesh, cfg: ModelConfig, run: RunConfig,
               shape: Optional[ShapeConfig] = None) -> Rules:
    return Rules(mesh, cfg, run, shape)
