"""GPipe-style pipeline parallelism over a mesh axis (`shard_map` + ppermute).

The model is split into S equal stages whose params are stacked on a leading
stage dim and sharded P(axis).  A microbatched forward sweeps the classic
GPipe wavefront: at tick t, stage s processes microbatch (t - s); hidden
states hop stage->stage over `ppermute` (on TPU: neighbour ICI links).  The
whole schedule is differentiable — `jax.grad` through the scan yields the
reverse wavefront, i.e. backward pipelining for free — so this composes with
the training step as an alternative to pure TP for deep models
(`RunConfig` knob; off by default, exercised in tests and the PP example).

Bubble fraction = (S-1)/(M+S-1), the standard GPipe trade; pick M >= 4·S.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_forward", "split_stages"]


def split_stages(stacked_layer_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-stacked."""
    def r(x):
        L = x.shape[0]
        if L % n_stages != 0:
            raise ValueError(f"{L} layers not divisible by {n_stages} stages")
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(r, stacked_layer_params)


def pipeline_forward(stage_fn: Callable, stage_params: Any, x: jnp.ndarray,
                     mesh: Mesh, axis: str = "model",
                     n_microbatches: int = 8, remat: bool = True) -> jnp.ndarray:
    """Run ``y = stages(x)`` through the pipeline.

    stage_fn(stage_params_slice, h) -> h', applied by each stage to the
    hidden state; x: (B, ...) with B % n_microbatches == 0.
    """
    S = mesh.shape[axis]
    M = n_microbatches
    B = x.shape[0]
    if B % M != 0:
        raise ValueError(f"batch {B} % microbatches {M} != 0")
    mb = B // M
    xs = x.reshape((M, mb) + x.shape[1:])

    body = jax.checkpoint(stage_fn) if remat else stage_fn

    def local(params, xs_local):
        # params: (1, L/S, ...) this stage's slice; xs_local: (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        s = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs_local[0])
        outs = jnp.zeros_like(xs_local)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = t - s
            active = (mb_idx >= 0) & (mb_idx < M)
            x0 = jax.lax.dynamic_index_in_dim(
                xs_local, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            inp = jnp.where(s == 0, x0, buf)
            out = body(params, inp)
            out = jnp.where(active, out, jnp.zeros_like(out))
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                active & (s == S - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.clip(mb_idx, 0, M - 1), 0),
                lambda o: o, outs)
            # hop to the next stage (ring permute; stage S-1 -> 0 ignored)
            nxt = jax.lax.ppermute(out, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(M + S - 1))
        # only the last stage holds real outputs (zeros elsewhere): a psum
        # replicates them to every stage
        return jax.lax.psum(outs, axis)

    in_specs = (P(axis), P())
    out_specs = P()
    try:
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except TypeError:
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    outs = jax.jit(fn)(stage_params, xs)
    return outs.reshape((B,) + x.shape[1:])
