"""Collective primitives mirroring the paper's reduction patterns on ICI.

``tree_allreduce`` is the cross-chip form of the paper's GEADD binary tree
(Alg. 3 / Fig. 7): a recursive-halving/doubling butterfly over `ppermute`,
log₂(n) rounds.  On a physical torus XLA's built-in `psum` already lowers to
ring/tree schedules; we keep the explicit version (a) as the faithful port
of the paper's reduction and (b) so the roofline harness can compare
collective-byte footprints of the two schedules (EXPERIMENTS.md §Perf).

``quantized_pod_allreduce`` is the gradient-compression path used across the
slow `pod` axis (DCN): error-feedback int8 — see optim/compress.py for the
error-feedback state handling.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["tree_allreduce", "ring_allreduce", "quantized_allreduce"]


def tree_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Butterfly (recursive-doubling) all-reduce over ``axis_name``.

    Must be called inside shard_map/pmap with that axis.  log₂(n) rounds of
    pairwise exchange — the GEADD tree of Alg. 3 where each GEADD's operands
    sit on different chips.  Requires the axis size to be a power of two
    (all production meshes here are).
    """
    n = jax.lax.axis_size(axis_name)
    if n & (n - 1):
        raise ValueError(f"tree_allreduce needs power-of-two axis, got {n}")
    rounds = int(math.log2(n))
    idx = jax.lax.axis_index(axis_name)
    for r in range(rounds):
        stride = 1 << r
        # partner = idx XOR stride; build the permutation both ways
        perm = [(i, i ^ stride) for i in range(n)]
        other = jax.lax.ppermute(x, axis_name, perm)
        x = x + other
    del idx
    return x


def ring_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Naive ring all-reduce (n-1 rounds) — the *sequential accumulation*
    baseline of paper Table I, for the tree-vs-sequential benchmark."""
    n = jax.lax.axis_size(axis_name)
    acc = x
    perm = [(i, (i + 1) % n) for i in range(n)]
    buf = x
    for _ in range(n - 1):
        buf = jax.lax.ppermute(buf, axis_name, perm)
        acc = acc + buf
    return acc


def quantized_allreduce(x: jnp.ndarray, axis_name: str,
                        bits: int = 8) -> jnp.ndarray:
    """All-reduce with per-tensor int8 quantization on the wire.

    Used on the cross-pod (DCN-like) axis where bandwidth, not latency,
    dominates: 4x byte reduction vs f32 at the cost of one extra max-abs
    all-reduce (tiny).  Dequantized sum is exact up to quantization noise;
    callers keep an error-feedback residual (optim/compress.py).
    """
    qmax = float(2 ** (bits - 1) - 1)
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / qmax + 1e-30
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    # int8 on the wire; sum in int32 (axis size <= 2**23 safe)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(x.dtype) * scale
