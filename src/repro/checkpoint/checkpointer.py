"""Sharding-aware checkpointing: atomic, keep-k, async, elastic-restore.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``meta.json``, written to a temp dir
and atomically renamed (a crashed save never corrupts the latest good
checkpoint).  Restore takes *target shardings*, so a checkpoint written on
one mesh restores onto any other (elastic re-scaling: the arrays are
device_put against the new mesh's NamedShardings).

On a real multi-host pod each host writes its addressable shards; here the
single-host fallback gathers to host (np.asarray) — the API is the same.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, meta: Optional[dict] = None,
             block: bool = False) -> None:
        self.wait()
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, **(meta or {})}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---- restore -------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``template``; ``shardings`` (same
        structure) enables elastic restore onto a different mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        with np.load(path) as data:
            flat_paths = list(_flatten(template).keys())
            arrays = {k: data[k] for k in flat_paths}
        sh_flat = _flatten(shardings) if shardings is not None else {}
        leaves = []
        for (p, leaf) in zip(flat_paths,
                             jax.tree_util.tree_leaves(template)):
            arr = arrays[p]
            if shardings is not None:
                leaves.append(jax.device_put(arr, sh_flat[p]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)

    def meta(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)
