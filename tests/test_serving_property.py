"""Hypothesis property tests for the rung-server batcher.

The scheduler in ``launch/rung_server.py`` is a pure, clock-injected
state machine, so its invariants can be checked over *arbitrary*
arrival/deadline interleavings with no threads, no device work, and no
wall-clock time — requests here are lightweight stand-ins that carry
only a grid.  The invariants:

* conservation — no request is lost or duplicated across any
  interleaving of batch-full, deadline-expiry, and drain flushes;
* deadline budget — every request leaves its queue no later than the
  flush-by time committed at submit (``min(now + max_delay,
  deadline)``), unless an earlier batch-full flush takes it sooner;
* rung keying — each flushed batch's key equals
  ``GridBucketPolicy.canonicalize`` of every member's grid (plus the
  shared RHS width);
* determinism — the same plan replayed twice emits identical batch
  signatures in identical order.
"""
import types

import pytest

from repro.core import GridBucketPolicy, TileGrid
from repro.launch.rung_server import FLUSH_FULL, RungRequest, RungScheduler

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.serving

SETTINGS = dict(max_examples=12, deadline=None)


def _fake_request(rid, grid, k=None, deadline=None):
    import numpy as np
    rhs = None if k is None else np.zeros((1, k), np.float32)
    return RungRequest(rid=rid, matrix=types.SimpleNamespace(grid=grid),
                       rhs=rhs, deadline=deadline)


def _grid(ndt):
    return TileGrid.from_tile_counts(8, ndt, 1, 1)


@st.composite
def arrival_plan(draw):
    """(max_batch, max_delay, [(gap, ndt, k, rel_deadline)...]) — arbitrary
    mixed-rung arrivals with optional per-request deadlines."""
    max_batch = draw(st.integers(1, 4))
    max_delay = draw(st.sampled_from([0.0, 0.5, 2.0]))
    events = draw(st.lists(st.tuples(
        st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False),
        st.integers(3, 10),                       # source ndt
        st.sampled_from([None, 1, 3]),            # rhs width
        st.sampled_from([None, 0.0, 0.25, 1.0]),  # deadline - arrival
    ), min_size=1, max_size=16))
    return max_batch, max_delay, events


@given(arrival_plan())
@settings(max_examples=25, deadline=None)
def test_scheduler_invariants(plan):
    """Conservation + deadline budget + rung keying, in one sweep."""
    max_batch, max_delay, events = plan
    policy = GridBucketPolicy()
    s = RungScheduler(policy=policy, max_batch=max_batch,
                      max_delay=max_delay)
    flushed = []
    now, rid = 0.0, 0
    requests = {}
    for gap, ndt, k, rel_dl in events:
        now += gap
        req = _fake_request(rid, _grid(ndt), k=k,
                            deadline=None if rel_dl is None else now + rel_dl)
        requests[rid] = req
        rid += 1
        flushed += s.tick(now, [req])
        nxt = s.next_flush_by()
        if nxt is not None and nxt <= now:
            # a zero-budget deadline flushes on the very next tick
            flushed += s.tick(now)
    end = now + max_delay + 1.0
    flushed += s.tick(end)
    flushed += s.drain(end)

    seen = [r.rid for b in flushed for r in b.requests]
    assert sorted(seen) == sorted(requests)       # no loss, no duplication
    for b in flushed:
        cgrid, k = b.key
        for r in b.requests:
            assert cgrid == policy.canonicalize(r.matrix.grid)
            assert r.k == k
            # flushed no later than the committed flush-by time (drain at
            # `end` is past every budget, so this covers it too)
            assert b.decided_at <= r.flush_by or b.reason == FLUSH_FULL


@given(arrival_plan())
@settings(**SETTINGS)
def test_scheduler_replay_identical(plan):
    """The state machine itself is deterministic: the same plan replayed
    twice emits the same batch signatures in the same order."""
    max_batch, max_delay, events = plan

    def run():
        s = RungScheduler(max_batch=max_batch, max_delay=max_delay)
        out, now = [], 0.0
        for i, (gap, ndt, k, rel_dl) in enumerate(events):
            now += gap
            out += s.tick(now, [_fake_request(
                i, _grid(ndt), k=k,
                deadline=None if rel_dl is None else now + rel_dl)])
        out += s.drain(now + max_delay + 1.0)
        return [b.signature() for b in out]

    assert run() == run()
