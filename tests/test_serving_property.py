"""Hypothesis property tests for the rung-server batcher.

The scheduler in ``launch/rung_server.py`` is a pure, clock-injected
state machine, so its invariants can be checked over *arbitrary*
arrival/deadline interleavings with no threads, no device work, and no
wall-clock time — requests here are lightweight stand-ins that carry
only a grid.  The invariants:

* conservation — no request is lost or duplicated across any
  interleaving of batch-full, deadline-expiry, shed, and drain flushes;
* deadline budget — every request leaves its queue at the driver's
  first opportunity at or past the flush-by time committed at submit
  (``min(now + max_delay, deadline)``): no later than the first tick at
  or after flush-by, unless an earlier batch-full flush takes it sooner
  or its deadline expired between ticks (then it leaves as an explicit
  ``FLUSH_SHED`` batch, never silently);
* rung keying — each flushed batch's key equals
  ``GridBucketPolicy.canonicalize`` of every member's grid (plus the
  shared RHS width);
* determinism — the same plan replayed twice emits identical batch
  signatures in identical order.

The full-server property drives a complete :class:`RungServer` (fake
executor, injected faults, admission bounds) through arbitrary
interleavings and asserts the end-to-end resilience contract: every
submitted request resolves exactly once — never lost, duplicated, or
left unresolved — and every terminal status is in the closed set
{OK, RECOVERED, FAILED, SHED}.
"""
import types

import pytest

from repro.core import (STATUS_FAILED, STATUS_OK, STATUS_RECOVERED,
                        STATUS_SHED, GridBucketPolicy, TileGrid)
from repro.launch.rung_server import (FLUSH_FULL, FLUSH_SHED, SHED_DEADLINE,
                                      DegradationPolicy, RungOverloadError,
                                      RungRequest, RungResult, RungScheduler,
                                      RungServer, SimClock)
from repro.runtime import telemetry

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.serving

SETTINGS = dict(max_examples=12, deadline=None)


def _fake_request(rid, grid, k=None, deadline=None):
    import numpy as np
    rhs = None if k is None else np.zeros((1, k), np.float32)
    return RungRequest(rid=rid, matrix=types.SimpleNamespace(grid=grid),
                       rhs=rhs, deadline=deadline)


def _grid(ndt):
    return TileGrid.from_tile_counts(8, ndt, 1, 1)


@st.composite
def arrival_plan(draw):
    """(max_batch, max_delay, [(gap, ndt, k, rel_deadline)...]) — arbitrary
    mixed-rung arrivals with optional per-request deadlines."""
    max_batch = draw(st.integers(1, 4))
    max_delay = draw(st.sampled_from([0.0, 0.5, 2.0]))
    events = draw(st.lists(st.tuples(
        st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False),
        st.integers(3, 10),                       # source ndt
        st.sampled_from([None, 1, 3]),            # rhs width
        st.sampled_from([None, 0.0, 0.25, 1.0]),  # deadline - arrival
    ), min_size=1, max_size=16))
    return max_batch, max_delay, events


@given(arrival_plan())
@settings(max_examples=25, deadline=None)
def test_scheduler_invariants(plan):
    """Conservation + deadline budget + rung keying, in one sweep."""
    max_batch, max_delay, events = plan
    policy = GridBucketPolicy()
    s = RungScheduler(policy=policy, max_batch=max_batch,
                      max_delay=max_delay)
    flushed = []
    now, rid = 0.0, 0
    requests = {}
    ticks = []
    for gap, ndt, k, rel_dl in events:
        now += gap
        req = _fake_request(rid, _grid(ndt), k=k,
                            deadline=None if rel_dl is None else now + rel_dl)
        requests[rid] = req
        rid += 1
        flushed += s.tick(now, [req])
        ticks.append(now)
        nxt = s.next_flush_by()
        if nxt is not None and nxt <= now:
            # a zero-budget deadline flushes on the very next tick
            flushed += s.tick(now)
            ticks.append(now)
    end = now + max_delay + 1.0
    flushed += s.tick(end)
    flushed += s.drain(end)
    ticks.append(end)

    seen = [r.rid for b in flushed for r in b.requests]
    assert sorted(seen) == sorted(requests)       # no loss, no duplication
    for b in flushed:
        cgrid, k = b.key
        for r in b.requests:
            assert cgrid == policy.canonicalize(r.matrix.grid)
            assert r.k == k
            if b.reason == FLUSH_SHED:
                # shedding is always explicit and justified: only a
                # request whose deadline truly passed between ticks may
                # leave this way
                assert b.detail == SHED_DEADLINE
                assert r.deadline is not None and b.decided_at > r.deadline
            else:
                # flushed at the driver's first opportunity at or past
                # flush-by (a tick may land late; the scheduler must not
                # hold the request past the *next* tick), unless an
                # earlier batch-full flush took it sooner
                first_due = min(t for t in ticks if t >= r.flush_by)
                assert b.decided_at <= first_due or b.reason == FLUSH_FULL


@given(arrival_plan())
@settings(**SETTINGS)
def test_scheduler_replay_identical(plan):
    """The state machine itself is deterministic: the same plan replayed
    twice emits the same batch signatures in the same order."""
    max_batch, max_delay, events = plan

    def run():
        s = RungScheduler(max_batch=max_batch, max_delay=max_delay)
        out, now = [], 0.0
        for i, (gap, ndt, k, rel_dl) in enumerate(events):
            now += gap
            out += s.tick(now, [_fake_request(
                i, _grid(ndt), k=k,
                deadline=None if rel_dl is None else now + rel_dl)])
        out += s.drain(now + max_delay + 1.0)
        return [b.signature() for b in out]

    assert run() == run()


# ---------------------------------------------------------------------------
# full-server resilience property: conservation under faults + overload
# ---------------------------------------------------------------------------

TERMINAL = {STATUS_OK, STATUS_RECOVERED, STATUS_FAILED, STATUS_SHED}


class _ChaoticExecutor:
    """Fake device: resolves futures with OK results, but fails dispatch
    for scripted rids — ``poison`` forever, ``flaky`` once each."""

    def __init__(self, poison, flaky):
        self.poison = set(poison)
        self.flaky = dict.fromkeys(flaky, 1)

    def dispatch(self, batch, now):
        for r in batch.requests:
            if r.rid in self.poison:
                raise RuntimeError(f"poison {r.rid}")
        for r in batch.requests:
            if self.flaky.get(r.rid, 0) > 0:
                self.flaky[r.rid] -= 1
                raise RuntimeError(f"flaky {r.rid}")
        return batch

    def finalize(self, batch, now):
        out = []
        for r in batch.requests:
            res = RungResult(rid=r.rid, status=STATUS_OK, attempts=1,
                             tau=0.0, x=None, factor=None,
                             latency=now - r.arrival, wall_latency_s=0.0,
                             flush_reason=batch.reason,
                             batch_size=len(batch.requests),
                             rung=telemetry.rung_tag(batch.key[0]))
            if r.future is not None:
                r.future._resolve(res)
            out.append(res)
        return out


@st.composite
def server_plan(draw):
    """Arbitrary interleaving of arrivals (gap, rung, deadline, fault)
    with server-shape knobs: queue bounds, overload mode, degradation."""
    events = draw(st.lists(st.tuples(
        st.sampled_from([0.0, 4e-4, 1.1e-3, 6e-3]),   # inter-arrival gap
        st.sampled_from([6, 9]),                       # rung (source ndt)
        st.sampled_from([None, 0.0, 1e-3, 5e-3]),      # deadline - arrival
        st.sampled_from([None, "flaky", "poison"]),    # dispatch fault
    ), min_size=1, max_size=24))
    max_queue = draw(st.sampled_from([None, 1, 2, 4]))
    on_overload = draw(st.sampled_from(["raise", "shed"]))
    degrade = draw(st.booleans())
    max_batch = draw(st.integers(1, 3))
    return events, max_queue, on_overload, degrade, max_batch


@given(server_plan())
@settings(max_examples=20, deadline=None)
def test_server_conservation_under_faults_and_overload(plan):
    """No request is ever lost, duplicated, or left unresolved — across
    arbitrary interleavings of arrivals, deadline expiries, dispatch
    faults (transient and poison), queue-bound rejections, and shutdown
    — and every terminal status is in the closed taxonomy."""
    events, max_queue, on_overload, degrade, max_batch = plan
    poison = {i for i, e in enumerate(events) if e[3] == "poison"}
    flaky = {i for i, e in enumerate(events) if e[3] == "flaky"}
    clock = SimClock()
    server = RungServer(
        clock=clock, executor=_ChaoticExecutor(poison, flaky),
        injector=None, max_batch=max_batch, max_delay=2e-3,
        max_queue=max_queue, on_overload=on_overload,
        degradation=DegradationPolicy(step_dwell=0.0) if degrade else None,
        max_retries=1, backoff_base=1e-5, breaker_threshold=3,
        breaker_reset=5e-3)
    futures, rejected = {}, 0
    for i, (gap, ndt, rel_dl, _fault) in enumerate(events):
        clock.advance(gap)
        dl = None if rel_dl is None else clock.now + rel_dl
        try:
            futures[i] = server.submit(
                types.SimpleNamespace(grid=_grid(ndt)), deadline=dl)
        except RungOverloadError:
            rejected += 1                  # typed backpressure, no future
        server.pump()
    server.drain()

    assert len(futures) + rejected == len(events)  # every event accounted
    for i, fut in futures.items():
        assert fut.done()                          # nothing left hanging
        r = fut.result(timeout=0)
        assert r.rid == fut.rid
        assert r.status in TERMINAL                # closed status taxonomy
        assert fut.duplicate_resolves == 0         # resolved exactly once
        if i in poison and r.status not in (STATUS_SHED,):
            assert r.status == STATUS_FAILED       # poison never "succeeds"
        if r.status == STATUS_SHED:
            assert r.detail                        # shed always says why
