"""Multi-partition fused Cholesky sweep: parity, bit-identity, batching.

The partitioned sweep runs one 2D Pallas launch — a parallel axis over
the independent band partitions of a block-separable problem (the
adaptive-ND shape, paper §III-A) and a sequential axis within each
partition — with per-partition corner Schur chunks combined by the GEADD
tree before the shared separator factorization.  These tests pin the
numerical contracts:

* ref and Pallas backends agree at 1/2/4 partitions;
* within a backend, the partitioned sweep is *bit-identical* to the
  fused single-partition sweep on block-separable inputs (the partitions
  really are independent — same tile math, same order);
* a trivial (single-partition) plan routes to the existing fused path
  and reproduces it bit for bit, corner included;
* ``start_tile`` identity prefixes and ``vmap`` compose with the 2D grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core import (BandedCTSF, SolverOptions, TileGrid,
                        detect_partition_plan, factorize_window,
                        factorize_window_batched)
from repro.core.ordering import PartitionPlan
from repro.data import block_separable_arrowhead, make_arrowhead
from repro.kernels import ops, ref
from repro.kernels.ring import band_row_to_col

CASE = dict(n=100, bandwidth=5, arrow=4, t=8)


def _split_inputs(n_parts, seed=0, **case):
    case = {**CASE, **case}
    A, st, bounds = block_separable_arrowhead(
        n_parts=n_parts, seed=seed, **case)
    g = TileGrid(st, case["t"])
    m = BandedCTSF.from_sparse(A, g)
    return A, g, m, bounds


@pytest.mark.parametrize("n_parts", [1, 2, 4])
def test_partitioned_sweep_ref_matches_pallas(n_parts):
    _, g, m, bounds = _split_inputs(n_parts)
    Ac = band_row_to_col(m.Dr)
    out_ref = ref.band_cholesky_partitioned_sweep_ref(Ac, m.R, bounds)
    out_pl = ops.band_cholesky_partitioned_sweep(Ac, m.R, bounds,
                                                 impl="pallas")
    for a, b in zip(out_ref, out_pl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("n_parts", [2, 4])
def test_partitioned_bit_identical_to_fused_within_backend(impl, n_parts):
    """On a genuinely block-separable input the fused sweep performs the
    identical per-partition tile math, so panels and arrow rows match bit
    for bit; only the Schur *chunking* differs (one chunk per partition
    vs nchunks), so the corner contributions agree to a sum reorder."""
    _, g, m, bounds = _split_inputs(n_parts)
    Ac = band_row_to_col(m.Dr)
    p_f, r_f, sch_f, st_f = ops.band_cholesky_sweep(Ac, m.R, nchunks=1,
                                                    impl=impl)
    p_p, r_p, sch_p, st_p = ops.band_cholesky_partitioned_sweep(
        Ac, m.R, bounds, impl=impl)
    assert np.asarray(p_f).tobytes() == np.asarray(p_p).tobytes()
    assert np.asarray(r_f).tobytes() == np.asarray(r_p).tobytes()
    np.testing.assert_allclose(np.asarray(sch_f[0]),
                               np.asarray(sch_p.sum(0)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_f), np.asarray(st_p))


def test_single_partition_plan_reproduces_fused_factorization():
    A, st = make_arrowhead(**{k: CASE[k] for k in ("n", "bandwidth")},
                           arrow=CASE["arrow"], seed=3)
    g = TileGrid(st, CASE["t"])
    m = BandedCTSF.from_sparse(A, g)
    plan = PartitionPlan.trivial(g.n_diag_tiles)
    base = factorize_window(m, options=SolverOptions(impl="ref"))
    via_plan = factorize_window(
        m, options=SolverOptions(impl="ref", partition_plan=plan))
    for a, b in zip(base.ctsf.arrays(), via_plan.ctsf.arrays()):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_partitioned_factorization_matches_dense_cholesky(impl):
    A, g, m, bounds = _split_inputs(3)
    plan = PartitionPlan(boundaries=bounds, sep_tiles=g.n_arrow_tiles)
    f = factorize_window(
        m, options=SolverOptions(impl=impl, partition_plan=plan))
    L = np.linalg.cholesky(m.to_dense(lower_only=True)
                           + np.triu(m.to_dense(lower_only=True).T, 1))
    err = np.abs(f.ctsf.to_dense() - np.tril(L)).max()
    assert err < 1e-3 * max(1.0, np.abs(L).max())


def test_detect_partition_plan_certifies_generator_cuts():
    A, g, m, bounds = _split_inputs(3)
    plan = detect_partition_plan(A, g.structure, g.t)
    assert plan.boundaries == bounds
    assert plan.n_partitions == 3
    assert plan.sep_tiles == g.n_arrow_tiles
    # a dense-band matrix detects as a single partition
    A1, st1 = make_arrowhead(CASE["n"], CASE["bandwidth"], CASE["arrow"],
                             seed=1)
    assert detect_partition_plan(A1, st1, CASE["t"]).n_partitions == 1


def test_auto_sweep_dispatches_partitioned_only_for_multi_partition_plans():
    _, g, m, bounds = _split_inputs(2)
    plan = PartitionPlan(boundaries=bounds, sep_tiles=g.n_arrow_tiles)
    assert plan.n_partitions == 2
    f_auto = factorize_window(
        m, options=SolverOptions(impl="ref", partition_plan=plan))
    f_expl = factorize_window(
        m, options=SolverOptions(impl="ref", sweep="partitioned",
                                 partition_plan=plan))
    for a, b in zip(f_auto.ctsf.arrays(), f_expl.ctsf.arrays()):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    with pytest.raises(ValueError):
        factorize_window(m, options=SolverOptions(sweep="partitioned"))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_partitioned_start_tile_prefix(impl):
    """A start_tile identity prefix (the canonical-grid embedding skip)
    emits identity panels before ``start_tile`` and the real
    factorization after, exactly like the fused sweep."""
    _, g, m, bounds = _split_inputs(2)
    Ac = np.asarray(band_row_to_col(m.Dr))
    start = bounds[1]            # skip the whole first partition
    eye = np.zeros_like(Ac)
    eye[:, 0] = np.eye(g.t, dtype=Ac.dtype)
    Ac_embedded = np.where(
        (np.arange(g.n_diag_tiles) < start)[:, None, None, None], eye, Ac)
    R_embedded = np.asarray(m.R).copy()
    R_embedded[:start] = 0.0
    p, r, sch, _ = ops.band_cholesky_partitioned_sweep(
        jnp.asarray(Ac_embedded), jnp.asarray(R_embedded), bounds,
        start_tile=start, impl=impl)
    np.testing.assert_array_equal(np.asarray(p[:start]), eye[:start])
    np.testing.assert_array_equal(np.asarray(r[:start]), 0.0)
    p_full, r_full, _, _ = ops.band_cholesky_partitioned_sweep(
        jnp.asarray(Ac), jnp.asarray(m.R), bounds, impl=impl)
    np.testing.assert_allclose(np.asarray(p[start:]),
                               np.asarray(p_full[start:]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_partitioned_sweep_vmaps(impl):
    _, g, m, bounds = _split_inputs(2)
    _, _, m2, _ = _split_inputs(2, seed=1)
    Ac = jnp.stack([band_row_to_col(m.Dr), band_row_to_col(m2.Dr)])
    R = jnp.stack([m.R, m2.R])
    fn = jax.vmap(lambda a, r: ops.band_cholesky_partitioned_sweep(
        a, r, bounds, impl=impl))
    p, ro, sch, st = fn(Ac, R)
    for i, mm in enumerate((m, m2)):
        p1, r1, s1, st1 = ops.band_cholesky_partitioned_sweep(
            band_row_to_col(mm.Dr), mm.R, bounds, impl=impl)
        np.testing.assert_allclose(np.asarray(p[i]), np.asarray(p1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ro[i]), np.asarray(r1),
                                   rtol=1e-5, atol=1e-6)


def test_factorize_window_batched_with_plan():
    A, g, m, bounds = _split_inputs(2)
    _, _, m2, _ = _split_inputs(2, seed=1)
    plan = PartitionPlan(boundaries=bounds, sep_tiles=g.n_arrow_tiles)
    opts = SolverOptions(impl="ref", partition_plan=plan)
    fb = factorize_window_batched([m, m2], options=opts)
    for i, mm in enumerate((m, m2)):
        fi = factorize_window(mm, options=opts)
        np.testing.assert_allclose(np.asarray(fb.ctsf.Dr[i]),
                                   np.asarray(fi.ctsf.Dr),
                                   rtol=1e-5, atol=1e-6)


def test_partition_plan_validation():
    with pytest.raises(ValueError):
        PartitionPlan(boundaries=(0,))             # too short
    with pytest.raises(ValueError):
        PartitionPlan(boundaries=(1, 4))           # must start at 0
    with pytest.raises(ValueError):
        PartitionPlan(boundaries=(0, 4, 4))        # strictly increasing
    with pytest.raises(ValueError):
        PartitionPlan(boundaries=(0, 4), sep_tiles=-1)
    plan = PartitionPlan(boundaries=(0, 3, 8), sep_tiles=2)
    assert plan.n_partitions == 2
    assert plan.n_tiles == 8
    assert plan.sizes == (3, 5)
    assert plan.max_tiles == 5
    assert plan.shifted(2).boundaries == (0, 5, 10)
    assert PartitionPlan.trivial(6).boundaries == (0, 6)
    # a plan sized for a different grid is rejected at dispatch
    _, g, m, _ = _split_inputs(2)
    bad = PartitionPlan.trivial(g.n_diag_tiles + 1)
    with pytest.raises(ValueError):
        factorize_window(m, options=SolverOptions(partition_plan=bad))


def test_partitioned_sweep_property_random_block_separable():
    pytest.importorskip("hypothesis",
                       reason="property tests need the hypothesis package")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(n_parts=st.integers(1, 4), seed=st.integers(0, 99),
           bw=st.integers(3, 9))
    def run(n_parts, seed, bw):
        A, g, m, bounds = _split_inputs(n_parts, seed=seed, bandwidth=bw)
        Ac = band_row_to_col(m.Dr)
        p_f, r_f, _, _ = ops.band_cholesky_sweep(Ac, m.R, nchunks=1,
                                                 impl="ref")
        p_p, r_p, _, _ = ops.band_cholesky_partitioned_sweep(
            Ac, m.R, bounds, impl="ref")
        assert np.asarray(p_f).tobytes() == np.asarray(p_p).tobytes()
        assert np.asarray(r_f).tobytes() == np.asarray(r_p).tobytes()

    run()
