"""End-to-end behaviour: training loop drives loss down; serving generates;
fault injection mid-training recovers; dry-run machinery works on a small
cell (subprocess with 512 fake devices)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from _mdev import REPO, run_multidevice
from repro.launch.train import train
from repro.runtime.fault_tolerance import FailureInjector


@pytest.mark.slow
def test_training_reduces_loss():
    out = train("qwen2-7b", steps=40, batch=8, seq=64, log_every=0,
                checkpoint_dir="/tmp/repro_test_ckpt_a")
    losses = out["losses"]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_training_with_arrowhead_optimizer_reduces_loss():
    out = train("qwen2-7b", steps=40, batch=8, seq=64, log_every=0,
                optimizer="arrowhead", checkpoint_dir="/tmp/repro_test_ckpt_b")
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_training_survives_injected_failures():
    inj = FailureInjector({7: 1, 13: 5})   # transient at 7; hard at 13
    out = train("granite-moe-1b-a400m", steps=20, batch=4, seq=32,
                log_every=0, injector=inj,
                checkpoint_dir="/tmp/repro_test_ckpt_c")
    assert int(out["state"].step) == 20    # finished despite failures
    assert 7 in inj.injected and 13 in inj.injected


@pytest.mark.slow
def test_serve_generates_tokens():
    from repro.configs import get
    from repro.configs.base import RunConfig
    from repro.launch.serve import Server
    from repro.launch.train import reduce_config
    cfg = reduce_config(get("qwen2-7b"), layers=2, d_model=64)
    server = Server(cfg, RunConfig(remat="none", loss_chunk=64), max_len=48)
    batch = {"tokens": np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 16)).astype(np.int32)}
    out = server.generate(batch, gen_len=8)
    assert out["tokens"].shape == (2, 8)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_padded).all()


@pytest.mark.slow
def test_dryrun_cell_small():
    """Full dry-run machinery on the cheapest real cell, 512 fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-1.3b",
         "--shape", "long_500k", "--no-extrapolate", "--out",
         "/tmp/repro_test_dryrun"],
        env=env, capture_output=True, text=True, timeout=560, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    with open("/tmp/repro_test_dryrun/mamba2-1.3b_long_500k_single.json") as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["memory"]["total_per_device_gib"] < 16.0   # fits v5e


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[1024,16]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16]
  %ag = bf16[512]{0} all-gather(%y), dimensions={0}, replica_groups=[8,2]<=[16]
  %rs = f32[8]{0} reduce-scatter(%z), dimensions={0}, replica_groups=[2,8]<=[16]
  %ags = (f32[64]{0}) all-gather-start(%q), replica_groups=[1,4]<=[4]
  %agd = f32[64]{0} all-gather-done(%ags)
  %cp = f32[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %other = f32[999]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 16 * 4
    assert out["all-gather"] == 512 * 2 / 2 + 64 * 4 / 4
    assert out["reduce-scatter"] == 8 * 4 * 8
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_mesh_functions_do_not_touch_devices():
    """Importing mesh.py must not initialize jax device state."""
    code = ("import repro.launch.mesh as m; import sys; "
            "assert 'jax' in sys.modules; print('OK')")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
