"""Preprocessing phase: structure measurement, tile mapping, orderings."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (ArrowheadStructure, TileGrid, measure_arrowhead,
                        tile_pattern_from_coo, banded_arrowhead_tile_pattern,
                        symbolic_factorize)
from repro.core.ordering import (adaptive_nd_ordering, amd_ordering,
                                 apply_permutation, best_ordering,
                                 rcm_ordering, tile_fill_in)
from repro.data import make_arrowhead


def test_measure_arrowhead_recovers_structure():
    A, st = make_arrowhead(300, 20, 12, seed=0)
    m = measure_arrowhead(A, arrow_hint=12)
    assert m.n == 300 and m.arrow == 12
    assert m.bandwidth <= 20 + 1  # generator band <= requested


def test_tile_grid_counts():
    st = ArrowheadStructure(n=200, bandwidth=24, arrow=16)
    g = TileGrid(st, t=16)
    assert g.n_diag_tiles == 12 and g.n_arrow_tiles == 1
    assert g.band_tiles == 2
    assert g.padded_n == 13 * 16


def test_tile_pattern_matches_band():
    A, st = make_arrowhead(200, 24, 16, seed=1)
    g = TileGrid(st, t=16)
    tiles = tile_pattern_from_coo(A, g)
    full = banded_arrowhead_tile_pattern(g)
    # actual nonzero tiles are a subset of the structural band pattern
    assert not (tiles & ~full).any()
    # diagonal always present
    assert tiles.diagonal().all()


def test_density_formula():
    st = ArrowheadStructure(n=100, bandwidth=5, arrow=4)
    d = st.density()
    assert 0 < d < 1


@pytest.mark.parametrize("partial", [True, False])
def test_rcm_is_permutation(partial):
    A, st = make_arrowhead(150, 16, 8, seed=2)
    perm = rcm_ordering(A, st, partial=partial)
    assert sorted(perm.tolist()) == list(range(150))
    if partial:
        # arrow region untouched (paper Fig. 3)
        assert (perm[-8:] == np.arange(142, 150)).all()


def test_amd_is_permutation():
    A, st = make_arrowhead(120, 12, 6, seed=3)
    perm = amd_ordering(A, st, partial=True)
    assert sorted(perm.tolist()) == list(range(120))
    assert (perm[-6:] == np.arange(114, 120)).all()


def test_adaptive_nd_partitions_independent():
    # rho=0 -> block diagonal: adaptive ND must produce independent parts
    A, st = make_arrowhead(256 + 16, 16, 16, rho=0.0, seed=4)
    res = adaptive_nd_ordering(A, st, n_parts=2)
    assert res.accepted
    assert sorted(res.perm.tolist()) == list(range(272))
    permuted = apply_permutation(A, res.perm)
    # partitions must not couple: check block structure of permuted matrix
    p_ids = res.partitions
    part0 = np.nonzero(p_ids == 0)[0]
    part1 = np.nonzero(p_ids == 1)[0]
    sub = sp.csr_matrix(permuted)[part0][:, part1]
    assert sub.nnz == 0


def test_fill_in_acceptance_rule():
    """The paper: 'if there is no improvement, the method is not used.'"""
    A, st = make_arrowhead(200, 24, 8, seed=5)
    res = best_ordering(A, st, t=16)
    assert res.fill_after <= res.fill_before
    if not res.accepted:
        assert (res.perm == np.arange(200)).all()


def test_scrambled_matrix_ordering_reduces_fill():
    """Scramble a banded matrix; RCM must recover (reduce tile fill)."""
    A, st = make_arrowhead(240, 12, 0, seed=6)
    rng = np.random.default_rng(0)
    perm = rng.permutation(240)
    scrambled = apply_permutation(A, perm)
    s_struct = measure_arrowhead(scrambled, arrow_hint=0)
    fill_scrambled = tile_fill_in(scrambled, s_struct, 16, total=True)
    res = best_ordering(scrambled, s_struct, t=16)
    assert res.accepted
    assert res.fill_after < fill_scrambled


def test_symbolic_thin_dag_for_arrowhead():
    """Fig. 2: the arrowhead DAG is thinner than the dense one."""
    n = 8
    dense = np.tril(np.ones((n, n), bool))
    arrow = np.zeros((n, n), bool)
    for k in range(n):
        arrow[k, k] = True
        if k + 1 < n - 1:
            arrow[k + 1, k] = True
        arrow[n - 1, k] = True
    sd = symbolic_factorize(dense)
    sa = symbolic_factorize(np.tril(arrow))
    assert sa.max_parallelism() < sd.max_parallelism()
    assert len(sa.tasks) < len(sd.tasks)


def test_symbolic_fill_counted():
    n = 6
    patt = np.eye(n, dtype=bool)
    patt[n - 1, :] = True  # arrow row -> no fill (already last)
    s = symbolic_factorize(np.tril(patt))
    assert s.fill_tiles == 0
    # first-column spike -> fills the whole trailing block
    patt2 = np.eye(n, dtype=bool)
    patt2[:, 0] = True
    s2 = symbolic_factorize(np.tril(patt2))
    assert s2.fill_tiles > 0
