"""API-surface contract tests: the ``repro.api`` facade and the unified
``SolverOptions`` knob object.

Two golden snapshots pin the public surface — ``repro.api.__all__`` and
the ``SolverOptions`` field set/defaults — so additions are deliberate
diffs and removals are loud failures.  The shim tests (marked
``legacy_shim``) assert every deprecated per-call kwarg still works and
warns exactly once; the options-path tests assert the blessed spelling
is silent under ``-W error::DeprecationWarning``.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro import api
from repro.core import BandedCTSF, TileGrid
from repro.core.options import SolverOptions, resolve_options
from repro.data import make_arrowhead

API_SNAPSHOT = [
    # matrix + grid types
    "ArrowheadStructure", "BandedCTSF", "TileGrid", "measure_arrowhead",
    # the one knob object + its ingredients
    "SolverOptions", "GridBucketPolicy", "PartitionPlan", "RegularizePolicy",
    # orderings / partition detection
    "adaptive_nd_ordering", "detect_partition_plan",
    "partition_plan_from_ordering",
    # factorization
    "CholeskyFactor", "FactorInfo", "factorize_window",
    "factorize_window_batched", "concurrent_factorize", "stack_ctsf",
    # solves
    "solve", "solve_many", "solve_many_batched", "forward_solve",
    "forward_solve_many", "backward_solve", "backward_solve_many",
    "concurrent_solve", "concurrent_quadratic_forms", "logdet",
    "concurrent_logdet", "sample_gmrf", "sample_gmrf_many",
    # selected inversion
    "SelectedInverse", "selected_inverse", "selinv_batched",
    "concurrent_selinv", "marginal_variances",
    # per-element status codes on FactorInfo
    "STATUS_OK", "STATUS_RECOVERED", "STATUS_FAILED", "STATUS_SHED",
    # serving
    "RungServer", "SimClock",
]

OPTIONS_FIELDS = {
    "policy": None,
    "regularize": None,
    "impl": None,
    "sweep": "auto",
    "partition_plan": None,
    "method": None,
}


def _factor(opts=None):
    A, st = make_arrowhead(64, 6, 4, seed=0)
    m = BandedCTSF.from_sparse(A, TileGrid(st, 8))
    return api.factorize_window(
        m, options=opts or SolverOptions(impl="ref")), m


# ---------------------------------------------------------------------------
# golden snapshots
# ---------------------------------------------------------------------------

def test_api_all_snapshot():
    assert list(api.__all__) == API_SNAPSHOT


def test_api_names_resolve():
    for name in api.__all__:
        assert getattr(api, name) is not None


def test_solver_options_field_snapshot():
    fields = {f.name: f.default for f in dataclasses.fields(SolverOptions)}
    assert fields == OPTIONS_FIELDS


def test_solver_options_frozen_and_hashable():
    opts = SolverOptions(impl="ref")
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.impl = "pallas"
    assert hash(opts) == hash(SolverOptions(impl="ref"))
    assert opts != SolverOptions(impl="pallas")
    assert opts.replace(sweep="fused").sweep == "fused"
    assert opts.replace(sweep="fused") is not opts


def test_compile_key_drops_non_compile_fields():
    from repro.core.robustness import RegularizePolicy
    a = SolverOptions(impl="ref", regularize=RegularizePolicy(),
                      method="panels")
    b = SolverOptions(impl="ref")
    assert a.compile_key() == b.compile_key()
    assert a.compile_key() != SolverOptions(impl="pallas").compile_key()


def test_resolve_options_rejects_wrong_type():
    with pytest.raises(TypeError):
        resolve_options({"impl": "ref"})


# ---------------------------------------------------------------------------
# the blessed options path is silent
# ---------------------------------------------------------------------------

def test_options_path_emits_no_deprecation_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        opts = SolverOptions(impl="ref")
        f, m = _factor(opts)
        b = np.zeros((m.grid.padded_n, 2), np.float32)
        b[:3, :] = 1.0
        api.solve_many(f, b, options=opts)
        api.selected_inverse(f, options=opts)
        api.marginal_variances(f, np.arange(4), options=opts)
        api.marginal_variances(f, np.arange(4),
                               options=opts.replace(method="panels"))
        batch = api.stack_ctsf([m, m])
        fb = api.concurrent_factorize(batch, options=opts)
        api.selinv_batched(fb, options=opts)
        api.concurrent_selinv(fb, options=opts)
        api.solve_many_batched(fb, b[None].repeat(2, 0), options=opts)


# ---------------------------------------------------------------------------
# every legacy kwarg warns (one DeprecationWarning per kwarg passed)
# ---------------------------------------------------------------------------

def _one_deprecation(fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    assert "options=SolverOptions(" in str(dep[0].message)
    return out


@pytest.mark.legacy_shim
def test_factorize_window_legacy_kwargs_warn():
    A, st = make_arrowhead(64, 6, 4, seed=0)
    m = BandedCTSF.from_sparse(A, TileGrid(st, 8))
    f_new = api.factorize_window(m, options=SolverOptions(impl="ref"))
    f_old = _one_deprecation(lambda: api.factorize_window(m, impl="ref"))
    np.testing.assert_array_equal(np.asarray(f_old.ctsf.Dr),
                                  np.asarray(f_new.ctsf.Dr))
    _one_deprecation(lambda: api.factorize_window(m, sweep="ring"))
    _one_deprecation(lambda: api.factorize_window(m, regularize=True))


@pytest.mark.legacy_shim
def test_solve_and_selinv_legacy_kwargs_warn():
    f, m = _factor()
    b = np.zeros((m.grid.padded_n, 2), np.float32)
    b[:3, :] = 1.0
    _one_deprecation(lambda: api.solve_many(f, b, impl="ref"))
    _one_deprecation(lambda: api.forward_solve_many(f, b, impl="ref"))
    _one_deprecation(lambda: api.backward_solve_many(f, b, impl="ref"))
    _one_deprecation(lambda: api.selected_inverse(f, impl="ref"))
    _one_deprecation(
        lambda: api.marginal_variances(f, np.arange(4), method="panels"))


@pytest.mark.legacy_shim
def test_batched_and_concurrent_legacy_kwargs_warn():
    _, m = _factor()
    batch = api.stack_ctsf([m, m])
    fb = _one_deprecation(lambda: api.concurrent_factorize(batch, impl="ref"))
    _one_deprecation(lambda: api.selinv_batched(fb, impl="ref"))
    _one_deprecation(lambda: api.concurrent_selinv(fb, impl="ref"))
    _one_deprecation(
        lambda: api.factorize_window_batched([m, m], impl="ref"))


@pytest.mark.legacy_shim
def test_legacy_kwarg_wins_over_options_field():
    # transition-period contract: an explicitly passed legacy kwarg
    # overrides the same field in options (and still warns)
    A, st = make_arrowhead(64, 6, 4, seed=0)
    m = BandedCTSF.from_sparse(A, TileGrid(st, 8))
    f = _one_deprecation(lambda: api.factorize_window(
        m, impl="ref", options=SolverOptions(impl="pallas", sweep="ring")))
    f_ref = api.factorize_window(
        m, options=SolverOptions(impl="ref", sweep="ring"))
    np.testing.assert_array_equal(np.asarray(f.ctsf.Dr),
                                  np.asarray(f_ref.ctsf.Dr))


@pytest.mark.legacy_shim
def test_rung_server_legacy_kwargs_warn():
    from repro.launch.rung_server import RungExecutor, RungServer, SimClock
    _one_deprecation(lambda: RungExecutor(impl="ref"))
    _one_deprecation(lambda: RungServer(clock=SimClock(), impl="ref"))
    # default server behavior keeps the jitter ladder on
    srv = RungServer(clock=SimClock())
    assert srv.options.regularize is True
    explicit = RungServer(clock=SimClock(), options=SolverOptions(impl="ref"))
    assert explicit.options.regularize is None
