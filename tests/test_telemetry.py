"""Telemetry subsystem (runtime/telemetry.py): registry units, disabled-mode
no-op + overhead guard, static kernel reports (launch/FLOP parity with the
numbers gated in BENCH_cholesky.json), exporter round-trips, instrumented
cache stats, and an end-to-end mixed-grid replay snapshot."""
import json
import os
import re
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid,
                        factorize_window, factorize_window_batched,
                        selinv_batched, solve_many)
from repro.core.batching import LRUCache
from repro.data import make_arrowhead
from repro.kernels import ops
from repro.kernels.ring import band_row_to_col
from repro.runtime import telemetry
from repro.runtime.telemetry import (Telemetry, count_pallas_launches,
                                     kernel_report, sweep_cost)
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts from (and leaves behind) a disabled, empty default
    registry — telemetry is process-global state."""
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


def _problem(n=96, bw=8, ar=4, t=8, seed=0):
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=seed)
    grid = TileGrid(struct, t=t)
    return grid, BandedCTSF.from_sparse(A, grid)


# ---------------------------------------------------------------------------
# Registry units
# ---------------------------------------------------------------------------

def test_counters_gauges_and_labels():
    reg = Telemetry(enabled=True)
    reg.inc("a")
    reg.inc("a", 2.5)
    reg.inc("a", 1, tag="x")
    reg.gauge("g", 7.0)
    reg.gauge("g", 3.0)            # last write wins
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.5
    assert snap["counters"]["a{tag=x}"] == 1.0
    assert snap["gauges"]["g"] == 3.0


def test_histogram_quantiles_nearest_rank():
    reg = Telemetry(enabled=True)
    for v in range(1, 101):
        reg.observe("h", float(v))
    s = reg.snapshot()["histograms"]["h"]
    assert s["count"] == 100 and s["sum"] == 5050.0
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["p50"] == 50.0
    assert s["p90"] == 90.0
    assert s["p99"] == 99.0


def test_histogram_sample_cap_keeps_exact_count():
    reg = Telemetry(enabled=True, max_samples=16)
    for v in range(100):
        reg.observe("h", float(v))
    s = reg.snapshot()["histograms"]["h"]
    assert s["count"] == 100 and s["max"] == 99.0
    assert s["samples_dropped"] == 100 - 16


def test_span_nesting_parents_and_timing():
    reg = Telemetry(enabled=True)
    with reg.span("outer", who="t"):
        with reg.span("mid"):
            with reg.span("leaf"):
                time.sleep(0.002)
    spans = {s["name"]: s for s in reg.snapshot()["spans"]}
    assert spans["leaf"]["parent"] == spans["mid"]["id"]
    assert spans["mid"]["parent"] == spans["outer"]["id"]
    assert spans["outer"]["parent"] is None
    assert spans["outer"]["tags"] == {"who": "t"}
    # durations nest: outer covers mid covers leaf, and leaf saw the sleep
    assert spans["outer"]["dur_us"] >= spans["mid"]["dur_us"] \
        >= spans["leaf"]["dur_us"] >= 1500


def test_span_tag_after_open():
    reg = Telemetry(enabled=True)
    with reg.span("s") as sp:
        sp.tag(rung="r1", k=4)
    (rec,) = reg.snapshot()["spans"]
    assert rec["tags"] == {"rung": "r1", "k": 4}


def test_counter_thread_hammer():
    reg = Telemetry(enabled=True)
    threads, per = 8, 2000

    def work(i):
        for _ in range(per):
            reg.inc("hammer")
            reg.observe("lat", float(i))
            with reg.span("w"):
                pass

    ts = [threading.Thread(target=work, args=(i,)) for i in range(threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    snap = reg.snapshot()
    assert snap["counters"]["hammer"] == threads * per
    assert snap["histograms"]["lat"]["count"] == threads * per
    assert len(snap["spans"]) == threads * per
    # top-level spans on each thread: no cross-thread parent leakage
    assert all(s["parent"] is None for s in snap["spans"])


def test_reset_clears_everything():
    reg = Telemetry(enabled=True)
    reg.inc("a")
    with reg.span("s"):
        pass
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"] == {} and snap["spans"] == []
    assert reg.enabled()               # reset does not flip the flag


def test_tracer_recording_fails_loudly():
    """jit-safety contract: recording a traced value must raise at the
    call site (never silently bury a host sync in traced code)."""
    reg = Telemetry(enabled=True)

    @jax.jit
    def f(x):
        reg.inc("bad", x)
        return x

    with pytest.raises(Exception):
        f(np.float32(1.0))


# ---------------------------------------------------------------------------
# Disabled mode: no-op behavior + overhead guard
# ---------------------------------------------------------------------------

def test_disabled_mode_records_nothing():
    assert not telemetry.enabled()
    telemetry.inc("c")
    telemetry.observe("h", 1.0)
    telemetry.gauge("g", 1.0)
    with telemetry.span("s", k=1) as sp:
        sp.tag(more="tags")
    snap = telemetry.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"] == {} and snap["spans"] == []


def test_capture_restores_previous_state():
    assert not telemetry.enabled()
    with telemetry.capture() as reg:
        assert telemetry.enabled()
        reg.inc("inside")
    assert not telemetry.enabled()
    assert telemetry.snapshot()["counters"]["inside"] == 1.0


def test_disabled_overhead_on_cached_solve_many_under_5pct():
    """Tier-1 guard: the disabled-mode cost of the telemetry surface a
    fully instrumented request crosses must stay under 5% of one cached
    ``solve_many`` dispatch.  Measured as per-op cost in a tight loop
    (deterministic) rather than an A/B wall-clock diff (bimodal in CI)."""
    grid, m = _problem()
    f = factorize_window(m, options=SolverOptions(impl="ref"))
    rng = np.random.default_rng(0)
    B = jax.numpy.asarray(
        rng.standard_normal((grid.padded_n, 4)).astype(np.float32))
    jax.block_until_ready(solve_many(f, B, options=SolverOptions(impl="ref")))  # warm the caches

    reps = 30
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(solve_many(f, B, options=SolverOptions(impl="ref")))
        times.append(time.perf_counter() - t0)
    dispatch = float(np.median(times))

    assert not telemetry.enabled()
    N = 5000
    t0 = time.perf_counter()
    for _ in range(N):
        # one request-worth of the disabled surface: a span with tags, a
        # post-open rung tag, a counter and a histogram observation
        with telemetry.span("solve.solve_many", k=4) as sp:
            sp.tag(grid=telemetry.rung_tag(grid))
        telemetry.inc("cache.hit", cache="batched_window")
        telemetry.observe("lat", 1.0)
    per_request = (time.perf_counter() - t0) / N
    # x3 headroom over the real per-call op count of the instrumented path
    assert 3 * per_request < 0.05 * dispatch, (
        f"disabled telemetry {per_request*1e6:.2f}us/request vs dispatch "
        f"{dispatch*1e6:.1f}us")


# ---------------------------------------------------------------------------
# Static kernel reports
# ---------------------------------------------------------------------------

def _bench_problem():
    """The exact quick problem bench_cholesky.py gates on."""
    n, bw, ar, t = 1024, 32, 16, 16
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=0)
    grid = TileGrid(struct, t=t)
    return grid, BandedCTSF.from_sparse(A, grid)


def test_kernel_report_one_launch_per_fused_sweep():
    """The three fused sweeps each trace to exactly one pallas_call — the
    launch counts gated in BENCH_cholesky.json, reproduced from library
    code (count_pallas_launches now lives in runtime/telemetry.py)."""
    grid, bm = _bench_problem()
    t, nat = grid.t, grid.n_arrow_tiles
    Ac = band_row_to_col(bm.Dr)

    rep_f = kernel_report(
        lambda a, r: ops.band_cholesky_sweep(a, r, nchunks=8, impl="pallas"),
        Ac, bm.R, grid=grid, sweep="cholesky")
    assert rep_f.pallas_launches == 1

    k = 4
    bd = jax.ShapeDtypeStruct((grid.n_diag_tiles, t, k), np.float32)
    rep_s = kernel_report(
        lambda d, r, b: ops.band_forward_sweep(d, r, b, impl="pallas"),
        bm.Dr, bm.R, bd, grid=grid, sweep="forward", k=k)
    assert rep_s.pallas_launches == 1

    sc = jax.ShapeDtypeStruct((nat, nat, t, t), np.float32)
    rep_i = kernel_report(
        lambda l, r, s: ops.selinv_sweep(l, r, s, impl="pallas"),
        Ac, bm.R, sc, grid=grid, sweep="selinv")
    assert rep_i.pallas_launches == 1

    # roofline terms populated and consistent with the hardware model
    for rep in (rep_f, rep_s, rep_i):
        assert rep.flops > 0 and rep.bytes_moved > 0
        assert rep.intensity == pytest.approx(rep.flops / rep.bytes_moved)
        assert rep.t_compute_s == pytest.approx(
            rep.flops / telemetry.PEAK_FLOPS)
        assert rep.bound in ("compute", "memory")


def test_kernel_report_matches_committed_bench_record():
    """Launch counts and FLOP/byte estimates reproduce the committed
    BENCH_cholesky.json from library code — the bench and the library can
    no longer drift (the bench imports the same implementation)."""
    path = os.path.join(_ROOT, "BENCH_cholesky.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["quick"], "parity test assumes the quick-problem record"
    grid, bm = _bench_problem()
    Ac = band_row_to_col(bm.Dr)
    rep = kernel_report(
        lambda a, r: ops.band_cholesky_sweep(a, r, nchunks=8, impl="pallas"),
        Ac, bm.R, grid=grid, sweep="cholesky")
    assert rep.pallas_launches == rec["fused_factorize_launches"] == 1
    kr = rec["kernel_report"]["cholesky"]
    assert rep.flops == pytest.approx(kr["flops"])
    assert rep.bytes_moved == pytest.approx(kr["bytes_moved"])
    cost = sweep_cost(grid, "selinv")
    assert cost["flops"] == pytest.approx(rec["kernel_report"]["selinv"]["flops"])


def test_sweep_cost_model_properties():
    grid, _ = _problem()
    chol = sweep_cost(grid, "cholesky")
    fwd = sweep_cost(grid, "forward", k=8)
    bwd = sweep_cost(grid, "backward", k=8)
    slv = sweep_cost(grid, "solve", k=8)
    sel = sweep_cost(grid, "selinv")
    # solve = forward + backward by construction
    assert slv["flops"] == fwd["flops"] + bwd["flops"]
    assert slv["bytes"] == fwd["bytes"] + bwd["bytes"]
    # factorization and selinv are O(t^3) per tile, solves O(t^2 k):
    # at k << t the panel sweeps are far cheaper
    assert chol["flops"] > fwd["flops"]
    assert sel["flops"] > fwd["flops"]
    with pytest.raises(ValueError):
        sweep_cost(grid, "nope")


def test_count_pallas_launches_multiplies_scan_bodies():
    """The pre-fusion per-panel path dispatches one launch per scanned
    panel — the counter must charge scan bodies by trip count (this is
    what makes the 'reduction' gate meaningful)."""
    grid, bm = _problem()
    Ac = band_row_to_col(bm.Dr)
    fused = count_pallas_launches(jax.make_jaxpr(
        lambda a, r: ops.band_cholesky_sweep(a, r, impl="pallas"))(Ac, bm.R))
    ref = count_pallas_launches(jax.make_jaxpr(
        lambda a, r: ops.band_cholesky_sweep(a, r, impl="ref"))(Ac, bm.R))
    assert fused == 1
    assert ref == 0          # the ref scan dispatches no pallas kernels


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

_PROM_LINE = re.compile(
    r"^(# TYPE \w+ (counter|gauge|summary)|"
    r"\w+(\{[\w]+=\"[^\"]*\"(,[\w]+=\"[^\"]*\")*\})? -?[\d.e+-]+(inf|nan)?)$")


def test_prometheus_text_parses():
    reg = Telemetry(enabled=True)
    reg.inc("cache.hit", 3, cache="batched_window")
    reg.gauge("queue_depth", 2)
    for v in (1.0, 2.0, 3.0):
        reg.observe("lat_seconds", v, path="solve")
    text = reg.to_prometheus_text()
    lines = text.strip().split("\n")
    for line in lines:
        assert _PROM_LINE.match(line), f"unparseable line: {line!r}"
    # sanitized + prefixed names, summary quantiles present
    assert 'repro_cache_hit{cache="batched_window"} 3' in lines
    assert any(l.startswith("repro_lat_seconds{") and 'quantile="0.99"' in l
               for l in lines)
    assert 'repro_lat_seconds_count{path="solve"} 3' in lines


def test_chrome_trace_round_trip_span_tree():
    reg = Telemetry(enabled=True)
    with reg.span("outer"):
        with reg.span("inner", rung="r"):
            pass
        with reg.span("inner2"):
            pass
    trace = json.loads(json.dumps(reg.to_chrome_trace()))
    evs = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    assert all(e["ph"] == "X" for e in evs)
    by_name = {e["name"]: e for e in evs}
    outer_id = by_name["outer"]["args"]["span_id"]
    assert by_name["inner"]["args"]["parent_id"] == outer_id
    assert by_name["inner2"]["args"]["parent_id"] == outer_id
    assert by_name["outer"]["args"]["parent_id"] is None
    assert by_name["inner"]["args"]["rung"] == "r"
    # timestamps are microseconds and children nest inside the parent
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3


# ---------------------------------------------------------------------------
# Instrumented caches
# ---------------------------------------------------------------------------

def test_lru_cache_stats_and_duplicate_trace():
    c = LRUCache(maxsize=2, name="unit_cache")
    assert c.get("a") is None                       # miss
    c.put("a", 1)
    assert c.get("a") == 1                          # hit
    c.put("a", 2)                                   # concurrent-miss double
    c.put("b", 1)
    c.put("c", 1)                                   # evicts "a"
    st = c.stats()
    assert st["hits"] == 1 and st["misses"] == 1
    assert st["duplicate_traces"] == 1
    assert st["evictions"] == 1
    assert st["size"] == 2 and st["maxsize"] == 2


def test_lru_cache_emits_telemetry_when_named():
    telemetry.enable()
    c = LRUCache(maxsize=8, name="emitting")
    c.get("k")
    c.put("k", 1)
    c.get("k")
    c.put("k", 2)
    v = c.get_or_create("k2", lambda: 42)
    assert v == 42
    snap = telemetry.snapshot()
    assert snap["counters"]["cache.miss{cache=emitting}"] == 2.0
    assert snap["counters"]["cache.hit{cache=emitting}"] == 1.0
    assert snap["counters"]["cache.duplicate_trace{cache=emitting}"] == 1.0
    assert snap["histograms"]["cache.trace_seconds{cache=emitting}"][
        "count"] == 1


def test_anonymous_cache_stays_silent():
    telemetry.enable()
    c = LRUCache(maxsize=2)
    c.get("a")
    c.put("a", 1)
    assert not any(k.startswith("cache.")
                   for k in telemetry.snapshot()["counters"])
    assert c.stats()["misses"] == 1                 # local stats still work


# ---------------------------------------------------------------------------
# End-to-end: mixed-grid replay snapshot (the ISSUE acceptance scenario)
# ---------------------------------------------------------------------------

def test_mixed_grid_replay_snapshot_and_trace():
    # earlier suite tests warm the module-level compile caches with the
    # same canonical-grid keys; start cold so the miss counters below are
    # deterministic under any test ordering
    from repro.core import cholesky as _chol_mod
    from repro.core import selinv as _selinv_mod
    _chol_mod._BATCHED_WINDOW_CACHE.clear()
    _selinv_mod._BATCHED_SELINV_CACHE.clear()
    telemetry.enable()
    pol = GridBucketPolicy()
    rng = np.random.default_rng(0)
    for (n, bw, ar), seed in [((96, 8, 4), 0), ((120, 14, 6), 1),
                              ((96, 8, 4), 2)]:
        A, s = make_arrowhead(n, bw, ar, rho=0.6, seed=seed)
        m = BandedCTSF.from_sparse(A, TileGrid(s, t=8))
        fb = factorize_window_batched([m, m], options=SolverOptions(impl="ref", policy=pol))
        f = factorize_window(m, options=SolverOptions(impl="ref", policy=pol))
        B = jax.numpy.asarray(rng.standard_normal(
            (m.grid.padded_n, 3)).astype(np.float32))
        jax.block_until_ready(solve_many(f, B, options=SolverOptions(impl="ref")))
        selinv_batched(fb, options=SolverOptions(impl="ref"))
    snap = telemetry.snapshot()
    counters = snap["counters"]
    # cache hit/miss counts: same-rung repeats hit, each rung misses once
    assert counters.get("cache.miss{cache=batched_window}", 0) >= 1
    assert counters.get("cache.hit{cache=batched_window}", 0) >= 1
    assert counters.get("cache.miss{cache=batched_selinv}", 0) >= 1
    # rung-hit histogram over the canonical rungs seen
    rung_hits = {k: v for k, v in counters.items()
                 if k.startswith("gridpolicy.rung_hit")}
    assert rung_hits and sum(rung_hits.values()) >= 6
    assert "gridpolicy.padded_flop_overhead" in snap["histograms"]
    # nested spans with grid/rung/batch-shape tags
    spans = snap["spans"]
    names = {s["name"] for s in spans}
    assert {"factorize.window_batched", "factorize.window",
            "solve.solve_many", "selinv.batched"} <= names
    fwb = next(s for s in spans if s["name"] == "factorize.window_batched")
    assert fwb["tags"]["b"] == 2 and "rung" in fwb["tags"]
    sm = next(s for s in spans if s["name"] == "solve.solve_many")
    assert sm["tags"]["k"] == 3 and "grid" in sm["tags"]
    # chrome trace is valid trace-event JSON over the same spans
    trace = json.loads(json.dumps(telemetry.to_chrome_trace()))
    assert len(trace["traceEvents"]) == len(spans)
    ids = {e["args"]["span_id"] for e in trace["traceEvents"]}
    assert all(e["args"]["parent_id"] in ids | {None}
               for e in trace["traceEvents"])


def test_robustness_ladder_counters():
    telemetry.enable()
    grid, m = _problem(seed=3)
    # clean input: one attempt, all ok — counted off the existing readback
    factorize_window(m, options=SolverOptions(impl="ref", regularize=True))
    snap = telemetry.snapshot()
    assert snap["counters"]["robustness.attempts"] >= 1.0
    assert snap["counters"]["robustness.status{outcome=ok}"] >= 1.0
    # indefinite input: ladder path counts recovered elements
    telemetry.reset()
    Dr = m.Dr.at[..., 0, 0, 0, 0].set(-50.0)       # break a diagonal
    bad = BandedCTSF(grid, Dr, m.R, m.C)
    f = factorize_window(bad, options=SolverOptions(impl="ref", regularize=True))
    assert f.info is not None
    snap = telemetry.snapshot()
    assert snap["counters"]["robustness.attempts"] >= 2.0
    assert "robustness.status{outcome=recovered}" in snap["counters"]
