"""Infrastructure satellites: kernel-backend env validation, the bounded
batching caches, and the benchmark harness's --check-only gate."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.core.options import SolverOptions

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# REPRO_KERNEL_IMPL validation (ops.default_impl)
# ---------------------------------------------------------------------------

def test_default_impl_env_override(monkeypatch):
    for valid in ("ref", "pallas", "unrolled"):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", valid)
        assert ops.default_impl() == valid
    monkeypatch.delenv("REPRO_KERNEL_IMPL")
    assert ops.default_impl() in ("ref", "pallas")


def test_default_impl_rejects_invalid_env(monkeypatch):
    """An invalid REPRO_KERNEL_IMPL must fail loudly, not silently fall
    back to the backend default (the old behavior hid typos like
    'palas')."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "palas")
    with pytest.raises(ValueError, match="REPRO_KERNEL_IMPL"):
        ops.default_impl()
    # the error propagates through a dispatching primitive too
    with pytest.raises(ValueError, match="REPRO_KERNEL_IMPL"):
        ops.potrf(jnp.eye(8))


# ---------------------------------------------------------------------------
# Bounded traced-callable caches (core/batching.py)
# ---------------------------------------------------------------------------

def test_lru_cache_bounds_and_recency():
    from repro.core.batching import LRUCache
    c = LRUCache(maxsize=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refresh "a" -> "b" is now LRU
    c.put("c", 3)                   # evicts "b"
    assert "b" not in c and c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2
    with pytest.raises(ValueError):
        LRUCache(maxsize=0)


def test_batched_window_cache_is_bounded():
    """The serving caches must not grow without limit across distinct
    grids; eviction only drops the Python wrapper, correctness is
    unaffected on re-entry."""
    from repro.core import cholesky, selinv
    assert cholesky._BATCHED_WINDOW_CACHE.maxsize <= 64
    assert selinv._BATCHED_SELINV_CACHE.maxsize <= 64


def test_bucketed_batched_call_pads_and_strips():
    from repro.core.batching import bucketed_batched_call, next_pow2
    assert [next_pow2(b) for b in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    seen = {}

    def fn(x):
        seen["n"] = x.shape[0]
        return (x * 2,)

    x = jnp.arange(6, dtype=jnp.float32)[:, None]
    (out,) = bucketed_batched_call(fn, (x,), bucket=True)
    assert seen["n"] == 8 and out.shape[0] == 6
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2)


def test_lru_eviction_follows_recency_order():
    """Eviction must walk least-recently-*used* order, not insertion
    order: a get() refresh moves an old key behind newer inserts."""
    from repro.core.batching import LRUCache
    c = LRUCache(maxsize=3)
    for k in "abc":
        c.put(k, k)
    assert c.keys() == ["a", "b", "c"]
    c.get("a")                      # refresh: now b is LRU
    c.put("d", "d")                 # evicts b
    assert c.keys() == ["c", "a", "d"]
    c.put("c", "C")                 # overwrite refreshes too
    c.put("e", "e")                 # evicts a (refreshed before c, d)
    assert c.keys() == ["d", "c", "e"]


def test_bucketed_batched_call_exact_pow2_boundary():
    """A batch already sitting on a pow2 boundary must dispatch unpadded
    (no silent 2x blow-up) and return all rows."""
    from repro.core.batching import bucketed_batched_call
    seen = {}

    def fn(x):
        seen["n"] = x.shape[0]
        return (x + 1,)

    for b in (1, 2, 8):
        x = jnp.zeros((b, 3), jnp.float32)
        (out,) = bucketed_batched_call(fn, (x,), bucket=True)
        assert seen["n"] == b and out.shape[0] == b
    # bucket=False never pads either
    x = jnp.zeros((5, 3), jnp.float32)
    (out,) = bucketed_batched_call(fn, (x,), bucket=False)
    assert seen["n"] == 5 and out.shape[0] == 5


def test_canonical_rung_cache_key_distinguishes_use_start():
    """Grids canonicalizing to the same rung share one policy-path cache
    entry, but that entry must be distinct from the plain (no-policy)
    entry for the canonical grid itself — colliding them would replay a
    static-zero trace for embedded inputs (or vice versa)."""
    from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid,
                            factorize_window_batched)
    from repro.core import cholesky as core_cholesky
    from repro.data import make_arrowhead
    pol = GridBucketPolicy()
    A, s = make_arrowhead(96, 10, 5, rho=0.6, seed=0)
    g = TileGrid(s, t=8)
    m = BandedCTSF.from_sparse(A, g)
    cgrid = pol.canonicalize(g)
    cache = core_cholesky._BATCHED_WINDOW_CACHE
    before = set(cache.keys())
    factorize_window_batched([m], tree_chunks=5, options=SolverOptions(impl="ref", policy=pol))
    new = set(cache.keys()) - before
    assert len(new) == 1
    (key,) = new
    assert key[0] == cgrid          # keyed on the canonical grid
    assert key[-1] is True          # ... with the traced-start variant
    # a same-rung grid with a different true shape reuses that entry
    A2, s2 = make_arrowhead(90, 9, 3, rho=0.6, seed=1)
    m2 = BandedCTSF.from_sparse(A2, TileGrid(s2, t=8))
    factorize_window_batched([m2], tree_chunks=5, options=SolverOptions(impl="ref", policy=pol))
    assert set(cache.keys()) - before == new


# ---------------------------------------------------------------------------
# benchmarks/run.py --check-only (validates committed BENCH_*.json)
# ---------------------------------------------------------------------------

def _run_check_only(cwd):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--check-only"],
        cwd=cwd, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(_ROOT, "src")})


@pytest.mark.slow
def test_check_only_passes_on_committed_records():
    """The committed BENCH_*.json artifacts must satisfy their own embedded
    thresholds — the fast CI gate against landing a regressed record."""
    res = _run_check_only(_ROOT)
    assert res.returncode == 0, res.stdout + res.stderr


def test_check_only_validation_logic(tmp_path):
    """--check-only flags threshold regressions and missing metrics, and
    never gates on interpret-mode diagnostics."""
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks.run import _record_failures
    finally:
        sys.path.remove(_ROOT)
    ok = {"x_speedup": 5.0, "thresholds": {"x_speedup_min": 3.0}, "pass": True}
    assert _record_failures(ok) == []
    bad = {"x_speedup": 2.0, "thresholds": {"x_speedup_min": 3.0}}
    assert any("x_speedup" in r for r in _record_failures(bad))
    missing = {"thresholds": {"x_speedup_min": 3.0}}
    assert any("missing" in r for r in _record_failures(missing))
    # interpret-mode-only timings are excluded from gating even if a
    # threshold (erroneously) names them
    diag = {"interpret_diagnostics": {"x_speedup": 0.5, "interpret_mode": True},
            "thresholds": {"x_speedup_min": 3.0}}
    assert _record_failures(diag) == []
    failed = {"pass": False}
    assert any("pass=false" in r for r in _record_failures(failed))


def test_check_only_fails_on_missing_registered_record(tmp_path, capsys):
    """Every suite registered in run.py RECORD_SUITES must have a
    committed BENCH_<suite>.json: deleting a record (instead of fixing a
    regression) must fail --check-only, not silently pass."""
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks.run import RECORD_SUITES, check_records
    finally:
        sys.path.remove(_ROOT)
    assert "bucketing" in RECORD_SUITES
    # all registered records present and passing -> clean
    for suite in RECORD_SUITES:
        (tmp_path / f"BENCH_{suite}.json").write_text(
            json.dumps({"pass": True}))
    assert check_records(root=str(tmp_path)) == 0
    # dropping one registered record -> exactly that failure
    (tmp_path / f"BENCH_{RECORD_SUITES[0]}.json").unlink()
    assert check_records(root=str(tmp_path)) == 1
    out = capsys.readouterr().out
    assert f"BENCH_{RECORD_SUITES[0]}.json" in out and "no committed" in out
