"""Deterministic replay tests for the continuous-batching rung server.

Async schedulers are where nondeterministic bugs hide, so every test here
drives the scheduler through its injected clock — no threads, no sleeps —
and the contracts are exact: same stream seed ⇒ identical batch
composition and flush order, bit-identical numerical results, parity with
a sequential per-request oracle, and fault isolation (a corrupted request
flags only itself; clean rung siblings match an uncontaminated run bit
for bit).  The one threaded end-to-end smoke test rides the ``slow``
marker.
"""
import threading
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid,
                        factorize_window, solve_many)
from repro.core import cholesky as _cholesky

# ``repro.core`` re-exports the ``solve`` *function*, shadowing the module
# attribute — go through importlib for the module's private cache.
import importlib
_solve = importlib.import_module("repro.core.solve")
from repro.data import make_arrowhead, request_stream
from repro.launch.rung_server import (FLUSH_DEADLINE, FLUSH_DRAIN,
                                      FLUSH_FULL, RungRequest, RungScheduler,
                                      RungServer, SimClock, replay)
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import NumericalFaultInjector
from repro.core.options import SolverOptions

pytestmark = pytest.mark.serving

CASES = [(64, 6, 4), (96, 12, 8), (120, 16, 4)]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _problem(n, bw, ar, seed=0, t=8, k=2):
    """(matrix, rhs) pair on its own grid, rhs in the padded layout."""
    A, st = make_arrowhead(n, bw, ar, rho=0.7, seed=seed)
    grid = TileGrid(st, t=t)
    m = BandedCTSF.from_sparse(A, grid)
    rng = np.random.default_rng(seed)
    b = np.zeros((grid.padded_n, k), np.float32)
    rows = np.array([grid.padded_index(i) for i in range(n)])
    b[rows] = rng.standard_normal((n, k)).astype(np.float32)
    return m, b


def _arrivals(num=6, k=2, gap=7e-4, deadline=None):
    """Deterministic mixed-grid arrival list for :func:`replay`."""
    out = []
    for i in range(num):
        n, bw, ar = CASES[i % len(CASES)]
        m, b = _problem(n, bw, ar, seed=i, k=k)
        out.append((gap * (i + 1), m, b,
                    None if deadline is None else gap * (i + 1) + deadline))
    return out


def _serve(arrivals, **kw):
    clock = SimClock()
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_delay", 3e-3)
    server = RungServer(clock=clock, **kw)
    futures = replay(server, clock, arrivals)
    return server, [f.result(timeout=0) for f in futures]


def _fake_request(rid, grid, k=None, deadline=None):
    """Scheduler-only request: a stand-in matrix carrying just a grid, so
    pure state-machine tests never build device arrays."""
    rhs = None if k is None else np.zeros((1, k), np.float32)
    return RungRequest(rid=rid, matrix=types.SimpleNamespace(grid=grid),
                       rhs=rhs, deadline=deadline)


def _grid(ndt=6, bt=1, nat=1, t=8):
    return TileGrid.from_tile_counts(t, ndt, bt, nat)


# ---------------------------------------------------------------------------
# scheduler state machine (pure, no arrays, no device)
# ---------------------------------------------------------------------------

def test_batch_full_flush_path():
    s = RungScheduler(max_batch=3, max_delay=1.0)
    g = _grid()
    batches = s.tick(0.0, [_fake_request(i, g) for i in range(7)])
    # two full batches leave immediately; the seventh waits for its delay
    assert [b.reason for b in batches] == [FLUSH_FULL, FLUSH_FULL]
    assert [tuple(r.rid for r in b.requests) for b in batches] == \
        [(0, 1, 2), (3, 4, 5)]
    assert s.pending == 1
    assert s.tick(0.5) == []                     # before the deadline: holds
    (late,) = s.tick(1.0)                        # max_delay expires
    assert late.reason == FLUSH_DEADLINE
    assert tuple(r.rid for r in late.requests) == (6,)
    assert s.pending == 0


def test_deadline_flush_takes_min_of_delay_and_request_deadline():
    s = RungScheduler(max_batch=8, max_delay=10.0)
    g = _grid()
    s.submit(0.0, _fake_request(0, g, deadline=2.0))
    s.submit(1.0, _fake_request(1, g))
    assert s.next_flush_by() == 2.0              # request deadline < delay
    assert s.tick(1.9) == []
    (b,) = s.tick(2.0)
    assert b.reason == FLUSH_DEADLINE
    # deadline expiry flushes the whole rung queue, not just the expired item
    assert tuple(r.rid for r in b.requests) == (0, 1)


def test_drain_flush_path():
    s = RungScheduler(max_batch=8, max_delay=10.0)
    ga, gb = _grid(ndt=6), _grid(ndt=12)
    s.tick(0.0, [_fake_request(0, ga), _fake_request(1, gb),
                 _fake_request(2, ga)])
    batches = s.drain(0.1)
    assert [b.reason for b in batches] == [FLUSH_DRAIN, FLUSH_DRAIN]
    assert {tuple(r.rid for r in b.requests) for b in batches} == \
        {(0, 2), (1,)}
    assert s.pending == 0 and s.next_flush_by() is None


def test_drain_classifies_due_flushes_as_deadline_first():
    s = RungScheduler(max_batch=8, max_delay=1.0)
    g = _grid()
    s.submit(0.0, _fake_request(0, g))
    (b,) = s.drain(5.0)                          # already past flush_by
    assert b.reason == FLUSH_DEADLINE


def test_rung_keys_match_policy_canonicalize():
    policy = GridBucketPolicy()
    s = RungScheduler(policy=policy, max_batch=8)
    for i, (n, bw, ar) in enumerate(CASES):
        _, st = make_arrowhead(n, bw, ar, rho=0.7, seed=0)
        g = TileGrid(st, t=8)
        key = s.submit(0.0, _fake_request(i, g, k=3))
        assert key == (policy.canonicalize(g), 3)
    # same canonical grid but different k is a different rung
    g0 = TileGrid(make_arrowhead(*CASES[0], rho=0.7, seed=0)[1], t=8)
    assert s.submit(0.0, _fake_request(9, g0, k=5))[1] == 5


# ---------------------------------------------------------------------------
# end-to-end replay (SimClock, synchronous pump — still thread-free)
# ---------------------------------------------------------------------------

def test_replay_bit_identical_and_history_stable():
    server1, res1 = _serve(_arrivals())
    server2, res2 = _serve(_arrivals())
    assert server1.history == server2.history
    assert len(server1.history) >= 2             # actually batched
    for a, b in zip(res1, res2):
        assert a.rid == b.rid and a.flush_reason == b.flush_reason
        assert a.x.tobytes() == b.x.tobytes()    # bit-identical, not close


def test_replay_matches_sequential_oracle():
    arrivals = _arrivals()
    _, results = _serve(arrivals)
    for (arrival, m, b, _dl), r in zip(arrivals, results):
        assert r.status == 0 and r.attempts == 1
        f = factorize_window(m, options=SolverOptions(regularize=True))
        x_oracle = np.asarray(solve_many(f, b))
        assert np.abs(r.x - x_oracle).max() < 2e-5
        # the per-request factor solves in the request's own layout too
        x_again = np.asarray(solve_many(r.factor, b))
        assert np.abs(x_again - x_oracle).max() < 2e-5


def test_compile_count_stays_at_rungs_not_grids():
    arrivals = _arrivals(num=9)                  # 3 distinct source grids
    policy = GridBucketPolicy()
    rungs = {telemetry.rung_tag(policy.canonicalize(m.grid))
             for _, m, _, _ in arrivals}
    fac0 = set(_cholesky._BATCHED_WINDOW_CACHE.keys())
    sol0 = set(_solve._BATCHED_SOLVE_CACHE.keys())
    _serve(arrivals)
    fac_new = set(_cholesky._BATCHED_WINDOW_CACHE.keys()) - fac0
    sol_new = set(_solve._BATCHED_SOLVE_CACHE.keys()) - sol0
    assert len(fac_new) <= len(rungs)
    assert len(sol_new) <= len(rungs)


def test_deadline_budget_respected_under_replay():
    with telemetry.capture() as reg:
        reg.reset()
        arrivals = _arrivals(num=5, deadline=1e-3)
        server, results = _serve(arrivals, max_batch=50, max_delay=5.0)
        wait = reg.hist_summary("serving.queue_wait")
    # with max_batch/max_delay out of reach, only per-request deadlines
    # flush — and every request leaves its queue within the 1 ms budget
    # (end-to-end latency additionally includes double-buffer pipeline
    # delay, so the budget contract is on queue wait, not on latency)
    assert {r.flush_reason for r in results} == {FLUSH_DEADLINE}
    assert wait["count"] == len(results)
    assert wait["max"] <= 1e-3 + 1e-12


def test_serving_telemetry_counters_and_spans():
    with telemetry.capture() as reg:
        reg.reset()
        server, results = _serve(_arrivals())
        snap = reg.snapshot()
    c = snap["counters"]
    assert c["serving.requests"] == len(results)
    assert sum(v for k, v in c.items()
               if k.startswith("serving.completed")) == len(results)
    flushes = sum(v for k, v in c.items() if k.startswith("serving.flush"))
    assert flushes == len(server.history)
    lat = reg.hist_summary("serving.request_seconds")
    assert lat is not None and lat["count"] == len(results)
    names = {s["name"] for s in snap["spans"]}
    assert {"serving.dispatch", "serving.finalize"} <= names


def test_fault_injection_under_serving():
    """Corrupted in-flight requests degrade to flagged futures; clean
    requests in the same rung batches stay bit-identical to an
    uncontaminated run."""
    clean = _arrivals(num=6)
    bad = _arrivals(num=6)
    inj = NumericalFaultInjector(seed=5)
    # rids 0 and 3 share the CASES[0] rung; corrupt 3 (nan -> FAILED)
    # and 4 (indefinite -> RECOVERED), leaving their batch siblings clean
    bad[3] = (bad[3][0], inj.corrupt_one(bad[3][1], "nan"),
              bad[3][2], bad[3][3])
    bad[4] = (bad[4][0], inj.corrupt_one(bad[4][1], "indefinite"),
              bad[4][2], bad[4][3])
    server_c, res_c = _serve(clean)
    server_b, res_b = _serve(bad)
    assert server_c.history == server_b.history  # composition unaffected
    assert res_b[3].status == 2 and not res_b[3].ok()
    assert res_b[4].status == 1 and res_b[4].ok()
    assert res_b[4].tau > 0 and res_b[4].attempts > 1
    for i in (0, 1, 2, 5):
        assert res_b[i].status == 0
        assert res_b[i].x.tobytes() == res_c[i].x.tobytes()
    # the recovered element's future carries a finite, usable solution —
    # it solves the jitter-perturbed corrupted system, so there is no
    # residual identity against the clean matrix to assert; the contract
    # is finite output + RECOVERED status + the tau actually applied
    assert np.isfinite(res_b[4].x).all()
    assert res_b[4].factor.info.matrix is not None  # perturbed source kept


def test_factorize_only_requests():
    n, bw, ar = CASES[0]
    m, _ = _problem(n, bw, ar, seed=11)
    clock = SimClock()
    server = RungServer(clock=clock, max_batch=4, max_delay=1e-3)
    fut = server.submit(m, rhs=None)
    clock.advance(1e-3)
    server.pump()
    server.drain()
    r = fut.result(timeout=0)
    assert r.x is None and r.status == 0
    f_oracle = factorize_window(m, options=SolverOptions(regularize=True))
    assert np.allclose(np.asarray(r.factor.restrict().ctsf.Dr),
                       np.asarray(f_oracle.ctsf.Dr), atol=2e-5)


def test_submit_validates_rhs_shape():
    m, _ = _problem(*CASES[0], seed=0)
    server = RungServer(clock=SimClock())
    with pytest.raises(ValueError, match="padded_n"):
        server.submit(m, rhs=np.zeros((3, 2), np.float32))


@pytest.mark.slow
def test_threaded_server_end_to_end_smoke():
    """Production shape: background pump on the real clock, futures
    resolving across threads.  Correctness only (parity with the oracle)
    — determinism is the SimClock tests' job."""
    arrivals = _arrivals(num=6)
    server = RungServer(max_batch=3, max_delay=0.05)
    server.start()
    try:
        futures = [server.submit(m, b) for _, m, b, _ in arrivals]
        results = [f.result(timeout=120.0) for f in futures]
    finally:
        server.stop()
    for (_, m, b, _), r in zip(arrivals, results):
        assert r.status == 0
        f = factorize_window(m, options=SolverOptions(regularize=True))
        assert np.abs(r.x - np.asarray(solve_many(f, b))).max() < 2e-5
    assert threading.active_count() >= 1         # pump thread joined
    assert server._thread is None
