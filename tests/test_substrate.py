"""Substrate tests: optimizer, arrowhead preconditioner, data determinism,
checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import MarkovStream, token_batch
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_lr, global_norm)
from repro.optim.arrowhead import build_precond
from repro.runtime.fault_tolerance import (FailureInjector, StragglerMonitor,
                                           TrainLoop)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = adamw_update(g, state, params, 0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clip_and_schedule():
    tree = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 100
    lrs = [float(cosine_lr(jnp.asarray(s), 1e-3, warmup=10, total=100))
           for s in range(0, 100, 10)]
    assert lrs[0] < lrs[1]            # warmup rises
    assert lrs[-1] < lrs[2]           # cosine decays


# ---------------------------------------------------------------------------
# arrowhead preconditioner (sTiles inside the optimizer)
# ---------------------------------------------------------------------------

def _toy_params(L=6, d=40, seed=0):
    k = jax.random.PRNGKey(seed)
    return {"embed": jax.random.normal(k, (32, 8)),
            "layers": {"w": jax.random.normal(k, (L, d))}}


def test_precond_identity_when_unit_curvature():
    """With A = I (damping-dominated, fresh stats), d == g exactly."""
    params = _toy_params()
    pre = build_precond(params, r=8, band=2, damping=1.0)
    state = pre.init_state()
    factor = pre.factorize(state)   # EMA zero -> A = damping*I = I
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    out = pre.precondition(factor, grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_precond_shrinks_high_curvature_directions():
    """Directions with accumulated curvature are damped relative to A=I."""
    params = _toy_params()
    pre = build_precond(params, r=8, band=1, damping=1e-2, ema=0.0)
    state = pre.init_state()
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    # feed the same gradient several times -> curvature builds along it
    for _ in range(5):
        state = pre.update_stats(state, grads)
    factor = pre.factorize(state)
    out = pre.precondition(factor, grads)
    lsk_g, _ = pre.sketch(grads)
    lsk_o, _ = pre.sketch(out)
    # preconditioned sketch has smaller norm along the curved direction
    assert float(jnp.linalg.norm(lsk_o)) < float(jnp.linalg.norm(lsk_g))


def test_precond_solves_assembled_system():
    """factorize/solve round-trip: A @ x == ĝ on the sketch subspace."""
    params = _toy_params()
    pre = build_precond(params, r=8, band=2, damping=0.1, ema=0.5)
    state = pre.init_state()
    key = jax.random.PRNGKey(0)
    for i in range(4):
        g = jax.tree.map(
            lambda p, k=i: jax.random.normal(jax.random.fold_in(key, k),
                                             p.shape), params)
        state = pre.update_stats(state, g)
    factor = pre.factorize(state)
    L = np.tril(
        __import__("repro.core.ctsf", fromlist=["BandedCTSF"]).BandedCTSF(
            pre.grid, factor["Dr"], factor["R"], factor["C"]).to_dense())
    # assembled A from stats + damping
    eye = np.eye(pre.r, dtype=np.float32)
    g_grid = pre.grid
    A = L @ L.T
    assert np.isfinite(A).all()
    # SPD check
    w = np.linalg.eigvalsh(A)
    assert w.min() > 0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_token_batch_deterministic():
    a = token_batch(7, 42, 4, 16, 1000)
    b = token_batch(7, 42, 4, 16, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(7, 43, 4, 16, 1000)
    assert (a["tokens"] != c["tokens"]).any()
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_markov_stream_learnable():
    s = MarkovStream(64, seed=1)
    assert 0 < s.entropy_floor < np.log(64)
    b1 = s.batch(0, 2, 32)
    b2 = s.batch(0, 2, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(5)}
    ck.save(5, state, meta={"note": "x"})
    out = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert ck.meta()["note"] == "x"


def test_checkpoint_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, {"w": jnp.ones(3) * s})
    assert ck.all_steps() == [3, 4]
    out = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full(3, 4.0))


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=1, async_save=True)
    ck.save(1, {"w": jnp.ones(4)})
    ck.wait()
    assert ck.all_steps() == [1]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def _counting_loop(tmp_path, injector, retries=2):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        return state + 1, {"loss": jnp.asarray(float(state))}

    def batch_fn(step):
        return step

    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    loop = TrainLoop(step_fn=step_fn, batch_fn=batch_fn, checkpointer=ck,
                     checkpoint_every=3, max_step_retries=retries,
                     injector=injector, log_every=0,
                     log_fn=lambda *a, **k: None)
    return loop, calls


def test_retry_recovers_from_transient_failure(tmp_path):
    inj = FailureInjector({4: 1})           # one transient failure at step 4
    loop, calls = _counting_loop(tmp_path, inj)
    final = loop.run(jnp.asarray(0), 0, 8)
    assert int(final) == 8                  # all steps applied exactly once
    assert inj.injected == [4]


def test_hard_failure_restores_checkpoint(tmp_path):
    inj = FailureInjector({5: 10})          # exceeds retries -> hard failure
    loop, calls = _counting_loop(tmp_path, inj)
    final = loop.run(jnp.asarray(0), 0, 8)
    # injector budget (10) is consumed over repeated restore/replay cycles,
    # then training completes; state must equal the step count
    assert int(final) == 8


def test_straggler_monitor_flags():
    mon = StragglerMonitor(factor=2.0)
    for i in range(10):
        mon.record(i, 0.01)
    mon.record(10, 0.5)
    assert mon.flagged and mon.flagged[0][0] == 10
