"""Shared fixtures.  NOTE: no XLA device-count forcing here — smoke tests
and benches must see the single real CPU device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (see _mdev.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
