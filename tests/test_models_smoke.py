"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED config of the same family — one forward/train step on CPU asserting
output shapes and no NaNs, plus prefill->decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunConfig
from repro.launch.train import reduce_config
from repro.models.registry import get_model, input_specs, supports_shape

RUN = RunConfig(remat="none", compute_dtype="float32", loss_chunk=64)
B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones((B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frame_embeds"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduce_config(configs.get(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg, S)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, batch, cfg, RUN))(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = reduce_config(configs.get(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init(key, cfg, S + 8)
    batch = _batch(cfg, key)
    batch.pop("labels")
    logits, caches = api.prefill(params, batch, cfg, RUN)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    # grow attention caches for decode

    def pad(path, x):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 5 and x.shape[2] == S:
            return jnp.pad(x, ((0, 0),) * 2 + ((0, 8),) + ((0, 0),) * 2)
        return x

    caches = jax.tree_util.tree_map_with_path(pad, caches)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = api.decode_step(params, caches, tok,
                                       jnp.asarray(S, jnp.int32), cfg, RUN)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_full_forward_dense():
    """Teacher-forced decode reproduces the full forward logits (dense)."""
    cfg = reduce_config(configs.get("qwen2-7b"), layers=2, d_model=64)
    api = get_model(cfg)
    key = jax.random.PRNGKey(2)
    T = 12
    params = api.init(key, cfg, T)
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab)
    # full forward logits at last position
    logits_full, _ = api.prefill(params, {"tokens": toks}, cfg, RUN)
    # prefill T-1 then decode the final token
    logits_pre, caches = api.prefill(params, {"tokens": toks[:, :-1]}, cfg, RUN)

    def pad(path, x):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 5:
            return jnp.pad(x, ((0, 0),) * 2 + ((0, 1),) + ((0, 0),) * 2)
        return x

    caches = jax.tree_util.tree_map_with_path(pad, caches)
    logits_dec, _ = api.decode_step(params, caches, toks[:, -1:],
                                    jnp.asarray(T - 1, jnp.int32), cfg, RUN)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_full_forward_ssm():
    """Mamba2: recurrent decode == chunked-scan forward (SSD duality)."""
    cfg = reduce_config(configs.get("mamba2-1.3b"), layers=2, d_model=64)
    api = get_model(cfg)
    key = jax.random.PRNGKey(3)
    T = 12
    params = api.init(key, cfg, T)
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab)
    logits_full, _ = api.prefill(params, {"tokens": toks}, cfg, RUN)
    _, caches = api.prefill(params, {"tokens": toks[:, :-1]}, cfg, RUN)
    logits_dec, _ = api.decode_step(params, caches, toks[:, -1:],
                                    jnp.asarray(T - 1, jnp.int32), cfg, RUN)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=5e-3, atol=5e-3)


def test_shape_skip_rules():
    from repro.configs.base import SHAPES
    assert supports_shape(configs.get("mamba2-1.3b"), SHAPES["long_500k"]) is None
    assert supports_shape(configs.get("zamba2-2.7b"), SHAPES["long_500k"]) is None
    for arch in ("qwen2-7b", "whisper-medium", "phi-3-vision-4.2b"):
        assert supports_shape(configs.get(arch), SHAPES["long_500k"]) is not None
        assert supports_shape(configs.get(arch), SHAPES["train_4k"]) is None


def test_input_specs_cover_all_cells():
    from repro.configs.base import SHAPES
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in SHAPES.values():
            spec = input_specs(cfg, shape)
            assert isinstance(spec, dict) and spec


def test_moe_capacity_dispatch_matches_dense_routing():
    """Sorted-capacity dispatch == direct per-token expert mix when capacity
    is ample."""
    from repro.models.moe import moe_params, moe_apply
    key = jax.random.PRNGKey(0)
    p = moe_params(key, 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y = moe_apply(p, x, top_k=2, capacity_factor=4.0)  # no drops
    # reference: dense routing
    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(4):
        h = xt @ p["wi"][e]
        g = xt @ p["wg"][e]
        out_e = (jax.nn.silu(g) * h) @ p["wo"][e]
        w = ((idx == e) * gates).sum(-1, keepdims=True)
        ref = ref + w * out_e
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
