"""Selected inversion: blocked Takahashi recurrence vs dense np.linalg.inv,
batched-vs-looped consistency, accessor semantics, and the panels path's
RHS-sparsity fast start."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, TileGrid, concurrent_selinv,
                        factorize_window, factorize_window_batched,
                        marginal_variances, selected_inverse, selinv_batched)
from repro.core.solve import _marginal_variances_map
from repro.data import make_arrowhead
from repro.core.options import SolverOptions


def _factored(n, bw, ar, t, seed=0, rho=0.6):
    A, struct = make_arrowhead(n, bw, ar, rho=rho, seed=seed)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)
    return bm, factorize_window(bm), grid


def _pattern_mask(grid, bm):
    """Dense mask of the stored band+arrow pattern (where Σ is defined)."""
    ones = BandedCTSF(grid, jnp.ones_like(bm.Dr), jnp.ones_like(bm.R),
                      jnp.ones_like(bm.C))
    return ones.to_dense(lower_only=False) > 0


@pytest.mark.parametrize("n,bw,ar,t", [
    (160, 16, 16, 16),     # square grid, one arrow tile
    (320, 24, 32, 16),     # wider band, two arrow tiles
    (96, 12, 0, 16),       # no arrow at all
    (80, 5, 8, 8),         # thin band, small tiles
    (64, 9, 16, 8),        # arrow thicker than band
])
def test_selected_inverse_matches_dense_inverse(n, bw, ar, t):
    """The Takahashi band + arrow block reproduces the corresponding entries
    of np.linalg.inv(A): the recurrence closed on the factor pattern is
    exact, so errors are pure fp32 roundoff."""
    bm, f, grid = _factored(n, bw, ar, t)
    sigma = selected_inverse(f)
    inv = np.linalg.inv(bm.to_dense(lower_only=False).astype(np.float64))
    got = sigma.to_dense_band()
    mask = _pattern_mask(grid, bm)
    err = np.abs(np.where(mask, got - inv, 0.0)).max()
    assert err < 5e-6 * max(1.0, np.abs(inv).max())


def test_selected_inverse_diagonal_and_covariance_accessors():
    bm, f, grid = _factored(160, 16, 16, 16)
    sigma = selected_inverse(f)
    inv = np.linalg.inv(bm.to_dense(lower_only=False).astype(np.float64))
    n = grid.structure.n
    diag = np.asarray(sigma.diagonal())
    assert diag.shape == (n,)
    pidx = np.asarray([grid.padded_index(i) for i in range(n)])
    np.testing.assert_allclose(diag, np.diag(inv)[pidx], rtol=1e-4, atol=1e-6)
    # band pairs, arrow rows, corner pairs — and symmetry of the accessor
    for i, j in [(0, 0), (5, 9), (100, 110), (3, 159), (159, 3), (150, 155),
                 (158, 159)]:
        want = inv[grid.padded_index(i), grid.padded_index(j)]
        np.testing.assert_allclose(float(sigma.covariance(i, j)), want,
                                   rtol=1e-3, atol=1e-6)
    with pytest.raises(ValueError):
        sigma.covariance(0, 120)       # outside the stored band
    with pytest.raises(ValueError):
        sigma.covariance(0, 200)       # out of range


def test_marginal_variances_selinv_agrees_with_panels_and_map():
    bm, f, grid = _factored(320, 24, 32, 16)
    idx = jnp.asarray([0, 7, 63, 150, 250, 319])
    got = np.asarray(marginal_variances(f, idx))
    panels = np.asarray(marginal_variances(f, idx, options=SolverOptions(method="panels")))
    ref = np.asarray(_marginal_variances_map(f, idx))
    np.testing.assert_allclose(got, panels, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)


def test_marginal_variances_panels_fast_start_matches_full_sweep():
    """The RHS-sparsity fast start (band sweep begins at the first nonzero
    tile) must be exact: selected indices far from the top mean many skipped
    band steps, yet the variances agree with the unskipped recurrence."""
    bm, f, grid = _factored(320, 24, 32, 16)
    idx = jnp.asarray([200, 250, 287, 300, 319])   # first band tile = 12
    panels = np.asarray(marginal_variances(f, idx, options=SolverOptions(method="panels")))
    got = np.asarray(marginal_variances(f, idx))
    ref = np.asarray(_marginal_variances_map(f, idx))
    np.testing.assert_allclose(panels, got, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(panels, ref, rtol=1e-4, atol=1e-6)


def test_marginal_variances_validates_indices():
    bm, f, grid = _factored(160, 16, 16, 16)
    with pytest.raises(ValueError):
        marginal_variances(f, jnp.asarray([0, 160]))
    with pytest.raises(ValueError):
        marginal_variances(f, jnp.asarray([-1]))
    with pytest.raises(ValueError):
        marginal_variances(f, jnp.asarray([[0, 1]]))


def test_selinv_batched_matches_looped():
    grid = None
    mats = []
    for s in range(3):
        A, struct = make_arrowhead(160, 16, 16, rho=0.6, seed=s)
        grid = TileGrid(struct, t=16)
        mats.append(BandedCTSF.from_sparse(A, grid))
    fb = factorize_window_batched(mats)          # bucket pads 3 -> 4
    sb = selinv_batched(fb)
    assert sb.Dr.shape[0] == 3
    for i, m in enumerate(mats):
        si = selected_inverse(factorize_window(m))
        np.testing.assert_allclose(np.asarray(sb.Dr[i]), np.asarray(si.Dr),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(sb.R[i]), np.asarray(si.R),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(sb.C[i]), np.asarray(si.C),
                                   atol=1e-5)
    # batched diagonal carries the batch axis
    assert sb.diagonal().shape == (3, grid.structure.n)
    # concurrent entry point without a mesh delegates to the batched path
    cs = concurrent_selinv(fb)
    np.testing.assert_allclose(np.asarray(cs.Dr), np.asarray(sb.Dr),
                               atol=1e-6)


def test_selinv_pallas_impl_matches_ref():
    """impl="pallas" now runs the whole Takahashi recurrence as one fused
    kernel launch (kernels.ops.selinv_sweep) — parity vs the per-column
    scan reference."""
    bm, f, grid = _factored(160, 16, 16, 16)
    s_ref = selected_inverse(f, options=SolverOptions(impl="ref"))
    s_pal = selected_inverse(f, options=SolverOptions(impl="pallas"))
    np.testing.assert_allclose(np.asarray(s_pal.Dr), np.asarray(s_ref.Dr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_pal.R), np.asarray(s_ref.R),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,bw,ar,t", [
    (160, 16, 16, 16),     # square grid, one arrow tile
    (96, 12, 0, 16),       # no arrow at all (dummy arrow ring in-kernel)
    (64, 9, 16, 8),        # arrow thicker than band
])
def test_selinv_fused_sweep_matches_dense_inverse(n, bw, ar, t):
    """The fused sweep is exact on the factor pattern, same bar as the scan
    path: its band + arrow block reproduces np.linalg.inv entries."""
    bm, f, grid = _factored(n, bw, ar, t)
    sigma = selected_inverse(f, options=SolverOptions(impl="pallas"))
    inv = np.linalg.inv(bm.to_dense(lower_only=False).astype(np.float64))
    mask = _pattern_mask(grid, bm)
    err = np.abs(np.where(mask, sigma.to_dense_band() - inv, 0.0)).max()
    assert err < 5e-6 * max(1.0, np.abs(inv).max())


def test_selinv_batched_pallas_rides_fused_sweep():
    """selinv_batched(options=SolverOptions(impl="pallas")) — the fused kernel under vmap —
    matches the looped ref recurrences."""
    mats = []
    for s in range(3):
        bm, f, grid = _factored(160, 16, 16, 16, seed=s)
        mats.append(bm)
    fb = factorize_window_batched(mats, options=SolverOptions(impl="ref"))
    sb = selinv_batched(fb, options=SolverOptions(impl="pallas"))
    for i, m in enumerate(mats):
        si = selected_inverse(
            factorize_window(m, options=SolverOptions(impl="ref")),
            options=SolverOptions(impl="ref"))
        np.testing.assert_allclose(np.asarray(sb.Dr[i]), np.asarray(si.Dr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sb.R[i]), np.asarray(si.R),
                                   rtol=2e-4, atol=2e-4)


def test_selinv_property_random_structures():
    """Hypothesis sweep: the recurrence's diagonal matches the dense inverse
    for random arrowhead structures (the invariant INLA serving relies on)."""
    pytest.importorskip("hypothesis",
                        reason="property tests need the hypothesis package")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def problem(draw):
        t = draw(st.sampled_from([8, 16]))
        ndt = draw(st.integers(3, 7))
        bw = draw(st.integers(1, 2 * t))
        arrow = draw(st.sampled_from([0, t // 2, t]))
        seed = draw(st.integers(0, 2 ** 16))
        return ndt * t + arrow, bw, arrow, t, seed

    @given(problem())
    @settings(max_examples=8, deadline=None)
    def check(p):
        n, bw, arrow, t, seed = p
        bm, f, grid = _factored(n, bw, arrow, t, seed=seed)
        sigma = selected_inverse(f)
        inv = np.linalg.inv(bm.to_dense(lower_only=False).astype(np.float64))
        pidx = np.asarray([grid.padded_index(i) for i in range(n)])
        np.testing.assert_allclose(np.asarray(sigma.diagonal()),
                                   np.diag(inv)[pidx], rtol=1e-3, atol=1e-5)

    check()
