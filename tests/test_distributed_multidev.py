"""Multi-device tests (8 fake CPU devices via subprocess — conftest must NOT
force the device count globally): concurrent/distributed factorization,
tree all-reduce, pipeline parallelism, compressed DP, elastic restore."""
import pytest

from _mdev import run_multidevice


@pytest.mark.slow
def test_concurrent_factorize_sharded():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.data import make_arrowhead
from repro.core import TileGrid, BandedCTSF
from repro.core.concurrent import stack_ctsf, concurrent_factorize, concurrent_logdet
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
mats, denses = [], []
for s in range(8):
    A, st = make_arrowhead(160, 16, 16, rho=0.5, seed=s)
    bm = BandedCTSF.from_sparse(A, TileGrid(st, t=16))
    mats.append(bm); denses.append(bm.to_dense(lower_only=False))
fac = concurrent_factorize(stack_ctsf(mats), mesh=mesh, axis="data")
lds = concurrent_logdet(fac)
for i in range(8):
    _, ldref = np.linalg.slogdet(denses[i])
    assert abs(float(lds[i]) - ldref) < 1e-2 * abs(ldref), i
print("OK")
""")


@pytest.mark.slow
def test_distributed_single_factorization():
    run_multidevice("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.data import make_arrowhead
from repro.core import TileGrid, BandedCTSF
from repro.core.distributed import partition_banded, distributed_factorize, assemble_factor
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
A, st = make_arrowhead(16*16 + 16, 16, 16, rho=0.0, seed=3)
g = TileGrid(st, t=16)
bm = BandedCTSF.from_sparse(A, g)
pm = partition_banded(bm, 4)
out = distributed_factorize(pm, mesh, axis="model")
f = assemble_factor(out, g)
Lref = np.linalg.cholesky(bm.to_dense(lower_only=False))
assert np.abs(f.ctsf.to_dense() - np.tril(Lref)).max() < 1e-4
print("OK")
""")


@pytest.mark.slow
def test_partition_rejects_coupled_bands():
    run_multidevice("""
from repro.data import make_arrowhead
from repro.core import TileGrid, BandedCTSF
from repro.core.distributed import partition_banded
A, st = make_arrowhead(16*16 + 16, 16, 16, rho=0.7, seed=0)  # coupled!
bm = BandedCTSF.from_sparse(A, TileGrid(st, t=16))
try:
    partition_banded(bm, 2)
    raise SystemExit("should have raised")
except ValueError as e:
    assert "partition boundary" in str(e)
print("OK")
""", n_devices=1)


@pytest.mark.slow
def test_tree_allreduce_matches_psum():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.sharding.collectives import tree_allreduce, ring_allreduce
mesh = Mesh(np.array(jax.devices()), ("x",))
data = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5)
def f(kind):
    def local(x):
        if kind == "tree": return tree_allreduce(x, "x")
        if kind == "ring": return ring_allreduce(x, "x")
        return jax.lax.psum(x, "x")
    try:
        fn = shard_map(local, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False)
    except TypeError:
        fn = shard_map(local, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
    return np.asarray(jax.jit(fn)(data))
ref = f("psum")
np.testing.assert_allclose(f("tree"), ref, rtol=1e-6)
np.testing.assert_allclose(f("ring"), ref, rtol=1e-6)
print("OK")
""")


@pytest.mark.slow
def test_quantized_allreduce_accuracy():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.sharding.collectives import quantized_allreduce
mesh = Mesh(np.array(jax.devices()), ("x",))
rng = np.random.default_rng(0)
data = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
def local(x): return quantized_allreduce(x, "x")
try:
    fn = shard_map(local, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False)
except TypeError:
    fn = shard_map(local, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
got = np.asarray(jax.jit(fn)(data))
ref = np.asarray(data).sum(0, keepdims=True).repeat(8, 0)
err = np.abs(got - ref).max() / np.abs(ref).max()
assert err < 0.02, err   # int8 quantization noise bound
print("OK")
""")


@pytest.mark.slow
def test_pipeline_parallel_fwd_and_grad():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sharding.pipeline import pipeline_forward, split_stages
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.standard_normal((8, 16, 16)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
def stage_fn(wstack, h):
    def body(h, w): return jnp.tanh(h @ w), None
    return jax.lax.scan(body, h, wstack)[0]
ref = stage_fn(Ws, x)
out = pipeline_forward(stage_fn, split_stages(Ws, 4), x, mesh, axis="model", n_microbatches=4)
assert float(jnp.abs(out - ref).max()) < 1e-5
g1 = jax.grad(lambda w: (pipeline_forward(stage_fn, split_stages(w,4), x, mesh, axis='model', n_microbatches=4)**2).sum())(Ws)
g2 = jax.grad(lambda w: (stage_fn(w, x)**2).sum())(Ws)
assert float(jnp.abs(g1-g2).max()/jnp.abs(g2).max()) < 1e-5
print("OK")
""")


@pytest.mark.slow
def test_compressed_dp_trains():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.runtime.dp_compressed import make_compressed_dp_step
from repro.optim.adamw import adamw_init
mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
rng = np.random.default_rng(0)
def loss_fn(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"])**2)
params = {"w": jnp.asarray(rng.standard_normal((8, 1)) * 0.1, jnp.float32)}
batch = {"x": jnp.asarray(rng.standard_normal((32, 8)), jnp.float32),
         "y": jnp.asarray(rng.standard_normal((32, 1)), jnp.float32)}
step, ef_init_fn = make_compressed_dp_step(loss_fn, mesh, axis="data", lr=0.05)
state = (params, adamw_init(params), ef_init_fn(params))
l0 = None
for i in range(30):
    state, m = step(state, batch)
    if l0 is None: l0 = float(m["loss"])
assert float(m["loss"]) < 0.9 * l0
print("OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Checkpoint written on a 4-device mesh restores onto 8 devices."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp, tempfile, os
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer
devs = jax.devices()
mesh4 = Mesh(np.array(devs[:4]), ("data",))
mesh8 = Mesh(np.array(devs), ("data",))
state = {"w": jax.device_put(jnp.arange(32.0).reshape(8, 4),
                             NamedSharding(mesh4, P("data")))}
d = tempfile.mkdtemp()
ck = Checkpointer(d, async_save=False)
ck.save(3, state)
sh8 = {"w": NamedSharding(mesh8, P("data"))}
out = ck.restore(state, shardings=sh8)
assert out["w"].sharding == sh8["w"]
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
print("OK")
""")
