"""Numerical factorization: both backends vs dense Cholesky, solves,
logdet, sampling, tree reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, TileGrid, TileMatrix, factorize_tasklist,
                        factorize_window, forward_solve, backward_solve,
                        logdet, sample_gmrf, solve)
from repro.data import make_arrowhead
from repro.core.options import SolverOptions

CASES = [
    # (n, bandwidth, arrow, tile, rho)
    (200, 24, 16, 16, 0.7),      # classic arrowhead
    (200, 24, 16, 16, 0.0),      # block-diagonal + arrow (paper ids 1,7,..)
    (160, 8, 0, 16, 0.5),        # pure band, no arrow
    (130, 40, 30, 16, 0.6),      # thick arrow, wide band (uneven tiles)
    (96, 90, 0, 32, 0.4),        # nearly dense band
]


def _setup(n, bw, ar, t, rho, seed=0):
    A, st = make_arrowhead(n, bw, ar, rho=rho, seed=seed)
    g = TileGrid(st, t=t)
    bm = BandedCTSF.from_sparse(A, g)
    dense = bm.to_dense(lower_only=False)
    return A, g, bm, dense


@pytest.mark.parametrize("n,bw,ar,t,rho", CASES)
def test_window_backend_matches_dense(n, bw, ar, t, rho):
    A, g, bm, dense = _setup(n, bw, ar, t, rho)
    f = factorize_window(bm)
    Lref = np.linalg.cholesky(dense)
    err = np.abs(f.ctsf.to_dense() - np.tril(Lref)).max()
    assert err < 1e-3 * max(1.0, np.abs(Lref).max())


@pytest.mark.parametrize("n,bw,ar,t,rho", CASES[:3])
def test_tasklist_backend_matches_dense(n, bw, ar, t, rho):
    A, g, bm, dense = _setup(n, bw, ar, t, rho)
    tm = TileMatrix.from_sparse(A, g)
    tiles = factorize_tasklist(tm)
    Lref = np.linalg.cholesky(dense)
    err = np.abs(np.tril(tm.to_dense(tiles)) - np.tril(Lref)).max()
    assert err < 1e-3 * max(1.0, np.abs(Lref).max())


def test_backends_agree():
    A, g, bm, dense = _setup(200, 24, 16, 16, 0.7)
    f = factorize_window(bm)
    tm = TileMatrix.from_sparse(A, g)
    tiles = factorize_tasklist(tm)
    assert np.allclose(np.tril(tm.to_dense(tiles)), f.ctsf.to_dense(),
                       atol=5e-4)


def test_tree_reduction_equivalent():
    """Alg. 3 changes association order only (paper §IV-A)."""
    A, g, bm, dense = _setup(200, 24, 16, 16, 0.7)
    f1 = factorize_window(bm, tree_chunks=1)
    f8 = factorize_window(bm, tree_chunks=8)
    assert np.allclose(f1.ctsf.to_dense(), f8.ctsf.to_dense(), atol=1e-4)
    tm = TileMatrix.from_sparse(A, g)
    t_seq = factorize_tasklist(tm, tree_reduction=False)
    t_tree = factorize_tasklist(tm, tree_reduction=True, tree_workers=4)
    assert np.allclose(np.asarray(t_seq), np.asarray(t_tree), atol=1e-4)


def test_solve_and_logdet():
    A, g, bm, dense = _setup(200, 24, 16, 16, 0.7)
    f = factorize_window(bm)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(g.padded_n).astype(np.float32)
    x = solve(f, jnp.asarray(b))
    xref = np.linalg.solve(dense, b)
    assert np.abs(np.asarray(x) - xref).max() < 1e-3 * np.abs(xref).max()
    sign, ldref = np.linalg.slogdet(dense)
    assert sign > 0
    assert abs(float(logdet(f)) - ldref) < 1e-2 * abs(ldref)


def test_forward_backward_are_triangular_solves():
    A, g, bm, dense = _setup(160, 8, 16, 16, 0.5)
    f = factorize_window(bm)
    L = np.tril(f.ctsf.to_dense())
    rng = np.random.default_rng(1)
    b = rng.standard_normal(g.padded_n).astype(np.float32)
    y = forward_solve(f, jnp.asarray(b))
    yref = np.linalg.solve(L, b)
    assert np.abs(np.asarray(y) - yref).max() < 1e-3 * np.abs(yref).max()
    x = backward_solve(f, jnp.asarray(y))
    xref = np.linalg.solve(L.T, np.asarray(y))
    assert np.abs(np.asarray(x) - xref).max() < 1e-3 * np.abs(xref).max()


def test_gmrf_sampling_covariance():
    """x = L^{-T} z has covariance A^{-1}: check via quadratic forms."""
    A, g, bm, dense = _setup(96, 8, 16, 16, 0.5)
    f = factorize_window(bm)
    keys = jax.random.split(jax.random.PRNGKey(0), 256)
    xs = np.stack([np.asarray(sample_gmrf(f, k)) for k in keys])
    emp = xs.T @ xs / xs.shape[0]
    cov = np.linalg.inv(dense)
    # loose statistical check on the dominant entries
    scale = np.abs(cov).max()
    assert np.abs(emp - cov).max() < 12 * scale / np.sqrt(xs.shape[0])


def test_pallas_impl_matches_ref_end_to_end():
    """impl="pallas" now rides the single-launch fused band-Cholesky sweep
    (sweep="auto" resolves to "fused" on the Pallas backend)."""
    A, g, bm, dense = _setup(128, 16, 16, 16, 0.6)
    f_ref = factorize_window(bm, options=SolverOptions(impl="ref"))
    f_pl = factorize_window(bm, options=SolverOptions(impl="pallas"))
    assert np.allclose(f_ref.ctsf.to_dense(), f_pl.ctsf.to_dense(), atol=2e-4)


@pytest.mark.parametrize("n,bw,ar,t,rho", CASES)
def test_fused_sweep_matches_dense(n, bw, ar, t, rho):
    """The one-launch factorization (sweep="fused") is a drop-in for the
    scan path on every grid shape, not just where Pallas is the default."""
    A, g, bm, dense = _setup(n, bw, ar, t, rho)
    f = factorize_window(bm, options=SolverOptions(sweep="fused"))
    Lref = np.linalg.cholesky(dense)
    err = np.abs(f.ctsf.to_dense() - np.tril(Lref)).max()
    assert err < 1e-3 * max(1.0, np.abs(Lref).max())
    f_ring = factorize_window(bm, options=SolverOptions(sweep="ring"))
    assert np.allclose(f.ctsf.to_dense(), f_ring.ctsf.to_dense(), atol=2e-4)


def test_factorize_window_batched_rides_fused_sweep():
    """End-to-end through the batched θ-sweep entry point: impl="pallas"
    (fused kernel under vmap) matches the looped ref factorizations."""
    from repro.core import factorize_window_batched
    mats = []
    for s in range(3):
        A, g, bm, dense = _setup(160, 8, 16, 16, 0.5, seed=s)
        mats.append(bm)
    fb = factorize_window_batched(mats, options=SolverOptions(impl="pallas"))    # bucket pads 3 -> 4
    assert fb.ctsf.Dr.shape[0] == 3
    for i, m in enumerate(mats):
        fi = factorize_window(m, options=SolverOptions(impl="ref"))
        np.testing.assert_allclose(np.asarray(fb.ctsf.Dr[i]),
                                   np.asarray(fi.ctsf.Dr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(fb.ctsf.R[i]),
                                   np.asarray(fi.ctsf.R),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(fb.ctsf.C[i]),
                                   np.asarray(fi.ctsf.C),
                                   rtol=2e-4, atol=2e-4)


# degenerate grids: single diag tile (bt=0), bt=0 + arrow, pure band
# (nat=0), and a single-tile matrix — the task-list backend's tree
# reduction was previously only exercised on the default grids
DEGENERATE_CASES = [
    (16, 4, 0, 16),      # one diagonal tile, no arrow (bt=0, nat=0)
    (30, 6, 14, 16),     # one diagonal tile + arrow (bt=0, nat=1)
    (64, 7, 0, 16),      # multi-tile pure band (nat=0)
    (48, 30, 12, 16),    # wide band + arrow, uneven tiles
]


@pytest.mark.parametrize("n,bw,ar,t", DEGENERATE_CASES)
def test_tasklist_tree_reduction_degenerate_grids(n, bw, ar, t):
    """factorize_tasklist(tree_reduction=True) parity against
    factorize_window across the degenerate grids."""
    A, g, bm, dense = _setup(n, bw, ar, t, 0.6)
    fw = factorize_window(bm)
    tm = TileMatrix.from_sparse(A, g)
    tiles = factorize_tasklist(tm, tree_reduction=True, tree_workers=4)
    assert np.allclose(np.tril(tm.to_dense(tiles)), fw.ctsf.to_dense(),
                       atol=5e-4)
    # and against the dense oracle directly
    Lref = np.linalg.cholesky(dense)
    err = np.abs(np.tril(tm.to_dense(tiles)) - np.tril(Lref)).max()
    assert err < 1e-3 * max(1.0, np.abs(Lref).max())
