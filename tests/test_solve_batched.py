"""Batched serving path: multi-RHS sweeps, one-sweep marginal variances,
vmapped window factorization (all vs their per-vector/per-matrix references)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, TileGrid, backward_solve_many,
                        factorize_window, factorize_window_batched,
                        forward_solve_many, marginal_variances, sample_gmrf,
                        sample_gmrf_many, solve, solve_many)
from repro.core.concurrent import (concurrent_quadratic_forms,
                                   concurrent_solve, stack_ctsf)
from repro.core.solve import _marginal_variances_map, backward_solve
from repro.data import make_arrowhead
from repro.core.options import SolverOptions


def _factored_problem(n=320, bw=24, ar=32, t=16, seed=0):
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=seed)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)
    return bm, factorize_window(bm), grid


def test_solve_many_matches_columnwise_solve():
    bm, f, grid = _factored_problem()
    rng = np.random.default_rng(1)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, 9)).astype(np.float32))
    X = np.asarray(solve_many(f, B))
    for i in range(B.shape[1]):
        xi = np.asarray(solve(f, B[:, i]))
        np.testing.assert_allclose(X[:, i], xi, atol=1e-5, rtol=1e-5)


def test_solve_many_matches_dense():
    bm, f, grid = _factored_problem()
    rng = np.random.default_rng(2)
    B = rng.standard_normal((grid.padded_n, 5)).astype(np.float32)
    X = np.asarray(solve_many(f, jnp.asarray(B)))
    want = np.linalg.solve(bm.to_dense(lower_only=False), B)
    np.testing.assert_allclose(X, want, rtol=2e-3, atol=2e-4)


def test_forward_backward_many_roundtrip():
    bm, f, grid = _factored_problem()
    rng = np.random.default_rng(3)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, 4)).astype(np.float32))
    Y = forward_solve_many(f, B)
    X = backward_solve_many(f, Y)
    # L L^T X = B  =>  A X = B
    dense = bm.to_dense(lower_only=False)
    np.testing.assert_allclose(np.asarray(dense @ np.asarray(X)),
                               np.asarray(B), atol=5e-3)


def test_marginal_variances_batched_vs_per_index():
    bm, f, grid = _factored_problem()
    idx = jnp.asarray([0, 7, 63, 150, 250, 319])
    got = np.asarray(marginal_variances(f, idx))
    ref = np.asarray(_marginal_variances_map(f, idx))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


def test_marginal_variances_match_dense_inverse():
    bm, f, grid = _factored_problem(n=160, bw=16, ar=16, seed=0)
    idx = jnp.asarray([0, 7, 63, 150, 159])
    got = np.asarray(marginal_variances(f, idx))
    inv = np.linalg.inv(bm.to_dense(lower_only=False))
    want = np.diag(inv)[np.asarray(idx)]
    np.testing.assert_allclose(got, want, rtol=2e-3)


def test_factorize_window_batched_matches_loop():
    mats = []
    for s in range(3):
        A, struct = make_arrowhead(320, 24, 32, rho=0.6, seed=s)
        mats.append(BandedCTSF.from_sparse(A, TileGrid(struct, t=16)))
    fb = factorize_window_batched(mats)          # bucket pads 3 -> 4
    assert fb.ctsf.Dr.shape[0] == 3
    for i, m in enumerate(mats):
        fi = factorize_window(m)
        np.testing.assert_allclose(np.asarray(fb.ctsf.Dr[i]),
                                   np.asarray(fi.ctsf.Dr), atol=1e-5)
        np.testing.assert_allclose(np.asarray(fb.ctsf.R[i]),
                                   np.asarray(fi.ctsf.R), atol=1e-5)
        np.testing.assert_allclose(np.asarray(fb.ctsf.C[i]),
                                   np.asarray(fi.ctsf.C), atol=1e-5)


def test_factorize_window_batched_stacked_input():
    mats = []
    for s in range(2):
        A, struct = make_arrowhead(160, 16, 16, rho=0.5, seed=s)
        mats.append(BandedCTSF.from_sparse(A, TileGrid(struct, t=16)))
    batch = stack_ctsf(mats)
    fb = factorize_window_batched(batch, bucket=False)
    fl = factorize_window_batched(mats)
    np.testing.assert_allclose(np.asarray(fb.ctsf.Dr), np.asarray(fl.ctsf.Dr),
                               atol=1e-6)


def test_concurrent_solve_and_quadratic_forms():
    mats = []
    for s in range(3):
        A, struct = make_arrowhead(160, 16, 16, rho=0.5, seed=10 + s)
        mats.append(BandedCTSF.from_sparse(A, TileGrid(struct, t=16)))
    fb = factorize_window_batched(mats)
    g = mats[0].grid
    y = jnp.asarray(np.random.default_rng(4).standard_normal(
        g.padded_n).astype(np.float32))
    quads = np.asarray(concurrent_quadratic_forms(fb, y))
    xs = np.asarray(concurrent_solve(fb, y))
    for i, m in enumerate(mats):
        dense = m.to_dense(lower_only=False)
        want_x = np.linalg.solve(dense, np.asarray(y))
        np.testing.assert_allclose(xs[i], want_x, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(quads[i], float(np.asarray(y) @ want_x),
                                   rtol=1e-4)


@pytest.mark.parametrize("k", [1, 16])
@pytest.mark.parametrize("problem", [dict(n=320, bw=24, ar=32, t=16),
                                     dict(n=256, bw=48, ar=0, t=16)])
def test_fused_pallas_solve_matches_looped_ref(k, problem):
    """solve_many with the fused Pallas sweeps (interpret mode on CPU)
    agrees with the per-tile fori_loop reference to fp32 tolerance, with
    and without an arrow block."""
    bm, f, grid = _factored_problem(**problem)
    rng = np.random.default_rng(11)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, k)).astype(np.float32))
    got = np.asarray(solve_many(f, B, options=SolverOptions(impl="pallas")))
    want = np.asarray(solve_many(f, B, options=SolverOptions(impl="ref")))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_fused_pallas_forward_start_tile_matches_ref():
    """The RHS-sparsity fast start (marginal_variances method="panels")
    takes the same fused kernel with a traced start tile."""
    bm, f, grid = _factored_problem()
    idx = [200, 210, 220, 300]
    E = jnp.zeros((grid.padded_n, len(idx)), jnp.float32)
    E = E.at[jnp.asarray(idx), jnp.arange(len(idx))].set(1.0)
    start = min(idx) // grid.t
    got = np.asarray(forward_solve_many(f, E, start_tile=start, options=SolverOptions(impl="pallas")))
    want = np.asarray(forward_solve_many(f, E, start_tile=start, options=SolverOptions(impl="ref")))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # and the fast start changes nothing vs the full sweep
    full = np.asarray(forward_solve_many(f, E, options=SolverOptions(impl="ref")))
    np.testing.assert_allclose(want, full, rtol=2e-4, atol=2e-4)


def test_concurrent_solve_fused_pallas_matches_ref():
    """The vmapped serving path (concurrent_solve) rides the fused sweep
    kernels unchanged — the batch axis maps onto the kernel dispatch."""
    mats = []
    for s in range(2):
        A, struct = make_arrowhead(160, 16, 16, rho=0.5, seed=20 + s)
        mats.append(BandedCTSF.from_sparse(A, TileGrid(struct, t=16)))
    fb = factorize_window_batched(mats)
    B = jnp.asarray(np.random.default_rng(6).standard_normal(
        (mats[0].grid.padded_n, 3)).astype(np.float32))
    got = np.asarray(concurrent_solve(fb, B, options=SolverOptions(impl="pallas")))
    want = np.asarray(concurrent_solve(fb, B, options=SolverOptions(impl="ref")))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_marginal_variances_panels_fused_pallas():
    """End-to-end: the panels marginals path (unit-vector RHS panel +
    fast-start forward sweep) under the fused kernels."""
    bm, f, grid = _factored_problem(n=160, bw=16, ar=16)
    idx = jnp.asarray([40, 90, 130, 159])
    got = np.asarray(marginal_variances(f, idx, options=SolverOptions(method="panels", impl="pallas")))
    want = np.asarray(marginal_variances(f, idx, options=SolverOptions(method="panels", impl="ref")))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-6)


def test_forward_solve_stays_reverse_differentiable():
    """The default (start_tile=0) sweep keeps static loop bounds, so
    reverse-mode autodiff through solves must keep working (the dynamic
    fast-start bound is only used by the panels marginals path)."""
    bm, f, grid = _factored_problem(n=160, bw=16, ar=16)
    b = jnp.ones((grid.padded_n,), jnp.float32)
    grad = jax.grad(lambda x: jnp.sum(forward_solve_many(f, x.reshape(-1, 1))
                                      ** 2))(b)
    assert np.isfinite(np.asarray(grad)).all()


def test_sample_gmrf_many_matches_columnwise_backward():
    bm, f, grid = _factored_problem(n=160, bw=16, ar=16)
    rng = np.random.default_rng(5)
    Z = jnp.asarray(rng.standard_normal((grid.padded_n, 3)).astype(np.float32))
    many = np.asarray(backward_solve_many(f, Z))
    for i in range(3):
        np.testing.assert_allclose(many[:, i],
                                   np.asarray(backward_solve(f, Z[:, i])),
                                   atol=1e-5, rtol=1e-5)
    s1 = sample_gmrf(f, jax.random.PRNGKey(0))
    sm = sample_gmrf_many(f, jax.random.PRNGKey(0), num=4)
    assert s1.shape == (grid.padded_n,)
    assert sm.shape == (grid.padded_n, 4)
    assert np.isfinite(np.asarray(sm)).all()
