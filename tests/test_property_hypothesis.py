"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core import (ArrowheadStructure, BandedCTSF, TileGrid,
                        chunked_tree_sum, factorize_window, logdet, solve,
                        symbolic_factorize, tile_pattern_from_coo)
from repro.core.ordering import rcm_ordering, apply_permutation
from repro.data import make_arrowhead

SETTINGS = dict(max_examples=12, deadline=None)


@st.composite
def arrowhead_problem(draw):
    t = draw(st.sampled_from([8, 16]))
    ndt = draw(st.integers(3, 8))
    bw = draw(st.integers(1, 2 * t))
    arrow = draw(st.sampled_from([0, t // 2, t]))
    rho = draw(st.sampled_from([0.0, 0.5, 0.9]))
    n = ndt * t + arrow
    seed = draw(st.integers(0, 2 ** 16))
    return n, bw, arrow, t, rho, seed


@given(arrowhead_problem())
@settings(**SETTINGS)
def test_factorization_reconstructs_matrix(problem):
    """L L^T == A (the defining property), for random structures."""
    n, bw, arrow, t, rho, seed = problem
    A, stc = make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
    g = TileGrid(stc, t=t)
    bm = BandedCTSF.from_sparse(A, g)
    dense = bm.to_dense(lower_only=False)
    f = factorize_window(bm)
    L = np.tril(f.ctsf.to_dense())
    recon = L @ L.T
    scale = max(1.0, np.abs(dense).max())
    assert np.abs(recon - dense).max() < 5e-3 * scale


@given(arrowhead_problem())
@settings(**SETTINGS)
def test_solve_inverts(problem):
    n, bw, arrow, t, rho, seed = problem
    A, stc = make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
    g = TileGrid(stc, t=t)
    bm = BandedCTSF.from_sparse(A, g)
    dense = bm.to_dense(lower_only=False)
    f = factorize_window(bm)
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(g.padded_n).astype(np.float32)
    x = np.asarray(solve(f, jnp.asarray(b)))
    resid = np.abs(dense @ x - b).max()
    assert resid < 1e-2 * max(1.0, np.abs(b).max(), np.abs(dense).max())


@given(arrowhead_problem())
@settings(**SETTINGS)
def test_logdet_matches_slogdet(problem):
    n, bw, arrow, t, rho, seed = problem
    A, stc = make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
    g = TileGrid(stc, t=t)
    bm = BandedCTSF.from_sparse(A, g)
    f = factorize_window(bm)
    sign, ld = np.linalg.slogdet(bm.to_dense(lower_only=False))
    assert sign > 0
    assert abs(float(logdet(f)) - ld) < 1e-2 * max(1.0, abs(ld))


@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_tree_reduction_is_reassociation(k, chunks, seed):
    """chunked_tree_sum == plain sum for any (K, chunk) combination."""
    rng = np.random.default_rng(seed)
    terms = jnp.asarray(rng.standard_normal((k, 5, 5)), jnp.float32)
    got = np.asarray(chunked_tree_sum(terms, chunks))
    np.testing.assert_allclose(got, np.asarray(terms.sum(0)),
                               rtol=1e-4, atol=1e-4)


@given(arrowhead_problem())
@settings(**SETTINGS)
def test_symbolic_pattern_contains_input(problem):
    """L pattern ⊇ A pattern; tasks only touch allocated tiles."""
    n, bw, arrow, t, rho, seed = problem
    A, stc = make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
    g = TileGrid(stc, t=t)
    a_tiles = tile_pattern_from_coo(A, g)
    s = symbolic_factorize(a_tiles)
    assert not (a_tiles & ~s.l_pattern).any()
    for task in s.tasks:
        if task.m >= 0 and task.type.name in ("TRSM", "GEMM"):
            assert s.l_pattern[task.m, task.k]


@given(st.integers(30, 200), st.integers(1, 20), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_rcm_permutation_bijective(n, bw, seed):
    A, stc = make_arrowhead(n, bw, 0, seed=seed)
    perm = rcm_ordering(A, stc, partial=False)
    assert sorted(perm.tolist()) == list(range(n))
    # symmetric permutation preserves symmetry + diagonal positivity
    P = apply_permutation(A, perm)
    assert (np.abs(P.toarray() - P.toarray().T) < 1e-9).all()
