"""Per-kernel validation: sweep shapes/dtypes, assert allclose vs the
pure-jnp oracles in kernels/ref.py (Pallas in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.band_update import band_update_pallas
from repro.kernels.gemm import gemm_pallas, geadd_pallas, syrk_pallas
from repro.kernels.potrf import potrf_pallas
from repro.kernels.selinv import selinv_step_pallas
from repro.kernels.trsm import trsm_pallas

TILES = [8, 16, 32, 64]
DTYPES = [jnp.float32]


def _spd(rng, t, dtype):
    a = rng.standard_normal((t, t)).astype(np.float32)
    return jnp.asarray(a @ a.T + t * np.eye(t), dtype)


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_potrf(rng, t, dtype):
    a = _spd(rng, t, dtype)
    out = potrf_pallas(a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.potrf_ref(a)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", TILES)
def test_potrf_batched(rng, t):
    a = jnp.stack([_spd(rng, t, jnp.float32) for _ in range(3)])
    out = potrf_pallas(a)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.potrf_ref(a[i])),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", TILES)
def test_trsm(rng, t):
    l = ref.potrf_ref(_spd(rng, t, jnp.float32))
    a = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    np.testing.assert_allclose(np.asarray(trsm_pallas(l, a)),
                               np.asarray(ref.trsm_ref(l, a)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t", TILES)
def test_trsm_batched(rng, t):
    l = ref.potrf_ref(_spd(rng, t, jnp.float32))
    a = jnp.asarray(rng.standard_normal((4, t, t)), jnp.float32)
    out = trsm_pallas(l, a)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.trsm_ref(l, a[i])),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("kblock", [8, 64])
def test_gemm_syrk(rng, t, kblock):
    c = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gemm_pallas(c, a, b, kblock=kblock)),
        np.asarray(ref.gemm_ref(c, a, b)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(syrk_pallas(c, a, kblock=kblock)),
        np.asarray(ref.syrk_ref(c, a)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t", TILES)
def test_geadd(rng, t):
    a = jnp.asarray(rng.standard_normal((5, t, t)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((5, t, t)), jnp.float32)
    np.testing.assert_allclose(np.asarray(geadd_pallas(a, b)),
                               np.asarray(a + b), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b1", [2, 3, 5, 9])
@pytest.mark.parametrize("t", [8, 16, 32])
@pytest.mark.parametrize("jblock", [2, 4, 16])
def test_band_update(rng, b1, t, jblock):
    w = jnp.asarray(rng.standard_normal((b1, b1, t, t)), jnp.float32)
    out = band_update_pallas(w, jblock=jblock)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.band_update_ref(w)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("e_n,j_n", [(1, 1), (3, 5), (4, 9), (2, 17)])
@pytest.mark.parametrize("t", [8, 16, 32])
@pytest.mark.parametrize("jblock", [2, 8])
def test_selinv_step(rng, e_n, j_n, t, jblock):
    s = jnp.asarray(rng.standard_normal((e_n, j_n, t, t)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((j_n, t, t)), jnp.float32)
    out = selinv_step_pallas(s, g, jblock=jblock)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.selinv_step_ref(s, g)),
                               rtol=2e-4, atol=2e-4)


def test_selinv_step_empty():
    s = jnp.zeros((0, 3, 8, 8), jnp.float32)
    g = jnp.zeros((3, 8, 8), jnp.float32)
    assert selinv_step_pallas(s, g).shape == (0, 8, 8)
    s2 = jnp.zeros((2, 0, 8, 8), jnp.float32)
    g2 = jnp.zeros((0, 8, 8), jnp.float32)
    assert np.abs(np.asarray(selinv_step_pallas(s2, g2))).max() == 0.0


def test_band_update_ref_semantics(rng):
    """Cross-check the fused contraction against the naive task loop."""
    b1, t = 4, 8
    w = np.asarray(rng.standard_normal((b1, b1, t, t)), np.float32)
    want = np.zeros((b1, t, t), np.float32)
    for e in range(b1):
        for j in range(1, b1 - e):
            want[e] += w[e, e + j] @ w[0, j].T
    np.testing.assert_allclose(np.asarray(ref.band_update_ref(jnp.asarray(w))),
                               want, rtol=1e-4, atol=1e-4)
