"""Per-kernel validation: sweep shapes/dtypes, assert allclose vs the
pure-jnp oracles in kernels/ref.py (Pallas in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.band_cholesky import band_cholesky_sweep_pallas
from repro.kernels.band_solve import (band_backward_sweep_pallas,
                                      band_forward_sweep_pallas)
from repro.kernels.band_update import band_update_pallas
from repro.kernels.gemm import gemm_pallas, geadd_pallas, syrk_pallas
from repro.kernels.potrf import potrf_pallas
from repro.kernels.ring import band_row_to_col
from repro.kernels.selinv import selinv_step_pallas, selinv_sweep_pallas
from repro.kernels.trsm import trsm_pallas
from repro.core.options import SolverOptions

TILES = [8, 16, 32, 64]
DTYPES = [jnp.float32]


def _spd(rng, t, dtype):
    a = rng.standard_normal((t, t)).astype(np.float32)
    return jnp.asarray(a @ a.T + t * np.eye(t), dtype)


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_potrf(rng, t, dtype):
    a = _spd(rng, t, dtype)
    out = potrf_pallas(a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.potrf_ref(a)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", TILES)
def test_potrf_batched(rng, t):
    a = jnp.stack([_spd(rng, t, jnp.float32) for _ in range(3)])
    out = potrf_pallas(a)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.potrf_ref(a[i])),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", TILES)
def test_trsm(rng, t):
    l = ref.potrf_ref(_spd(rng, t, jnp.float32))
    a = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    np.testing.assert_allclose(np.asarray(trsm_pallas(l, a)),
                               np.asarray(ref.trsm_ref(l, a)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t", TILES)
def test_trsm_batched(rng, t):
    l = ref.potrf_ref(_spd(rng, t, jnp.float32))
    a = jnp.asarray(rng.standard_normal((4, t, t)), jnp.float32)
    out = trsm_pallas(l, a)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.trsm_ref(l, a[i])),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("kblock", [8, 64])
def test_gemm_syrk(rng, t, kblock):
    c = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((t, t)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gemm_pallas(c, a, b, kblock=kblock)),
        np.asarray(ref.gemm_ref(c, a, b)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(syrk_pallas(c, a, kblock=kblock)),
        np.asarray(ref.syrk_ref(c, a)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t", TILES)
def test_geadd(rng, t):
    a = jnp.asarray(rng.standard_normal((5, t, t)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((5, t, t)), jnp.float32)
    np.testing.assert_allclose(np.asarray(geadd_pallas(a, b)),
                               np.asarray(a + b), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b1", [2, 3, 5, 9])
@pytest.mark.parametrize("t", [8, 16, 32])
@pytest.mark.parametrize("jblock", [2, 4, 16])
def test_band_update(rng, b1, t, jblock):
    w = jnp.asarray(rng.standard_normal((b1, b1, t, t)), jnp.float32)
    out = band_update_pallas(w, jblock=jblock)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.band_update_ref(w)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("e_n,j_n", [(1, 1), (3, 5), (4, 9), (2, 17)])
@pytest.mark.parametrize("t", [8, 16, 32])
@pytest.mark.parametrize("jblock", [2, 8])
def test_selinv_step(rng, e_n, j_n, t, jblock):
    s = jnp.asarray(rng.standard_normal((e_n, j_n, t, t)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((j_n, t, t)), jnp.float32)
    out = selinv_step_pallas(s, g, jblock=jblock)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.selinv_step_ref(s, g)),
                               rtol=2e-4, atol=2e-4)


def test_selinv_step_empty():
    s = jnp.zeros((0, 3, 8, 8), jnp.float32)
    g = jnp.zeros((3, 8, 8), jnp.float32)
    assert selinv_step_pallas(s, g).shape == (0, 8, 8)
    s2 = jnp.zeros((2, 0, 8, 8), jnp.float32)
    g2 = jnp.zeros((0, 8, 8), jnp.float32)
    assert np.abs(np.asarray(selinv_step_pallas(s2, g2))).max() == 0.0


def _band_factor(rng, ndt, bt, nat, t):
    """Random row-band factor tiles with the BandedCTSF conventions:
    well-conditioned lower-triangular diagonal tiles, structural zeros
    above the band (Dr[m, j] = 0 for j > m)."""
    Dr = rng.standard_normal((ndt, bt + 1, t, t)).astype(np.float32)
    for m in range(ndt):
        Dr[m, 0] = np.tril(Dr[m, 0]) + t * np.eye(t)
        Dr[m, min(m, bt) + 1:] = 0.0
    R = rng.standard_normal((ndt, nat, t, t)).astype(np.float32)
    return jnp.asarray(Dr), jnp.asarray(R)


# grids cover: single tile (bt=0), no arrow, bandwidth > 1, deep band
SWEEP_GRIDS = [(1, 0, 0), (5, 1, 0), (6, 2, 2), (9, 4, 1)]


@pytest.mark.parametrize("ndt,bt,nat", SWEEP_GRIDS)
@pytest.mark.parametrize("k", [1, 13])
def test_band_forward_sweep(rng, ndt, bt, nat, k):
    t = 8
    Dr, R = _band_factor(rng, ndt, bt, nat, t)
    bd = jnp.asarray(rng.standard_normal((ndt, t, k)), jnp.float32)
    yd, acca = band_forward_sweep_pallas(Dr, R, bd)
    yr, accr = ref.band_forward_sweep_ref(Dr, R, bd)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(acca), np.asarray(accr),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("start_tile", [1, 3, 6])
def test_band_forward_sweep_start_tile(rng, start_tile):
    """Rows above start_tile come out identically zero on both backends,
    even when the RHS is nonzero there (the reference never writes them)."""
    ndt, bt, nat, t, k = 7, 2, 1, 8, 4
    Dr, R = _band_factor(rng, ndt, bt, nat, t)
    bd = jnp.asarray(rng.standard_normal((ndt, t, k)), jnp.float32)
    yd, acca = band_forward_sweep_pallas(Dr, R, bd, start_tile=start_tile)
    yr, accr = ref.band_forward_sweep_ref(Dr, R, bd, start_tile=start_tile)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(acca), np.asarray(accr),
                               rtol=2e-4, atol=2e-4)
    assert np.abs(np.asarray(yd[:start_tile])).max() == 0.0


@pytest.mark.parametrize("ndt,bt,nat", SWEEP_GRIDS)
@pytest.mark.parametrize("k", [1, 13])
def test_band_backward_sweep(rng, ndt, bt, nat, k):
    t = 8
    Dr, R = _band_factor(rng, ndt, bt, nat, t)
    yd = jnp.asarray(rng.standard_normal((ndt, t, k)), jnp.float32)
    xa = jnp.asarray(rng.standard_normal((nat, t, k)), jnp.float32)
    xd = band_backward_sweep_pallas(Dr, R, yd, xa)
    xr = ref.band_backward_sweep_ref(Dr, R, yd, xa)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xr),
                               rtol=2e-4, atol=2e-4)


def test_band_sweeps_vmap(rng):
    """Batched factors (concurrent_solve's shape) ride the fused kernels
    through jax.vmap; the shared RHS panel is broadcast."""
    ndt, bt, nat, t, k, nb = 6, 2, 1, 8, 5, 3
    Drs, Rs = zip(*[_band_factor(rng, ndt, bt, nat, t) for _ in range(nb)])
    Drb, Rb = jnp.stack(Drs), jnp.stack(Rs)
    bd = jnp.asarray(rng.standard_normal((ndt, t, k)), jnp.float32)
    xa = jnp.asarray(rng.standard_normal((nat, t, k)), jnp.float32)
    yb, ab = jax.vmap(lambda d, r: band_forward_sweep_pallas(d, r, bd))(Drb, Rb)
    xb = jax.vmap(lambda d, r: band_backward_sweep_pallas(d, r, bd, xa))(Drb, Rb)
    for i in range(nb):
        yr, ar = ref.band_forward_sweep_ref(Drb[i], Rb[i], bd)
        np.testing.assert_allclose(np.asarray(yb[i]), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(ab[i]), np.asarray(ar),
                                   rtol=2e-4, atol=2e-4)
        xr = ref.band_backward_sweep_ref(Drb[i], Rb[i], bd, xa)
        np.testing.assert_allclose(np.asarray(xb[i]), np.asarray(xr),
                                   rtol=2e-4, atol=2e-4)


def test_band_sweep_ref_semantics(rng):
    """Cross-check the sweep reference against naive per-row substitution."""
    import scipy.linalg
    ndt, bt, nat, t, k = 5, 2, 1, 8, 3
    Dr, R = _band_factor(rng, ndt, bt, nat, t)
    bd = rng.standard_normal((ndt, t, k)).astype(np.float32)
    Drn, Rn = np.asarray(Dr), np.asarray(R)
    want = np.zeros((ndt, t, k), np.float32)
    for m in range(ndt):
        acc = sum(Drn[m, j] @ want[m - j] for j in range(1, min(m, bt) + 1))
        want[m] = scipy.linalg.solve_triangular(Drn[m, 0], bd[m] - acc,
                                                lower=True)
    want_acc = np.einsum("niab,nbk->iak", Rn, want)
    yd, acca = ref.band_forward_sweep_ref(Dr, R, jnp.asarray(bd))
    np.testing.assert_allclose(np.asarray(yd), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(acca), want_acc, rtol=2e-4, atol=2e-4)


def _spd_ctsf(n, bw, ar, t, seed=0):
    """A real SPD banded-arrowhead CTSF (the fused factorization/selinv
    sweeps need genuinely factorizable inputs, unlike the solve sweeps)."""
    from repro.core import BandedCTSF, TileGrid
    from repro.data import make_arrowhead
    A, st = make_arrowhead(n, bw, ar, rho=0.6, seed=seed)
    grid = TileGrid(st, t=t)
    return BandedCTSF.from_sparse(A, grid), grid


def _corner_sigma(C, nat, t):
    """Dense corner seed Σ_cc = L_c^{-T} L_c^{-1} (mirrors core/selinv.py)."""
    if not nat:
        return jnp.zeros((0, 0, t, t), C.dtype)
    nc = nat * t
    cd = C.transpose(0, 2, 1, 3).reshape(nc, nc)
    winv = jax.scipy.linalg.solve_triangular(
        cd, jnp.eye(nc, dtype=C.dtype), lower=True)
    return jnp.dot(winv.T, winv).reshape(nat, t, nat, t).transpose(0, 2, 1, 3)


# grids cover: single tile (bt=0), bt=0 + arrow, nat=0 with bt=1, thick
# arrow / wide band, deep band with small tiles
CHOLESKY_GRIDS = [(16, 4, 0, 16), (30, 6, 14, 16), (160, 8, 0, 16),
                  (130, 40, 30, 16), (96, 40, 16, 8)]


@pytest.mark.parametrize("n,bw,ar,t", CHOLESKY_GRIDS)
@pytest.mark.parametrize("nchunks", [1, 3])
def test_band_cholesky_sweep(n, bw, ar, t, nchunks):
    """One-launch factorization matches the ring-scan oracle: panels,
    factored arrow rows and the per-chunk corner-Schur partial sums."""
    bm, grid = _spd_ctsf(n, bw, ar, t)
    Ac = band_row_to_col(bm.Dr)
    got = band_cholesky_sweep_pallas(Ac, bm.R, nchunks=nchunks)
    want = ref.band_cholesky_sweep_ref(Ac, bm.R, nchunks=nchunks)
    for g, w, name in zip(got, want, ("panels", "R_out", "schur", "status")):
        assert g.shape == w.shape, name
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_band_cholesky_sweep_vmap(rng):
    """Batched matrices (factorize_window_batched's shape) ride the fused
    kernel through jax.vmap."""
    mats = [_spd_ctsf(130, 40, 30, 16, seed=s)[0] for s in range(3)]
    Acb = jnp.stack([band_row_to_col(m.Dr) for m in mats])
    Rb = jnp.stack([m.R for m in mats])
    got = jax.vmap(lambda a, r: band_cholesky_sweep_pallas(a, r, nchunks=2))(
        Acb, Rb)
    for i in range(3):
        want = ref.band_cholesky_sweep_ref(Acb[i], Rb[i], nchunks=2)
        for g, w, name in zip(got, want, ("panels", "R_out", "schur", "status")):
            np.testing.assert_allclose(np.asarray(g[i]), np.asarray(w),
                                       rtol=2e-4, atol=2e-4, err_msg=name)


@pytest.mark.parametrize("n,bw,ar,t", CHOLESKY_GRIDS)
def test_selinv_sweep(n, bw, ar, t):
    """One-launch Takahashi recurrence matches the per-column scan oracle."""
    from repro.core import factorize_window
    bm, grid = _spd_ctsf(n, bw, ar, t)
    f = factorize_window(bm, options=SolverOptions(impl="ref")).ctsf
    lcol = band_row_to_col(f.Dr)
    sc = _corner_sigma(f.C, grid.n_arrow_tiles, t)
    gp, ga = selinv_sweep_pallas(lcol, f.R, sc)
    wp, wa = ref.selinv_sweep_ref(lcol, f.R, sc)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(wa),
                               rtol=2e-4, atol=2e-4)


def test_selinv_sweep_vmap():
    from repro.core import factorize_window
    facs, grids = zip(*[(_spd_ctsf(96, 40, 16, 8, seed=s)) for s in range(2)])
    fs = [factorize_window(m, options=SolverOptions(impl="ref")).ctsf for m in facs]
    lcolb = jnp.stack([band_row_to_col(f.Dr) for f in fs])
    Rb = jnp.stack([f.R for f in fs])
    scb = jnp.stack([_corner_sigma(f.C, grids[0].n_arrow_tiles, 8)
                     for f in fs])
    gp, ga = jax.vmap(selinv_sweep_pallas)(lcolb, Rb, scb)
    for i in range(2):
        wp, wa = ref.selinv_sweep_ref(lcolb[i], Rb[i], scb[i])
        np.testing.assert_allclose(np.asarray(gp[i]), np.asarray(wp),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(ga[i]), np.asarray(wa),
                                   rtol=2e-4, atol=2e-4)


def test_fused_sweeps_are_single_launch():
    """The whole factorization / selinv recurrence is exactly one Pallas
    launch (vs 3·ndt / 2·ndt per-panel dispatches for the scan paths).
    Uses the same jaxpr counter the CI launch-count gate gates on."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.bench_cholesky import count_pallas_launches
    finally:
        sys.path.pop(0)
    _count_pallas_calls = count_pallas_launches
    bm, grid = _spd_ctsf(130, 40, 30, 16)
    Ac = band_row_to_col(bm.Dr)
    jx = jax.make_jaxpr(
        lambda a, r: band_cholesky_sweep_pallas(a, r, nchunks=4))(Ac, bm.R)
    assert _count_pallas_calls(jx) == 1
    sc = jnp.zeros((2, 2, 16, 16), jnp.float32)   # tracing only needs shapes
    jx2 = jax.make_jaxpr(selinv_sweep_pallas)(Ac, bm.R, sc)
    assert _count_pallas_calls(jx2) == 1


@pytest.mark.parametrize("start_tile", [2, 5])
def test_band_cholesky_sweep_start_tile(start_tile):
    """With a start_tile prefix, both backends emit identity panels / zero
    arrow rows for the prefix and the exact factor of the identity-embedded
    matrix elsewhere — the canonical-grid embedding contract
    (core/gridpolicy.py)."""
    from repro.core import embed_ctsf, GridBucketPolicy, TileGrid
    bm, grid = _spd_ctsf(96, 16, 8, 8)
    cgrid = TileGrid.from_tile_counts(
        8, grid.n_diag_tiles + start_tile, grid.band_tiles,
        grid.n_arrow_tiles)
    emb = embed_ctsf(bm, cgrid)
    Ac = band_row_to_col(emb.Dr)
    # traced start (as the serving path passes it) and both backends
    st = jnp.asarray(start_tile, jnp.int32)
    got = band_cholesky_sweep_pallas(Ac, emb.R, nchunks=3, start_tile=st)
    want = ref.band_cholesky_sweep_ref(Ac, emb.R, nchunks=3, start_tile=st)
    for g, w, name in zip(got, want, ("panels", "R_out", "schur", "status")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=2e-4, err_msg=name)
    panels = np.asarray(got[0])
    np.testing.assert_allclose(panels[:start_tile, 0],
                               np.broadcast_to(np.eye(8), (start_tile, 8, 8)),
                               atol=1e-6)
    assert np.abs(panels[:start_tile, 1:]).max() == 0.0
    # prefix skip leaves the suffix identical to the unembedded sweep
    plain = ref.band_cholesky_sweep_ref(band_row_to_col(bm.Dr), bm.R)
    np.testing.assert_allclose(panels[start_tile:], np.asarray(plain[0]),
                               rtol=2e-4, atol=2e-4)


def test_selinv_sweep_start_tile():
    """Prefix columns of the fused/ref Takahashi sweeps emit identity Σ
    panels (Σ_embedded = blockdiag(I, Σ)); the suffix matches the
    unembedded recurrence."""
    from repro.core import embed_ctsf, factorize_window, TileGrid
    bm, grid = _spd_ctsf(96, 16, 8, 8)
    pad = 3
    cgrid = TileGrid.from_tile_counts(
        8, grid.n_diag_tiles + pad, grid.band_tiles, grid.n_arrow_tiles)
    f = factorize_window(embed_ctsf(bm, cgrid), options=SolverOptions(impl="ref")).ctsf
    lcol = band_row_to_col(f.Dr)
    sc = _corner_sigma(f.C, cgrid.n_arrow_tiles, 8)
    st = jnp.asarray(pad, jnp.int32)
    gp, ga = selinv_sweep_pallas(lcol, f.R, sc, start_tile=st)
    wp, wa = ref.selinv_sweep_ref(lcol, f.R, sc, start_tile=st)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(wa),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gp)[:pad, 0],
                               np.broadcast_to(np.eye(8), (pad, 8, 8)),
                               atol=1e-6)
    assert np.abs(np.asarray(gp)[:pad, 1:]).max() == 0.0
    f0 = factorize_window(bm, options=SolverOptions(impl="ref")).ctsf
    wp0, _ = ref.selinv_sweep_ref(band_row_to_col(f0.Dr), f0.R,
                                  _corner_sigma(f0.C, grid.n_arrow_tiles, 8))
    np.testing.assert_allclose(np.asarray(gp)[pad:], np.asarray(wp0),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("start_tile", [1, 4])
def test_band_backward_sweep_start_tile(rng, start_tile):
    """Rows below start_tile come out identically zero on both backends
    (the reverse-sweep mirror of the forward fast start)."""
    ndt, bt, nat, t, k = 7, 2, 1, 8, 4
    Dr, R = _band_factor(rng, ndt, bt, nat, t)
    yd = jnp.asarray(rng.standard_normal((ndt, t, k)), jnp.float32)
    xa = jnp.asarray(rng.standard_normal((nat, t, k)), jnp.float32)
    st = jnp.asarray(start_tile, jnp.int32)
    xd = band_backward_sweep_pallas(Dr, R, yd, xa, start_tile=st)
    xr = ref.band_backward_sweep_ref(Dr, R, yd, xa, start_tile=st)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xr),
                               rtol=2e-4, atol=2e-4)
    assert np.abs(np.asarray(xd)[:start_tile]).max() == 0.0
    # rows >= start_tile agree with the full sweep (suffix decouples
    # upward: X_m only reads X_{m+j}, never the skipped prefix)
    xfull = ref.band_backward_sweep_ref(Dr, R, yd, xa)
    np.testing.assert_allclose(np.asarray(xd)[start_tile:],
                               np.asarray(xfull)[start_tile:],
                               rtol=2e-4, atol=2e-4)


def test_band_update_ref_semantics(rng):
    """Cross-check the fused contraction against the naive task loop."""
    b1, t = 4, 8
    w = np.asarray(rng.standard_normal((b1, b1, t, t)), np.float32)
    want = np.zeros((b1, t, t), np.float32)
    for e in range(b1):
        for j in range(1, b1 - e):
            want[e] += w[e, e + j] @ w[0, j].T
    np.testing.assert_allclose(np.asarray(ref.band_update_ref(jnp.asarray(w))),
                               want, rtol=1e-4, atol=1e-4)
