"""Numerical fault tolerance: in-sweep breakdown detection (status words),
the escalating-jitter recovery ladder, per-element graceful degradation,
refinement, and the hardened (assert-free) validation paths."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid,
                        STATUS_FAILED, STATUS_OK, STATUS_RECOVERED,
                        FactorInfo, RegularizePolicy, factorize_window,
                        factorize_window_batched, solve_many)
from repro.core.cholesky import CholeskyFactor
from repro.core.robustness import add_diagonal_jitter, gershgorin_shift
from repro.data import (indefinite_arrowhead, make_arrowhead,
                        nan_contaminated_arrowhead, near_singular_arrowhead)
from repro.kernels import ref
from repro.kernels.band_cholesky import band_cholesky_sweep_pallas
from repro.kernels.potrf import factorize_tile
from repro.kernels.ring import band_row_to_col
from repro.runtime.fault_tolerance import NumericalFaultInjector
from repro.core.options import SolverOptions

GRIDS = [(16, 4, 0, 16), (30, 6, 14, 16), (160, 8, 0, 16),
         (130, 40, 30, 16), (96, 40, 16, 8)]


def _spd(n, bw, ar, t, seed=0, rho=0.6):
    A, st = make_arrowhead(n, bw, ar, rho=rho, seed=seed)
    g = TileGrid(st, t=t)
    bm = BandedCTSF.from_sparse(A, g)
    return g, bm, bm.to_dense(lower_only=False)


def _corrupt_diag(bm, tile=0, shift=10.0):
    """Make one band diagonal tile indefinite (mean-diagonal-scaled drop)."""
    diag = jnp.diagonal(bm.Dr[:, 0], axis1=-2, axis2=-1)
    drop = shift * jnp.mean(jnp.abs(diag))
    Dr = bm.Dr.at[tile, 0].add(-drop * jnp.eye(bm.grid.t, dtype=bm.Dr.dtype))
    return BandedCTSF(bm.grid, Dr, bm.R, bm.C)


# ---------------------------------------------------------------- detection

@pytest.mark.parametrize("n,bw,ar,t", GRIDS)
def test_status_word_parity_clean(n, bw, ar, t):
    """Both sweep backends emit the same [min_pivot, nonfinite, first_bad]
    word on SPD inputs: finite, positive pivot, first_bad == -1."""
    g, bm, _ = _spd(n, bw, ar, t)
    Ac = band_row_to_col(bm.Dr)
    *_, sp = band_cholesky_sweep_pallas(Ac, bm.R)
    *_, sr = ref.band_cholesky_sweep_ref(Ac, bm.R)
    sp, sr = np.asarray(sp), np.asarray(sr)
    np.testing.assert_allclose(sp[0], sr[0], rtol=2e-4)
    assert sp[1] == sr[1] == 0.0
    assert sp[2] == sr[2] == -1.0
    assert sp[0] > 0


@pytest.mark.parametrize("n,bw,ar,t", GRIDS)
def test_status_word_parity_corrupted(n, bw, ar, t):
    """An indefinite tile is flagged identically by both backends — same
    nonfinite bit and same first failing tile, with no exception raised."""
    g, bm, _ = _spd(n, bw, ar, t)
    tile = g.n_diag_tiles // 2
    bad = _corrupt_diag(bm, tile=tile)
    Ac = band_row_to_col(bad.Dr)
    *_, sp = band_cholesky_sweep_pallas(Ac, bad.R)
    *_, sr = ref.band_cholesky_sweep_ref(Ac, bad.R)
    sp, sr = np.asarray(sp), np.asarray(sr)
    assert sp[1] == sr[1]
    assert sp[2] == sr[2]
    assert sp[2] >= 0.0  # breakdown localized, at or after the bad tile
    np.testing.assert_allclose(sp[0], sr[0], rtol=2e-4, atol=1e-6)


def test_factorize_tile_raw_pivot():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    spd = jnp.asarray(a @ a.T + 8 * np.eye(8, dtype=np.float32))
    l0 = factorize_tile(spd)
    l1, piv = factorize_tile(spd, return_status=True)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    assert float(piv) > 0
    # corrupt only the LAST diagonal entry: every earlier pivot stays clean,
    # so the raw (signed, pre-rsqrt) pivot of the broken column survives the
    # min-fold un-poisoned
    _, piv_bad = factorize_tile(spd.at[7, 7].add(-100.0), return_status=True)
    assert float(piv_bad) < 0  # true signed pivot, pre-rsqrt
    # a mid-tile breakdown NaN-poisons later pivots; the status still
    # reads as breakdown (never a false positive)
    _, piv_mid = factorize_tile(spd - 100.0 * jnp.eye(8), return_status=True)
    assert not float(piv_mid) > 0


# ----------------------------------------------------------------- recovery

def test_ladder_recovers_indefinite_single():
    """Breakdown -> escalating jitter -> RECOVERED, and the emitted factor
    is exactly the Cholesky factor of A + tau*I."""
    g, bm, dense = _spd(96, 16, 8, 8)
    bad = _corrupt_diag(bm, tile=2)
    f = factorize_window(bad, options=SolverOptions(regularize=True))
    info = f.info
    assert int(np.asarray(info.status)) == STATUS_RECOVERED
    assert int(np.asarray(info.attempts)) > 1
    tau = float(np.asarray(info.tau))
    assert tau > 0
    L = np.tril(f.ctsf.to_dense())
    target = np.asarray(bad.to_dense(lower_only=False)) \
        + tau * np.eye(g.padded_n, dtype=np.float32)
    scale = max(1.0, np.abs(target).max())
    assert np.abs(L @ L.T - target).max() < 5e-3 * scale
    assert info.ok()


def test_ladder_leaves_spd_untouched():
    """regularize=True on a clean SPD input: zero jitter, one attempt, and
    a bit-identical factor to the unregularized call."""
    g, bm, _ = _spd(130, 40, 30, 16)
    f0 = factorize_window(bm)
    f1 = factorize_window(bm, options=SolverOptions(regularize=True))
    info = f1.info
    assert int(np.asarray(info.status)) == STATUS_OK
    assert int(np.asarray(info.attempts)) == 1
    assert float(np.asarray(info.tau)) == 0.0
    assert int(np.asarray(info.first_bad_tile)) == -1
    assert info.matrix is None
    for a, b in [(f0.ctsf.Dr, f1.ctsf.Dr), (f0.ctsf.R, f1.ctsf.R),
                 (f0.ctsf.C, f1.ctsf.C)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gershgorin_rung_guarantees_finite_recovery():
    """A violently indefinite (but finite) input exhausts the relative taus
    and lands on the Gershgorin rung — still RECOVERED, never FAILED."""
    g, bm, _ = _spd(64, 8, 0, 8)
    bad = _corrupt_diag(bm, tile=1, shift=1e4)
    sh = float(np.asarray(gershgorin_shift(bad.Dr, bad.R, bad.C, g)))
    assert sh > 0
    f = factorize_window(bad, options=SolverOptions(regularize=True))
    assert int(np.asarray(f.info.status)) == STATUS_RECOVERED
    assert np.isfinite(np.asarray(f.ctsf.Dr)).all()


# ---------------------------------------------- batched graceful degradation

def test_batched_injection_end_to_end():
    """Injected faults in a batch: indefinite -> RECOVERED, NaN -> FAILED
    (flagged, not raised), healthy elements bit-identical to the same
    batched call without regularize=."""
    B = 4
    mats = []
    for s in range(B):
        _, bm, _ = _spd(96, 16, 8, 8, seed=s)
        mats.append(bm)
    g = mats[0].grid
    batch = BandedCTSF(g, jnp.stack([m.Dr for m in mats]),
                       jnp.stack([m.R for m in mats]),
                       jnp.stack([m.C for m in mats]))
    inj = NumericalFaultInjector(seed=0, shift=10.0)
    corrupted = inj.corrupt(batch, {1: "indefinite", 2: "nan"})
    assert [(i, m) for i, m, _ in inj.injected] == [(1, "indefinite"),
                                                    (2, "nan")]

    f = factorize_window_batched(corrupted, bucket=False, options=SolverOptions(regularize=True))
    status = np.asarray(f.info.status)
    assert status.shape == (B,)
    assert status[0] == STATUS_OK and status[3] == STATUS_OK
    assert status[1] == STATUS_RECOVERED
    assert status[2] == STATUS_FAILED
    np.testing.assert_array_equal(f.info.ok(), [True, True, False, True])
    assert float(np.asarray(f.info.tau)[1]) > 0
    assert int(np.asarray(f.info.first_bad_tile)[1]) >= 0
    assert int(np.asarray(f.info.first_bad_tile)[0]) == -1

    plain = factorize_window_batched(corrupted, bucket=False)
    for i in (0, 3):  # healthy: bit-for-bit their first attempt
        np.testing.assert_array_equal(np.asarray(f.ctsf.Dr[i]),
                                      np.asarray(plain.ctsf.Dr[i]))
        np.testing.assert_array_equal(np.asarray(f.ctsf.C[i]),
                                      np.asarray(plain.ctsf.C[i]))
        assert np.isfinite(np.asarray(f.ctsf.Dr[i])).all()


def test_batched_bucketed_gridpolicy_ladder():
    """The ladder composes with pow2 bucketing and the canonical-grid
    policy: a 3-element (padded-to-4) embedded batch comes back with (3,)
    per-element status and the injected element recovered."""
    B = 3
    mats = [_spd(96, 16, 8, 8, seed=s)[1] for s in range(B)]
    g = mats[0].grid
    batch = BandedCTSF(g, jnp.stack([m.Dr for m in mats]),
                       jnp.stack([m.R for m in mats]),
                       jnp.stack([m.C for m in mats]))
    corrupted = NumericalFaultInjector(seed=1).corrupt(batch,
                                                       {1: "indefinite"})
    pol = GridBucketPolicy()
    f = factorize_window_batched(corrupted, bucket=True, options=SolverOptions(policy=pol, regularize=True))
    assert f.source_grid == g
    status = np.asarray(f.info.status)
    assert status.shape == (B,)
    assert status[1] == STATUS_RECOVERED
    assert status[0] == STATUS_OK and status[2] == STATUS_OK
    plain = factorize_window_batched(corrupted, bucket=True, options=SolverOptions(policy=pol))
    for i in (0, 2):
        np.testing.assert_array_equal(np.asarray(f.ctsf.Dr[i]),
                                      np.asarray(plain.ctsf.Dr[i]))


def test_concurrent_factorize_ladder_mesh():
    """regularize= threads through concurrent_factorize, both the vmapped
    default and the sharded mesh path (status replicated per element)."""
    from jax.sharding import Mesh
    from repro.core import concurrent_factorize
    from repro.core.concurrent import stack_ctsf
    mats = [_spd(96, 16, 8, 8, seed=s)[1] for s in range(4)]
    bad = NumericalFaultInjector(seed=0).corrupt(stack_ctsf(mats),
                                                 {2: "indefinite"})
    f = concurrent_factorize(bad, options=SolverOptions(regularize=True))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    fm = concurrent_factorize(bad, mesh=mesh, options=SolverOptions(regularize=True))
    for fi in (f, fm):
        status = np.asarray(fi.info.status)
        assert status[2] == STATUS_RECOVERED
        assert (status[[0, 1, 3]] == STATUS_OK).all()
        assert np.isfinite(np.asarray(fi.ctsf.Dr)).all()


def test_nan_single_flagged_not_raised():
    A, st = nan_contaminated_arrowhead(64, 8, 4, seed=0)
    g = TileGrid(st, t=8)
    bm = BandedCTSF.from_sparse(A, g)
    f = factorize_window(bm, options=SolverOptions(regularize=True))  # must not raise
    assert int(np.asarray(f.info.status)) == STATUS_FAILED
    assert not f.info.ok()


# -------------------------------------------------- pathological generators

def test_pathological_generators():
    n, bw, ar = 64, 8, 4
    A_ind, _ = indefinite_arrowhead(n, bw, ar, seed=0)
    eig_ind = np.linalg.eigvalsh(A_ind.toarray())
    assert eig_ind.min() < 0

    A_ns, _ = near_singular_arrowhead(n, bw, ar, seed=0, eig_min=1e-5)
    eig_ns = np.linalg.eigvalsh(A_ns.toarray())
    np.testing.assert_allclose(eig_ns.min(), 1e-5, rtol=1e-2)

    A_nan, _ = nan_contaminated_arrowhead(n, bw, ar, seed=0)
    D = A_nan.toarray()
    assert np.isnan(D).any()
    # symmetry preserved, NaN included
    assert ((D == D.T) | (np.isnan(D) & np.isnan(D.T))).all()


def test_indefinite_generator_recovers_through_ladder():
    A, st = indefinite_arrowhead(96, 16, 8, seed=3)
    g = TileGrid(st, t=8)
    bm = BandedCTSF.from_sparse(A, g)
    f = factorize_window(bm, options=SolverOptions(regularize=True))
    assert int(np.asarray(f.info.status)) == STATUS_RECOVERED
    assert np.isfinite(np.asarray(f.ctsf.Dr)).all()


# --------------------------------------------------------------- refinement

def test_solve_many_refines_jittered_factor():
    """A perturbed factor used as preconditioner: one residual-checked
    refinement step against the retained original matrix shrinks the
    solve residual vs using the jittered factor alone."""
    g, bm, dense = _spd(96, 16, 8, 8)
    # one refinement step contracts each residual mode by tau/(lambda+tau);
    # tau = lambda_min/2 bounds that by 1/3 across the whole spectrum
    tau = 0.5 * float(np.linalg.eigvalsh(dense).min())
    DrJ, CJ = add_diagonal_jitter(bm.Dr, bm.C, g, jnp.float32(tau))
    fJ = factorize_window(BandedCTSF(g, DrJ, bm.R, CJ))
    info = FactorInfo(status=jnp.asarray(STATUS_RECOVERED, jnp.int32),
                      attempts=jnp.asarray(2, jnp.int32),
                      tau=jnp.asarray(tau, jnp.float32),
                      min_pivot=jnp.asarray(1.0, jnp.float32),
                      first_bad_tile=jnp.asarray(0, jnp.int32),
                      matrix=bm)
    refined = CholeskyFactor(fJ.ctsf, info=info)

    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((g.padded_n, 3)).astype(np.float32))
    X_plain = np.asarray(solve_many(fJ, B))
    X_ref = np.asarray(solve_many(refined, B))
    r_plain = np.linalg.norm(dense @ X_plain - np.asarray(B), axis=0)
    r_ref = np.linalg.norm(dense @ X_ref - np.asarray(B), axis=0)
    assert (r_ref <= r_plain).all()          # never accepted a worse column
    assert r_ref.max() < 0.6 * r_plain.max()  # and it genuinely helped


# ------------------------------------------------- hardened validation paths

def test_policy_resolve():
    assert RegularizePolicy.resolve(None) is None
    assert RegularizePolicy.resolve(False) is None
    assert RegularizePolicy.resolve(True) == RegularizePolicy()
    pol = RegularizePolicy(taus=(1e-3,), gershgorin=False)
    assert RegularizePolicy.resolve(pol) is pol
    with pytest.raises(ValueError, match="regularize"):
        RegularizePolicy.resolve("yes")


def test_validation_survives_optimized_mode():
    """The hardened checks raise ValueError (not bare assert, which
    `python -O` strips)."""
    g, bm, _ = _spd(64, 8, 4, 8)
    f = factorize_window(bm)
    with pytest.raises(ValueError, match="rhs panel"):
        solve_many(f, jnp.zeros((g.padded_n + 1, 2)))
    with pytest.raises(ValueError, match="rhs panel"):
        solve_many(f, jnp.zeros((g.padded_n,)))

    from repro.sharding.pipeline import pipeline_forward, split_stages
    with pytest.raises(ValueError, match="not divisible"):
        split_stages({"w": jnp.zeros((5, 2))}, 2)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="microbatches"):
        pipeline_forward(lambda p, h: h, {"w": jnp.zeros((1, 1, 2))},
                         jnp.zeros((5, 2)), mesh, n_microbatches=2)

    from repro import configs
    from repro.models.zamba2 import _n_super
    cfg = dataclasses.replace(configs.get("zamba2-2.7b"), n_layers=7)
    with pytest.raises(ValueError, match="divisible"):
        _n_super(cfg)

    from repro.core.concurrent import stack_ctsf
    with pytest.raises(ValueError, match="at least one"):
        stack_ctsf([])

    inj = NumericalFaultInjector()
    batch = BandedCTSF(g, bm.Dr[None], bm.R[None], bm.C[None])
    with pytest.raises(ValueError, match="corruption mode"):
        inj.corrupt(batch, {0: "gamma-ray"})


def test_lru_cache_thread_safety():
    from repro.core.batching import LRUCache
    with pytest.raises(ValueError):
        LRUCache(maxsize=0)
    cache = LRUCache(maxsize=16)
    errors = []

    def hammer(tid):
        try:
            for i in range(400):
                k = (tid * 7 + i) % 40
                cache.put(k, tid * 1000 + i)
                cache.get((k + 1) % 40)
                len(cache)
                k in cache
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    assert len(cache) <= 16


# ------------------------------------------------------- property (optional)

try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=10, deadline=None)

    @st_h.composite
    def spd_problem(draw):
        ndt = draw(st_h.integers(2, 5))
        t = 8
        bw = draw(st_h.integers(1, t))
        arrow = draw(st_h.sampled_from([0, t // 2]))
        rho = draw(st_h.sampled_from([0.0, 0.5]))
        seed = draw(st_h.integers(0, 2 ** 16))
        return ndt * t + arrow, bw, arrow, t, rho, seed

    @given(spd_problem())
    @settings(**SETTINGS)
    def test_ladder_is_identity_on_spd(problem):
        """Property: for any SPD input the ladder applies no jitter and the
        factor is bit-identical to the unregularized path."""
        n, bw, arrow, t, rho, seed = problem
        A, stc = make_arrowhead(n, bw, arrow, rho=rho, seed=seed)
        g = TileGrid(stc, t=t)
        bm = BandedCTSF.from_sparse(A, g)
        f0 = factorize_window(bm)
        f1 = factorize_window(bm, options=SolverOptions(regularize=True))
        assert float(np.asarray(f1.info.tau)) == 0.0
        assert int(np.asarray(f1.info.status)) == STATUS_OK
        np.testing.assert_array_equal(np.asarray(f0.ctsf.Dr),
                                      np.asarray(f1.ctsf.Dr))
        np.testing.assert_array_equal(np.asarray(f0.ctsf.C),
                                      np.asarray(f1.ctsf.C))
