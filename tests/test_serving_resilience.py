"""Resilience tests for the rung server: admission control, shedding,
dispatch-failure isolation, circuit breaking, graceful degradation, and
shutdown.

Everything except the wedged-shutdown regression runs thread-free on a
``SimClock`` with fake executors (no device work), so every failure path
is driven deterministically: faults are injected as exceptions from a
scripted executor or via the seeded
:class:`~repro.runtime.fault_tolerance.DispatchFaultInjector`, and the
contracts are exact — shed is always an explicit ``STATUS_SHED`` result,
a poison request quarantines alone, a broken rung never starves a
healthy one, and ``stop()`` leaves no future unresolved even when the
executor is wedged inside a dispatch.
"""
import threading
import time
import types

import numpy as np
import pytest

from repro.core import STATUS_FAILED, STATUS_OK, STATUS_RECOVERED, \
    STATUS_SHED, TileGrid
from repro.core.batching import RungQueue, RungQueueFull
from repro.data.synthetic import request_stream
from repro.launch.rung_server import (FLUSH_DEADLINE, FLUSH_SHED,
                                      SHED_BREAKER, SHED_DEADLINE,
                                      SHED_OVERLOAD, SHED_SHUTDOWN,
                                      SHED_SLACK, CircuitBreaker,
                                      DegradationPolicy, RungOverloadError,
                                      RungRequest, RungResult, RungScheduler,
                                      RungServer, SimClock)
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import (DispatchFaultInjector,
                                           InjectedDispatchError,
                                           StragglerMonitor)

pytestmark = pytest.mark.serving


def _grid(ndt=6):
    return TileGrid.from_tile_counts(8, ndt, 1, 1)


def _fake_request(rid, grid, deadline=None):
    return RungRequest(rid=rid, matrix=types.SimpleNamespace(grid=grid),
                       rhs=None, deadline=deadline)


def _stub_matrix(ndt=6):
    return types.SimpleNamespace(grid=_grid(ndt))


class ScriptedExecutor:
    """Duck-typed RungExecutor whose failures are scripted per rid:
    ``poison`` rids raise on every dispatch, ``flaky[rid] = n`` raises on
    the first ``n`` dispatches that include the rid.  Counts dispatches
    so tests can assert shed batches never touch the 'device'."""

    def __init__(self, poison=(), flaky=None):
        self.poison = set(poison)
        self.flaky = dict(flaky or {})
        self.dispatches = 0
        self.dispatched_rids = []

    def dispatch(self, batch, now):
        self.dispatches += 1
        rids = [r.rid for r in batch.requests]
        for rid in rids:
            if rid in self.poison:
                raise RuntimeError(f"poison rid {rid}")
        for rid in rids:
            if self.flaky.get(rid, 0) > 0:
                self.flaky[rid] -= 1
                raise RuntimeError(f"flaky rid {rid}")
        self.dispatched_rids.extend(rids)
        return batch

    def finalize(self, batch, now):
        results = []
        for r in batch.requests:
            res = RungResult(rid=r.rid, status=STATUS_OK, attempts=1,
                             tau=0.0, x=None, factor=None,
                             latency=now - r.arrival, wall_latency_s=0.0,
                             flush_reason=batch.reason,
                             batch_size=len(batch.requests),
                             rung=telemetry.rung_tag(batch.key[0]))
            if r.future is not None:
                r.future._resolve(res)
            results.append(res)
        return results


def _server(clock=None, executor=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 1e-3)
    kw.setdefault("injector", None)
    kw.setdefault("backoff_base", 1e-6)
    return RungServer(clock=clock or SimClock(),
                      executor=executor or ScriptedExecutor(), **kw)


# ---------------------------------------------------------------------------
# bounded queues (core/batching.py)
# ---------------------------------------------------------------------------

def test_rung_queue_bound_and_shedding_primitives():
    q = RungQueue(maxlen=2)
    q.push("a", 1.0)
    q.push("b", 2.0)
    assert q.full
    with pytest.raises(RungQueueFull) as ei:
        q.push("c", 3.0)
    assert ei.value.depth == 2 and ei.value.maxlen == 2
    assert q.remove_if(lambda it: it == "a") == ["a"]
    q.push("c", 0.5)
    # evict_min takes the minimizer; ties go to the oldest
    assert q.evict_min(lambda it: 0.0) == "b"
    assert q.pop() == ["c"]


# ---------------------------------------------------------------------------
# admission control + typed backpressure
# ---------------------------------------------------------------------------

def test_submit_raises_typed_overload_on_rung_bound():
    server = _server(max_queue=2, max_delay=10.0)
    for _ in range(2):
        server.submit(_stub_matrix())
    with pytest.raises(RungOverloadError) as ei:
        server.submit(_stub_matrix())
    assert ei.value.scope == "rung"
    assert ei.value.depth == 2 and ei.value.limit == 2
    # other rungs are unaffected by one rung's bound
    server.submit(_stub_matrix(ndt=12))


def test_submit_raises_on_global_bound():
    server = _server(max_pending=2, max_delay=10.0)
    server.submit(_stub_matrix(ndt=6))
    server.submit(_stub_matrix(ndt=12))
    with pytest.raises(RungOverloadError) as ei:
        server.submit(_stub_matrix(ndt=9))
    assert ei.value.scope == "global"


def test_overload_shed_mode_resolves_future_immediately():
    server = _server(max_queue=1, max_delay=10.0, on_overload="shed")
    server.submit(_stub_matrix())
    fut = server.submit(_stub_matrix())
    r = fut.result(timeout=0)
    assert r.status == STATUS_SHED and r.detail == SHED_OVERLOAD
    assert not r.ok()
    # per-call override beats the server default
    server2 = _server(max_queue=1, max_delay=10.0)
    server2.submit(_stub_matrix())
    r2 = server2.submit(_stub_matrix(), on_overload="shed").result(timeout=0)
    assert r2.status == STATUS_SHED


# ---------------------------------------------------------------------------
# deadline-expiry shedding
# ---------------------------------------------------------------------------

def test_expired_requests_shed_never_dispatch():
    s = RungScheduler(max_batch=8, max_delay=10.0)
    g = _grid()
    s.submit(0.0, _fake_request(0, g, deadline=1.0))
    s.submit(0.0, _fake_request(1, g))
    # strictly past the deadline: 0 is swept out as a shed batch; 1 (its
    # own flush_by is arrival + max_delay = 10) keeps its queue slot
    # instead of being dragged out with the expired sibling
    batches = s.tick(1.5)
    assert [b.reason for b in batches] == [FLUSH_SHED]
    assert batches[0].detail == SHED_DEADLINE
    assert tuple(r.rid for r in batches[0].requests) == (0,)
    assert s.pending == 1
    (late,) = s.tick(10.0)
    assert late.reason == FLUSH_DEADLINE
    assert tuple(r.rid for r in late.requests) == (1,)


def test_flush_at_exact_deadline_still_serves():
    # at exactly the deadline the request is served (FLUSH_DEADLINE), not
    # shed — the boundary the pre-existing deadline tests rely on
    s = RungScheduler(max_batch=8, max_delay=10.0)
    s.submit(0.0, _fake_request(0, _grid(), deadline=2.0))
    (b,) = s.tick(2.0)
    assert b.reason == FLUSH_DEADLINE


def test_dead_on_arrival_is_shed():
    s = RungScheduler(max_batch=8, max_delay=10.0)
    s.submit(5.0, _fake_request(0, _grid(), deadline=1.0))
    (b,) = s.tick(5.0)
    assert b.reason == FLUSH_SHED and b.detail == SHED_DEADLINE


def test_shed_future_resolves_with_status_shed_and_no_device_time():
    clock = SimClock()
    ex = ScriptedExecutor()
    server = _server(clock=clock, executor=ex, max_delay=10.0)
    fut = server.submit(_stub_matrix(), deadline=1.0)
    clock.advance(2.0)
    server.pump()
    r = fut.result(timeout=0)
    assert r.status == STATUS_SHED and r.detail == SHED_DEADLINE
    assert r.flush_reason == FLUSH_SHED
    assert r.x is None and r.factor is None
    assert ex.dispatches == 0                     # never touched the device
    # shed batches are part of the replayable flush history
    assert server.history[-1][3] == FLUSH_SHED
    assert server.history[-1][4] == SHED_DEADLINE


# ---------------------------------------------------------------------------
# dispatch-failure isolation: retry, bisect, quarantine
# ---------------------------------------------------------------------------

def test_transient_failure_retries_and_recovers():
    clock = SimClock()
    ex = ScriptedExecutor(flaky={0: 1})
    server = _server(clock=clock, executor=ex, max_retries=2)
    futs = [server.submit(_stub_matrix()) for _ in range(2)]
    clock.advance(1e-3)
    server.pump()
    server.drain()
    rs = [f.result(timeout=0) for f in futs]
    # served after one retry: both marked RECOVERED, nothing failed
    assert [r.status for r in rs] == [STATUS_RECOVERED] * 2
    assert all(r.ok() for r in rs)
    kinds = [e[0] for e in server.events]
    assert "retry" in kinds and "quarantine" not in kinds


def test_poison_request_quarantined_siblings_survive():
    clock = SimClock()
    ex = ScriptedExecutor(poison={2})
    server = _server(clock=clock, executor=ex, max_retries=1)
    futs = [server.submit(_stub_matrix()) for _ in range(4)]
    clock.advance(1e-3)
    server.pump()
    server.drain()
    rs = [f.result(timeout=0) for f in futs]
    assert rs[2].status == STATUS_FAILED
    assert rs[2].detail == "dispatch_failed"
    assert rs[2].x is None and rs[2].factor is None
    for i in (0, 1, 3):
        assert rs[i].status == STATUS_RECOVERED and rs[i].ok()
    kinds = [e[0] for e in server.events]
    assert "bisect" in kinds and "quarantine" in kinds
    # exceptions never leak: every future resolved exactly once
    assert all(f.duplicate_resolves == 0 for f in futs)


def test_backoff_burns_injected_clock_deterministically():
    def run():
        clock = SimClock()
        server = _server(clock=clock, executor=ScriptedExecutor(flaky={0: 2}),
                         max_retries=3, backoff_base=1e-3)
        fut = server.submit(_stub_matrix())
        clock.advance(1e-3)
        server.pump()
        server.drain()
        return fut.result(timeout=0), clock.now, list(server.events)

    r1, t1, e1 = run()
    r2, t2, e2 = run()
    assert r1.status == STATUS_RECOVERED
    assert t1 == t2 and e1 == e2                  # backoff replays exactly
    assert t1 > 2e-3                              # retries actually waited


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=2, reset_timeout=1.0)
    assert br.allow(0.0) and br.state == "closed"
    br.record_failure(0.0)
    assert br.state == "closed"
    br.record_failure(0.1)
    assert br.state == "open"
    assert not br.allow(0.5)                      # still open
    assert br.allow(1.2) and br.state == "half_open"
    br.record_failure(1.3)                        # trial failed: reopen
    assert br.state == "open" and not br.allow(1.4)
    assert br.allow(2.4) and br.state == "half_open"
    br.record_success(2.5)
    assert br.state == "closed" and br.failures == 0


def test_open_breaker_sheds_rung_but_not_neighbors():
    clock = SimClock()
    ex = ScriptedExecutor(poison={0, 1, 2})      # rung ndt=6 always fails
    server = _server(clock=clock, executor=ex, max_retries=0,
                     breaker_threshold=2, breaker_reset=100.0, max_batch=1)
    bad = [server.submit(_stub_matrix(ndt=6)) for _ in range(3)]
    good = [server.submit(_stub_matrix(ndt=12)) for _ in range(3)]
    clock.advance(1e-3)
    server.pump()
    server.drain()
    rb = [f.result(timeout=0) for f in bad]
    rg = [f.result(timeout=0) for f in good]
    # first two poison batches fail through the ladder and trip the
    # breaker; the third is shed without a dispatch attempt
    assert [r.status for r in rb] == [STATUS_FAILED, STATUS_FAILED,
                                      STATUS_SHED]
    assert rb[2].detail == SHED_BREAKER
    # the healthy rung keeps serving throughout
    assert all(r.status == STATUS_OK for r in rg)
    states = [e[2] for e in server.events if e[0] == "breaker"]
    assert states == ["open"]


def test_breaker_recovers_through_half_open_trial():
    clock = SimClock()
    ex = ScriptedExecutor(flaky={0: 1, 1: 1})     # each first try fails
    server = _server(clock=clock, executor=ex, max_retries=0,
                     breaker_threshold=2, breaker_reset=0.5, max_batch=1,
                     max_delay=1e-3)
    f0 = server.submit(_stub_matrix())
    f1 = server.submit(_stub_matrix())
    clock.advance(1e-3)
    server.pump()                                 # two failures: breaker opens
    server.drain()                                # settle the double buffer
    assert f0.result(timeout=0).status == STATUS_FAILED
    assert f1.result(timeout=0).status == STATUS_FAILED
    f2 = server.submit(_stub_matrix())            # while open: shed
    clock.advance(2e-3)
    server.pump()
    assert f2.result(timeout=0).detail == SHED_BREAKER
    clock.advance(0.5)                            # past reset_timeout
    f3 = server.submit(_stub_matrix())
    clock.advance(1e-3)
    server.pump()                                 # half-open trial succeeds
    server.drain()
    assert f3.result(timeout=0).status == STATUS_OK
    states = [e[2] for e in server.events if e[0] == "breaker"]
    assert states == ["open", "half_open", "closed"]


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

def test_degradation_steps_up_and_sheds_lowest_slack():
    pol = DegradationPolicy(high_watermark=0.5, low_watermark=0.1,
                            step_dwell=0.0, recover_dwell=1.0)
    s = RungScheduler(max_batch=8, max_delay=1.0, max_queue=4,
                      degradation=pol)
    g = _grid()
    # fill to the watermark: level steps up, effective knobs shrink
    for i in range(4):
        s.submit(float(i) * 1e-3, _fake_request(i, g, deadline=10.0 + i))
    assert s.level >= 1
    assert s.effective_max_delay() < 1.0
    assert s.effective_max_batch() < 8
    # at the bound under degradation: lowest-slack victim is shed, the
    # newcomer (more slack) is admitted
    s.submit(4e-3, _fake_request(9, g, deadline=99.0))
    batches = [b for b in s.tick(5e-3) if b.reason == FLUSH_SHED]
    assert len(batches) == 1 and batches[0].detail == SHED_SLACK
    assert tuple(r.rid for r in batches[0].requests) == (0,)


def test_degradation_recovers_hysteretically():
    pol = DegradationPolicy(high_watermark=0.5, low_watermark=0.25,
                            step_dwell=0.0, recover_dwell=1.0, max_level=1)
    s = RungScheduler(max_batch=8, max_delay=1.0, max_queue=4,
                      degradation=pol)
    g = _grid()
    for i in range(4):
        s.submit(0.0, _fake_request(i, g))
    assert s.level == 1
    s.tick(1.0)                                   # queue flushes: idle now
    assert s.level == 1                           # no instant flap
    s.tick(1.5)
    assert s.level == 1                           # dwell not yet served
    s.tick(2.5)                                   # >= recover_dwell below low
    assert s.level == 0


def test_straggler_flags_feed_degradation():
    pol = DegradationPolicy(straggler_trigger=2, step_dwell=0.0)
    s = RungScheduler(max_batch=8, max_delay=1.0, degradation=pol)
    s.note_straggler(0.0)
    assert s.level == 0
    s.note_straggler(0.1)
    assert s.level == 1


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(factor=3.0, window=8, min_history=3)
    for i in range(5):
        assert not m.record(i, 1.0)
    assert m.record(5, 10.0)                      # 10x the median
    assert not m.record(6, 1.1)


# ---------------------------------------------------------------------------
# chaos injector determinism
# ---------------------------------------------------------------------------

def test_injector_decisions_hash_composition_not_call_order():
    a = DispatchFaultInjector(seed=3, transient_rate=0.5)
    b = DispatchFaultInjector(seed=3, transient_rate=0.5)
    probe = [("ndt6.bt1.nat1.t8", (0, 1)), ("ndt12.bt1.nat1.t8", (2,)),
             ("ndt6.bt1.nat1.t8", (3, 4, 5))]

    def outcomes(inj, order):
        out = []
        for tag, rids in order:
            try:
                inj.before_dispatch(tag, rids, attempt=0)
                out.append((tag, rids, None))
            except InjectedDispatchError as e:
                out.append((tag, rids, e.kind))
        return out

    fwd = outcomes(a, probe)
    rev = outcomes(b, list(reversed(probe)))
    assert sorted(fwd) == sorted(rev)             # order-independent draws


def test_injector_poison_and_transient_modes():
    inj = DispatchFaultInjector(seed=0, transient_rate=1.0,
                                transient_attempts=1, poison_rids=(7,))
    with pytest.raises(InjectedDispatchError) as ei:
        inj.before_dispatch("t", (0, 1), attempt=0)
    assert ei.value.kind == "transient"
    inj.before_dispatch("t", (0, 1), attempt=1)   # transient clears
    for attempt in range(3):                      # poison never clears
        with pytest.raises(InjectedDispatchError) as ei:
            inj.before_dispatch("t", (6, 7), attempt=attempt)
        assert ei.value.kind == "permanent"


def test_chaos_replay_is_bit_identical():
    def run():
        clock = SimClock()
        inj = DispatchFaultInjector(seed=11, transient_rate=0.4,
                                    transient_attempts=1, poison_rids=(3,),
                                    straggler_rate=0.3, straggler_extra=2e-3)
        server = _server(clock=clock, executor=ScriptedExecutor(),
                         injector=inj, max_retries=2, backoff_base=1e-4,
                         max_batch=2, max_delay=1e-3)
        futs = [server.submit(_stub_matrix(ndt=6 + 3 * (i % 2)),
                              deadline=clock.now + 5e-3)
                for i in range(8)]
        for _ in range(8):
            clock.advance(1e-3)
            server.pump()
        server.drain()
        rs = [f.result(timeout=0) for f in futs]
        return (list(server.history), list(server.events),
                [(r.rid, r.status, r.detail) for r in rs])

    assert run() == run()


# ---------------------------------------------------------------------------
# burst arrivals (data/synthetic.py)
# ---------------------------------------------------------------------------

def test_burst_mode_off_is_bit_compatible():
    base = request_stream(3, [(64, 6, 4)], 32, rate=500.0)
    off = request_stream(3, [(64, 6, 4)], 32, rate=500.0, burst_factor=1.0)
    assert base == off


def test_burst_mode_is_seeded_and_compresses_arrivals():
    kw = dict(rate=500.0, burst_factor=8.0, burst_len=20e-3,
              normal_len=20e-3)
    a = request_stream(3, [(64, 6, 4)], 64, **kw)
    b = request_stream(3, [(64, 6, 4)], 64, **kw)
    assert a == b                                 # seeded, replayable
    arr = [s["arrival"] for s in a]
    assert all(x < y for x, y in zip(arr, arr[1:]))
    base = [s["arrival"] for s in request_stream(3, [(64, 6, 4)], 64,
                                                 rate=500.0)]
    # bursts only ever accelerate the modulated clock
    assert arr[-1] < base[-1]
    # everything but arrival times (cases, seeds, k) is draw-identical
    strip = lambda specs: [{k: v for k, v in s.items()
                            if k not in ("arrival", "deadline")}
                           for s in specs]
    assert strip(a) == strip(request_stream(3, [(64, 6, 4)], 64, rate=500.0))


# ---------------------------------------------------------------------------
# shutdown: no future left behind
# ---------------------------------------------------------------------------

class WedgedExecutor(ScriptedExecutor):
    """Dispatch parks forever — the stuck-device regression case."""

    def __init__(self):
        super().__init__()
        self.entered = threading.Event()

    def dispatch(self, batch, now):
        self.entered.set()
        time.sleep(3600.0)


def test_stop_resolves_all_futures_when_executor_wedges():
    ex = WedgedExecutor()
    server = RungServer(executor=ex, injector=None, max_batch=1,
                        max_delay=1e-3, poll_interval=1e-3)
    server.start()
    futs = [server.submit(_stub_matrix()) for _ in range(3)]
    assert ex.entered.wait(timeout=30.0)          # pump is now wedged
    t0 = time.perf_counter()
    server.stop(timeout=0.2)                      # must not hang on drain
    assert time.perf_counter() - t0 < 30.0
    for f in futs:
        r = f.result(timeout=0)                   # already resolved
        assert r.status == STATUS_SHED and r.detail == SHED_SHUTDOWN
    assert server._thread is None


def test_stop_without_thread_is_noop():
    server = _server()
    server.stop()                                 # never started: fine


def test_env_var_arms_default_chaos_injector(monkeypatch):
    """REPRO_CHAOS_SEED arms a seeded injector on servers built with the
    default ``injector="auto"`` — and the armed server still conserves
    every future (transients recover through the retry ladder)."""
    monkeypatch.setenv("REPRO_CHAOS_SEED", "23")
    clock = SimClock()
    server = RungServer(clock=clock, executor=ScriptedExecutor(),
                        max_batch=2, max_delay=1e-3, backoff_base=1e-6)
    assert server.executor.injector is not None
    assert server.executor.injector.seed == 23
    futs = [server.submit(_stub_matrix()) for _ in range(6)]
    clock.advance(2e-3)
    server.pump()
    server.drain()
    for f in futs:
        assert f.done() and f.duplicate_resolves == 0
        assert f.result(timeout=0).status in (STATUS_OK, STATUS_RECOVERED)

    # explicit pins always win over the env var
    monkeypatch.setenv("REPRO_CHAOS_SEED", "99")
    assert _server().executor.injector is None
