"""Additional coverage: INLA marginal variances, grad-accumulation
equivalence, flash-attention GQA sweep, bf16 kernels, MoE expert padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core import (BandedCTSF, TileGrid, factorize_window,
                        marginal_variances)
from repro.data import make_arrowhead


def test_marginal_variances_match_dense_inverse():
    A, struct = make_arrowhead(160, 16, 16, rho=0.6, seed=0)
    g = TileGrid(struct, t=16)
    bm = BandedCTSF.from_sparse(A, g)
    f = factorize_window(bm)
    idx = jnp.asarray([0, 7, 63, 150, 159])
    got = np.asarray(marginal_variances(f, idx))
    inv = np.linalg.inv(bm.to_dense(lower_only=False))
    want = np.diag(inv)[np.asarray(idx)]
    np.testing.assert_allclose(got, want, rtol=2e-3)


def test_grad_accumulation_equivalent():
    """ga=4 must produce the same loss and (averaged) grads as ga=1."""
    from repro.configs import get
    from repro.configs.base import RunConfig
    from repro.launch.train import (TrainState, init_state, make_train_step,
                                    reduce_config)
    from repro.data.synthetic import token_batch
    cfg = reduce_config(get("qwen2-7b"), layers=2, d_model=64)
    key = jax.random.PRNGKey(0)
    batch = token_batch(0, 0, 8, 32, cfg.vocab)
    outs = {}
    for ga in (1, 4):
        run = RunConfig(remat="none", loss_chunk=32, grad_accum=ga,
                        compute_dtype="float32")
        state = init_state(key, cfg, run, max_seq=32)
        step = make_train_step(cfg, run, None, total_steps=10)
        new_state, metrics = jax.jit(step)(state, batch)
        outs[ga] = (float(metrics["loss"]), float(metrics["grad_norm"]),
                    jax.tree.leaves(new_state.params)[0])
    assert abs(outs[1][0] - outs[4][0]) < 1e-4          # loss equal
    assert abs(outs[1][1] - outs[4][1]) / outs[1][1] < 1e-3   # grad norm
    np.testing.assert_allclose(np.asarray(outs[1][2]),
                               np.asarray(outs[4][2]), atol=1e-5)


@given(st.sampled_from([(4, 1), (4, 2), (4, 4), (8, 2)]),
       st.sampled_from([16, 24, 48]),
       st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_flash_attention_gqa_sweep(heads_kv, seq, seed):
    """Flash vs naive for random GQA group configurations and odd lengths."""
    from repro.models.layers import chunked_attention
    H, KV = heads_kv
    D = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((2, seq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, seq, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, seq, KV, D)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    # naive reference
    q5 = q.reshape(2, seq, KV, H // KV, D) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(2, seq, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", [16, 32])
def test_kernels_bf16(rng, t):
    """Tile kernels accept bf16 inputs (f32 accumulation inside)."""
    from repro.kernels.gemm import gemm_pallas
    from repro.kernels import ref
    c = jnp.asarray(rng.standard_normal((t, t)), jnp.bfloat16)
    a = jnp.asarray(rng.standard_normal((t, t)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((t, t)), jnp.bfloat16)
    got = gemm_pallas(c, a, b)
    want = ref.gemm_ref(c.astype(jnp.float32), a.astype(jnp.float32),
                        b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=0.05, atol=0.05)


def test_moe_expert_padding_routes_only_real_experts():
    from repro.models.moe import moe_params, moe_apply
    key = jax.random.PRNGKey(0)
    p = moe_params(key, 16, 32, n_experts=5, pad_to=8)
    assert p["wi"].shape[0] == 8 and p["router"].shape[1] == 5
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    y = moe_apply(p, x, top_k=2, capacity_factor=4.0)
    assert np.isfinite(np.asarray(y)).all()
    # padded experts contribute nothing: zeroing them changes nothing
    p2 = dict(p)
    for w in ("wi", "wg", "wo"):
        p2[w] = p[w].at[5:].set(0.0)
    y2 = moe_apply(p2, x, top_k=2, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)


def test_ring_sweep_equals_window_sweep():
    import repro.core.cholesky as C
    A, struct = make_arrowhead(320, 24, 16, rho=0.7, seed=5)
    g = TileGrid(struct, t=16)
    bm = BandedCTSF.from_sparse(A, g)
    ring = C._factorize_window_impl(bm.Dr, bm.R, bm.C, g, "ref", 4, "ring")
    win = C._factorize_window_impl(bm.Dr, bm.R, bm.C, g, "ref", 4, "window")
    for a, b in zip(ring, win):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_band_update_unrolled_matches_einsum(rng):
    from repro.kernels import ref
    for b1 in (2, 4, 6):
        w = jnp.asarray(rng.standard_normal((b1, b1, 8, 8)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ref.band_update_unrolled_ref(w)),
            np.asarray(ref.band_update_ref(w)), rtol=1e-4, atol=1e-4)
