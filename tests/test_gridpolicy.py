"""Canonical-grid bucketing (core/gridpolicy.py): policy unit tests,
identity-embedding invariants, and bucketed-vs-unbucketed parity for every
serving entry point — band, arrow, corner, logdet and selinv diagonal must
match the per-grid path to fp32 tolerance, including a grid that already
sits on a canonical rung (zero padding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandedCTSF, GridBucketPolicy, TileGrid, embed_ctsf,
                        embed_rhs, factorize_window, factorize_window_batched,
                        marginal_variances, padded_flop_overhead,
                        restrict_factor, restrict_rhs, restrict_selinv,
                        sample_gmrf_many, selected_inverse, selinv_batched,
                        solve_many)
from repro.core.concurrent import (concurrent_logdet,
                                   concurrent_quadratic_forms,
                                   concurrent_solve, stack_ctsf)
from repro.data import make_arrowhead
from repro.core.options import SolverOptions

POLICY = GridBucketPolicy()

# (n, bandwidth, arrow): diagonal padding only / band+diag padding /
# exactly on a canonical rung (zero padding — the embedding must be a
# no-op that still rides the policy machinery)
CASES = [(96, 10, 5), (120, 18, 8), (136, 15, 8)]


def _problem(n, bw, ar, t=8, seed=1):
    A, struct = make_arrowhead(n, bw, ar, rho=0.6, seed=seed)
    grid = TileGrid(struct, t=t)
    return A, grid, BandedCTSF.from_sparse(A, grid)


def _assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol,
                               rtol=tol)


# ---------------------------------------------------------------------------
# Policy unit tests
# ---------------------------------------------------------------------------

def test_canonicalize_rounds_up_and_is_idempotent():
    _, grid, _ = _problem(96, 10, 5)
    cg = POLICY.canonicalize(grid)
    assert cg.t == grid.t
    assert cg.n_diag_tiles >= grid.n_diag_tiles
    assert cg.band_tiles >= grid.band_tiles
    assert cg.n_arrow_tiles >= grid.n_arrow_tiles
    assert cg.n_diag_tiles & (cg.n_diag_tiles - 1) == 0  # pow2
    assert cg.band_tiles in POLICY.band_rungs
    assert cg.n_arrow_tiles in POLICY.arrow_rungs
    # canonical grids are fixed points — re-bucketing never moves them
    assert POLICY.canonicalize(cg) == cg
    # padded_index is the identity on canonical grids (tile-aligned)
    assert cg.padded_n == cg.structure.n


def test_equal_rungs_give_equal_canonical_grids():
    """The compile-cache dedup property: different true shapes landing on
    the same rung must produce *equal* (hashable-equal) canonical grids."""
    _, g1, _ = _problem(96, 10, 5)
    _, g2, _ = _problem(90, 9, 3)
    c1, c2 = POLICY.canonicalize(g1), POLICY.canonicalize(g2)
    assert g1 != g2
    assert c1 == c2 and hash(c1) == hash(c2)


def test_zero_padding_case_is_exactly_on_rung():
    _, grid, _ = _problem(136, 15, 8)
    cg = POLICY.canonicalize(grid)
    assert (cg.n_diag_tiles, cg.band_tiles, cg.n_arrow_tiles) == \
        (grid.n_diag_tiles, grid.band_tiles, grid.n_arrow_tiles)
    assert padded_flop_overhead(grid, cg) == 0.0


def test_rungs_above_top_fall_back_to_pow2():
    pol = GridBucketPolicy(band_rungs=(1, 2), arrow_rungs=(0, 1))
    grid = TileGrid.from_tile_counts(8, 32, 5, 3)
    cg = pol.canonicalize(grid)
    assert cg.band_tiles == 8 and cg.n_arrow_tiles == 4


def test_join_takes_elementwise_max_rung():
    _, g1, _ = _problem(96, 10, 5)     # -> (16, 2, 1)
    _, g2, _ = _problem(120, 18, 8)    # -> (16, 4, 1)
    j = POLICY.join([g1, g2])
    c1, c2 = POLICY.canonicalize(g1), POLICY.canonicalize(g2)
    assert j.band_tiles == max(c1.band_tiles, c2.band_tiles)
    assert j.n_diag_tiles == max(c1.n_diag_tiles, c2.n_diag_tiles)
    with pytest.raises(ValueError, match="mixed tile sizes"):
        POLICY.join([g1, TileGrid(g2.structure, t=4)])


def test_policy_and_tile_count_validation():
    with pytest.raises(ValueError, match="ascending"):
        GridBucketPolicy(band_rungs=(4, 2))
    with pytest.raises(ValueError, match="band_rungs"):
        GridBucketPolicy(band_rungs=(0, 1))
    with pytest.raises(ValueError, match="band_tiles"):
        TileGrid.from_tile_counts(8, 4, 4, 1)     # bt > ndt - 1
    with pytest.raises(ValueError, match="band_tiles=0"):
        TileGrid.from_tile_counts(8, 4, 0, 1)     # multi-tile diag, no band
    # round-trip: derived tile counts match the requested ones
    g = TileGrid.from_tile_counts(8, 16, 4, 2)
    assert (g.n_diag_tiles, g.band_tiles, g.n_arrow_tiles) == (16, 4, 2)


# ---------------------------------------------------------------------------
# Embedding invariants
# ---------------------------------------------------------------------------

def test_embed_is_identity_blockdiag_and_restrict_roundtrips():
    _, grid, m = _problem(96, 10, 5)
    cg = POLICY.canonicalize(grid)
    emb = embed_ctsf(m, cg)
    pad_d = cg.n_diag_tiles - grid.n_diag_tiles
    t = grid.t
    dense = emb.to_dense(lower_only=False)
    # identity prefix, decoupled
    _assert_close(dense[:pad_d * t, :pad_d * t], np.eye(pad_d * t), 1e-7)
    assert np.all(dense[:pad_d * t, pad_d * t:] == 0)
    # original block intact (band part sits right after the prefix)
    src = m.to_dense(lower_only=False)
    nb = grid.n_diag_tiles * t
    _assert_close(dense[pad_d * t:pad_d * t + nb, pad_d * t:pad_d * t + nb],
                  src[:nb, :nb], 1e-7)
    # restrict(embed) is the identity on all three blocks
    from repro.core.cholesky import CholeskyFactor
    r = restrict_factor(CholeskyFactor(emb), grid)
    _assert_close(r.ctsf.Dr, m.Dr, 1e-7)
    _assert_close(r.ctsf.R, m.R, 1e-7)
    _assert_close(r.ctsf.C, m.C, 1e-7)


def test_identity_embeds_to_identity():
    """BandedCTSF.eye is the embedding's neutral element: embedding the
    identity of the source grid yields exactly the identity of the
    canonical grid — pinning eye() and embed_ctsf to one contract."""
    _, grid, _ = _problem(96, 10, 5)
    cg = POLICY.canonicalize(grid)
    emb = embed_ctsf(BandedCTSF.eye(grid), cg)
    want = BandedCTSF.eye(cg)
    _assert_close(emb.Dr, want.Dr, 0)
    _assert_close(emb.R, want.R, 0)
    _assert_close(emb.C, want.C, 0)


def test_rhs_embed_restrict_roundtrip_and_validation(rng):
    _, grid, _ = _problem(96, 10, 5)
    cg = POLICY.canonicalize(grid)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, 3)).astype(np.float32))
    Bc = embed_rhs(B, grid, cg)
    assert Bc.shape == (cg.padded_n, 3)
    _assert_close(restrict_rhs(Bc, grid, cg), B, 0)
    with pytest.raises(ValueError, match="padded_n"):
        embed_rhs(B[:-1], grid, cg)
    with pytest.raises(ValueError, match="does not embed"):
        embed_rhs(Bc, cg, grid)   # canonical into smaller source


# ---------------------------------------------------------------------------
# Serving entry-point parity: bucketed == unbucketed per grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bw,ar", CASES)
def test_factorize_window_policy_parity(n, bw, ar):
    _, grid, m = _problem(n, bw, ar)
    f0 = factorize_window(m, options=SolverOptions(impl="ref"))
    fp = factorize_window(m, options=SolverOptions(impl="ref", policy=POLICY))
    assert fp.source_grid == grid
    assert fp.ctsf.grid == POLICY.canonicalize(grid)
    fr = fp.restrict()
    _assert_close(fr.ctsf.Dr, f0.ctsf.Dr)       # band
    _assert_close(fr.ctsf.R, f0.ctsf.R)         # arrow
    _assert_close(fr.ctsf.C, f0.ctsf.C)         # corner
    _assert_close(fp.logdet(), f0.logdet())     # logdet on the embedding


@pytest.mark.parametrize("n,bw,ar", CASES)
def test_solve_and_marginals_policy_parity(n, bw, ar, rng):
    A, grid, m = _problem(n, bw, ar)
    f0 = factorize_window(m, options=SolverOptions(impl="ref"))
    fp = factorize_window(m, options=SolverOptions(impl="ref", policy=POLICY))
    B = jnp.asarray(rng.standard_normal((grid.padded_n, 4))
                    .astype(np.float32))
    X0 = solve_many(f0, B, options=SolverOptions(impl="ref"))
    _assert_close(solve_many(fp, B, options=SolverOptions(impl="ref")), X0)
    # policy on a plain factor embeds on the fly — same answer
    _assert_close(solve_many(f0, B, options=SolverOptions(impl="ref", policy=POLICY)), X0)
    idx = np.arange(0, grid.structure.n, 7)
    v0 = marginal_variances(f0, idx, options=SolverOptions(impl="ref"))
    _assert_close(marginal_variances(fp, idx, options=SolverOptions(impl="ref")), v0)
    _assert_close(marginal_variances(fp, idx, options=SolverOptions(method="panels", impl="ref")),
                  marginal_variances(f0, idx, options=SolverOptions(method="panels", impl="ref")))
    # sampling reproduces the unbucketed draw bit-for-bit per key
    s0 = sample_gmrf_many(f0, jax.random.PRNGKey(5), 3, options=SolverOptions(impl="ref"))
    s1 = sample_gmrf_many(fp, jax.random.PRNGKey(5), 3, options=SolverOptions(impl="ref"))
    _assert_close(s1, s0, 0)


@pytest.mark.parametrize("n,bw,ar", CASES)
def test_selinv_policy_parity(n, bw, ar):
    _, grid, m = _problem(n, bw, ar)
    f0 = factorize_window(m, options=SolverOptions(impl="ref"))
    fp = factorize_window(m, options=SolverOptions(impl="ref", policy=POLICY))
    s0 = selected_inverse(f0, options=SolverOptions(impl="ref"))
    s1 = selected_inverse(fp, options=SolverOptions(impl="ref"))
    assert s1.grid == grid
    _assert_close(s1.Dr, s0.Dr)                 # Σ band
    _assert_close(s1.R, s0.R)                   # Σ arrow
    _assert_close(s1.C, s0.C)                   # Σ corner
    _assert_close(s1.diagonal(), s0.diagonal())


def test_pallas_fused_sweeps_ride_the_embedding(rng):
    """The fused kernels' traced start_tile path: pallas bucketed results
    must match the unbucketed ref path."""
    _, grid, m = _problem(96, 10, 5)
    f0 = factorize_window(m, options=SolverOptions(impl="ref"))
    fp = factorize_window(m, options=SolverOptions(impl="pallas", policy=POLICY))
    _assert_close(fp.restrict().ctsf.Dr, f0.ctsf.Dr)
    B = jnp.asarray(rng.standard_normal((grid.padded_n, 4))
                    .astype(np.float32))
    _assert_close(solve_many(fp, B, options=SolverOptions(impl="pallas")),
                  solve_many(f0, B, options=SolverOptions(impl="ref")))
    _assert_close(selected_inverse(fp, options=SolverOptions(impl="pallas")).diagonal(),
                  selected_inverse(f0, options=SolverOptions(impl="ref")).diagonal())


def test_batched_and_concurrent_policy_parity(rng):
    _, grid, m = _problem(96, 10, 5)
    mats = [m] * 3
    fb0 = factorize_window_batched(mats, options=SolverOptions(impl="ref"))
    fbp = factorize_window_batched(mats, options=SolverOptions(impl="ref", policy=POLICY))
    assert fbp.source_grid == grid
    _assert_close(restrict_factor(fbp).ctsf.Dr, fb0.ctsf.Dr)
    _assert_close(concurrent_logdet(fbp), concurrent_logdet(fb0))
    y = jnp.asarray(rng.standard_normal((grid.padded_n,)).astype(np.float32))
    _assert_close(concurrent_solve(fbp, y, options=SolverOptions(impl="ref")),
                  concurrent_solve(fb0, y, options=SolverOptions(impl="ref")))
    _assert_close(concurrent_quadratic_forms(fbp, y, options=SolverOptions(impl="ref")),
                  concurrent_quadratic_forms(fb0, y, options=SolverOptions(impl="ref")))
    sb0 = selinv_batched(fb0, options=SolverOptions(impl="ref"))
    sbp = selinv_batched(fbp, options=SolverOptions(impl="ref"))
    assert sbp.grid == grid
    _assert_close(sbp.diagonal(), sb0.diagonal())
    _assert_close(sbp.Dr, sb0.Dr)


def test_stack_ctsf_policy_embeds_mixed_grids():
    _, g1, m1 = _problem(96, 10, 5)
    _, g2, m2 = _problem(120, 18, 8)
    with pytest.raises(ValueError, match="equal structure"):
        stack_ctsf([m1, m2])
    stacked = stack_ctsf([m1, m2], policy=POLICY)
    assert stacked.grid == POLICY.join([g1, g2])
    assert stacked.Dr.shape[0] == 2
    # each slice factorizes to the same (restricted) factor as its source
    fb = factorize_window_batched(stacked, options=SolverOptions(impl="ref", policy=POLICY))
    f1 = factorize_window(m1, options=SolverOptions(impl="ref", policy=POLICY))
    band1 = embed_ctsf(f1.ctsf, stacked.grid).Dr
    _assert_close(fb.ctsf.Dr[0], band1)


def test_stack_ctsf_embeds_bandless_grid_with_banded_ones():
    """An arrow-only (ndt=0) problem embeds into a banded canonical grid —
    its whole band part is identity prefix — so mixed corner-only and
    banded traffic can share one stacked batch."""
    import scipy.sparse as sp
    from repro.core import ArrowheadStructure
    _, g1, m1 = _problem(96, 10, 5)
    rng0 = np.random.default_rng(7)
    x = rng0.standard_normal((16, 16)).astype(np.float32)
    dense = x @ x.T + 16 * np.eye(16, dtype=np.float32)
    g0 = TileGrid(ArrowheadStructure(n=16, bandwidth=0, arrow=16), t=8)
    assert g0.n_diag_tiles == 0
    m0 = BandedCTSF.from_sparse(sp.csc_matrix(dense), g0)
    stacked = stack_ctsf([m1, m0], policy=POLICY)
    assert stacked.grid.n_diag_tiles > 0
    # the embedded corner-only slice factorizes to blockdiag(I, chol(A))
    fb = factorize_window_batched(stacked, options=SolverOptions(impl="ref", policy=POLICY))
    want = np.linalg.cholesky(dense)
    corner = np.asarray(fb.ctsf.C[1])
    got = corner.transpose(0, 2, 1, 3).reshape(16, 16)
    np.testing.assert_allclose(np.tril(got), want, rtol=2e-4, atol=2e-4)
    # band slice of the corner-only item is pure identity prefix
    np.testing.assert_allclose(
        np.asarray(fb.ctsf.Dr[1, :, 0]),
        np.broadcast_to(np.eye(8), (stacked.grid.n_diag_tiles, 8, 8)),
        atol=1e-6)


def test_logdet_broadcasts_over_batched_factors():
    """CholeskyFactor.logdet on a batched factor returns one value per
    batch element (it used to index the batch axis as the band axis and
    collapse everything into one wrong scalar)."""
    _, grid, m = _problem(96, 10, 5)
    f1 = factorize_window(m, options=SolverOptions(impl="ref"))
    fb = factorize_window_batched([m, m, m], options=SolverOptions(impl="ref"))
    ld = fb.logdet()
    assert ld.shape == (3,)
    _assert_close(ld, jnp.full((3,), f1.logdet()))
    _assert_close(concurrent_logdet(fb), ld)


def test_mixed_grid_stream_shares_canonical_cache_entries():
    """The compile-count contract: a stream of distinct grids landing on
    one canonical rung adds exactly one traced-callable cache entry."""
    from repro.core import cholesky as core_cholesky
    cache = core_cholesky._BATCHED_WINDOW_CACHE
    probs = [_problem(96, 10, 5), _problem(90, 9, 3), _problem(88, 11, 2)]
    rungs = {POLICY.canonicalize(g) for _, g, _ in probs}
    assert len(rungs) == 1
    before = set(cache.keys())
    # tree_chunks=7 keeps this test's key space disjoint from whatever
    # earlier tests already traced into the module-level cache
    outs = [factorize_window_batched([m, m], tree_chunks=7, options=SolverOptions(impl="ref", policy=POLICY))
            for _, _, m in probs]
    new = set(cache.keys()) - before
    assert len(new) == 1
    # ... and despite sharing the compiled sweep, each grid's results are
    # its own (no cache-key collision across true shapes)
    for (_, g, m), f in zip(probs, outs):
        f0 = factorize_window_batched([m, m], tree_chunks=7, options=SolverOptions(impl="ref"))
        _assert_close(restrict_factor(f).ctsf.Dr, f0.ctsf.Dr)
