"""Execute the README's fenced ``python`` code blocks so the docs can't rot.

Extracts every ```python block from README.md (in order, concatenated into
one module so later blocks may reuse earlier names) and runs it in-process.
CI's ``docs`` job invokes this with ``PYTHONPATH=src``; any exception —
including the snippet's own asserts — fails the job.

    PYTHONPATH=src python docs/check_quickstart.py [path/to/README.md]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def extract_python_blocks(markdown: str) -> list:
    return [m.group(1) for m in _FENCE.finditer(markdown)]


def main(argv: list) -> int:
    readme = Path(argv[1]) if len(argv) > 1 else \
        Path(__file__).resolve().parent.parent / "README.md"
    blocks = extract_python_blocks(readme.read_text())
    if not blocks:
        print(f"error: no ```python blocks found in {readme}", file=sys.stderr)
        return 1
    src = "\n\n".join(blocks)
    print(f"running {len(blocks)} python block(s) from {readme} "
          f"({len(src.splitlines())} lines)")
    code = compile(src, str(readme), "exec")
    exec(code, {"__name__": "__main__"})  # noqa: S102 - that's the point
    print("README quickstart: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
