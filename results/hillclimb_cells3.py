import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from repro.launch.dryrun import dryrun_cell
from repro.configs.base import RunConfig
from benchmarks.roofline import analyse_record
EXPS = [
    ("cmdr_ga8_dots",  "command-r-plus-104b", "train_4k", dict(grad_accum=8, remat="dots")),
    ("granite3b_ep_ga2_dots", "granite-moe-3b-a800m", "train_4k", dict(grad_accum=2, remat="dots")),
]
out = {}
for tag, arch, shape, kw in EXPS:
    try:
        rec = dryrun_cell(arch, shape, run=RunConfig(**kw), extrapolate=True, verbose=False)
        a = analyse_record(rec)
        out[tag] = {"mem_gib": rec["memory"]["total_per_device_gib"],
                    "t_compute": a["t_compute_s"], "t_memory": a["t_memory_s"],
                    "t_coll": a["t_collective_s"], "frac": a["roofline_fraction"],
                    "useful": a["useful_ratio"]}
        print(f"{tag:24s} mem={out[tag]['mem_gib']:7.2f} cmp={a['t_compute_s']:.2e} "
              f"mem_t={a['t_memory_s']:.2e} coll={a['t_collective_s']:.2e} "
              f"frac={a['roofline_fraction']:.3f} useful={a['useful_ratio']:.2f}", flush=True)
    except Exception as e:
        out[tag] = {"error": str(e)[:300]}
        print(f"{tag:24s} ERROR {str(e)[:200]}", flush=True)
json.dump(out, open("results/hillclimb_iter3.json", "w"), indent=1)
