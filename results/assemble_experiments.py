"""Assemble EXPERIMENTS.md sections from results/ artifacts."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_all, markdown_table, analyse_record


def dryrun_table():
    lines = ["| arch | shape | mesh | status | mem GiB/dev | lower s | compile s | collectives (scanned module) |",
             "|---|---|---|---|---|---|---|---|"]
    for tag in ("single", "multi"):
        for fn in sorted(glob.glob(f"results/dryrun/*_{tag}.json")):
            d = json.load(open(fn))
            if d["status"] == "ok":
                coll = d.get("collectives_scanned", {})
                cs = " ".join(f"{k}:{v/2**20:.0f}MiB" for k, v in coll.items()
                              if k != "total" and v > 0)
                lines.append(
                    f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
                    f"{d['memory']['total_per_device_gib']:.2f} | "
                    f"{d.get('lower_s','—')} | {d.get('compile_s','—')} | {cs} |")
            else:
                lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                             f"{d['status']} | — | — | — | — |")
    return "\n".join(lines)


def perf_section():
    parts = []
    parts.append(open("results/solver_hillclimb.md").read())
    parts.append("""
## LM-cell §Perf track (b): the three hillclimbed cells (dry-run roofline terms, single-pod 16x16)

Selection per spec: worst-fitting/largest (command-r-plus-104b train_4k),
most collective-bound (granite-moe-3b train_4k, coll/compute = 54x), most
representative of the paper's banded/structured-state regime (zamba2-2.7b
train_4k — hybrid SSM + the arrowhead-preconditioner training target).
Terms in seconds/step/device; "fits" = total <= 16 GiB (v5e HBM).
""")
    rows = ["| cell | change | mem GiB | compute s | memory s | collective s | frac | verdict |",
            "|---|---|---|---|---|---|---|---|"]

    base = {}
    for r in load_all("single"):
        if "skipped" not in r:
            base[(r["arch"], r["shape"])] = r

    def row(cell, change, d, verdict):
        rows.append(f"| {cell} | {change} | {d['mem_gib']:.2f} | "
                    f"{d['t_compute']:.2e} | {d['t_memory']:.2e} | "
                    f"{d['t_coll']:.2e} | {d['frac']:.3f} | {verdict} |")

    def baserow(arch, shape):
        b = base[(arch, shape)]
        rows.append(f"| {arch} {shape} | baseline | {b['mem_gib']:.2f} | "
                    f"{b['t_compute_s']:.2e} | {b['t_memory_s']:.2e} | "
                    f"{b['t_collective_s']:.2e} | {b['roofline_fraction']:.3f} | "
                    f"{'FITS' if b['mem_gib'] <= 16 else 'DOES NOT FIT'} |")

    it1 = json.load(open("results/hillclimb_iter1.json"))
    it2 = json.load(open("results/hillclimb_iter2.json"))
    it3 = json.load(open("results/hillclimb_iter3.json")) \
        if os.path.exists("results/hillclimb_iter3.json") else {}

    baserow("command-r-plus-104b", "train_4k")
    row("", "grad_accum=4", it1["cmdr_ga4"], "mem 36->19 GiB (still over)")
    row("", "grad_accum=8", it2["cmdr_ga8"], "FITS (14.2); +re-gather cost")
    if "cmdr_ga8_dots" in it3 and "error" not in it3["cmdr_ga8_dots"]:
        row("", "ga8 + remat=dots", it3["cmdr_ga8_dots"], "REFUTED for capacity: 25.9 GiB (dots saves matmul outputs) despite compute -18% and useful 0.75->0.91; keep remat=full+ga8")
    baserow("granite-moe-3b-a800m", "train_4k")
    row("", "EP padding 40->48", it1["granite3b_ep"], "collective 13.6->3.4 s (4x)")
    row("", "+ grad_accum=2", it1["granite3b_ep_ga2"], "FITS (11.0)")
    if "granite3b_ep_ga2_dots" in it3 and "error" not in it3["granite3b_ep_ga2_dots"]:
        row("", "+ remat=dots", it3["granite3b_ep_ga2_dots"], "<5% on all terms -> stop (3 consecutive small gains)")
    baserow("zamba2-2.7b", "train_4k")
    row("", "per-layer remat + DP-only acts", it1["zamba_fix"], "mem 28->13 GiB; bytes UP (model axis idle)")
    row("", "+ ssd_chunk=32", it1["zamba_fix_q32"], "REFUTED: no change")
    row("", "+ grad_accum=2", it1["zamba_fix_ga2"], "7.0 GiB")
    if "zamba_headshard_ga2" in it2 and "error" not in it2.get("zamba_headshard_ga2", {"error": 1}):
        row("", "SSD head-shard + ga2", it2["zamba_headshard_ga2"], "mostly REFUTED: bytes ~-11% only (GSPMD reshards around the constraint)")
    if "zamba_seqforce_ga2" in it2 and "error" not in it2.get("zamba_seqforce_ga2", {"error": 1}):
        row("", "forced seq-shard + ga2", it2["zamba_seqforce_ga2"], "WINNER: 6.8 GiB fits, terms back to baseline level (frac 0.038 vs 0.043) -> seq-sharding restored as the all-family default")
    parts.append("\n".join(rows))
    parts.append("""

**Recommended production configs** (memory-feasible on v5e-256, best measured
terms): command-r-plus-104b train: `remat=full, grad_accum=8` (14.2 GiB,
frac 0.214 — the only *runnable* config; baseline frac 0.322 is an OOM
paper number); granite-moe-3b train: `expert_pad_to=48 (EP), grad_accum=2`
(11.0 GiB, collective term 4x down); zamba2-2.7b train: `seq-sharded acts +
per-layer remat + grad_accum=2` (6.8 GiB at baseline-level terms —
re-measured under the restored defaults: 6.79 GiB, frac 0.038, reproducing
the winner exactly).  The same recipe extends to qwen2-72b train (26.0 GiB
baseline): measured ga=2 -> 19.3 GiB (not enough), ga=4 -> **12.7 GiB,
frac 0.236** (fits; `results/hillclimb_verify.json`).  Perf score
note: decode cells are HBM-bandwidth-bound by nature (roofline fraction
measured against the 6ND/2ND compute convention, which excludes
cache-attention work — the dominant real work at 32k-500k contexts).""")
    return "\n".join(parts)


def main():
    src_md = "EXPERIMENTS.template.md" if __import__("os").path.exists("EXPERIMENTS.template.md") else "EXPERIMENTS.md"
    md = open(src_md).read()
    if os.path.exists("results/bench_output.csv"):
        bench = open("results/bench_output.csv").read()
        md = md.replace("<!-- BENCH_RESULTS -->",
                        "```\n" + bench.strip() + "\n```")
    md = md.replace("<!-- DRYRUN_RESULTS -->", dryrun_table())
    rows = load_all("single")
    md = md.replace("<!-- ROOFLINE_RESULTS -->", markdown_table(rows))
    md = md.replace("<!-- PERF_RESULTS -->", perf_section())
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
