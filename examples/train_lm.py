"""End-to-end training driver: a ~100M-parameter qwen2-style LM on synthetic
Markov data, with fault-tolerant checkpointing and a choice of AdamW or the
sTiles banded-arrowhead curvature preconditioner.

Default runs a ~10M reduced model for 200 steps (CPU-budget); ``--full``
trains the ~100M config (hours on CPU, minutes on a real accelerator).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --optimizer arrowhead
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import train as T


def model_100m() -> ModelConfig:
    return ModelConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=8192, head_dim=64, qk_norm=True)


def model_10m() -> ModelConfig:
    return ModelConfig(name="lm-10m", family="dense", n_layers=6,
                       d_model=320, n_heads=8, n_kv_heads=4, d_ff=896,
                       vocab=2048, head_dim=40, qk_norm=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--optimizer", default="adamw",
                   choices=["adamw", "arrowhead"])
    p.add_argument("--full", action="store_true", help="~100M params")
    args = p.parse_args()

    cfg = model_100m() if args.full else model_10m()
    n_params = cfg.param_count()
    print(f"model {cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"optimizer={args.optimizer}")

    # reuse the launch driver with an explicit config
    import repro.configs as configs
    configs._MODULES[cfg.name] = None   # register pass-through

    def _get(name, _orig=configs.get):
        return cfg if name == cfg.name else _orig(name)
    configs.get = _get
    T.configs.get = _get

    out = T.train(cfg.name, steps=args.steps, batch=args.batch, seq=args.seq,
                  optimizer=args.optimizer, reduced=False,
                  checkpoint_dir=f"/tmp/repro_lm_{cfg.name}", log_every=20)
    losses = out["losses"]
    k = max(5, len(losses) // 20)
    print(f"\nloss: {np.mean(losses[:k]):.4f} -> {np.mean(losses[-k:]):.4f} "
          f"(markov entropy floor {out['entropy_floor']:.4f})")
    ck = len(out['loop'].straggler.times)
    print(f"steps timed: {ck}, median step {out['loop'].straggler.median*1e3:.0f} ms, "
          f"stragglers flagged: {len(out['loop'].straggler.flagged)}")


if __name__ == "__main__":
    main()
