"""Distributed single-matrix factorization across a device mesh — the
paper's "future work" (App. A), built on adaptive-ND partitioning +
cross-chip GEADD-tree reduction (DESIGN.md §2).

Uses 8 fake CPU devices (set before jax import) to emulate the mesh; on a
real pod the same code runs over ICI.

    PYTHONPATH=src python examples/distributed_factorization.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import BandedCTSF, TileGrid
from repro.core.distributed import (assemble_factor, distributed_factorize,
                                    partition_banded)
from repro.data import make_arrowhead


def main():
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # block-independent diagonal (the paper's bandwidth-100/1000 regime,
    # rho=0) + dense arrow: adaptive-ND partitions are exact
    t, parts = 16, 4
    n = 64 * t + 2 * t
    A, struct = make_arrowhead(n, t, 2 * t, rho=0.0, seed=0)
    grid = TileGrid(struct, t=t)
    bm = BandedCTSF.from_sparse(A, grid)

    pm = partition_banded(bm, parts)
    print(f"partitioned: {parts} independent diagonal blocks of "
          f"{pm.Dr.shape[1]} tiles + shared {grid.n_arrow_tiles}-tile corner")

    out = distributed_factorize(pm, mesh, axis="model")
    f = assemble_factor(out, grid)

    Lref = np.linalg.cholesky(bm.to_dense(lower_only=False))
    err = np.abs(f.ctsf.to_dense() - np.tril(Lref)).max()
    print(f"distributed factor matches dense Cholesky: max err {err:.2e}")

    # time it vs single-device
    fn = jax.jit(lambda p: distributed_factorize(pm, mesh, axis="model").Dr)
    jax.block_until_ready(fn(pm.Dr))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(pm.Dr))
    print(f"sharded factorization step: {(time.perf_counter()-t0)*1e3:.1f} ms "
          f"(partitions in parallel + ppermute GEADD tree for the corner)")


if __name__ == "__main__":
    main()
