"""Quickstart: factorize a block-arrowhead precision matrix with sTiles.

Builds a Table-II-style spatio-temporal GMRF precision matrix, runs the
paper's preprocessing (structure measurement, ordering with the fill-in
acceptance rule), factorizes with both backends, and uses the factor for
solve / log-determinant / sampling — the three INLA primitives.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (BandedCTSF, TileGrid, factorize_window, logdet,
                       marginal_variances, measure_arrowhead, sample_gmrf,
                       solve)
from repro.core import (TileMatrix, factorize_tasklist, symbolic_factorize,
                        tile_pattern_from_coo)
from repro.core.ordering import best_ordering
from repro.data import make_arrowhead


def main():
    # -- 1. build: N=2048 latent field, bandwidth 48, 32 fixed effects ------
    n, bw, arrow, t = 2048, 48, 32, 32
    A, struct = make_arrowhead(n, bw, arrow, rho=0.7, seed=0)
    print(f"matrix: n={n} bandwidth={bw} arrow={arrow} "
          f"nnz={A.nnz} density={A.nnz/n/n:.2%}")

    # -- 2. preprocessing (paper §III-A): measure + order --------------------
    measured = measure_arrowhead(A, arrow_hint=arrow)
    print(f"measured structure: {measured}")
    ordering = best_ordering(A, measured, t=t)
    print(f"ordering: {ordering.name} accepted={ordering.accepted} "
          f"L-tiles {ordering.fill_before} -> {ordering.fill_after}")

    grid = TileGrid(measured, t=t)
    symb = symbolic_factorize(tile_pattern_from_coo(A, grid))
    print(f"symbolic: {len(symb.tasks)} tasks, fill={symb.fill_tiles} tiles, "
          f"critical path={symb.critical_path_length()}, "
          f"max parallelism={symb.max_parallelism()}")

    # -- 3. numerical factorization ------------------------------------------
    bm = BandedCTSF.from_sparse(A, grid)
    fw = lambda: factorize_window(bm, tree_chunks=8).ctsf.Dr
    jax.block_until_ready(fw())  # compile (factorize_window jits internally)
    t0 = time.perf_counter()
    jax.block_until_ready(fw())
    dt = time.perf_counter() - t0
    factor = factorize_window(bm, tree_chunks=8)
    gflops = symb.total_flops(t) / dt / 1e9
    print(f"window backend: {dt*1e3:.1f} ms ({gflops:.1f} GFLOP/s)")

    tm = TileMatrix.from_sparse(A, grid)
    tiles = factorize_tasklist(tm)
    err = np.abs(np.tril(tm.to_dense(tiles)) - factor.ctsf.to_dense()).max()
    print(f"tasklist backend agrees to {err:.2e}")

    # -- 4. INLA primitives ---------------------------------------------------
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(grid.padded_n), jnp.float32)
    x = solve(factor, b)
    print(f"solve:   residual={np.abs(bm.to_dense(lower_only=False) @ np.asarray(x) - np.asarray(b)).max():.2e}")
    print(f"logdet:  {float(logdet(factor)):.2f}")
    s = sample_gmrf(factor, jax.random.PRNGKey(1))
    print(f"sample:  GMRF draw, std={float(jnp.std(s)):.3f}")
    mv = marginal_variances(factor, jnp.asarray([0, n // 2, n - 1]))
    print(f"posterior marginal variances (INLA): {np.round(np.asarray(mv), 5).tolist()}")


if __name__ == "__main__":
    main()
