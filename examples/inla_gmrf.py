"""INLA-style Bayesian inference — the paper's driving application.

Spatio-temporal GMRF with fixed effects:

    y = X beta + u + eps,   u ~ N(0, K(theta)^{-1}),  K = Q_t(rho) (x) ... (x) Q_s

The joint latent precision Q(theta) is exactly the paper's block-arrowhead
pattern (Fig. 1): banded latent block + dense fixed-effect arrow.  Each
objective evaluation needs a Cholesky factorization (logdet + solve), and
the central-difference gradient over the hyperparameters theta needs 2·dim
*independent* factorizations — the concurrent workload of Appendix A, run
here as one batched/sharded `concurrent_factorize` call.

Batched serving
---------------
Every stage of the pipeline below is batched — nothing loops over matrices
or right-hand sides in Python:

* **Factorization** — all 2·dim+1 θ probes ride one
  ``factorize_window_batched`` dispatch (a single vmapped ring sweep +
  corner Schur, bucketed to bound XLA compiles per grid).
* **Quadratic forms** — ``y^T Q^{-1} y`` per probe is one vmapped forward
  sweep (``concurrent_quadratic_forms``): ‖L_i^{-1} y‖², half the work of a
  full solve.
* **Posterior marginals** — INLA's per-latent posterior variances *and*
  neighbour covariances at the fitted θ come from a single
  ``selected_inverse`` call: one backward Takahashi tile sweep yields the
  whole band + arrow block of Σ = Q^{-1}, cost independent of how many
  entries are read.
* **Posterior sampling** — ``sample_gmrf_many`` draws a panel of GMRF
  realizations through one blocked backward sweep.

    PYTHONPATH=src python examples/inla_gmrf.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.api import (ArrowheadStructure, BandedCTSF, CholeskyFactor,
                       TileGrid, concurrent_logdet,
                       concurrent_quadratic_forms, factorize_window_batched,
                       sample_gmrf_many, selected_inverse, stack_ctsf)
from repro.data.gmrf import ar1_precision, lattice_precision


NS = 48   # spatial lattice side — also the temporal-neighbour lag below


def build_precision(theta, nt=32, ns=NS, n_fixed=16, seed=0):
    """Q(theta) for theta = (log tau_t, logit rho, log tau_s)."""
    ltau_t, lrho, ltau_s = theta
    rho = float(np.tanh(lrho))
    qt = ar1_precision(nt, rho=rho, tau=float(np.exp(ltau_t)))
    qs = lattice_precision(ns, coupling=0.4, tau=float(np.exp(ltau_s)))
    k = sp.kron(qt, sp.eye(ns)) + sp.kron(sp.eye(nt), qs)
    nd = nt * ns
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((nd, n_fixed)) * (0.5 / np.sqrt(nd))
    c = float((x ** 2).sum() / 1e-3 + 1.0)
    q = sp.bmat([[k, sp.csc_matrix(x)],
                 [sp.csc_matrix(x.T), sp.csc_matrix(np.eye(n_fixed) * c)]],
                format="csc")
    struct = ArrowheadStructure(n=nd + n_fixed, bandwidth=ns, arrow=n_fixed)
    return sp.csc_matrix(q), struct


def objective_terms(thetas, grid, y):
    """Batched objective: -logdet(Q)/2 + y^T Q^{-1} y / 2 for each theta.

    One batched factorization dispatch covers every probe, and the
    quadratic forms ride one vmapped forward sweep — no per-theta Python
    loop after matrix assembly.
    """
    mats = []
    for th in thetas:
        Q, struct = build_precision(th)
        mats.append(BandedCTSF.from_sparse(Q, grid))
    batch = stack_ctsf(mats)
    t0 = time.perf_counter()
    factor = factorize_window_batched(batch)        # Appendix A workload
    lds = concurrent_logdet(factor)
    quads = concurrent_quadratic_forms(factor, y)
    jax.block_until_ready(quads)
    dt = time.perf_counter() - t0
    obj = -0.5 * np.asarray(lds) + 0.5 * np.asarray(quads)
    return obj, factor, dt


def main():
    theta = np.array([0.0, 0.5, 0.0])
    Q0, struct = build_precision(theta)
    grid = TileGrid(struct, t=16)
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.standard_normal(grid.padded_n) * 0.1, jnp.float32)

    print(f"latent dim {struct.n_diag} + {struct.arrow} fixed effects; "
          f"bandwidth {struct.bandwidth}")
    h, lr = 0.05, 0.1
    for it in range(5):
        # central differences: 2*dim(theta) independent factorizations + f(x)
        probes = [theta]
        for d in range(3):
            for s in (+h, -h):
                tp = theta.copy()
                tp[d] += s
                probes.append(tp)
        vals, _, dt = objective_terms(probes, grid, y)
        grad = np.array([(vals[1 + 2 * d] - vals[2 + 2 * d]) / (2 * h)
                         for d in range(3)])
        theta = theta - lr * grad / max(1.0, np.abs(grad).max())
        print(f"iter {it}: f={vals[0]:.2f} |grad|={np.abs(grad).max():.3f} "
              f"theta={np.round(theta, 3).tolist()} "
              f"({len(probes)} factorizations in {dt*1e3:.0f} ms)")

    # --- posterior summaries at the fitted theta (one selinv sweep) --------
    Qf, _ = build_precision(theta)
    fb = factorize_window_batched([BandedCTSF.from_sparse(Qf, grid)])
    ctsf = fb.ctsf
    fitted = CholeskyFactor(BandedCTSF(grid, ctsf.Dr[0], ctsf.R[0], ctsf.C[0]))
    t0 = time.perf_counter()
    sigma = selected_inverse(fitted)        # one backward Takahashi sweep
    samples = sample_gmrf_many(fitted, jax.random.PRNGKey(0), num=32)
    jax.block_until_ready((sigma.Dr, samples))
    dt = time.perf_counter() - t0

    var = np.asarray(sigma.diagonal())      # every latent + fixed effect
    sd = np.sqrt(var[:struct.n_diag])
    # temporal neighbour correlations (lag = NS): same Σ block, no extra work
    pairs = np.linspace(0, struct.n_diag - 1 - NS, 8).astype(np.int64)
    corr = np.array([float(sigma.covariance(int(i), int(i + NS)))
                     / np.sqrt(var[i] * var[i + NS]) for i in pairs])
    print(f"posterior marginal sd range [{sd.min():.4f}, {sd.max():.4f}] "
          f"over all {struct.n_diag} latents; temporal-neighbour corr range "
          f"[{corr.min():.3f}, {corr.max():.3f}]; {samples.shape[1]} "
          f"posterior samples — one selinv sweep + one blocked backward "
          f"sweep, {dt*1e3:.0f} ms total")
    print("done — hyperparameters fitted with batched sTiles factorizations")


if __name__ == "__main__":
    main()
